//! Measurement statistics for benches and the coordinator's metrics:
//! online summaries, percentiles, and a tiny wall-clock bench runner
//! (criterion is unavailable offline).

// lint:allow(no-wall-clock, "bench runner measures real host time by design")
use std::time::Instant;

/// Streaming summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile via linear interpolation on the sorted sample (p in [0,100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Result of one [`bench`] run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Wall-clock micro-bench: warms up, then measures `iters` runs of `f`.
/// Used by the `harness = false` benches in `rust/benches/`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        // lint:allow(no-wall-clock, "bench runner measures real host time by design")
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        median_ns: s.median(),
        stddev_ns: s.stddev(),
        min_ns: s.min(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        for x in [10.0, 20.0, 30.0, 40.0, 50.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert_eq!(s.median(), 30.0);
        assert_eq!(s.percentile(25.0), 20.0);
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(Summary::new().percentile(50.0), 0.0);
    }

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns + 1.0);
    }
}
