//! Formatting helpers for paper-style tables and units.

/// Format nanoseconds as milliseconds with two decimals (paper tables).
pub fn ms(ns: f64) -> String {
    format!("{:.2}", ns / 1e6)
}

/// Format a byte count using binary units.
pub fn bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Percentage with one decimal, as in the paper's utilization tables.
pub fn pct(frac: f64) -> String {
    format!("{:.1}", frac * 100.0)
}

/// Render an ASCII table with a header row: column widths auto-fit.
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| format!("+{}", "-".repeat(w + 2)))
        .collect::<String>()
        + "+";
    let render_row = |cells: &[String]| -> String {
        let mut line = String::new();
        for (i, w) in widths.iter().enumerate() {
            let empty = String::new();
            let cell = cells.get(i).unwrap_or(&empty);
            line += &format!("| {cell:>w$} ", w = w);
        }
        line + "|"
    };
    let mut out = String::new();
    out += &sep;
    out += "\n";
    out += &render_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out += "\n";
    out += &sep;
    out += "\n";
    for row in rows {
        out += &render_row(row);
        out += "\n";
    }
    out += &sep;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_two_decimals() {
        assert_eq!(ms(4_210_000.0), "4.21");
        assert_eq!(ms(251_410_000.0), "251.41");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(4 * 1024 * 1024), "4.00 MiB");
    }

    #[test]
    fn pct_one_decimal() {
        assert_eq!(pct(0.967), "96.7");
    }

    #[test]
    fn table_renders_aligned() {
        let t = table(
            &["Op", "Latency"],
            &[
                vec!["Causal".into(), "251.41".into()],
                vec!["Linear".into(), "3.81".into()],
            ],
        );
        assert!(t.contains("| Causal"));
        assert!(t.contains("| Latency |"));
        // All lines same width.
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }
}
