//! Small shared utilities: deterministic RNG + property-check harness,
//! statistics, and formatting helpers.
//!
//! The offline crate set has no `proptest`/`criterion`, so [`check`]
//! provides a minimal forall-style harness and [`stats`] the measurement
//! machinery the benches need.

pub mod check;
pub mod fmt;
pub mod stats;
