//! Minimal deterministic property-testing harness.
//!
//! `proptest` is not in the offline crate set, so this module provides the
//! small subset the test suite needs: a seedable xorshift PRNG and a
//! `forall` driver that runs a property over generated cases and reports
//! the failing seed for reproduction.

/// Xorshift64* PRNG — deterministic, seedable, no external deps.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // 0 is a fixed point of xorshift; nudge it.
        Self { state: seed.max(1).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `prop` over `cases` generated inputs; panics with the offending seed
/// on the first failure so the case can be replayed exactly.
pub fn forall<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            assert!(rng.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let x = rng.range(3, 5);
            assert!((3..=5).contains(&x));
            saw_lo |= x == 3;
            saw_hi |= x == 5;
        }
        assert!(saw_lo && saw_hi, "range endpoints should both occur");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 50, |r| r.range(0, 100), |&x| {
            if x <= 100 { Ok(()) } else { Err("out of range".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn forall_reports_failure() {
        forall("failing", 50, |r| r.range(0, 100), |&x| {
            if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) }
        });
    }
}
