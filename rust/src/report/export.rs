//! CSV export for bench outputs (`target/report/*.csv`).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Result;

/// Default output directory for bench CSVs.
pub fn report_dir() -> PathBuf {
    PathBuf::from("target/report")
}

/// Write a CSV with a header row; creates parent directories.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path.as_ref())?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("npuperf-csv-{}", std::process::id()));
        let path = dir.join("t.csv");
        write_csv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }
}
