//! Registry-driven operator × context sweep with bottleneck classification
//! (the `npuperf sweep` report).
//!
//! Runs **every registered operator** — builtins and anything a deployment
//! registered on its own [`OperatorRegistry`] — across a grid of context
//! lengths on the NPU simulator, and renders one comparative table: per
//! cell the latency, engine-utilization split, stall and cache-efficiency
//! counters, and the paper's taxonomy verdict ([`BoundClass`]): memory-,
//! compute-, vector-compute-, or data-movement-bound. This is the paper's
//! central artifact — the bottleneck *spectrum* across operators — as one
//! command over the pluggable operator inventory.

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::coordinator::DeviceStat;
use crate::memory::MemoryConfig;
use crate::npu;
use crate::ops::registry::{self, classify, BoundClass, CausalOperator, OperatorRegistry};
use crate::util::fmt;

/// One evaluated (operator, context) cell.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Registry name of the operator.
    pub name: &'static str,
    /// Display name for tables.
    pub paper_name: &'static str,
    /// Asymptotic cost class.
    pub complexity: &'static str,
    /// Context length N.
    pub n: usize,
    /// Persistent session-state bytes at this context (capacity axis).
    pub state_bytes: u64,
    /// Simulated latency, ms.
    pub latency_ms: f64,
    /// Utilization shares [DPU, DMA, SHAVE] summing to 1.
    pub utilization: [f64; 3],
    /// Compute pipeline-stall fraction.
    pub stall: f64,
    /// Scratchpad hit rate.
    pub cache_eff: f64,
    /// Dominant-engine bottleneck string (Table II column).
    pub bottleneck: String,
    /// Paper-taxonomy classification.
    pub class: BoundClass,
}

/// Evaluate every operator in `reg` at every context in `contexts`.
pub fn run_sweep(
    reg: &OperatorRegistry,
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    for op in reg.iter() {
        for &n in contexts {
            let spec = WorkloadSpec::new(op.kind(), n);
            let r = npu::run(&op.lower(&spec, hw, sim), hw, sim);
            cells.push(SweepCell {
                name: op.name(),
                paper_name: op.paper_name(),
                complexity: op.complexity(),
                n,
                state_bytes: op.state_footprint(&spec, n),
                latency_ms: r.latency_ms(),
                utilization: r.utilization(),
                stall: r.stall.stall_frac(),
                cache_eff: r.cache.efficiency(),
                bottleneck: r.bottleneck().to_string(),
                class: classify(&r),
            });
        }
    }
    cells
}

/// Render the sweep over an explicit registry.
pub fn sweep_report_with(
    reg: &OperatorRegistry,
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> String {
    let cells = run_sweep(reg, contexts, hw, sim);
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.paper_name.to_string(),
                c.complexity.to_string(),
                c.n.to_string(),
                fmt::bytes(c.state_bytes),
                format!("{:.2}", c.latency_ms),
                fmt::pct(c.utilization[0]),
                fmt::pct(c.utilization[1]),
                fmt::pct(c.utilization[2]),
                fmt::pct(c.stall),
                fmt::pct(c.cache_eff),
                c.bottleneck.clone(),
                c.class.to_string(),
            ]
        })
        .collect();
    let table = fmt::table(
        &[
            "Operator",
            "Complexity",
            "N",
            "State",
            "Latency ms",
            "DPU %",
            "DMA %",
            "SHAVE %",
            "Stall %",
            "Cache %",
            "Bottleneck",
            "Classification",
        ],
        &rows,
    );

    // Verdict per operator at the longest context — the regime the paper's
    // conclusions are drawn from.
    let longest = contexts.iter().copied().max().unwrap_or(0);
    let mut verdicts = String::new();
    for c in cells.iter().filter(|c| c.n == longest) {
        verdicts += &format!(
            "  {:<12} {:<14} -> {} at N={}\n",
            c.paper_name, c.complexity, c.class, c.n
        );
    }
    format!(
        "Operator sweep over {} registered operators x {:?} contexts\n\
         (taxonomy per paper §IV: memory- / compute- / vector-compute- / \
         data-movement-bound)\n{table}\n\nLong-context verdicts:\n{verdicts}",
        reg.len(),
        contexts,
    )
}

/// Render the sweep over the process-wide default registry.
pub fn sweep_report(contexts: &[usize], hw: &NpuConfig, sim: &SimConfig) -> String {
    sweep_report_with(registry::global(), contexts, hw, sim)
}

/// Machine-diffable snapshot of every registered operator's simulated
/// cost at each context: one line per (operator, context) with the exact
/// span, DMA traffic, logical ops and [`BoundClass`]. This is what the
/// conformance suite pins in `rust/tests/golden/` — any cost-model change
/// shows up as a byte diff here, with `--bless` as the intentional-change
/// path.
pub fn conformance_snapshot(
    reg: &OperatorRegistry,
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> String {
    let mut out = String::new();
    for op in reg.iter() {
        for &n in contexts {
            let spec = WorkloadSpec::new(op.kind(), n);
            let r = npu::run(&op.lower(&spec, hw, sim), hw, sim);
            out += &format!(
                "{} n={} {} class={}\n",
                op.name(),
                n,
                r.conformance_line(),
                classify(&r)
            );
        }
    }
    out
}

/// Max concurrently resident sessions for one operator at context `n`,
/// given the pool geometry in `mem`.
pub fn max_sessions_at(op: &dyn CausalOperator, n: usize, mem: &MemoryConfig) -> u64 {
    let spec = WorkloadSpec::new(op.kind(), n);
    mem.max_sessions(op.state_footprint(&spec, n))
}

/// Serving-capacity table over an explicit registry: for every
/// (operator × context), the per-session state footprint, its page
/// extent, and the maximum number of concurrently resident sessions the
/// session-memory pool sustains — the paper's quadratic-vs-constant
/// state divergence expressed as a capacity number.
pub fn capacity_report_with(
    reg: &OperatorRegistry,
    contexts: &[usize],
    mem: &MemoryConfig,
) -> String {
    let pool_pages = mem.pool_pages();
    let rows: Vec<Vec<String>> = reg
        .iter()
        .flat_map(|op| {
            contexts.iter().map(move |&n| {
                let spec = WorkloadSpec::new(op.kind(), n);
                let fp = op.state_footprint(&spec, n);
                vec![
                    op.paper_name().to_string(),
                    op.complexity().to_string(),
                    n.to_string(),
                    fmt::bytes(fp),
                    mem.pages_for(fp).max(1).to_string(),
                    mem.max_sessions(fp).to_string(),
                ]
            })
        })
        .collect();
    let table = fmt::table(
        &["Operator", "Complexity", "N", "State/session", "Pages", "Max sessions"],
        &rows,
    );

    // Verdict per operator: does capacity collapse with context, or hold?
    let lo = contexts.iter().copied().min().unwrap_or(0);
    let hi = contexts.iter().copied().max().unwrap_or(0);
    let mut verdicts = String::new();
    for op in reg.iter() {
        let (a, b) = (max_sessions_at(op, lo, mem), max_sessions_at(op, hi, mem));
        verdicts += &format!(
            "  {:<12} {:>12} sessions at N={lo} -> {:>12} at N={hi}  ({})\n",
            op.paper_name(),
            a,
            b,
            if b * 4 < a { "collapses with context" } else { "flat" }
        );
    }
    format!(
        "Session-memory capacity: pool {} in {pool_pages} pages of {}\n\
         (spill/refill priced at {:.2} GB/s effective DMA)\n{table}\n\n\
         Capacity verdicts:\n{verdicts}",
        fmt::bytes(mem.pool_bytes),
        fmt::bytes(mem.page_bytes),
        mem.beta_eff_gbps,
    )
}

/// Serving-capacity table over the process-wide registry, with the pool
/// sized from `hw` and spills priced by the calibrated DMA ceiling.
pub fn capacity_report(contexts: &[usize], hw: &NpuConfig, sim: &SimConfig) -> String {
    capacity_report_with(registry::global(), contexts, &MemoryConfig::calibrated(hw, sim))
}

/// Capacity report for a fleet of `devices` identical NPUs. Each device
/// owns its own session-memory pool, so fleet capacity scales linearly
/// with the device count (until placement skew concentrates sessions);
/// the appended section states the fleet ceilings per operator.
pub fn capacity_fleet_report(
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
    devices: usize,
) -> String {
    let devices = devices.max(1);
    let base = capacity_report(contexts, hw, sim);
    if devices == 1 {
        return base;
    }
    let mem = MemoryConfig::calibrated(hw, sim);
    let lo = contexts.iter().copied().min().unwrap_or(0);
    let hi = contexts.iter().copied().max().unwrap_or(0);
    let mut fleet =
        format!("\nFleet capacity ({devices} devices, one pool each — linear ceiling):\n");
    for op in registry::global().iter() {
        fleet += &format!(
            "  {:<12} {:>12} sessions at N={lo} -> {:>12} at N={hi}\n",
            op.paper_name(),
            max_sessions_at(op, lo, &mem) * devices as u64,
            max_sessions_at(op, hi, &mem) * devices as u64,
        );
    }
    base + &fleet
}

/// Per-device occupancy table for a finished (or running) serve: how the
/// fleet's model-time work spread across devices. `Occupancy` is each
/// device's executed model time over the fleet makespan — the fraction
/// of the critical path it was busy — so a perfectly balanced fleet
/// shows equal occupancies and the makespan speedup is their sum.
pub fn fleet_occupancy_report(stats: &[DeviceStat]) -> String {
    let makespan = stats.iter().map(|s| s.busy_until_ns).max().unwrap_or(0);
    let rows: Vec<Vec<String>> = stats
        .iter()
        .map(|s| {
            vec![
                s.label.to_string(),
                s.served.to_string(),
                s.batches.to_string(),
                s.sessions.to_string(),
                s.resident_sessions.to_string(),
                format!("{:.3}", s.busy_ns_total as f64 / 1e6),
                format!("{:.3}", s.busy_until_ns as f64 / 1e6),
                if makespan > 0 {
                    format!("{:.1}%", s.busy_ns_total as f64 / makespan as f64 * 100.0)
                } else {
                    "-".to_string()
                },
                s.migrations_in.to_string(),
            ]
        })
        .collect();
    let table = fmt::table(
        &[
            "Device",
            "Served",
            "Batches",
            "Sessions",
            "Resident",
            "Busy ms",
            "Until ms",
            "Occupancy",
            "Migrations",
        ],
        &rows,
    );
    format!(
        "Fleet occupancy: {} devices, makespan {:.3} ms\n{table}",
        stats.len(),
        makespan as f64 / 1e6,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> (NpuConfig, SimConfig) {
        (NpuConfig::default(), SimConfig::default())
    }

    #[test]
    fn sweep_covers_registry_times_contexts() {
        let (hw, sim) = cfg();
        let cells = run_sweep(registry::global(), &[128, 256], &hw, &sim);
        assert_eq!(cells.len(), registry::global().len() * 2);
        for c in &cells {
            assert!(c.latency_ms > 0.0, "{}", c.name);
            let total: f64 = c.utilization.iter().sum();
            assert!((total - 1.0).abs() < 1e-6, "{}: {total}", c.name);
        }
    }

    #[test]
    fn report_renders_every_operator_and_a_classification() {
        let (hw, sim) = cfg();
        let text = sweep_report(&[128, 256], &hw, &sim);
        for op in registry::global().iter() {
            assert!(text.contains(op.paper_name()), "missing {}", op.name());
        }
        assert!(text.contains("Classification"));
        assert!(text.contains("-bound"));
        assert!(text.contains("Long-context verdicts"));
    }

    #[test]
    fn capacity_collapses_for_attention_and_holds_for_constant_state() {
        let mem = MemoryConfig::from_hw(&NpuConfig::default());
        let reg = registry::global();
        let causal = reg.get("causal").unwrap();
        let retentive = reg.get("retentive").unwrap();
        let linear = reg.get("linear").unwrap();
        assert!(
            max_sessions_at(causal, 512, &mem) >= 8 * max_sessions_at(causal, 16384, &mem),
            "KV capacity must collapse with context"
        );
        assert_eq!(max_sessions_at(retentive, 512, &mem), max_sessions_at(retentive, 16384, &mem));
        assert_eq!(max_sessions_at(linear, 512, &mem), max_sessions_at(linear, 16384, &mem));

        let text = capacity_report_with(reg, &[512, 16384], &mem);
        assert!(text.contains("collapses with context"), "{text}");
        assert!(text.contains("flat"), "{text}");
        assert!(text.contains("Max sessions"), "{text}");
    }

    #[test]
    fn sweep_reports_the_state_column() {
        let (hw, sim) = cfg();
        let cells = run_sweep(registry::global(), &[256, 1024], &hw, &sim);
        let causal: Vec<&SweepCell> =
            cells.iter().filter(|c| c.name == "causal").collect();
        assert_eq!(causal[1].state_bytes, 4 * causal[0].state_bytes, "KV grows O(N)");
        let text = sweep_report(&[256], &hw, &sim);
        assert!(text.contains("State"), "{text}");
    }

    #[test]
    fn fleet_capacity_appends_only_on_real_fleets() {
        let (hw, sim) = cfg();
        let one = capacity_fleet_report(&[512, 2048], &hw, &sim, 1);
        assert_eq!(one, capacity_report(&[512, 2048], &hw, &sim));
        assert!(!one.contains("Fleet capacity"), "{one}");
        let four = capacity_fleet_report(&[512, 2048], &hw, &sim, 4);
        assert!(four.contains("Fleet capacity (4 devices"), "{four}");
        assert!(four.starts_with(&one), "fleet section appends, never rewrites: {four}");
    }

    #[test]
    fn fleet_occupancy_renders_one_row_per_device() {
        let stats = vec![
            DeviceStat {
                id: 0,
                label: "d0",
                busy_until_ns: 2_000_000,
                busy_ns_total: 1_500_000,
                served: 3,
                batches: 2,
                sessions: 1,
                resident_sessions: 1,
                migrations_in: 0,
            },
            DeviceStat {
                id: 1,
                label: "d1",
                busy_until_ns: 1_000_000,
                busy_ns_total: 1_000_000,
                served: 1,
                batches: 1,
                sessions: 1,
                resident_sessions: 1,
                migrations_in: 1,
            },
        ];
        let out = fleet_occupancy_report(&stats);
        assert!(out.contains("makespan 2.000 ms"), "{out}");
        assert!(out.contains("d0") && out.contains("d1"), "{out}");
        // Occupancy = busy over the fleet makespan.
        assert!(out.contains("75.0%") && out.contains("50.0%"), "{out}");
    }

    #[test]
    fn conformance_snapshot_is_deterministic_and_complete() {
        let (hw, sim) = cfg();
        let reg = registry::global();
        let a = conformance_snapshot(reg, &[128, 256], &hw, &sim);
        let b = conformance_snapshot(reg, &[128, 256], &hw, &sim);
        assert_eq!(a, b, "two runs must be byte-identical");
        assert_eq!(a.lines().count(), reg.len() * 2);
        for op in reg.iter() {
            assert!(a.contains(&format!("{} n=128 ", op.name())), "{a}");
        }
        assert!(a.contains("class="), "{a}");
    }

    #[test]
    fn custom_registry_is_honored() {
        let (hw, sim) = cfg();
        let mut reg = OperatorRegistry::new();
        // A one-operator deployment: only toeplitz.
        struct Only;
        impl crate::ops::CausalOperator for Only {
            fn name(&self) -> &'static str {
                "toeplitz"
            }
            fn paper_name(&self) -> &'static str {
                "Toeplitz"
            }
            fn kind(&self) -> crate::config::OperatorKind {
                crate::config::OperatorKind::Toeplitz
            }
            fn complexity(&self) -> &'static str {
                "O(N*B*d)"
            }
            fn lower(
                &self,
                spec: &WorkloadSpec,
                hw: &NpuConfig,
                sim: &SimConfig,
            ) -> crate::ops::OpGraph {
                crate::ops::toeplitz::lower(spec, hw, sim)
            }
        }
        reg.register(Box::new(Only));
        let text = sweep_report_with(&reg, &[256], &hw, &sim);
        assert!(text.contains("Toeplitz"));
        assert!(!text.contains("Fourier"));
    }
}
