//! Registry-driven operator × context sweep with bottleneck classification
//! (the `npuperf sweep` report).
//!
//! Runs **every registered operator** — builtins and anything a deployment
//! registered on its own [`OperatorRegistry`] — across a grid of context
//! lengths on the NPU simulator, and renders one comparative table: per
//! cell the latency, engine-utilization split, stall and cache-efficiency
//! counters, and the paper's taxonomy verdict ([`BoundClass`]): memory-,
//! compute-, vector-compute-, or data-movement-bound. This is the paper's
//! central artifact — the bottleneck *spectrum* across operators — as one
//! command over the pluggable operator inventory.

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::npu;
use crate::ops::registry::{self, classify, BoundClass, CausalOperator, OperatorRegistry};
use crate::util::fmt;

/// One evaluated (operator, context) cell.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Registry name of the operator.
    pub name: &'static str,
    /// Display name for tables.
    pub paper_name: &'static str,
    /// Asymptotic cost class.
    pub complexity: &'static str,
    /// Context length N.
    pub n: usize,
    /// Simulated latency, ms.
    pub latency_ms: f64,
    /// Utilization shares [DPU, DMA, SHAVE] summing to 1.
    pub utilization: [f64; 3],
    /// Compute pipeline-stall fraction.
    pub stall: f64,
    /// Scratchpad hit rate.
    pub cache_eff: f64,
    /// Dominant-engine bottleneck string (Table II column).
    pub bottleneck: String,
    /// Paper-taxonomy classification.
    pub class: BoundClass,
}

/// Evaluate every operator in `reg` at every context in `contexts`.
pub fn run_sweep(
    reg: &OperatorRegistry,
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    for op in reg.iter() {
        for &n in contexts {
            let spec = WorkloadSpec::new(op.kind(), n);
            let r = npu::run(&op.lower(&spec, hw, sim), hw, sim);
            cells.push(SweepCell {
                name: op.name(),
                paper_name: op.paper_name(),
                complexity: op.complexity(),
                n,
                latency_ms: r.latency_ms(),
                utilization: r.utilization(),
                stall: r.stall.stall_frac(),
                cache_eff: r.cache.efficiency(),
                bottleneck: r.bottleneck().to_string(),
                class: classify(&r),
            });
        }
    }
    cells
}

/// Render the sweep over an explicit registry.
pub fn sweep_report_with(
    reg: &OperatorRegistry,
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> String {
    let cells = run_sweep(reg, contexts, hw, sim);
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.paper_name.to_string(),
                c.complexity.to_string(),
                c.n.to_string(),
                format!("{:.2}", c.latency_ms),
                fmt::pct(c.utilization[0]),
                fmt::pct(c.utilization[1]),
                fmt::pct(c.utilization[2]),
                fmt::pct(c.stall),
                fmt::pct(c.cache_eff),
                c.bottleneck.clone(),
                c.class.to_string(),
            ]
        })
        .collect();
    let table = fmt::table(
        &[
            "Operator",
            "Complexity",
            "N",
            "Latency ms",
            "DPU %",
            "DMA %",
            "SHAVE %",
            "Stall %",
            "Cache %",
            "Bottleneck",
            "Classification",
        ],
        &rows,
    );

    // Verdict per operator at the longest context — the regime the paper's
    // conclusions are drawn from.
    let longest = contexts.iter().copied().max().unwrap_or(0);
    let mut verdicts = String::new();
    for c in cells.iter().filter(|c| c.n == longest) {
        verdicts += &format!(
            "  {:<12} {:<14} -> {} at N={}\n",
            c.paper_name, c.complexity, c.class, c.n
        );
    }
    format!(
        "Operator sweep over {} registered operators x {:?} contexts\n\
         (taxonomy per paper §IV: memory- / compute- / vector-compute- / \
         data-movement-bound)\n{table}\n\nLong-context verdicts:\n{verdicts}",
        reg.len(),
        contexts,
    )
}

/// Render the sweep over the process-wide default registry.
pub fn sweep_report(contexts: &[usize], hw: &NpuConfig, sim: &SimConfig) -> String {
    sweep_report_with(registry::global(), contexts, hw, sim)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> (NpuConfig, SimConfig) {
        (NpuConfig::default(), SimConfig::default())
    }

    #[test]
    fn sweep_covers_registry_times_contexts() {
        let (hw, sim) = cfg();
        let cells = run_sweep(registry::global(), &[128, 256], &hw, &sim);
        assert_eq!(cells.len(), registry::global().len() * 2);
        for c in &cells {
            assert!(c.latency_ms > 0.0, "{}", c.name);
            let total: f64 = c.utilization.iter().sum();
            assert!((total - 1.0).abs() < 1e-6, "{}: {total}", c.name);
        }
    }

    #[test]
    fn report_renders_every_operator_and_a_classification() {
        let (hw, sim) = cfg();
        let text = sweep_report(&[128, 256], &hw, &sim);
        for op in registry::global().iter() {
            assert!(text.contains(op.paper_name()), "missing {}", op.name());
        }
        assert!(text.contains("Classification"));
        assert!(text.contains("-bound"));
        assert!(text.contains("Long-context verdicts"));
    }

    #[test]
    fn custom_registry_is_honored() {
        let (hw, sim) = cfg();
        let mut reg = OperatorRegistry::new();
        // A one-operator deployment: only toeplitz.
        struct Only;
        impl crate::ops::CausalOperator for Only {
            fn name(&self) -> &'static str {
                "toeplitz"
            }
            fn paper_name(&self) -> &'static str {
                "Toeplitz"
            }
            fn kind(&self) -> crate::config::OperatorKind {
                crate::config::OperatorKind::Toeplitz
            }
            fn complexity(&self) -> &'static str {
                "O(N*B*d)"
            }
            fn lower(
                &self,
                spec: &WorkloadSpec,
                hw: &NpuConfig,
                sim: &SimConfig,
            ) -> crate::ops::OpGraph {
                crate::ops::toeplitz::lower(spec, hw, sim)
            }
        }
        reg.register(Box::new(Only));
        let text = sweep_report_with(&reg, &[256], &hw, &sim);
        assert!(text.contains("Toeplitz"));
        assert!(!text.contains("Fourier"));
    }
}
