//! Reproduction of every table and figure in the paper's evaluation.
//!
//! [`tables`] renders Tables I-VIII in the paper's format, with the paper's
//! published values printed alongside our simulated values so deviations
//! are visible at a glance. [`figures`] regenerates Figs 3-8 as ASCII
//! plots + CSV series. [`export`] writes the CSV files the benches emit.
//! [`sweep`] is the registry-driven comparative report behind
//! `npuperf sweep`: every registered operator across a context grid, with
//! the paper's bottleneck-taxonomy classification per cell.

pub mod export;
pub mod figures;
pub mod sweep;
pub mod tables;

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::npu::{self, ExecReport};

/// The context sweep used throughout the paper's evaluation.
pub const CONTEXTS: [usize; 7] = [128, 256, 512, 1024, 2048, 4096, 8192];

/// Run one (operator, context) cell on the simulator (registry-dispatched).
pub fn run_cell(op: OperatorKind, n: usize, hw: &NpuConfig, sim: &SimConfig) -> ExecReport {
    npu::run_workload(&WorkloadSpec::new(op, n), hw, sim)
}

/// Run a full operator × context grid (reused by several tables/figures).
pub fn run_grid(
    ops_list: &[OperatorKind],
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<(OperatorKind, usize, ExecReport)> {
    let mut out = Vec::new();
    for &op in ops_list {
        for &n in contexts {
            out.push((op, n, run_cell(op, n, hw, sim)));
        }
    }
    out
}
