//! Reproduction of every table and figure in the paper's evaluation.
//!
//! [`tables`] renders Tables I-VIII in the paper's format, with the paper's
//! published values printed alongside our simulated values so deviations
//! are visible at a glance. [`figures`] regenerates Figs 3-8 as ASCII
//! plots + CSV series. [`export`] writes the CSV files the benches emit.

pub mod export;
pub mod figures;
pub mod tables;

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::npu::{self, ExecReport};
use crate::ops;

/// The context sweep used throughout the paper's evaluation.
pub const CONTEXTS: [usize; 7] = [128, 256, 512, 1024, 2048, 4096, 8192];

/// Run one (operator, context) cell on the simulator.
pub fn run_cell(op: OperatorKind, n: usize, hw: &NpuConfig, sim: &SimConfig) -> ExecReport {
    let spec = WorkloadSpec::new(op, n);
    let g = ops::lower(&spec, hw, sim);
    npu::run(&g, hw, sim)
}

/// Run a full operator × context grid (reused by several tables/figures).
pub fn run_grid(
    ops_list: &[OperatorKind],
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<(OperatorKind, usize, ExecReport)> {
    let mut out = Vec::new();
    for &op in ops_list {
        for &n in contexts {
            out.push((op, n, run_cell(op, n, hw, sim)));
        }
    }
    out
}
