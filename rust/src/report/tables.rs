//! Paper tables I-VIII, rendered with our simulated values next to the
//! paper's published numbers ("paper" columns) so the reproduction quality
//! is visible cell by cell.

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::model::{calibrate, Roofline};
use crate::util::fmt;

use super::run_cell;

/// Table I: hardware specification (ours = the simulator defaults).
pub fn table1(hw: &NpuConfig) -> String {
    let rows = vec![
        vec!["CPU".into(), "16 cores (8P + 8E)".into(), "Control Logic".into()],
        vec![
            "NPU".into(),
            format!("{:.0} TOPS @ 35W", hw.peak_int8_gops() / 1000.0),
            "Systolic Array Acceleration".into(),
        ],
        vec![
            "DPU (PE Array)".into(),
            format!("{}x{} INT8", hw.pe_array, hw.pe_array),
            "Matrix Multiplication".into(),
        ],
        vec![
            "Scratchpad".into(),
            fmt::bytes(hw.scratchpad_bytes),
            "Persistent State Storage".into(),
        ],
        vec![
            "DMA Bandwidth".into(),
            format!("{:.0} GB/s", hw.dma_bw_gbps),
            "Data Movement".into(),
        ],
        vec![
            "SHAVE Cores".into(),
            format!("{} @ {} GHz", hw.shave_cores, hw.shave_clock_ghz),
            "Element-Wise Operations".into(),
        ],
        vec!["Memory".into(), fmt::bytes(hw.dram_bytes), "Global Buffer".into()],
    ];
    format!(
        "TABLE I: Hardware Specifications\n{}",
        fmt::table(&["Component", "Specification", "Relevance"], &rows)
    )
}

/// Paper Table II reference: (context, dpu, dma, shave) per operator.
pub const PAPER_TABLE2_FOURIER: [(usize, f64, f64, f64); 7] = [
    (128, 56.4, 23.1, 20.5),
    (256, 60.8, 25.3, 13.9),
    (512, 47.2, 46.9, 5.9),
    (1024, 46.6, 48.9, 4.5),
    (2048, 46.2, 52.5, 1.2),
    (4096, 48.4, 51.3, 0.3),
    (8192, 61.1, 38.9, 0.0),
];
pub const PAPER_TABLE2_RETENTIVE: [(usize, f64, f64, f64); 7] = [
    (128, 68.4, 0.0, 31.6),
    (256, 64.9, 0.0, 35.1),
    (512, 61.9, 0.0, 38.1),
    (1024, 34.9, 0.0, 65.1),
    (2048, 24.6, 0.0, 75.4),
    (4096, 28.1, 0.0, 71.9),
    (8192, 23.6, 0.0, 76.4),
];

/// Table II: device utilization breakdown for Fourier & Retentive.
pub fn table2(hw: &NpuConfig, sim: &SimConfig) -> String {
    let mut rows = Vec::new();
    for (op, paper) in [
        (OperatorKind::Fourier, &PAPER_TABLE2_FOURIER),
        (OperatorKind::Retentive, &PAPER_TABLE2_RETENTIVE),
    ] {
        for &(n, p_dpu, p_dma, p_shave) in paper.iter() {
            let r = run_cell(op, n, hw, sim);
            let [dpu, dma, shave] = r.utilization();
            rows.push(vec![
                op.paper_name().to_string(),
                n.to_string(),
                fmt::pct(dpu),
                fmt::pct(dma),
                fmt::pct(shave),
                r.bottleneck().to_string(),
                format!("{p_dpu}/{p_dma}/{p_shave}"),
            ]);
        }
    }
    format!(
        "TABLE II: Device Utilization Breakdown (%)\n{}",
        fmt::table(
            &["Model", "Context", "DPU %", "DMA %", "SHAVE %", "Bottleneck", "paper D/M/S"],
            &rows
        )
    )
}

/// Paper Table III reference latencies (ms): [fourier, retentive, toeplitz, linear].
pub const PAPER_TABLE3: [(usize, [f64; 4]); 7] = [
    (128, [0.39, 0.19, 0.06, 0.09]),
    (256, [0.79, 0.37, 0.08, 0.13]),
    (512, [2.54, 0.97, 0.11, 0.24]),
    (1024, [6.50, 2.52, 0.18, 0.44]),
    (2048, [15.24, 10.04, 0.35, 0.72]),
    (4096, [45.69, 39.52, 0.59, 1.52]),
    (8192, [347.79, 85.41, 1.01, 3.16]),
];

/// Table III: latency scaling of the four sub-quadratic operators.
pub fn table3(hw: &NpuConfig, sim: &SimConfig) -> String {
    let ops = [
        OperatorKind::Fourier,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
    ];
    let mut rows = Vec::new();
    for &(n, paper) in PAPER_TABLE3.iter() {
        let mut row = vec![n.to_string()];
        for (i, &op) in ops.iter().enumerate() {
            let r = run_cell(op, n, hw, sim);
            row.push(format!("{:.2} ({:.2})", r.latency_ms(), paper[i]));
        }
        rows.push(row);
    }
    format!(
        "TABLE III: Latency scaling (ms), ours (paper)\n{}",
        fmt::table(&["Context", "Fourier", "Retentive", "Toeplitz", "Linear"], &rows)
    )
}

/// Paper Table IV reference: (op, lat512, lat8192, thr512, thr8192).
pub const PAPER_TABLE4: [(&str, f64, f64, f64, f64); 5] = [
    ("Full Causal", 4.21, 251.41, 237.0, 4.0),
    ("Retentive", 3.10, 45.10, 322.0, 22.0),
    ("Fourier", 1.59, 170.50, 631.0, 6.0),
    ("Linear", 0.30, 3.81, 3333.0, 263.0),
    ("Toeplitz", 0.75, 5.10, 1330.0, 196.0),
];

/// Table IV: latency + throughput at N = 512 and 8192.
pub fn table4(hw: &NpuConfig, sim: &SimConfig) -> String {
    let ops = [
        OperatorKind::Causal,
        OperatorKind::Retentive,
        OperatorKind::Fourier,
        OperatorKind::Linear,
        OperatorKind::Toeplitz,
    ];
    let mut rows = Vec::new();
    for (i, &op) in ops.iter().enumerate() {
        let r512 = run_cell(op, 512, hw, sim);
        let r8192 = run_cell(op, 8192, hw, sim);
        let p = PAPER_TABLE4[i];
        rows.push(vec![
            op.paper_name().to_string(),
            format!("{:.2} ({:.2})", r512.latency_ms(), p.1),
            format!("{:.2} ({:.2})", r8192.latency_ms(), p.2),
            format!("{:.0} ({:.0})", r512.throughput_ops_s(), p.3),
            format!("{:.0} ({:.0})", r8192.throughput_ops_s(), p.4),
        ]);
    }
    format!(
        "TABLE IV: Latency and throughput at N=512 / N=8192, ours (paper)\n{}",
        fmt::table(
            &["Operator", "Lat 512 ms", "Lat 8192 ms", "Thr 512 ops/s", "Thr 8192 ops/s"],
            &rows
        )
    )
}

/// Paper Table V reference: (op, context, stall %, cache %, reuse ms).
pub const PAPER_TABLE5: [(&str, usize, f64, f64, f64); 5] = [
    ("Full Causal", 8192, 96.7, 7.7, 119.92),
    ("Retentive", 8192, 94.8, 28.1, 25.62),
    ("Fourier", 4096, 95.2, 28.6, 24.94),
    ("Linear", 8192, 55.2, 83.8, 1.94),
    ("Toeplitz", 4096, 36.4, 87.9, 1.38),
];

/// Table V: efficiency metrics at long contexts.
pub fn table5(hw: &NpuConfig, sim: &SimConfig) -> String {
    let cells = [
        (OperatorKind::Causal, 8192),
        (OperatorKind::Retentive, 8192),
        (OperatorKind::Fourier, 4096),
        (OperatorKind::Linear, 8192),
        (OperatorKind::Toeplitz, 4096),
    ];
    let mut rows = Vec::new();
    for (i, &(op, n)) in cells.iter().enumerate() {
        let r = run_cell(op, n, hw, sim);
        let p = PAPER_TABLE5[i];
        rows.push(vec![
            op.paper_name().to_string(),
            n.to_string(),
            format!("{} ({})", fmt::pct(r.stall.stall_frac()), p.2),
            format!("{} ({})", fmt::pct(r.cache.efficiency()), p.3),
            format!("{:.2} ({})", r.cache.reuse_ns / 1e6, p.4),
        ]);
    }
    format!(
        "TABLE V: Efficiency metrics at long contexts, ours (paper)\n{}",
        fmt::table(&["Operator", "Context", "Stall %", "Cache Eff %", "Reuse ms"], &rows)
    )
}

/// Paper Table VI reference: (op, ms @ d_state 16, ms @ d_state 128).
pub const PAPER_TABLE6: [(&str, f64, f64); 3] =
    [("Linear", 2.39, 3.37), ("Toeplitz", 0.65, 2.73), ("Fourier", 15.50, 56.82)];

/// Table VI: d_state sweep at N = 4096.
pub fn table6(hw: &NpuConfig, sim: &SimConfig) -> String {
    let ops = [OperatorKind::Linear, OperatorKind::Toeplitz, OperatorKind::Fourier];
    let mut rows = Vec::new();
    for (i, &op) in ops.iter().enumerate() {
        let lo = {
            let spec = WorkloadSpec::new(op, 4096);
            let g = crate::ops::lower(&spec, hw, sim);
            crate::npu::run(&g, hw, sim)
        };
        let hi = {
            let spec = WorkloadSpec::new(op, 4096).with_d_state(128);
            let g = crate::ops::lower(&spec, hw, sim);
            crate::npu::run(&g, hw, sim)
        };
        let p = PAPER_TABLE6[i];
        rows.push(vec![
            op.paper_name().to_string(),
            format!("{:.2} ({:.2})", lo.latency_ms(), p.1),
            format!("{:.2} ({:.2})", hi.latency_ms(), p.2),
            format!("{:.2}x ({:.2}x)", hi.latency_ms() / lo.latency_ms(), p.2 / p.1),
        ]);
    }
    format!(
        "TABLE VI: d_state impact at N=4096, ours (paper)\n{}",
        fmt::table(&["Operator", "d_state=16 ms", "d_state=128 ms", "growth"], &rows)
    )
}

/// Paper Table VII reference: (op, intensity, measured GOP/s).
pub const PAPER_TABLE7: [(&str, f64, f64); 5] = [
    ("Full Causal", 61.13, 21.4),
    ("Retentive", 50.00, 53.5),
    ("Toeplitz", 25.00, 12.2),
    ("Linear", 16.00, 14.0),
    ("Fourier", 15.00, 0.34),
];

/// Table VII: operational intensity + measured performance at N = 4096.
pub fn table7(hw: &NpuConfig, sim: &SimConfig) -> String {
    let ceilings = calibrate(hw, sim);
    let roofline = Roofline::new(ceilings);
    let ops = [
        OperatorKind::Causal,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
        OperatorKind::Fourier,
    ];
    let mut rows = Vec::new();
    for (i, &op) in ops.iter().enumerate() {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, hw, sim);
        let point = roofline.place(&spec, &r, sim.elem_bytes);
        let p = PAPER_TABLE7[i];
        rows.push(vec![
            op.paper_name().to_string(),
            format!("{:.2} ({:.2})", point.intensity, p.1),
            format!("{:.1} ({:.2})", point.measured_gops, p.2),
            format!("{:.1}", point.bound_gops),
        ]);
    }
    format!(
        "TABLE VII: Intensity & measured GOP/s at N=4096, ours (paper)\n\
         calibrated: pi_eff={:.0} GOP/s (paper 500), beta_eff={:.2} GB/s (paper 3.2), \
         I_crit={:.0} (paper 156)\n{}",
        ceilings.pi_eff_gops,
        ceilings.beta_eff_gbps,
        ceilings.i_crit(),
        fmt::table(
            &["Operator", "Intensity Op/B", "Measured GOP/s", "Bound GOP/s"],
            &rows
        )
    )
}

/// Paper Table VIII reference: (op, stall %, cache %, compute util %).
pub const PAPER_TABLE8: [(&str, f64, f64, f64); 5] = [
    ("Full Causal", 96.7, 7.7, 4.3),
    ("Retentive", 94.8, 28.1, 33.4),
    ("Toeplitz", 36.4, 87.9, 15.2),
    ("Linear", 55.2, 83.8, 27.3),
    ("Fourier", 95.2, 28.6, 0.7),
];

/// Table VIII: hardware utilization metrics at N = 4096.
pub fn table8(hw: &NpuConfig, sim: &SimConfig) -> String {
    let ceilings = calibrate(hw, sim);
    let roofline = Roofline::new(ceilings);
    let ops = [
        OperatorKind::Causal,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
        OperatorKind::Fourier,
    ];
    let mut rows = Vec::new();
    for (i, &op) in ops.iter().enumerate() {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, hw, sim);
        let point = roofline.place(&spec, &r, sim.elem_bytes);
        // Compute utilization vs the effective compute ceiling pi_eff.
        // (The paper divides by each operator's memory-side *bound*; our
        // fused lowerings move less DRAM traffic than the paper's kernels,
        // so several operators exceed those bounds — see EXPERIMENTS.md.)
        let util = point.measured_gops / ceilings.pi_eff_gops;
        let p = PAPER_TABLE8[i];
        rows.push(vec![
            op.paper_name().to_string(),
            format!("{} ({})", fmt::pct(r.stall.stall_frac()), p.1),
            format!("{} ({})", fmt::pct(r.cache.efficiency()), p.2),
            format!("{} ({})", fmt::pct(util), p.3),
        ]);
    }
    format!(
        "TABLE VIII: Hardware utilization at N=4096, ours (paper)\n{}",
        fmt::table(&["Operator", "Stall %", "Cache Eff %", "Compute Util %"], &rows)
    )
}

/// All tables in order (the `npuperf tables` command).
pub fn all_tables(hw: &NpuConfig, sim: &SimConfig) -> String {
    [
        table1(hw),
        table2(hw, sim),
        table3(hw, sim),
        table4(hw, sim),
        table5(hw, sim),
        table6(hw, sim),
        table7(hw, sim),
        table8(hw, sim),
    ]
    .join("\n\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::CONTEXTS;

    fn cfg() -> (NpuConfig, SimConfig) {
        (NpuConfig::default(), SimConfig::default())
    }

    #[test]
    fn table1_mentions_key_specs() {
        let t = table1(&NpuConfig::default());
        assert!(t.contains("128x128 INT8"));
        assert!(t.contains("4.00 MiB"));
        assert!(t.contains("64 GB/s"));
    }

    #[test]
    fn table3_has_all_contexts() {
        let (hw, sim) = cfg();
        let t = table3(&hw, &sim);
        for n in CONTEXTS {
            assert!(t.contains(&format!("| {n} ")) || t.contains(&format!("{n} |")), "{n}");
        }
    }

    #[test]
    fn table4_throughput_is_reciprocal() {
        let (hw, sim) = cfg();
        let t = table4(&hw, &sim);
        assert!(t.contains("Full Causal"));
        assert!(t.contains("(251.41)"), "paper reference column present");
    }

    #[test]
    fn table7_reports_calibration() {
        let (hw, sim) = cfg();
        let t = table7(&hw, &sim);
        assert!(t.contains("pi_eff"));
        assert!(t.contains("(61.13)"), "paper causal intensity");
    }

    #[test]
    fn all_tables_renders_everything() {
        let (hw, sim) = cfg();
        let t = all_tables(&hw, &sim);
        for tag in ["TABLE I:", "TABLE II:", "TABLE III:", "TABLE IV:", "TABLE V:",
                    "TABLE VI:", "TABLE VII:", "TABLE VIII:"] {
            assert!(t.contains(tag), "missing {tag}");
        }
    }
}
