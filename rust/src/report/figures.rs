//! Paper figures 3-8 as ASCII renderings + CSV series.

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::model::{calibrate, Roofline};
use crate::ops::masks::{self, MaskFamily};

use super::{run_cell, CONTEXTS};

/// Fig 1: persistent memory & layer-wise dataflow, attention vs SSM —
/// rendered with the *actual* numbers from the state manager.
pub fn fig1() -> String {
    use crate::config::OperatorKind;
    use crate::coordinator::state::StateManager;
    let mut out = String::from(
        "FIG 1: Memory-state tradeoff (persistent bytes vs context, one head)\n\n\
         Attention (Llama-like): KV cache grows O(N*d)   | SSM (Mamba-like): fixed state O(d*d_state)\n",
    );
    for n in [1024usize, 4096, 16_384, 65_536, 131_072] {
        let mut mm = StateManager::new(u64::MAX);
        mm.open(0, OperatorKind::Causal, 64, 16);
        mm.open(1, OperatorKind::Linear, 64, 16);
        mm.append(0, n);
        mm.append(1, n);
        let kv = mm.session_bytes(0).unwrap();
        let ssm = mm.session_bytes(1).unwrap();
        let bar = (kv as f64).log2().max(0.0) as usize;
        out += &format!(
            "{n:>7} tokens  KV {:<12} |{}|   state {:<10} (x{:.0} smaller)\n",
            crate::util::fmt::bytes(kv),
            "#".repeat(bar.min(40)),
            crate::util::fmt::bytes(ssm),
            kv as f64 / ssm as f64,
        );
    }
    out
}

/// Fig 2: the NPU dataflow architecture (static schematic).
pub fn fig2(hw: &NpuConfig) -> String {
    format!(
        "FIG 2: NPU dataflow architecture\n\
         \n\
         +--------------------------------------------------------------+\n\
         |  Global system memory ({:>9})            LPDDR5X           |\n\
         +------------------------------+-------------------------------+\n\
                                        | DMA {:>3.0} GB/s (descriptor\n\
                                        |     setup {:.1} us, alloc {:.0} us)\n\
         +------------------------------v-------------------------------+\n\
         |  Scratchpad ({:>9}) -- software-managed, persistent state |\n\
         +----+--------------------+--------------------+---------------+\n\
              |                    |                    |\n\
         +----v-----------+  +-----v-----------+  +-----v-------------+\n\
         | DPU            |  | SHAVE x{:<2}       |  | DSP (control)     |\n\
         | {}x{} PE     |  | {:.1} GHz SIMD    |  | descriptor issue  |\n\
         | systolic array |  | softmax/eltwise |  | {:.1} us / primitive|\n\
         | fill/drain {:>3} |  | exp {:>2} cyc/elem |  |                   |\n\
         +----------------+  +-----------------+  +-------------------+\n\
         \n\
         No high-bandwidth memory for persistent state: everything beyond\n\
         the {:>9} scratchpad rides the DMA engine (the paper's point).\n",
        crate::util::fmt::bytes(hw.dram_bytes),
        hw.dma_bw_gbps,
        hw.dma_setup_ns / 1000.0,
        hw.dma_alloc_ns / 1000.0,
        crate::util::fmt::bytes(hw.scratchpad_bytes),
        hw.shave_cores,
        hw.pe_array,
        hw.pe_array,
        hw.shave_clock_ghz,
        hw.dpu_issue_ns / 1000.0,
        hw.dpu_fill_cycles,
        hw.shave_exp_cycles,
        crate::util::fmt::bytes(hw.scratchpad_bytes),
    )
}

/// Fig 3: the six causal mask structures.
pub fn fig3(n: usize) -> String {
    let mut out = String::from("FIG 3: Causal attention mask families\n");
    for fam in MaskFamily::ALL {
        out += &format!(
            "\n--- {} (density {:.0}% @ eps=0.01) ---\n{}",
            fam.name(),
            100.0 * masks::density(fam, n, 0.01),
            masks::render(fam, n)
        );
    }
    out
}

/// One utilization series for Fig 4: (context, dpu, dma, shave).
pub fn fig4_series(
    op: OperatorKind,
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<(usize, f64, f64, f64)> {
    CONTEXTS
        .iter()
        .map(|&n| {
            let r = run_cell(op, n, hw, sim);
            let [dpu, dma, shave] = r.utilization();
            (n, dpu * 100.0, dma * 100.0, shave * 100.0)
        })
        .collect()
}

/// Fig 4: utilization shift with context (Fourier & Retentive), as
/// stacked ASCII bars.
pub fn fig4(hw: &NpuConfig, sim: &SimConfig) -> String {
    let mut out = String::from(
        "FIG 4: NPU subcomponent utilization vs context (D=DPU, M=DMA, S=SHAVE)\n",
    );
    for op in [OperatorKind::Fourier, OperatorKind::Retentive] {
        out += &format!("\n{}:\n", op.paper_name());
        for (n, dpu, dma, shave) in fig4_series(op, hw, sim) {
            let w = 50.0;
            let d = (dpu / 100.0 * w).round() as usize;
            let m = (dma / 100.0 * w).round() as usize;
            let s = (w as usize).saturating_sub(d + m);
            out += &format!(
                "{n:>5} |{}{}{}| D={dpu:.1} M={dma:.1} S={shave:.1}\n",
                "D".repeat(d),
                "M".repeat(m),
                "S".repeat(s)
            );
        }
    }
    out
}

/// Fig 5 series: latency (ms) per operator across contexts.
pub fn fig5_series(hw: &NpuConfig, sim: &SimConfig) -> Vec<(OperatorKind, Vec<(usize, f64)>)> {
    [
        OperatorKind::Fourier,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
    ]
    .iter()
    .map(|&op| {
        let series =
            CONTEXTS.iter().map(|&n| (n, run_cell(op, n, hw, sim).latency_ms())).collect();
        (op, series)
    })
    .collect()
}

/// Fig 5: log-log latency scaling plot.
pub fn fig5(hw: &NpuConfig, sim: &SimConfig) -> String {
    let series = fig5_series(hw, sim);
    let (w, h) = (64usize, 20usize);
    let (y_min, y_max) = (0.01f64, 1000.0f64);
    let mut grid = vec![vec![' '; w]; h];
    let glyphs = ['F', 'R', 'T', 'L'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(n, ms) in pts {
            let xf = ((n as f64).ln() - 128f64.ln()) / (8192f64.ln() - 128f64.ln());
            let yf = ((ms.max(y_min)).ln() - y_min.ln()) / (y_max.ln() - y_min.ln());
            let x = (xf.clamp(0.0, 1.0) * (w - 1) as f64).round() as usize;
            let y = h - 1 - (yf.clamp(0.0, 1.0) * (h - 1) as f64).round() as usize;
            grid[y][x] = glyphs[si];
        }
    }
    let mut out = String::from(
        "FIG 5: Latency vs context, log-log (F=Fourier R=Retentive T=Toeplitz L=Linear)\n",
    );
    out += "ms (0.01 .. 1000)\n";
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out += &format!("+{}\n context 128 .. 8192 (log)\n", "-".repeat(w));
    out
}

/// Fig 6: efficiency bars (stall, cache) + reuse line, per operator.
pub fn fig6(hw: &NpuConfig, sim: &SimConfig) -> String {
    let cells = [
        (OperatorKind::Causal, 8192),
        (OperatorKind::Retentive, 8192),
        (OperatorKind::Fourier, 4096),
        (OperatorKind::Linear, 8192),
        (OperatorKind::Toeplitz, 4096),
    ];
    let mut out = String::from("FIG 6: Efficiency metrics at long context\n");
    for (op, n) in cells {
        let r = run_cell(op, n, hw, sim);
        let stall = r.stall.stall_frac();
        let cache = r.cache.efficiency();
        out += &format!(
            "{:<12} stall |{:<25}| {:>5.1}%   cache |{:<25}| {:>5.1}%   reuse {:>8.2} ms\n",
            op.paper_name(),
            "#".repeat((stall * 25.0).round() as usize),
            stall * 100.0,
            "#".repeat((cache * 25.0).round() as usize),
            cache * 100.0,
            r.cache.reuse_ns / 1e6
        );
    }
    out
}

/// Fig 7: the roofline plot.
pub fn fig7(hw: &NpuConfig, sim: &SimConfig) -> String {
    let roofline = Roofline::new(calibrate(hw, sim));
    let points: Vec<_> = [
        OperatorKind::Causal,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
        OperatorKind::Fourier,
    ]
    .iter()
    .map(|&op| {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, hw, sim);
        roofline.place(&spec, &r, sim.elem_bytes)
    })
    .collect();
    format!("FIG 7: Roofline (N=4096)\n{}", roofline.ascii_plot(&points, 64, 18))
}

/// Fig 8: utilization breakdown bars at N = 4096.
pub fn fig8(hw: &NpuConfig, sim: &SimConfig) -> String {
    let ceilings = calibrate(hw, sim);
    let roofline = Roofline::new(ceilings);
    let mut out = String::from("FIG 8: Hardware utilization breakdown at N=4096\n");
    for op in [
        OperatorKind::Causal,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
        OperatorKind::Fourier,
    ] {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, hw, sim);
        let point = roofline.place(&spec, &r, sim.elem_bytes);
        let cutil = point.measured_gops / ceilings.pi_eff_gops;
        let bar = |v: f64| "#".repeat((v.clamp(0.0, 1.0) * 30.0).round() as usize);
        out += &format!(
            "{:<12} stall {:>5.1}% |{:<30}|\n             cache {:>5.1}% |{:<30}|\n             cutil {:>5.1}% |{:<30}|\n",
            op.paper_name(),
            r.stall.stall_frac() * 100.0,
            bar(r.stall.stall_frac()),
            r.cache.efficiency() * 100.0,
            bar(r.cache.efficiency()),
            cutil * 100.0,
            bar(cutil),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> (NpuConfig, SimConfig) {
        (NpuConfig::default(), SimConfig::default())
    }

    #[test]
    fn fig1_shows_memory_separation() {
        let f = fig1();
        assert!(f.contains("KV"));
        assert!(f.contains("131072 tokens") || f.contains("131072"));
        assert!(f.contains("smaller"));
    }

    #[test]
    fn fig2_reflects_hw_config() {
        let hw = NpuConfig::default();
        let f = fig2(&hw);
        assert!(f.contains("128x128 PE"));
        assert!(f.contains("SHAVE x8"));
        assert!(f.contains("4.00 MiB"));
    }

    #[test]
    fn fig3_renders_six_masks() {
        let f = fig3(16);
        for fam in MaskFamily::ALL {
            assert!(f.contains(fam.name()), "missing {}", fam.name());
        }
    }

    #[test]
    fn fig4_series_covers_contexts() {
        let (hw, sim) = cfg();
        let s = fig4_series(OperatorKind::Retentive, &hw, &sim);
        assert_eq!(s.len(), CONTEXTS.len());
        for (_, d, m, sh) in s {
            assert!((d + m + sh - 100.0).abs() < 0.5, "shares sum to 100");
        }
    }

    #[test]
    fn fig5_plot_contains_all_series() {
        let (hw, sim) = cfg();
        let f = fig5(&hw, &sim);
        for g in ['F', 'R', 'T', 'L'] {
            assert!(f.contains(g), "missing series {g}");
        }
    }

    #[test]
    fn fig6_and_fig8_render_all_operators() {
        let (hw, sim) = cfg();
        for f in [fig6(&hw, &sim), fig8(&hw, &sim)] {
            for op in OperatorKind::ALL {
                assert!(f.contains(op.paper_name()), "missing {op}");
            }
        }
    }

    #[test]
    fn fig7_contains_roofline_legend() {
        let (hw, sim) = cfg();
        let f = fig7(&hw, &sim);
        assert!(f.contains("I_crit"));
        assert!(f.contains("% of roof"));
    }
}
