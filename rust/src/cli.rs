//! Hand-rolled CLI (no clap in the offline crate set).
//!
//! ```text
//! npuperf tables                 # all paper tables, ours vs published
//! npuperf table <1..8>           # one table
//! npuperf figures                # figs 3-8
//! npuperf sweep [--contexts A,B] # every registered operator x context grid
//! npuperf capacity [--contexts A,B] [--devices N] # max resident sessions per op x context
//! npuperf operators              # list the operator registry
//! npuperf simulate <op> <N> [--d-state D] [--offload] [--no-double-buffer]
//! npuperf roofline               # calibation + fig 7
//! npuperf masks [N]              # fig 3
//! npuperf rank <N>               # cost-model operator ranking (§V)
//! npuperf chunking <N>           # chunked-prefill plan sweep (§V)
//! npuperf validate [dir]         # golden-validate every artifact via PJRT
//! npuperf serve [dir] [--requests K --seed S] [--devices N] [--deterministic]
//!               [--trace-out F] [--metrics-out F] [--events-out F]
//! npuperf obs <file>             # validate an exported observability artifact
//! npuperf selftest [--seeds A,B,C] [--contexts A,B] [--bless]
//! npuperf hw                     # table 1
//! ```
//!
//! Every operator-touching command dispatches through the
//! [operator registry](crate::ops::registry); `sweep` and `operators` are
//! the registry's front door (enumerate, classify, compare).

use anyhow::{anyhow, bail, Result};

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::coordinator::{self, chunking, Clock, Coordinator, CoordinatorConfig, Request};
use crate::model::{calibrate, Roofline};
use crate::ops::CausalOperator;
use crate::report::{figures, tables};
use crate::{npu, ops};

/// Resolve an operator argument: exact registry names first (so variants
/// like `retentive-chunked` are runnable), then the `OperatorKind` aliases
/// (`dra`, `tsa`, ...), which map to the kind's canonical registry entry.
fn resolve_operator(arg: &str) -> Result<&'static dyn CausalOperator> {
    let reg = ops::registry::global();
    if let Some(op) = reg.get(&arg.to_ascii_lowercase()) {
        return Ok(op);
    }
    let kind: OperatorKind = arg.parse().map_err(|e: String| {
        anyhow!("{e} (or a registry name: {})", reg.names().join("|"))
    })?;
    reg.try_for_kind(kind)
        .ok_or_else(|| anyhow!("no operator registered for workload kind {kind}"))
}

/// Parse an optional `--contexts A,B,C` flag; `default` when absent.
/// Duplicates are dropped and the grid is sorted ascending, so
/// `--contexts 256,128,256` and `--contexts 128,256` produce identical
/// reports (sweep verdicts key on the min/max context, so order and
/// duplicates would otherwise change output).
fn parse_contexts(rest: &[&str], default: &[usize]) -> Result<Vec<usize>> {
    match rest.iter().position(|a| *a == "--contexts") {
        None => Ok(default.to_vec()),
        Some(i) => {
            let list = rest.get(i + 1).ok_or_else(|| {
                anyhow!("--contexts expects a comma-separated list of lengths")
            })?;
            let mut contexts = list
                .split(',')
                .map(|x| {
                    let n = x
                        .trim()
                        .parse::<usize>()
                        .map_err(|e| anyhow!("bad context {x:?}: {e}"))?;
                    if n == 0 {
                        bail!("context length must be positive, got {x:?}");
                    }
                    Ok(n)
                })
                .collect::<Result<Vec<usize>>>()?;
            contexts.sort_unstable();
            contexts.dedup();
            Ok(contexts)
        }
    }
}

/// Parse an optional `--devices N` flag (positive; 1 when absent).
fn parse_devices(rest: &[&str]) -> Result<usize> {
    match rest.iter().position(|a| *a == "--devices") {
        None => Ok(1),
        Some(i) => {
            let s = rest
                .get(i + 1)
                .ok_or_else(|| anyhow!("--devices expects a positive device count"))?;
            let n: usize = s.parse().map_err(|e| anyhow!("bad --devices {s:?}: {e}"))?;
            if n == 0 {
                bail!("--devices must be positive");
            }
            Ok(n)
        }
    }
}

/// Parse an optional `--seeds A,B,C` flag (u64 list, deduped + sorted);
/// `default` when absent.
fn parse_seeds(rest: &[&str], default: &[u64]) -> Result<Vec<u64>> {
    match rest.iter().position(|a| *a == "--seeds") {
        None => Ok(default.to_vec()),
        Some(i) => {
            let list = rest
                .get(i + 1)
                .ok_or_else(|| anyhow!("--seeds expects a comma-separated list"))?;
            let mut seeds = list
                .split(',')
                .map(|x| x.trim().parse::<u64>().map_err(|e| anyhow!("bad seed {x:?}: {e}")))
                .collect::<Result<Vec<u64>>>()?;
            seeds.sort_unstable();
            seeds.dedup();
            if seeds.is_empty() {
                bail!("--seeds expects at least one seed");
            }
            Ok(seeds)
        }
    }
}

/// Entry point used by `main`.
pub fn run(args: &[String]) -> Result<String> {
    let mut hw = NpuConfig::default();
    let mut sim = SimConfig::default();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<&str> = args.iter().skip(1).map(String::as_str).collect();
    // Global-ish flags consumed by simulate.
    let flag = |name: &str| rest.iter().any(|a| *a == name);
    let opt = |name: &str| {
        rest.iter().position(|a| *a == name).and_then(|i| rest.get(i + 1)).copied()
    };
    if flag("--offload") {
        sim.offload_concat_to_cpu = true;
    }
    if flag("--no-double-buffer") {
        sim.double_buffer = false;
    }
    // Hardware what-if overrides: --hw-config FILE and/or --hw key=value.
    if let Some(path) = opt("--hw-config") {
        hw = crate::config::parse::from_file(path)?;
    }
    for (i, a) in rest.iter().enumerate() {
        if *a == "--hw" {
            let kv = rest
                .get(i + 1)
                .ok_or_else(|| anyhow!("--hw expects key=value"))?;
            let (k, v) =
                kv.split_once('=').ok_or_else(|| anyhow!("--hw expects key=value"))?;
            crate::config::parse::apply(&mut hw, k, v)?;
        }
    }

    match cmd {
        "help" | "--help" | "-h" => Ok(HELP.to_string()),
        "hw" => Ok(tables::table1(&hw)),
        "tables" => Ok(tables::all_tables(&hw, &sim)),
        "table" => {
            let arg = rest.first().ok_or_else(|| anyhow!("usage: npuperf table <1..8>"))?;
            let which: u32 = arg
                .parse()
                .map_err(|e| anyhow!("bad table number {arg:?} (usage: npuperf table <1..8>): {e}"))?;
            Ok(match which {
                1 => tables::table1(&hw),
                2 => tables::table2(&hw, &sim),
                3 => tables::table3(&hw, &sim),
                4 => tables::table4(&hw, &sim),
                5 => tables::table5(&hw, &sim),
                6 => tables::table6(&hw, &sim),
                7 => tables::table7(&hw, &sim),
                8 => tables::table8(&hw, &sim),
                _ => bail!("table must be 1..8"),
            })
        }
        "figures" => Ok([
            figures::fig1(),
            figures::fig2(&hw),
            figures::fig3(32),
            figures::fig4(&hw, &sim),
            figures::fig5(&hw, &sim),
            figures::fig6(&hw, &sim),
            figures::fig7(&hw, &sim),
            figures::fig8(&hw, &sim),
        ]
        .join("\n\n")),
        "masks" => {
            let n = rest.first().and_then(|s| s.parse().ok()).unwrap_or(32);
            Ok(figures::fig3(n))
        }
        "sweep" => {
            let contexts = parse_contexts(&rest, &[512, 2048, 8192])?;
            Ok(crate::report::sweep::sweep_report(&contexts, &hw, &sim))
        }
        "capacity" => {
            let contexts = parse_contexts(&rest, &[512, 2048, 8192, 32768])?;
            let devices = parse_devices(&rest)?;
            Ok(crate::report::sweep::capacity_fleet_report(&contexts, &hw, &sim, devices))
        }
        "selftest" => {
            let opts = crate::testkit::SelftestOptions {
                seeds: parse_seeds(&rest, &[1, 2, 3])?,
                contexts: parse_contexts(&rest, &[256, 1024, 4096])?,
                bless: flag("--bless"),
                golden_dir: None,
            };
            let rep = crate::testkit::selftest(&hw, &sim, &opts);
            if rep.passed() {
                Ok(rep.render())
            } else {
                bail!("{}", rep.render())
            }
        }
        "operators" => {
            let mut out = String::from(
                "Registered causal operators (name / table name / kind / complexity):\n",
            );
            for op in ops::registry::global().iter() {
                out += &format!(
                    "  {:<18} {:<12} {:<10} {}\n",
                    op.name(),
                    op.paper_name(),
                    op.kind().name(),
                    op.complexity()
                );
            }
            out += "\nAdd one by implementing ops::CausalOperator and registering it \
                    (docs/ARCHITECTURE.md).\n";
            Ok(out)
        }
        "simulate" => {
            let entry = resolve_operator(
                rest.first().ok_or_else(|| anyhow!("usage: npuperf simulate <op> <N>"))?,
            )?;
            let arg = rest.get(1).ok_or_else(|| anyhow!("usage: npuperf simulate <op> <N>"))?;
            let n: usize = arg.parse().map_err(|e| {
                anyhow!("bad context length {arg:?} (usage: npuperf simulate <op> <N>): {e}")
            })?;
            let d_state = rest
                .iter()
                .position(|a| *a == "--d-state")
                .and_then(|i| rest.get(i + 1))
                .and_then(|s| s.parse().ok())
                .unwrap_or(16);
            let spec = WorkloadSpec::new(entry.kind(), n).with_d_state(d_state);
            let g = entry.lower(&spec, &hw, &sim);
            let r = npu::run(&g, &hw, &sim);
            let [dpu, dma, shave] = r.utilization();
            Ok(format!(
                "{spec} [op={}]\n  latency      {:.3} ms\n  throughput   {:.0} ops/s\n  \
                 utilization  DPU {:.1}% / DMA {:.1}% / SHAVE {:.1}%  -> {}\n  \
                 stall        {:.1}%\n  cache eff    {:.1}%\n  reuse        {:.3} ms\n  \
                 achieved     {:.1} GOP/s over {} DMA bytes\n  graph        {} prims",
                entry.name(),
                r.latency_ms(),
                r.throughput_ops_s(),
                dpu * 100.0,
                dma * 100.0,
                shave * 100.0,
                r.bottleneck(),
                r.stall.stall_frac() * 100.0,
                r.cache.efficiency() * 100.0,
                r.cache.reuse_ns / 1e6,
                r.achieved_gops(),
                r.dma_bytes,
                r.prim_count.iter().sum::<u64>(),
            ))
        }
        "roofline" => {
            let c = calibrate(&hw, &sim);
            let _ = Roofline::new(c);
            Ok(format!(
                "Effective ceilings (calibrated on the simulator, paper §IV-A):\n  \
                 pi_eff   {:.0} GOP/s  ({:.1}% of {:.0} nominal; paper: 500 = 5%)\n  \
                 beta_eff {:.2} GB/s   ({:.1}% of {:.0} nominal; paper: 3.2 = 5%)\n  \
                 I_crit   {:.0} Ops/Byte (paper: 156)\n\n{}",
                c.pi_eff_gops,
                100.0 * c.compute_derate(),
                c.pi_nominal_gops,
                c.beta_eff_gbps,
                100.0 * c.bandwidth_derate(),
                c.beta_nominal_gbps,
                c.i_crit(),
                figures::fig7(&hw, &sim)
            ))
        }
        "rank" => {
            let arg = rest.first().ok_or_else(|| anyhow!("usage: npuperf rank <N>"))?;
            let n: usize = arg.parse().map_err(|e| {
                anyhow!("bad context length {arg:?} (usage: npuperf rank <N>): {e}")
            })?;
            let router = coordinator::Router::standard();
            let mut out = format!(
                "Cost-model operator ranking at N={n} (full registry; run variants \
                 by name, e.g. `npuperf simulate retentive-chunked {n}`):\n"
            );
            for (i, (op, ms)) in router.rank_all(n, &hw, &sim).iter().enumerate() {
                out += &format!("  {}. {:<12} {:.3} ms\n", i + 1, op.paper_name(), ms);
            }
            Ok(out)
        }
        "chunking" => {
            let arg = rest.first().ok_or_else(|| anyhow!("usage: npuperf chunking <N>"))?;
            let n: usize = arg.parse().map_err(|e| {
                anyhow!("bad context length {arg:?} (usage: npuperf chunking <N>): {e}")
            })?;
            let mut out = format!("Chunked-prefill sweep for N={n} (d=64):\n");
            for c in [256usize, 512, 1024, 2048, 4096, 8192] {
                if c > n.max(256) {
                    continue;
                }
                let p = chunking::plan(n, c, 64, &hw);
                out += &format!(
                    "  C={:<5} chunks={:<3} peak={:<9} lat={:.2} ms{}\n",
                    p.chunk,
                    p.chunks,
                    crate::util::fmt::bytes(p.peak_bytes),
                    p.latency_ms,
                    if p.overflows { "  [scratchpad overflow]" } else { "" }
                );
            }
            let best = chunking::optimal_chunk(n, 64, &hw);
            out += &format!(
                "optimal chunk: {} ({}x peak-memory reduction vs monolithic; paper: 2048, 8x)\n",
                best.chunk,
                chunking::peak_memory_reduction(n, best.chunk, 64).round()
            );
            Ok(out)
        }
        "decode" => {
            let entry = resolve_operator(
                rest.first().ok_or_else(|| anyhow!("usage: npuperf decode <op> <N>"))?,
            )?;
            let arg = rest.get(1).ok_or_else(|| anyhow!("usage: npuperf decode <op> <N>"))?;
            let n: usize = arg.parse().map_err(|e| {
                anyhow!("bad context length {arg:?} (usage: npuperf decode <op> <N>): {e}")
            })?;
            let spec = WorkloadSpec::new(entry.kind(), n);
            let g = entry.lower_decode(&spec, &hw, &sim);
            let r = npu::run(&g, &hw, &sim);
            Ok(format!(
                "{} decode step at retained context N={n}:\n  \
                 per-token latency {:.3} ms -> {:.0} tokens/s sustained\n  \
                 bottleneck {} ({} prims)",
                entry.paper_name(),
                r.latency_ms(),
                r.throughput_ops_s(),
                r.bottleneck(),
                g.len(),
            ))
        }
        "trace" => {
            let entry = resolve_operator(
                rest.first()
                    .ok_or_else(|| anyhow!("usage: npuperf trace <op> <N> [--out F]"))?,
            )?;
            let arg =
                rest.get(1).ok_or_else(|| anyhow!("usage: npuperf trace <op> <N> [--out F]"))?;
            let n: usize = arg.parse().map_err(|e| {
                anyhow!("bad context length {arg:?} (usage: npuperf trace <op> <N> [--out F]): {e}")
            })?;
            let out = opt("--out").unwrap_or("trace.json").to_string();
            let spec = WorkloadSpec::new(entry.kind(), n);
            let g = entry.lower(&spec, &hw, &sim);
            let trace = npu::simulate(&g, &hw, &sim);
            let json = npu::trace_dump::to_chrome_trace(&g, &trace);
            std::fs::write(&out, &json)?;
            Ok(format!(
                "wrote {} events ({} bytes) to {out} — open in chrome://tracing or Perfetto",
                g.len(),
                json.len()
            ))
        }
        "energy" => {
            let n: usize =
                rest.first().and_then(|s| s.parse().ok()).unwrap_or(4096);
            let m = crate::model::EnergyModel::default();
            let mut out = format!(
                "Energy per inference at N={n} (35 W envelope, LPDDR5X DRAM):\n"
            );
            for op in OperatorKind::ALL {
                let spec = WorkloadSpec::new(op, n);
                let g = ops::lower(&spec, &hw, &sim);
                let r = npu::run(&g, &hw, &sim);
                let e = m.evaluate(&r);
                out += &format!(
                    "  {:<12} {:>10.3} mJ  avg {:>5.1} W  {:>8.1} GOP/J  \
                     (dpu {:.1}% shave {:.1}% dma {:.1}% dram {:.1}% idle {:.1}%)\n",
                    op.paper_name(),
                    e.total_mj(),
                    m.average_power_w(&r),
                    e.gops_per_joule(r.logical_ops),
                    100.0 * e.dpu_j / e.total_j(),
                    100.0 * e.shave_j / e.total_j(),
                    100.0 * e.dma_j / e.total_j(),
                    100.0 * e.dram_j / e.total_j(),
                    100.0 * e.idle_j / e.total_j(),
                );
            }
            Ok(out)
        }
        "plan-model" => {
            let n: usize =
                rest.first().and_then(|s| s.parse().ok()).unwrap_or(4096);
            Ok(crate::model::llm::feasibility_report(n, &hw, &sim))
        }
        "validate" => {
            let dir = rest.first().map(|s| s.to_string()).unwrap_or_else(|| "artifacts".into());
            let mut rt = crate::runtime::HloRuntime::new(&dir)?;
            let names: Vec<String> =
                rt.manifest().entries.iter().map(|e| e.name.clone()).collect();
            let mut out = format!("Validating {} artifacts on {}:\n", names.len(), rt.platform());
            let mut worst = 0.0f32;
            for name in names {
                let diff = rt.validate(&name)?;
                worst = worst.max(diff);
                out += &format!("  {name:<28} max|Δ| = {diff:.2e}\n");
            }
            out += &format!("worst deviation: {worst:.2e}\n");
            Ok(out)
        }
        "serve" => {
            // Positional artifact dir; flags like --hw are not a dir. An
            // explicit dir must exist (Coordinator::new errors if not);
            // with no dir, a missing ./artifacts falls back to a
            // simulation-only deployment instead of failing.
            let artifact_dir = match rest.first().filter(|s| !s.starts_with("--")) {
                Some(d) => Some(std::path::PathBuf::from(d)),
                None => {
                    let p = std::path::PathBuf::from("artifacts");
                    p.is_dir().then_some(p)
                }
            };
            let requests_n: Option<usize> = match opt("--requests") {
                Some(s) => {
                    let k = s.parse().map_err(|e| anyhow!("bad --requests {s:?}: {e}"))?;
                    if k == 0 {
                        bail!("--requests must be positive");
                    }
                    Some(k)
                }
                None => None,
            };
            let seed: u64 = match opt("--seed") {
                Some(s) => s.parse().map_err(|e| anyhow!("bad --seed {s:?}: {e}"))?,
                None => 1,
            };
            let trace_out = opt("--trace-out").map(str::to_string);
            let metrics_out = opt("--metrics-out").map(str::to_string);
            let events_out = opt("--events-out").map(str::to_string);
            let deterministic = flag("--deterministic");
            let devices = parse_devices(&rest)?;
            // Honor --hw/--sim overrides: the session-memory pool is
            // sized from the configured device, not the default one.
            let base = CoordinatorConfig::for_hw(hw, sim);
            // --deterministic mirrors testkit's deterministic
            // coordinator: batch size 1 (dispatch at submission order)
            // on a frozen ManualClock, so every latency/queue sample is
            // exactly zero and the metrics exposition is a pure function
            // of the seed — what the CI golden snapshot pins.
            let coord = Coordinator::new(CoordinatorConfig {
                artifact_dir,
                devices,
                trace: trace_out.is_some() || events_out.is_some(),
                max_batch: if deterministic { 1 } else { base.max_batch },
                max_wait_ns: if deterministic { 100_000 } else { base.max_wait_ns },
                clock: if deterministic {
                    Some(std::sync::Arc::new(coordinator::ManualClock::new())
                        as std::sync::Arc<dyn coordinator::Clock>)
                } else {
                    None
                },
                ..base
            })?;
            let reqs: Vec<Request> = match requests_n {
                // Seeded stream: same generator as the conformance suite.
                Some(k) => crate::testkit::workload::stream(
                    &crate::testkit::workload::StreamConfig {
                        requests: k,
                        ..crate::testkit::workload::StreamConfig::new(seed)
                    },
                ),
                // Legacy demo grid: every operator x a small context menu.
                None => {
                    let mut reqs = Vec::new();
                    for (i, op) in OperatorKind::ALL.iter().enumerate() {
                        for n in [128usize, 256, 512, 2048] {
                            reqs.push(Request {
                                spec: WorkloadSpec::new(*op, n),
                                session: i as u64 * 100 + n as u64,
                                inputs: None,
                            });
                        }
                    }
                    reqs
                }
            };
            let total = reqs.len();
            // Routed through the blessed clock module (the lint's
            // no-wall-clock rule): this is a real serving run, so host
            // time is the right thing to report.
            let t0 = coordinator::WallClock::new();
            let pendings = reqs
                .into_iter()
                .map(|r| coord.submit_async(r))
                .collect::<Result<Vec<_>>>()?;
            let (mut served, mut pjrt, mut shed) = (0usize, 0usize, 0usize);
            for p in pendings {
                match p.wait() {
                    Ok(r) => {
                        served += 1;
                        if r.backend == coordinator::BackendKind::Pjrt {
                            pjrt += 1;
                        }
                    }
                    Err(_) => shed += 1,
                }
            }
            let wall = t0.now_ns() as f64 / 1e9;
            let mut out = format!(
                "served {served}/{total} requests in {wall:.2}s ({:.1} req/s) — \
                 {pjrt} on PJRT, {} simulated, {shed} shed\n",
                total as f64 / wall.max(1e-9),
                served - pjrt,
            );
            if trace_out.is_some() || events_out.is_some() {
                let traces = coord.traces()?;
                if let Some(path) = &trace_out {
                    let json = crate::obs::chrome(&traces);
                    std::fs::write(path, &json)?;
                    out += &format!(
                        "wrote merged timeline ({} request spans, {} bytes) to {path} — \
                         open in chrome://tracing or Perfetto\n",
                        traces.len(),
                        json.len()
                    );
                }
                if let Some(path) = &events_out {
                    let log = crate::obs::jsonl(&traces);
                    std::fs::write(path, &log)?;
                    out += &format!("wrote {} JSONL events to {path}\n", log.lines().count());
                }
            }
            if let Some(path) = &metrics_out {
                let prom = coord.metrics_prometheus()?;
                std::fs::write(path, &prom)?;
                let samples = prom
                    .lines()
                    .filter(|l| !l.is_empty() && !l.starts_with('#'))
                    .count();
                out += &format!("wrote Prometheus exposition ({samples} samples) to {path}\n");
            }
            out += "\n";
            out += &coord.metrics_snapshot()?;
            if devices > 1 {
                out += "\n";
                out += &crate::report::sweep::fleet_occupancy_report(&coord.fleet()?);
            }
            Ok(out)
        }
        "obs" => {
            let path = rest
                .first()
                .filter(|s| !s.starts_with("--"))
                .ok_or_else(|| anyhow!("usage: npuperf obs <file>"))?;
            let data = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
            // Dispatch on extension first (".jsonl" event logs are many
            // JSON documents, one per line, which a whole-file parse
            // would reject as trailing content), then on leading byte.
            let kind = if path.ends_with(".jsonl") {
                "jsonl"
            } else if path.ends_with(".json") {
                "json"
            } else if path.ends_with(".prom") || path.ends_with(".txt") {
                "prom"
            } else {
                match data.trim_start().chars().next() {
                    Some('[') | Some('{') => "json",
                    _ => "prom",
                }
            };
            match kind {
                "jsonl" => {
                    let mut events = 0usize;
                    for (i, line) in data.lines().enumerate() {
                        if line.trim().is_empty() {
                            continue;
                        }
                        crate::obs::validate_json(line).map_err(|e| {
                            anyhow!("{path}:{}: invalid JSONL event: {e}", i + 1)
                        })?;
                        events += 1;
                    }
                    Ok(format!("{path}: OK — {events} valid JSONL events"))
                }
                "json" => {
                    crate::obs::validate_json(&data)
                        .map_err(|e| anyhow!("{path}: invalid JSON: {e}"))?;
                    let spans = data.matches("\"ph\":\"X\"").count();
                    let meta = data.matches("\"ph\":\"M\"").count();
                    if spans + meta > 0 {
                        Ok(format!(
                            "{path}: OK — Chrome trace with {spans} spans, \
                             {meta} metadata records ({} bytes)",
                            data.len()
                        ))
                    } else {
                        Ok(format!("{path}: OK — valid JSON ({} bytes)", data.len()))
                    }
                }
                _ => {
                    let lint = crate::obs::lint_prometheus(&data)
                        .map_err(|e| anyhow!("{path}: invalid Prometheus exposition: {e}"))?;
                    Ok(format!(
                        "{path}: OK — Prometheus exposition with {} samples, \
                         {} histogram series, {} HELP lines",
                        lint.samples, lint.histograms, lint.help_lines
                    ))
                }
            }
        }
        "lint" => {
            let root = rest.first().filter(|s| !s.starts_with("--")).copied().unwrap_or(".");
            let report = crate::analysis::lint_repo(std::path::Path::new(root))?;
            // Write the machine-readable reports before deciding
            // pass/fail so CI can upload them as artifacts on failure.
            if let Some(path) = opt("--json-out") {
                std::fs::write(path, report.render_jsonl())
                    .map_err(|e| anyhow!("cannot write {path}: {e}"))?;
            }
            if let Some(path) = opt("--sarif-out") {
                std::fs::write(path, crate::analysis::sarif::render_sarif(&report))
                    .map_err(|e| anyhow!("cannot write {path}: {e}"))?;
            }
            let current = crate::analysis::baseline::Baseline::from_report(&report);
            if flag("--update-baseline") {
                let path = opt("--baseline").unwrap_or("lint-baseline.json");
                std::fs::write(path, current.render())
                    .map_err(|e| anyhow!("cannot write {path}: {e}"))?;
                return Ok(format!(
                    "{}baseline updated: {path} now records {} entries\n",
                    report.render_human(),
                    current.entries.len()
                ));
            }
            if let Some(path) = opt("--baseline") {
                // Ratchet mode: the gate is "no growth over the recorded
                // baseline" instead of "zero active findings".
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("cannot read baseline {path}: {e}"))?;
                let recorded = crate::analysis::baseline::Baseline::parse(&text)
                    .map_err(|e| anyhow!("{path}: {e}"))?;
                let outcome = recorded.check(&current);
                let out = format!("{}{}", report.render_human(), outcome.render_human());
                if outcome.passed() {
                    return Ok(out);
                }
                bail!("{out}");
            }
            if report.is_clean() {
                Ok(report.render_human())
            } else {
                bail!("{}", report.render_human())
            }
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "npuperf — NPU causal-operator performance modeling (paper reproduction)
commands:
  tables | table <1..8>     paper tables, ours vs published values
  figures | masks [N]       paper figures 3-8
  sweep [--contexts A,B,C]  run every registered operator across a context
                            grid; per-cell bottleneck classification
  capacity [--contexts A,B] [--devices N]
                            max concurrently resident sessions per operator
                            x context under the paged session-memory pool;
                            --devices appends the linear fleet ceiling
  selftest [--seeds A,B,C] [--contexts A,B] [--bless]
                            deterministic conformance suite: differential
                            serve-vs-direct check, memory/batcher invariants,
                            replay determinism, golden fixtures (docs/TESTING.md)
  operators                 list the operator registry
  simulate <op> <N> [--d-state D] [--offload] [--no-double-buffer]
  decode <op> <N>           one autoregressive decode step + tokens/s
                            (<op> = kind alias or registry name, e.g.
                             retentive-chunked — see `operators`)
  trace <op> <N> [--out F]  export Chrome/Perfetto trace of the schedule
  energy [N]                per-operator energy model (35 W envelope)
  roofline                  effective-ceiling calibration + fig 7
  rank <N>                  cost-model operator ranking
  chunking <N>              chunked-prefill plan sweep
  plan-model [N]            whole-LLM deployment feasibility per operator
  validate [dir]            golden-validate AOT artifacts via PJRT
  serve [dir] [--requests K --seed S] [--devices N] [--deterministic]
        [--trace-out F] [--metrics-out F] [--events-out F]
                            serving run: seeded request stream (or the demo
                            grid), optional merged Perfetto timeline, JSONL
                            event log and Prometheus metrics exposition;
                            --devices sizes the execution fleet (session-
                            affine placement, per-device occupancy table);
                            --deterministic freezes the clock for byte-stable
                            metrics (CI golden snapshots)
  obs <file>                validate an exported artifact: Chrome trace /
                            metrics JSON, JSONL event log, or Prometheus
                            exposition
  lint [repo-root] [--json-out F] [--sarif-out F]
       [--baseline F] [--update-baseline]
                            project-specific static analysis: determinism,
                            panic-freedom (token + call-graph reachability),
                            unit consistency, iteration-order determinism,
                            metric/doc consistency (rules in docs/LINTS.md);
                            exits non-zero on findings, --json-out writes
                            JSONL, --sarif-out writes SARIF 2.1.0;
                            --baseline gates on the ratchet (findings may
                            only shrink), --update-baseline rewrites it
  hw                        hardware spec (table 1)
global flags: --hw-config FILE | --hw key=value (repeatable) — what-if hardware";

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cmd(args: &[&str]) -> Result<String> {
        run(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn help_lists_commands() {
        let out = run_cmd(&["help"]).unwrap();
        assert!(out.contains("simulate"));
        assert!(out.contains("roofline"));
        assert!(out.contains("sweep"));
        assert!(out.contains("operators"));
        assert!(out.contains("capacity"));
    }

    #[test]
    fn capacity_shows_collapse_and_flat_lines() {
        let out = run_cmd(&["capacity", "--contexts", "512,8192"]).unwrap();
        assert!(out.contains("Max sessions"), "{out}");
        assert!(out.contains("collapses with context"), "{out}");
        assert!(out.contains("flat"), "{out}");
        for name in ["Full Causal", "Retentive", "Toeplitz", "Linear", "Fourier"] {
            assert!(out.contains(name), "missing {name}:\n{out}");
        }
    }

    #[test]
    fn capacity_rejects_malformed_contexts() {
        assert!(run_cmd(&["capacity", "--contexts", "12a"]).is_err());
        assert!(run_cmd(&["capacity", "--contexts"]).is_err());
    }

    #[test]
    fn capacity_devices_appends_fleet_ceiling() {
        let one = run_cmd(&["capacity", "--contexts", "512,8192"]).unwrap();
        assert!(!one.contains("Fleet capacity"), "{one}");
        let four = run_cmd(&["capacity", "--contexts", "512,8192", "--devices", "4"]).unwrap();
        assert!(four.contains("Fleet capacity (4 devices"), "{four}");
    }

    #[test]
    fn devices_flag_is_validated() {
        assert_eq!(parse_devices(&["--devices", "4"]).unwrap(), 4);
        assert_eq!(parse_devices(&[]).unwrap(), 1);
        assert!(parse_devices(&["--devices", "0"]).is_err());
        assert!(parse_devices(&["--devices", "x"]).is_err());
        assert!(parse_devices(&["--devices"]).is_err());
    }

    #[test]
    fn sweep_classifies_every_registered_operator() {
        let out = run_cmd(&["sweep", "--contexts", "128,256"]).unwrap();
        for name in ["Full Causal", "Retentive", "Toeplitz", "Linear", "Fourier", "Ret-Chunked"]
        {
            assert!(out.contains(name), "missing {name}:\n{out}");
        }
        assert!(out.contains("Classification"), "{out}");
        assert!(out.contains("-bound"), "{out}");
    }

    #[test]
    fn sweep_rejects_malformed_contexts() {
        assert!(run_cmd(&["sweep", "--contexts", "12a"]).is_err());
        assert!(run_cmd(&["sweep", "--contexts", ""]).is_err());
        assert!(run_cmd(&["sweep", "--contexts"]).is_err(), "missing value must not be ignored");
    }

    #[test]
    fn contexts_are_deduped_and_sorted() {
        let rest = ["--contexts", "256,128,256"];
        assert_eq!(parse_contexts(&rest, &[512]).unwrap(), vec![128, 256]);
        let rest = ["--contexts", "8192,512,2048,512"];
        assert_eq!(parse_contexts(&rest, &[]).unwrap(), vec![512, 2048, 8192]);
        assert_eq!(parse_contexts(&[], &[512, 2048]).unwrap(), vec![512, 2048]);
    }

    #[test]
    fn zero_context_is_rejected() {
        let err = parse_contexts(&["--contexts", "0,128"], &[]).unwrap_err();
        assert!(err.to_string().contains("positive"), "{err}");
    }

    #[test]
    fn duplicate_contexts_give_identical_reports() {
        let a = run_cmd(&["sweep", "--contexts", "256,128,256"]).unwrap();
        let b = run_cmd(&["sweep", "--contexts", "128,256"]).unwrap();
        assert_eq!(a, b, "dupes and order must not change the report");
    }

    #[test]
    fn seeds_are_deduped_and_sorted() {
        assert_eq!(parse_seeds(&["--seeds", "3,1,3"], &[9]).unwrap(), vec![1, 3]);
        assert_eq!(parse_seeds(&[], &[1, 2, 3]).unwrap(), vec![1, 2, 3]);
        assert!(parse_seeds(&["--seeds", "x"], &[]).is_err());
        assert!(parse_seeds(&["--seeds"], &[]).is_err());
    }

    #[test]
    fn selftest_smoke_passes_on_defaults() {
        // Small grid/seed count so the smoke test stays fast; the golden
        // sections still use their own pinned grids.
        let out = run_cmd(&["selftest", "--seeds", "1", "--contexts", "128,256"]).unwrap();
        assert!(out.contains("result: PASS"), "{out}");
        assert!(out.contains("differential"), "{out}");
        assert!(out.contains("replay-determinism"), "{out}");
    }

    #[test]
    fn op_commands_accept_registry_variant_names() {
        let out = run_cmd(&["simulate", "retentive-chunked", "512"]).unwrap();
        assert!(out.contains("[op=retentive-chunked]"), "{out}");
        let out = run_cmd(&["decode", "retentive-chunked", "1024"]).unwrap();
        assert!(out.contains("Ret-Chunked"), "{out}");
    }

    #[test]
    fn operators_lists_registry() {
        let out = run_cmd(&["operators"]).unwrap();
        assert!(out.contains("retentive-chunked"), "{out}");
        assert!(out.contains("O(N^2*d)"), "{out}");
    }

    #[test]
    fn simulate_parses_and_reports() {
        let out = run_cmd(&["simulate", "toeplitz", "1024"]).unwrap();
        assert!(out.contains("latency"));
        assert!(out.contains("Toeplitz"));
    }

    #[test]
    fn simulate_flags() {
        let base = run_cmd(&["simulate", "fourier", "2048"]).unwrap();
        let off = run_cmd(&["simulate", "fourier", "2048", "--offload"]).unwrap();
        assert_ne!(base, off, "offload must change the report");
    }

    #[test]
    fn rank_orders_operators() {
        let out = run_cmd(&["rank", "4096"]).unwrap();
        assert!(out.contains("1. Toeplitz") || out.contains("1. Linear"));
    }

    #[test]
    fn chunking_reports_optimum() {
        let out = run_cmd(&["chunking", "16384"]).unwrap();
        assert!(out.contains("optimal chunk: 2048"), "{out}");
    }

    /// Per-test scratch dir (tests run concurrently in one process, so
    /// file names must not collide across tests).
    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("npuperf-cli-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn serve_writes_observability_artifacts() {
        let dir = scratch("artifacts");
        let trace = dir.join("serve.trace.json");
        let prom = dir.join("serve.metrics.prom");
        let events = dir.join("serve.events.jsonl");
        let out = run_cmd(&[
            "serve",
            "--requests",
            "8",
            "--seed",
            "1",
            "--deterministic",
            "--trace-out",
            trace.to_str().unwrap(),
            "--metrics-out",
            prom.to_str().unwrap(),
            "--events-out",
            events.to_str().unwrap(),
        ])
        .unwrap();
        assert!(out.contains("served 8/8"), "{out}");
        assert!(out.contains("wrote merged timeline"), "{out}");
        assert!(out.contains("Prometheus exposition"), "{out}");
        // Each artifact passes its own inspector, and the inspector
        // recognizes the trace as a Chrome trace specifically.
        for p in [&trace, &events, &prom] {
            let verdict = run_cmd(&["obs", p.to_str().unwrap()]).unwrap();
            assert!(verdict.contains("OK"), "{verdict}");
        }
        let verdict = run_cmd(&["obs", trace.to_str().unwrap()]).unwrap();
        assert!(verdict.contains("Chrome trace"), "{verdict}");
    }

    #[test]
    fn serve_deterministic_metrics_are_byte_stable() {
        let dir = scratch("stable");
        let (a, b) = (dir.join("a.prom"), dir.join("b.prom"));
        for p in [&a, &b] {
            run_cmd(&[
                "serve",
                "--requests",
                "6",
                "--seed",
                "42",
                "--deterministic",
                "--metrics-out",
                p.to_str().unwrap(),
            ])
            .unwrap();
        }
        let (ta, tb) =
            (std::fs::read_to_string(&a).unwrap(), std::fs::read_to_string(&b).unwrap());
        assert!(!ta.is_empty());
        assert_eq!(ta, tb, "frozen clock + seeded stream must reproduce bytes");
    }

    #[test]
    fn serve_rejects_bad_request_counts() {
        assert!(run_cmd(&["serve", "--requests", "0"]).is_err());
        assert!(run_cmd(&["serve", "--requests", "nope"]).is_err());
        assert!(run_cmd(&["serve", "--seed", "x", "--requests", "1"]).is_err());
        assert!(run_cmd(&["serve", "--requests", "1", "--devices", "0"]).is_err());
    }

    #[test]
    fn serve_multi_device_prints_fleet_occupancy() {
        let out = run_cmd(&[
            "serve",
            "--requests",
            "12",
            "--seed",
            "1",
            "--deterministic",
            "--devices",
            "4",
        ])
        .unwrap();
        assert!(out.contains("served 12/12"), "{out}");
        assert!(out.contains("devices=4"), "{out}");
        assert!(out.contains("Fleet occupancy: 4 devices"), "{out}");
        assert!(out.contains("d0") && out.contains("d3"), "{out}");
    }

    #[test]
    fn obs_rejects_malformed_artifacts() {
        let dir = scratch("malformed");
        let bad_json = dir.join("bad.json");
        std::fs::write(&bad_json, "[{\"name\":\"x\",]\n").unwrap();
        let err = run_cmd(&["obs", bad_json.to_str().unwrap()]).unwrap_err();
        assert!(err.to_string().contains("invalid JSON"), "{err}");
        let bad_prom = dir.join("bad.prom");
        std::fs::write(&bad_prom, "npuperf_x{oops 3\n").unwrap();
        let err = run_cmd(&["obs", bad_prom.to_str().unwrap()]).unwrap_err();
        assert!(err.to_string().contains("Prometheus"), "{err}");
        assert!(run_cmd(&["obs", dir.join("missing.json").to_str().unwrap()]).is_err());
        assert!(run_cmd(&["obs"]).is_err(), "obs needs a file argument");
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cmd(&["bogus"]).is_err());
    }

    #[test]
    fn bad_operator_errors() {
        assert!(run_cmd(&["simulate", "nope", "128"]).is_err());
    }

    #[test]
    fn numeric_args_fail_with_usage_hints() {
        for (args, hint) in [
            (&["table", "eight"][..], "npuperf table"),
            (&["simulate", "toeplitz", "12a"][..], "npuperf simulate"),
            (&["rank", "-3"][..], "npuperf rank"),
            (&["chunking", "big"][..], "npuperf chunking"),
            (&["decode", "toeplitz", "1k"][..], "npuperf decode"),
            (&["trace", "toeplitz", "x"][..], "npuperf trace"),
        ] {
            let err = run_cmd(args).unwrap_err().to_string();
            assert!(err.contains("usage:"), "{args:?}: {err}");
            assert!(err.contains(hint), "{args:?}: {err}");
        }
    }

    #[test]
    fn lint_self_hosts_at_head() {
        // The repo must pass its own lint (the json-out path is covered
        // here too: a clean run still writes the waived findings).
        let out_file = scratch("lint").join("report.jsonl");
        let out = run_cmd(&[
            "lint",
            env!("CARGO_MANIFEST_DIR"),
            "--json-out",
            out_file.to_str().unwrap(),
        ])
        .unwrap();
        assert!(out.contains("clean"), "{out}");
        let jsonl = std::fs::read_to_string(&out_file).unwrap();
        for line in jsonl.lines() {
            crate::obs::validate_json(line).expect(line);
        }
    }

    #[test]
    fn lint_sarif_and_ratchet_flags_roundtrip() {
        let dir = scratch("lint-ratchet");
        let sarif = dir.join("lint.sarif");
        let base = dir.join("baseline.json");
        // --update-baseline records the current (clean) run...
        let out = run_cmd(&[
            "lint",
            env!("CARGO_MANIFEST_DIR"),
            "--sarif-out",
            sarif.to_str().unwrap(),
            "--baseline",
            base.to_str().unwrap(),
            "--update-baseline",
        ])
        .unwrap();
        assert!(out.contains("baseline updated"), "{out}");
        let doc = std::fs::read_to_string(&sarif).unwrap();
        crate::obs::validate_json(doc.trim()).expect("SARIF must be valid JSON");
        // ...and gating against what was just recorded passes.
        let out =
            run_cmd(&["lint", env!("CARGO_MANIFEST_DIR"), "--baseline", base.to_str().unwrap()])
                .unwrap();
        assert!(out.contains("clean"), "{out}");
    }

    #[test]
    fn lint_rejects_roots_without_sources() {
        let err = run_cmd(&["lint", scratch("lint-empty").to_str().unwrap()]).unwrap_err();
        assert!(err.to_string().contains("rust/src"), "{err}");
    }
}
