//! Band-limited Toeplitz structured attention lowering.
//!
//! The paper's best citizen (§V "Hardware-Aligned Sparse Attention"): the
//! constant-diagonal decay confines attention to a band, so each query
//! block touches one fixed-size K/V window. Consecutive windows overlap by
//! `band` rows — the LRU tile pool turns that overlap into scratchpad hits
//! (87.9 % cache efficiency in Table V), control flow is static, and the
//! banded matmul maps straight onto the systolic array. Compute and
//! traffic are O(N·band·d): the near-linear latency row of Table III.

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};

use super::flops::TOEPLITZ_BAND;
use super::graph::{BufferAccess, EltKind, NodeId, OpGraph, PrimOp, TransferDir};
use super::tiling::{tiles, Lowering};

/// Effective band: the paper's d_state sweep (Table VI) widens the retained
/// window proportionally — for Toeplitz the band *is* the state.
pub fn band_for(spec: &WorkloadSpec) -> usize {
    TOEPLITZ_BAND * (spec.d_state.max(1)).div_ceil(16)
}

pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let t = sim.tile;
    let tq = tiles(n, t);
    let eb = sim.elem_bytes;
    let band = band_for(spec).min(n);
    let window = (band + t).min(n);
    let wt = tiles(window, t); // tiles per K/V window
    let mut l = Lowering::new(format!("toeplitz N={n} d={d} band={band}"), hw, sim);

    let qkv_bytes = (n * d) as u64 * eb;
    let tile_rows_bytes = (t * d) as u64 * eb;

    let (q_buf, q_pull, _) = l.stage_input(qkv_bytes.min(l.spad.free_bytes() / 2));
    let k_buf = l.b.buffer();
    let v_buf = l.b.buffer();
    let score_buf = l.b.buffer(); // 128×window — always scratchpad-resident
    let out_buf = l.b.buffer();

    let mut prev_tail: Option<NodeId> = None;
    for qi in 0..tq {
        // Window tiles [start, start+wt): only the leading tile(s) are new;
        // the overlap with the previous window is already resident (hits).
        let new_tiles = if qi == 0 { wt } else { 1 };
        let mut deps = vec![q_pull];
        // Without double buffering the next window's pulls wait for this
        // block's writeback (ring-buffer reuse); with it they prefetch.
        if !l.sim.double_buffer {
            if let Some(p) = prev_tail {
                deps.push(p);
            }
        }
        let mut k_pulls = Vec::new();
        for _ in 0..new_tiles {
            k_pulls.push(l.b.push(
                PrimOp::Transfer { bytes: tile_rows_bytes, dir: TransferDir::Pull, fresh_alloc: false },
                deps.clone(),
                vec![BufferAccess::new(k_buf, tile_rows_bytes, false)],
                vec![],
            ));
            k_pulls.push(l.b.push(
                PrimOp::Transfer { bytes: tile_rows_bytes, dir: TransferDir::Pull, fresh_alloc: false },
                deps.clone(),
                vec![BufferAccess::new(v_buf, tile_rows_bytes, false)],
                vec![],
            ));
        }
        // Banded QK^T over the window (one fused DPU descriptor).
        let mut reads = vec![BufferAccess::new(q_buf, tile_rows_bytes, true)];
        reads.extend(l.reads(k_buf, tile_rows_bytes, wt, true));
        let mm = l.b.push(
            PrimOp::MatMul { m: t.min(n), n: window, k: d },
            k_pulls,
            reads,
            vec![BufferAccess::new(score_buf, (t * window) as u64 * eb, true)],
        );
        // Decay weights gamma^|i-j| are a 1-D LUT along the diagonal —
        // simple-class multiply (no per-element exp: constant diagonals).
        let decay = l.b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: t.min(n) * window },
            vec![mm],
            vec![BufferAccess::new(score_buf, (t * window) as u64 * eb, true)],
            vec![BufferAccess::new(score_buf, (t * window) as u64 * eb, true)],
        );
        // Softmax over the window only (short rows: single-pass reduce).
        let sm = l.b.push(
            PrimOp::Softmax { rows: t.min(n), cols: window },
            vec![decay],
            l.reads(score_buf, (t * t) as u64 * eb, wt, true),
            vec![BufferAccess::new(score_buf, (t * window) as u64 * eb, true)],
        );
        // PV over the window.
        let mut reads = l.reads(score_buf, (t * t) as u64 * eb, wt, true);
        reads.extend(l.reads(v_buf, tile_rows_bytes, wt, true));
        let pv = l.b.push(
            PrimOp::MatMul { m: t.min(n), n: d, k: window },
            vec![sm],
            reads,
            vec![BufferAccess::new(out_buf, tile_rows_bytes, true)],
        );
        let push = l.b.push(
            PrimOp::Transfer { bytes: tile_rows_bytes, dir: TransferDir::Push, fresh_alloc: false },
            vec![pv],
            vec![],
            vec![],
        );
        prev_tail = Some(push);
    }

    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::npu;

    fn run(n: usize) -> npu::ExecReport {
        let spec = WorkloadSpec::new(OperatorKind::Toeplitz, n);
        let g = lower(&spec, &NpuConfig::default(), &SimConfig::default());
        g.validate().unwrap();
        npu::run(&g, &NpuConfig::default(), &SimConfig::default())
    }

    #[test]
    fn latency_scales_near_linearly() {
        let r1 = run(2048);
        let r2 = run(8192);
        let ratio = r2.span_ns / r1.span_ns;
        assert!((3.0..6.0).contains(&ratio), "4x context => ~4x latency: {ratio}");
    }

    #[test]
    fn cache_efficiency_is_high() {
        // Table V: 87.9 % — window overlap reuse.
        let r = run(4096);
        assert!(r.cache.efficiency() > 0.7, "cache eff {}", r.cache.efficiency());
    }

    #[test]
    fn stall_is_moderate() {
        // Table V: 36.4 % — static streaming schedule.
        let r = run(4096);
        assert!(r.stall.stall_frac() < 0.6, "stall {}", r.stall.stall_frac());
    }

    #[test]
    fn band_widens_with_d_state() {
        let base = WorkloadSpec::new(OperatorKind::Toeplitz, 4096);
        let wide = base.with_d_state(128);
        assert_eq!(band_for(&base), 128);
        assert_eq!(band_for(&wide), 1024);
    }

    #[test]
    fn d_state_sweep_raises_latency() {
        // Table VI: 0.65 ms -> 2.73 ms for d_state 16 -> 128 at N=4096.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let lo = WorkloadSpec::new(OperatorKind::Toeplitz, 4096);
        let hi = lo.with_d_state(128);
        let rl = npu::run(&lower(&lo, &hw, &sim), &hw, &sim);
        let rh = npu::run(&lower(&hi, &hw, &sim), &hw, &sim);
        let ratio = rh.span_ns / rl.span_ns;
        assert!((2.0..8.0).contains(&ratio), "d_state ratio {ratio}");
    }

    #[test]
    fn much_faster_than_causal() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let causal = {
            let spec = WorkloadSpec::new(OperatorKind::Causal, 4096);
            npu::run(&super::super::causal::lower(&spec, &hw, &sim), &hw, &sim)
        };
        let toe = run(4096);
        assert!(
            causal.span_ns / toe.span_ns > 10.0,
            "paper: ~50-100x at 4096; got {}",
            causal.span_ns / toe.span_ns
        );
    }
}
