//! Fourier structured attention (FSA) lowering.
//!
//! The transform has no efficient systolic mapping ("FFT overheads violate
//! NPU execution assumptions", §IV-D): the vendor path realizes each
//! r/iDFT as a *per-k-tile sequence* of small matmul descriptors — no
//! k-chaining, one dispatch per 128-step butterfly stage — with the DFT
//! weight tiles streamed from DRAM, plus hierarchical spectrum-merge
//! concats (the "state management" of Table II) that each allocate a fresh
//! contiguous buffer. Result: DPU-bound at short N, DMA-heavy in the
//! mid-range, and catastrophic scaling at N = 8192 (347 ms in Table III).

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};

use super::graph::{BufferAccess, EltKind, NodeId, OpGraph, PrimOp, TransferDir};
use super::tiling::{tiles, Lowering};

/// Chunk length for spectrum state management.
const SPECTRUM_CHUNK: usize = 512;

pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let t = sim.tile;
    let tn = tiles(n, t);
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("fourier N={n} d={d}"), hw, sim);

    let qkv_bytes = (n * d) as u64 * eb;
    let weight_tile_bytes = (t * t) as u64 * eb;

    let (q_buf, q_pull, _) = l.stage_input(qkv_bytes);
    let (k_buf, k_pull, _) = l.stage_input(qkv_bytes);
    let (v_buf, v_pull, _) = l.stage_input(qkv_bytes);
    let w_buf = l.b.buffer(); // DFT weight tiles (streamed, never resident)
    let spec_buf = l.b.buffer(); // spectra (re+im), f32
    let out_buf = l.b.buffer();

    // Transform units: 3 forward (q, k, v — real input ⇒ re+im output, 2
    // real matmul passes each) + inverse over d_state-blocked channels
    // (complex input ⇒ 4 real matmul passes per 16-channel group).
    let inverse_groups = spec.d_state.max(1).div_ceil(16);
    let transform_passes = 3 * 2 + 4 * inverse_groups;

    let mut transform_tails: Vec<NodeId> = Vec::new();
    for pass in 0..transform_passes {
        let (src_buf, src_pull) = match pass {
            0 | 1 => (q_buf, q_pull),
            2 | 3 => (k_buf, k_pull),
            4 | 5 => (v_buf, v_pull),
            _ => (spec_buf, v_pull),
        };
        let mut last: Option<NodeId> = None;
        // Per (m-tile, k-tile) descriptor: the no-k-chaining pathology.
        for _mi in 0..tn {
            for _ki in 0..tn {
                let w_pull = l.b.push(
                    PrimOp::Transfer {
                        bytes: weight_tile_bytes,
                        dir: TransferDir::Pull,
                        fresh_alloc: false,
                    },
                    last.map(|x| vec![x]).unwrap_or_default(),
                    vec![BufferAccess::new(w_buf, weight_tile_bytes, false)],
                    vec![],
                );
                let mm = l.b.push(
                    PrimOp::MatMul { m: t.min(n), n: d.min(t), k: t.min(n) },
                    vec![w_pull, src_pull],
                    vec![
                        BufferAccess::new(w_buf, weight_tile_bytes, false),
                        BufferAccess::new(src_buf, (t.min(n) * d) as u64 * eb, true),
                    ],
                    vec![BufferAccess::new(spec_buf, (t.min(n) * d) as u64 * 4, true)],
                );
                last = Some(mm);
            }
        }
        if let Some(x) = last {
            transform_tails.push(x);
        }
    }

    // Spectrum product on SHAVE: out = Qw ⊙ conj(Kw) ⊙ Vw over re/im
    // planes — 6 multiplies + 2 adds per frequency-channel element, one
    // dispatch per 16-channel group, exp-class rate (the strided complex
    // access pattern defeats simple vector streaming).
    let groups = d.div_ceil(16);
    let mut spectrum_tail = Vec::with_capacity(groups);
    for _ in 0..groups {
        let node = l.b.push(
            PrimOp::EltWise { kind: EltKind::Exp, elems: 8 * (n / 2 + 1) * 16 },
            transform_tails.clone(),
            l.reads(spec_buf, (n as u64 / 2 + 1) * 4, 6, false),
            vec![BufferAccess::new(spec_buf, (n as u64) * 16 * 4, true)],
        );
        spectrum_tail.push(node);
    }

    // Chunk-pair spectrum-merge concats: partial chunk spectra are
    // pairwise reduced, each merge gathering into a freshly allocated
    // contiguous buffer. The count grows quadratically in the chunk count
    // — the §III-B "concat operations required to manage the state" that
    // saturate the DMA engine at mid-range contexts.
    let chunks = n.div_ceil(sim.tile);
    let merges = (chunks * chunks).max(1);
    let merge_bytes = (SPECTRUM_CHUNK.min(n) as u64 * d as u64 / 2) * 4;
    let mut concat_deps = spectrum_tail;
    let host_offload = l.sim.offload_concat_to_cpu;
    for _ in 0..merges {
        let node = if host_offload {
            // §V ablation: concat on the host CPU frees the DMA engine.
            l.b.push(PrimOp::HostOp { bytes: merge_bytes }, concat_deps.clone(), vec![], vec![])
        } else {
            l.b.push(
                PrimOp::Concat { bytes: merge_bytes },
                concat_deps.clone(),
                vec![BufferAccess::new(spec_buf, merge_bytes, false)],
                vec![BufferAccess::new(spec_buf, merge_bytes, false)],
            )
        };
        concat_deps = vec![node];
    }

    // Output writeback (persistent I/O buffer — no alloc penalty).
    l.b.push(
        PrimOp::Transfer { bytes: qkv_bytes, dir: TransferDir::Push, fresh_alloc: false },
        concat_deps,
        vec![],
        vec![BufferAccess::new(out_buf, qkv_bytes, false)],
    );

    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::npu;

    fn run_cfg(n: usize, sim: &SimConfig) -> npu::ExecReport {
        let spec = WorkloadSpec::new(OperatorKind::Fourier, n);
        let g = lower(&spec, &NpuConfig::default(), sim);
        g.validate().unwrap();
        npu::run(&g, &NpuConfig::default(), sim)
    }

    fn run(n: usize) -> npu::ExecReport {
        run_cfg(n, &SimConfig::default())
    }

    #[test]
    fn worst_scaling_of_all_operators() {
        // Table III: Fourier 347.79 ms at 8192 vs Toeplitz 1.01 ms.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let toe = {
            let spec = WorkloadSpec::new(OperatorKind::Toeplitz, 4096);
            npu::run(&super::super::toeplitz::lower(&spec, &hw, &sim), &hw, &sim)
        };
        let fsa = run(4096);
        assert!(fsa.span_ns / toe.span_ns > 10.0, "ratio {}", fsa.span_ns / toe.span_ns);
    }

    #[test]
    fn dpu_bound_at_short_context() {
        // Table II: DPU 56-61 % at N=128-256.
        let r = run(128);
        let [dpu, _, _] = r.utilization();
        assert!(dpu > 0.4, "short-context DPU share {dpu}");
    }

    #[test]
    fn dma_share_peaks_midrange() {
        // Table II: DMA ~47-53 % at 512-4096.
        let short = run(128);
        let mid = run(2048);
        let [_, dma_short, _] = short.utilization();
        let [_, dma_mid, _] = mid.utilization();
        assert!(dma_mid > dma_short, "DMA share must grow into the midrange");
        assert!(dma_mid > 0.2, "midrange DMA share {dma_mid}");
    }

    #[test]
    fn quadratic_latency_growth() {
        let r1 = run(2048);
        let r2 = run(4096);
        let ratio = r2.span_ns / r1.span_ns;
        assert!(ratio > 3.0, "DFT-matmul growth: {ratio}");
    }

    #[test]
    fn offload_ablation_reduces_latency() {
        // §V: CPU concat offload cut Fourier latency by 32 %.
        let base = run_cfg(4096, &SimConfig::default());
        let off = run_cfg(4096, &SimConfig::default().with_offload(true));
        assert!(
            off.span_ns < base.span_ns,
            "offload {} !< base {}",
            off.span_ns,
            base.span_ns
        );
    }

    #[test]
    fn d_state_sweep_scales_inverse_transform() {
        // Table VI: 15.5 -> 56.8 ms (x3.7) for d_state 16 -> 128.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let lo = WorkloadSpec::new(OperatorKind::Fourier, 2048);
        let hi = lo.with_d_state(128);
        let rl = npu::run(&lower(&lo, &hw, &sim), &hw, &sim);
        let rh = npu::run(&lower(&hi, &hw, &sim), &hw, &sim);
        let ratio = rh.span_ns / rl.span_ns;
        assert!((1.8..6.0).contains(&ratio), "d_state ratio {ratio}");
    }
}
