//! Operator IR and lowerings.
//!
//! Each causal operator (paper §II-C) is lowered — exactly like the vendor
//! NPU compiler would — into a DAG of *primitive ops* scheduled onto the
//! NPU's engines:
//!
//! - [`PrimOp::MatMul`]   → DPU (systolic array)
//! - [`PrimOp::EltWise`] / [`PrimOp::Softmax`] → SHAVE vector cores
//! - [`PrimOp::Transfer`] / [`PrimOp::Concat`] → DMA engine
//! - [`PrimOp::HostOp`]   → host CPU (§V concat-offload ablation)
//!
//! The lowering makes all data movement *explicit*: every operand that is
//! not resident in the 4 MB scratchpad appears as a `Transfer` node, and
//! every buffer access is tagged hit/miss by the scratchpad allocator in
//! [`tiling`]. The event-driven simulator in [`crate::npu`] then executes
//! the DAG and the paper's utilization/stall/cache numbers fall out.

pub mod causal;
pub mod decode;
pub mod flops;
pub mod fourier;
pub mod graph;
pub mod linear;
pub mod masks;
pub mod retentive;
pub mod retentive_chunked;
pub mod tiling;
pub mod toeplitz;

pub use graph::{
    BufferAccess, BufferId, Engine, EltKind, GraphBuilder, Node, NodeId, OpGraph, PrimOp,
    TransferDir,
};

use crate::config::{OperatorKind, SimConfig, WorkloadSpec};
use crate::config::hw::NpuConfig;

/// Lower a workload to its primitive-op DAG (dispatch over operator kind).
pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    match spec.op {
        OperatorKind::Causal => causal::lower(spec, hw, sim),
        OperatorKind::Retentive => retentive::lower(spec, hw, sim),
        OperatorKind::Toeplitz => toeplitz::lower(spec, hw, sim),
        OperatorKind::Linear => linear::lower(spec, hw, sim),
        OperatorKind::Fourier => fourier::lower(spec, hw, sim),
    }
}
