//! Operator IR, lowerings, and the operator registry.
//!
//! Each causal operator (paper §II-C) is lowered — exactly like the vendor
//! NPU compiler would — into a DAG of *primitive ops* scheduled onto the
//! NPU's engines:
//!
//! - [`PrimOp::MatMul`]   → DPU (systolic array)
//! - [`PrimOp::EltWise`] / [`PrimOp::Softmax`] → SHAVE vector cores
//! - [`PrimOp::Transfer`] / [`PrimOp::Concat`] → DMA engine
//! - [`PrimOp::HostOp`]   → host CPU (§V concat-offload ablation)
//!
//! The lowering makes all data movement *explicit*: every operand that is
//! not resident in the 4 MB scratchpad appears as a `Transfer` node, and
//! every buffer access is tagged hit/miss by the scratchpad allocator in
//! [`tiling`]. The event-driven simulator in [`crate::npu`] then executes
//! the DAG and the paper's utilization/stall/cache numbers fall out.
//!
//! Dispatch is owned by the [`registry`]: every operator is a
//! [`CausalOperator`] implementation registered by name in an
//! [`OperatorRegistry`], and the pipeline entry points ([`lower`],
//! [`lower_decode`]) resolve the workload's kind through the process-wide
//! registry instead of hardcoded `match` arms. New operators plug in by
//! implementing the trait and registering — no pipeline changes (see
//! `docs/ARCHITECTURE.md`).

pub mod causal;
pub mod decode;
pub mod flops;
pub mod fourier;
pub mod graph;
pub mod linear;
pub mod masks;
pub mod registry;
pub mod retentive;
pub mod retentive_chunked;
pub mod tiling;
pub mod toeplitz;

pub use graph::{
    BufferAccess, BufferId, Engine, EltKind, GraphBuilder, Node, NodeId, OpGraph, PrimOp,
    TransferDir,
};
pub use registry::{classify, BoundClass, CausalOperator, OperatorRegistry};

use crate::config::hw::NpuConfig;
use crate::config::{SimConfig, WorkloadSpec};

/// Lower a prefill workload to its primitive-op DAG via the operator
/// registry (kind-based dispatch to the canonical lowering).
pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    registry::global().for_kind(spec.op).lower(spec, hw, sim)
}

/// Lower one autoregressive decode step via the operator registry.
pub fn lower_decode(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    registry::global().for_kind(spec.op).lower_decode(spec, hw, sim)
}
