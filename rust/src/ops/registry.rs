//! First-class operator registry — the dispatch hub of the serving stack.
//!
//! Every causal inference operator the system can serve is described by one
//! [`CausalOperator`] implementation: its prefill lowering, its decode-step
//! lowering, its analytical FLOP/byte profile, and its cost-model latency
//! estimate. Implementations are registered **by name** in an
//! [`OperatorRegistry`] and enumerated at runtime, so the pipeline layers
//! (CLI → coordinator → NPU engine → report) never hardcode `match` arms
//! over operator kinds: adding an operator is *implement the trait + one
//! [`OperatorRegistry::register`] call* (see `docs/ARCHITECTURE.md` for the
//! full walkthrough).
//!
//! The module also owns the paper's bottleneck taxonomy ([`BoundClass`],
//! [`classify`]): each simulated run is classified as memory-bound,
//! compute-bound, vector-compute-bound, or data-movement-bound from its
//! engine-utilization split, pipeline-stall fraction, and scratchpad cache
//! efficiency — the §IV story that quadratic attention thrashes memory
//! while the sub-quadratic operators fail in operator-specific ways.
//!
//! The built-in inventory covers the paper's five operators plus the §V
//! co-design variant:
//!
//! | name                | kind      | lowering                        |
//! |---------------------|-----------|---------------------------------|
//! | `causal`            | Causal    | [`super::causal::lower`]        |
//! | `retentive`         | Retentive | [`super::retentive::lower`]     |
//! | `toeplitz`          | Toeplitz  | [`super::toeplitz::lower`]      |
//! | `linear`            | Linear    | [`super::linear::lower`]        |
//! | `fourier`           | Fourier   | [`super::fourier::lower`]       |
//! | `retentive-chunked` | Retentive | [`super::retentive_chunked::lower`] |

use std::fmt;
use std::sync::OnceLock;

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::npu::ExecReport;

use super::flops::{self, OpProfile};
use super::graph::OpGraph;
use super::{causal, decode, fourier, linear, retentive, retentive_chunked, toeplitz};

/// One pluggable causal inference operator.
///
/// The contract every implementation must satisfy:
///
/// - [`lower`](CausalOperator::lower) emits a valid topologically-ordered
///   [`OpGraph`] for a prefill invocation at `spec` (checked by
///   `OpGraph::validate` in tests),
/// - [`lower_decode`](CausalOperator::lower_decode) emits the graph of one
///   autoregressive decode step at retained context `spec.n`,
/// - [`profile`](CausalOperator::profile) returns the analytical op/byte
///   accounting used for roofline placement (paper Table VII convention),
/// - [`predict_ms`](CausalOperator::predict_ms) is the cost-model latency
///   estimate the router ranks operators by; the default simulates the
///   lowered graph.
pub trait CausalOperator: Send + Sync {
    /// Registry key, lower-case and stable (e.g. `"toeplitz"`).
    fn name(&self) -> &'static str;

    /// Display name used in report tables (e.g. `"Toeplitz"`).
    fn paper_name(&self) -> &'static str;

    /// The workload-spec kind this operator executes. Several registry
    /// entries may share a kind (e.g. `retentive` and `retentive-chunked`
    /// are two lowerings of the same retention workload).
    fn kind(&self) -> OperatorKind;

    /// Asymptotic cost class, for the sweep report (e.g. `"O(N^2*d)"`).
    fn complexity(&self) -> &'static str;

    /// Lower a prefill invocation to its primitive-op DAG.
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph;

    /// Lower one autoregressive decode step at retained context `spec.n`.
    fn lower_decode(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        decode::lower_step(&WorkloadSpec { op: self.kind(), ..*spec }, hw, sim)
    }

    /// Analytical FLOP / DMA-byte accounting (roofline x-axis).
    fn profile(&self, spec: &WorkloadSpec, elem_bytes: u64) -> OpProfile {
        flops::profile(&WorkloadSpec { op: self.kind(), ..*spec }, elem_bytes)
    }

    /// Cost-model latency estimate in milliseconds (router ranking).
    fn predict_ms(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> f64 {
        crate::npu::run(&self.lower(spec, hw, sim), hw, sim).latency_ms()
    }

    /// Persistent session-state bytes retained after `position` tokens of
    /// context — the growth curve the paged session-memory pool
    /// (`crate::memory`) charges this operator. This is what turns the
    /// cost model into a *capacity* model: attention-class KV grows
    /// O(N·d), retention/SSM state stays O(d·d) constant, and banded
    /// operators keep an O(band·d) ring buffer.
    ///
    /// The default models an explicit fp16 K/V cache (the quadratic
    /// baseline's behavior); constant-state operators must override it or
    /// the pool will overcharge them into early eviction.
    fn state_footprint(&self, spec: &WorkloadSpec, position: usize) -> u64 {
        // K + V rows at fp16.
        2 * position as u64 * spec.d_head as u64 * 2
    }
}

/// Bottleneck classification per the paper's taxonomy (§IV, Table II/V).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BoundClass {
    /// DMA-dominated with catastrophic cache efficiency and pipeline stalls
    /// — the spilling quadratic-attention signature (Table V row 1).
    Memory,
    /// DPU (systolic array) dominated: the operator keeps the matmul engine
    /// fed — the well-matched Toeplitz/Linear regime.
    Compute,
    /// SHAVE vector cores dominate — Retentive's decay-epilogue wall past
    /// N ≈ 1024 (Table II).
    VectorCompute,
    /// DMA-dominated but streaming (healthy cache): deliberate data
    /// movement, e.g. Fourier's DFT weight streams + spectrum concats.
    DataMovement,
}

impl BoundClass {
    /// Every class, in taxonomy order — lets metrics consumers iterate
    /// the label space without hardcoding it.
    pub const ALL: [BoundClass; 4] = [
        BoundClass::Memory,
        BoundClass::Compute,
        BoundClass::VectorCompute,
        BoundClass::DataMovement,
    ];

    /// Stable lower-case label used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            BoundClass::Memory => "memory-bound",
            BoundClass::Compute => "compute-bound",
            BoundClass::VectorCompute => "vector-compute-bound",
            BoundClass::DataMovement => "data-movement-bound",
        }
    }
}

impl fmt::Display for BoundClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Classify a simulated run into the paper's bottleneck taxonomy.
///
/// Rules, in order:
/// 1. SHAVE holds the largest busy share → [`BoundClass::VectorCompute`].
/// 2. DMA ≥ DPU with compute stalled (> 60 %) *and* cache-hostile
///    (< 20 % scratchpad hit rate) → [`BoundClass::Memory`] — traffic that
///    exists only because the working set thrashes (score-matrix spills).
/// 3. Otherwise DMA > DPU → [`BoundClass::DataMovement`] — the operator
///    genuinely streams data (weights, spectra) but reuses what it stages.
/// 4. Otherwise → [`BoundClass::Compute`].
pub fn classify(report: &ExecReport) -> BoundClass {
    let [dpu, dma, shave] = report.utilization();
    if dpu == 0.0 && dma == 0.0 && shave == 0.0 {
        return BoundClass::Compute; // empty / degenerate graph
    }
    if shave >= dpu && shave >= dma {
        return BoundClass::VectorCompute;
    }
    if dma >= dpu && report.stall.stall_frac() > 0.6 && report.cache.efficiency() < 0.2 {
        return BoundClass::Memory;
    }
    if dma > dpu {
        return BoundClass::DataMovement;
    }
    BoundClass::Compute
}

// ---- Built-in operator implementations ---------------------------------

/// Full Causal Mask attention — the quadratic, phase-separated baseline.
struct CausalAttention;

impl CausalOperator for CausalAttention {
    fn name(&self) -> &'static str {
        "causal"
    }
    fn paper_name(&self) -> &'static str {
        "Full Causal"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Causal
    }
    fn complexity(&self) -> &'static str {
        "O(N^2*d)"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        causal::lower(spec, hw, sim)
    }
}

/// Retentive decay attention (DRA) — fused quadratic kernel, the paper's
/// measured form.
struct RetentiveAttention;

impl CausalOperator for RetentiveAttention {
    fn name(&self) -> &'static str {
        "retentive"
    }
    fn paper_name(&self) -> &'static str {
        "Retentive"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Retentive
    }
    fn complexity(&self) -> &'static str {
        "O(N^2*d)"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        retentive::lower(spec, hw, sim)
    }
    fn state_footprint(&self, spec: &WorkloadSpec, _position: usize) -> u64 {
        // The retention formulation carries a d×d decayed-state
        // accumulator across steps (f32) — constant in context, however
        // the prefill kernel is lowered.
        (spec.d_head * spec.d_head) as u64 * 4
    }
}

/// Band-limited Toeplitz structured attention.
struct ToeplitzAttention;

impl CausalOperator for ToeplitzAttention {
    fn name(&self) -> &'static str {
        "toeplitz"
    }
    fn paper_name(&self) -> &'static str {
        "Toeplitz"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Toeplitz
    }
    fn complexity(&self) -> &'static str {
        "O(N*B*d)"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        toeplitz::lower(spec, hw, sim)
    }
    fn state_footprint(&self, spec: &WorkloadSpec, position: usize) -> u64 {
        // Banded window: an O(band·d) fp16 K/V ring buffer — grows until
        // the band fills, then stays flat (the causal-conv analogue).
        2 * position.min(toeplitz::band_for(spec)) as u64 * spec.d_head as u64 * 2
    }
}

/// Causal linear attention with low-rank phi (chunked, state-carrying).
struct LinearAttention;

impl CausalOperator for LinearAttention {
    fn name(&self) -> &'static str {
        "linear"
    }
    fn paper_name(&self) -> &'static str {
        "Linear"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Linear
    }
    fn complexity(&self) -> &'static str {
        "O(N*C*(r+d))"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        linear::lower(spec, hw, sim)
    }
    fn state_footprint(&self, spec: &WorkloadSpec, _position: usize) -> u64 {
        // Compressed recurrent state: the d_head × d_state f32 outer
        // -product accumulator — context-independent (Fig 1's flat line).
        (spec.d_head * spec.d_state) as u64 * 4
    }
}

/// Fourier structured attention (frequency-domain product).
struct FourierAttention;

impl CausalOperator for FourierAttention {
    fn name(&self) -> &'static str {
        "fourier"
    }
    fn paper_name(&self) -> &'static str {
        "Fourier"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Fourier
    }
    fn complexity(&self) -> &'static str {
        "O(N^2*d)"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        fourier::lower(spec, hw, sim)
    }
    fn state_footprint(&self, spec: &WorkloadSpec, _position: usize) -> u64 {
        // Retained spectrum: d_state frequency modes per head dimension,
        // complex f32 (re + im) — constant in context.
        2 * (spec.d_head * spec.d_state) as u64 * 4
    }
}

/// Chunkwise-recurrent retention — the §V co-design alternative to the
/// quadratic DRA kernel (same workload kind, hardware-aware lowering).
struct ChunkedRetention;

impl CausalOperator for ChunkedRetention {
    fn name(&self) -> &'static str {
        "retentive-chunked"
    }
    fn paper_name(&self) -> &'static str {
        "Ret-Chunked"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Retentive
    }
    fn complexity(&self) -> &'static str {
        "O(N*C*d)"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        retentive_chunked::lower(spec, hw, sim)
    }
    fn lower_decode(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        // Chunkwise retention decodes through its d×d recurrent state, not
        // a KV scan: reuse the recurrent decode path with r = d.
        let recurrent = WorkloadSpec {
            op: OperatorKind::Linear,
            d_state: spec.d_head,
            ..*spec
        };
        let mut g = decode::lower_step(&recurrent, hw, sim);
        g.label = format!("retentive-chunked-decode N={}", spec.n);
        g
    }
    fn profile(&self, spec: &WorkloadSpec, elem_bytes: u64) -> OpProfile {
        // Per token: intra-chunk tile (4·C·d) + state readout/update
        // (4·d²); traffic: chunk q/k/v in + y out, nothing spilled.
        let n = spec.n as u64;
        let d = spec.d_head as u64;
        let c = (retentive_chunked::CHUNK as u64).min(n);
        OpProfile {
            ops: 4 * n * c * d + 4 * n * d * d + 4 * n * c,
            bytes: 4 * n * d * elem_bytes,
        }
    }
    fn state_footprint(&self, spec: &WorkloadSpec, _position: usize) -> u64 {
        // Decodes through the same d×d recurrent state as canonical
        // retention — the co-design keeps the constant-state property.
        (spec.d_head * spec.d_head) as u64 * 4
    }
}

// ---- The registry -------------------------------------------------------

/// Name-keyed, runtime-enumerable inventory of [`CausalOperator`]s.
///
/// Registration order is preserved and meaningful:
/// [`OperatorRegistry::for_kind`] returns the *first* entry of a kind, so
/// the canonical paper kernels (registered first by
/// [`OperatorRegistry::with_builtins`]) stay the default lowering for their
/// kind while variants like `retentive-chunked` remain addressable by name
/// and visible to enumeration.
#[derive(Default)]
pub struct OperatorRegistry {
    entries: Vec<Box<dyn CausalOperator>>,
}

impl OperatorRegistry {
    /// Empty registry (for fully custom deployments; prefer
    /// [`OperatorRegistry::with_builtins`]).
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    /// Registry pre-populated with the paper's five operators plus the
    /// chunkwise-recurrent retention variant.
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        r.register(Box::new(CausalAttention));
        r.register(Box::new(RetentiveAttention));
        r.register(Box::new(ToeplitzAttention));
        r.register(Box::new(LinearAttention));
        r.register(Box::new(FourierAttention));
        r.register(Box::new(ChunkedRetention));
        r
    }

    /// Register an operator. A same-named entry is replaced in place (so a
    /// deployment can override a builtin lowering); new names append.
    pub fn register(&mut self, op: Box<dyn CausalOperator>) {
        match self.entries.iter_mut().find(|e| e.name() == op.name()) {
            Some(slot) => *slot = op,
            None => self.entries.push(op),
        }
    }

    /// Look up an operator by registry name.
    pub fn get(&self, name: &str) -> Option<&dyn CausalOperator> {
        self.entries.iter().find(|e| e.name() == name).map(|b| b.as_ref())
    }

    /// Default operator for a workload kind (first registered of that
    /// kind), or `None` for a kind this registry does not cover.
    pub fn try_for_kind(&self, kind: OperatorKind) -> Option<&dyn CausalOperator> {
        self.entries.iter().find(|e| e.kind() == kind).map(|b| b.as_ref())
    }

    /// Default operator for a workload kind (first registered of that
    /// kind). Panics if the kind has no entry — impossible with builtins;
    /// long-lived servers should prefer [`OperatorRegistry::try_for_kind`]
    /// and surface the miss as a request error.
    pub fn for_kind(&self, kind: OperatorKind) -> &dyn CausalOperator {
        self.try_for_kind(kind)
            // lint:allow(panic-reachability, "assert-style API by contract; the serve path resolves operators via try_for_kind and never calls this")
            .unwrap_or_else(|| panic!("no operator registered for kind {kind:?}"))
    }

    /// Enumerate all registered operators in registration order.
    pub fn iter(&self) -> impl Iterator<Item = &dyn CausalOperator> {
        self.entries.iter().map(|b| b.as_ref())
    }

    /// Registry names in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

static GLOBAL: OnceLock<OperatorRegistry> = OnceLock::new();

/// Process-wide default registry, used by the pipeline layers for
/// kind-based dispatch. Defaults to [`OperatorRegistry::with_builtins`];
/// a deployment installs its own inventory with [`init_global`] before
/// first use, or threads an explicit [`OperatorRegistry`] through the
/// registry-parameterized APIs (`report::sweep::sweep_report_with`).
pub fn global() -> &'static OperatorRegistry {
    GLOBAL.get_or_init(OperatorRegistry::with_builtins)
}

/// Install `reg` as the process-wide default registry — the deployment
/// hook that makes a custom operator reachable from *every* pipeline
/// layer (CLI dispatch, coordinator serving, router ranking, sweep)
/// without touching pipeline code. Call once, at the top of `main`,
/// before anything touches [`global`].
///
/// The registry should cover every [`OperatorKind`] it will be asked to
/// serve (start from [`OperatorRegistry::with_builtins`] and add to it);
/// a missing kind panics at dispatch time.
///
/// Returns `Err(reg)` untouched if the global registry was already
/// initialized (by a previous call or a prior [`global`] use).
pub fn init_global(reg: OperatorRegistry) -> Result<(), OperatorRegistry> {
    GLOBAL.set(reg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::graph::{BufferAccess, EltKind, GraphBuilder, PrimOp, TransferDir};

    fn cfg() -> (NpuConfig, SimConfig) {
        (NpuConfig::default(), SimConfig::default())
    }

    #[test]
    fn bound_class_all_covers_every_label_once() {
        assert_eq!(BoundClass::ALL.len(), 4);
        let mut labels: Vec<&str> = BoundClass::ALL.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 4, "labels are distinct");
        assert!(labels.contains(&"memory-bound"));
        assert!(labels.contains(&"vector-compute-bound"));
    }

    #[test]
    fn builtins_enumerate_all_operators() {
        let r = OperatorRegistry::with_builtins();
        assert_eq!(r.len(), 6);
        let names = r.names();
        for want in ["causal", "retentive", "toeplitz", "linear", "fourier", "retentive-chunked"]
        {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        for kind in OperatorKind::ALL {
            let _ = r.for_kind(kind); // total over kinds
        }
    }

    #[test]
    fn for_kind_prefers_the_canonical_kernel() {
        let r = OperatorRegistry::with_builtins();
        assert_eq!(r.for_kind(OperatorKind::Retentive).name(), "retentive");
    }

    #[test]
    fn try_for_kind_is_total_over_partial_registries() {
        let mut r = OperatorRegistry::new();
        r.register(Box::new(ToeplitzAttention));
        assert!(r.try_for_kind(OperatorKind::Toeplitz).is_some());
        assert!(r.try_for_kind(OperatorKind::Fourier).is_none(), "no panic, just None");
    }

    #[test]
    fn get_by_name() {
        let r = OperatorRegistry::with_builtins();
        assert_eq!(r.get("retentive-chunked").unwrap().paper_name(), "Ret-Chunked");
        assert!(r.get("no-such-op").is_none());
    }

    #[test]
    fn register_replaces_same_name_appends_new() {
        struct Override;
        impl CausalOperator for Override {
            fn name(&self) -> &'static str {
                "toeplitz"
            }
            fn paper_name(&self) -> &'static str {
                "Toeplitz*"
            }
            fn kind(&self) -> OperatorKind {
                OperatorKind::Toeplitz
            }
            fn complexity(&self) -> &'static str {
                "O(N)"
            }
            fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
                toeplitz::lower(spec, hw, sim)
            }
        }
        let mut r = OperatorRegistry::with_builtins();
        let before = r.len();
        r.register(Box::new(Override));
        assert_eq!(r.len(), before, "same name replaces");
        assert_eq!(r.get("toeplitz").unwrap().paper_name(), "Toeplitz*");
    }

    #[test]
    fn registry_lowering_matches_direct_module_calls() {
        let (hw, sim) = cfg();
        let r = OperatorRegistry::with_builtins();
        for (kind, direct) in [
            (OperatorKind::Causal, causal::lower as fn(&WorkloadSpec, &NpuConfig, &SimConfig) -> OpGraph),
            (OperatorKind::Retentive, retentive::lower),
            (OperatorKind::Toeplitz, toeplitz::lower),
            (OperatorKind::Linear, linear::lower),
            (OperatorKind::Fourier, fourier::lower),
        ] {
            let spec = WorkloadSpec::new(kind, 256);
            let via_registry = r.for_kind(kind).lower(&spec, &hw, &sim);
            let via_module = direct(&spec, &hw, &sim);
            assert_eq!(via_registry.label, via_module.label, "{kind}");
            assert_eq!(via_registry.len(), via_module.len(), "{kind}");
            assert_eq!(via_registry.logical_ops, via_module.logical_ops, "{kind}");
        }
    }

    #[test]
    fn decode_variants_lower_valid_graphs() {
        let (hw, sim) = cfg();
        for op in OperatorRegistry::with_builtins().iter() {
            let spec = WorkloadSpec::new(op.kind(), 1024);
            let g = op.lower_decode(&spec, &hw, &sim);
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", op.name()));
            assert!(!g.is_empty(), "{}", op.name());
        }
    }

    #[test]
    fn chunked_profile_is_linear_in_n() {
        let r = OperatorRegistry::with_builtins();
        let op = r.get("retentive-chunked").unwrap();
        let p1 = op.profile(&WorkloadSpec::new(OperatorKind::Retentive, 2048), 2);
        let p2 = op.profile(&WorkloadSpec::new(OperatorKind::Retentive, 4096), 2);
        assert!((p2.ops as f64 / p1.ops as f64 - 2.0).abs() < 0.1);
        assert!((p2.bytes as f64 / p1.bytes as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn state_footprints_follow_the_paper_classes() {
        let r = OperatorRegistry::with_builtins();
        let fp = |name: &str, n: usize| {
            let op = r.get(name).unwrap();
            op.state_footprint(&WorkloadSpec::new(op.kind(), n), n)
        };
        // Attention KV doubles with context (O(N·d))...
        assert_eq!(fp("causal", 4096), 2 * fp("causal", 2048));
        assert_eq!(fp("causal", 1024), 2 * 1024 * 64 * 2);
        // ...while retention/SSM state is context-independent (O(d·d))...
        for op in ["retentive", "retentive-chunked", "linear", "fourier"] {
            assert_eq!(fp(op, 256), fp(op, 1 << 20), "{op} state must stay flat");
        }
        // ...and the banded ring buffer fills its window then flattens.
        assert!(fp("toeplitz", 64) < fp("toeplitz", 2048));
        assert_eq!(fp("toeplitz", 2048), fp("toeplitz", 1 << 20));
    }

    #[test]
    fn predict_ms_orders_structured_before_quadratic() {
        let (hw, sim) = cfg();
        let r = OperatorRegistry::with_builtins();
        let at = |name: &str| {
            let op = r.get(name).unwrap();
            op.predict_ms(&WorkloadSpec::new(op.kind(), 2048), &hw, &sim)
        };
        assert!(at("toeplitz") < at("causal"));
        assert!(at("retentive-chunked") < at("retentive"));
    }

    // ---- classification ------------------------------------------------

    fn report_of(build: impl FnOnce(&mut GraphBuilder)) -> ExecReport {
        let (hw, sim) = cfg();
        let mut b = GraphBuilder::new("classify");
        build(&mut b);
        let g = b.finish();
        crate::npu::run(&g, &hw, &sim)
    }

    #[test]
    fn eltwise_graph_is_vector_bound() {
        let r = report_of(|b| {
            b.push_simple(PrimOp::EltWise { kind: EltKind::Exp, elems: 1 << 20 }, vec![]);
        });
        assert_eq!(classify(&r), BoundClass::VectorCompute);
    }

    #[test]
    fn matmul_graph_is_compute_bound() {
        let r = report_of(|b| {
            b.push_simple(PrimOp::MatMul { m: 1024, n: 1024, k: 1024 }, vec![]);
        });
        assert_eq!(classify(&r), BoundClass::Compute);
    }

    #[test]
    fn streaming_transfers_are_movement_bound() {
        let r = report_of(|b| {
            let buf = b.buffer();
            for _ in 0..8 {
                b.push(
                    PrimOp::Transfer { bytes: 1 << 20, dir: TransferDir::Pull, fresh_alloc: false },
                    vec![],
                    vec![BufferAccess::new(buf, 1 << 20, true)],
                    vec![],
                );
            }
        });
        assert_eq!(classify(&r), BoundClass::DataMovement);
    }

    #[test]
    fn stalled_missing_pipeline_is_memory_bound() {
        // Serialized fresh-alloc pull -> small matmul chain, all misses:
        // DMA dominates, compute sits stalled, cache efficiency is zero.
        let r = report_of(|b| {
            let buf = b.buffer();
            let mut prev_mm = None;
            for _ in 0..8 {
                let deps = prev_mm.map(|p| vec![p]).unwrap_or_default();
                let t = b.push(
                    PrimOp::Transfer { bytes: 1 << 20, dir: TransferDir::Pull, fresh_alloc: true },
                    deps,
                    vec![BufferAccess::new(buf, 1 << 20, false)],
                    vec![],
                );
                prev_mm = Some(b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![t]));
            }
        });
        assert_eq!(classify(&r), BoundClass::Memory);
    }

    #[test]
    fn init_global_after_first_use_is_rejected() {
        // Success-path installation can only be exercised in a fresh
        // process (tests share one); the contract tested here is that a
        // late install is refused and hands the registry back.
        let _ = global();
        let rejected = init_global(OperatorRegistry::with_builtins());
        let reg = rejected.expect_err("global already initialized");
        assert_eq!(reg.len(), 6, "rejected registry is returned intact");
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(BoundClass::Memory.to_string(), "memory-bound");
        assert_eq!(BoundClass::Compute.label(), "compute-bound");
        assert_eq!(BoundClass::VectorCompute.label(), "vector-compute-bound");
        assert_eq!(BoundClass::DataMovement.label(), "data-movement-bound");
    }
}
