//! Analytical operation / traffic / intensity accounting (paper §IV-B,
//! Table VII).
//!
//! Intensity = logical ops / bytes that must cross the DMA boundary. The
//! byte terms mirror what each *lowering* actually streams:
//!
//! - **Full Causal** (phase-separated, spilling): the N×N score matrix is
//!   written and re-read (2·N²·e) on top of Q/K/V/O (8·N·d·e). At N=4096,
//!   d=64, e=2 this gives 61.1 Ops/Byte — the paper's 61.13.
//! - **Retentive** (decay epilogue adds a modify pass: 2.5 score-matrix
//!   streams) → 50 Ops/Byte, matching the paper.
//! - **Toeplitz** (band-limited): only the N×B score band streams.
//! - **Linear** (chunked): per-step state stream 2·N·r·d·e dominates.
//! - **Fourier**: DFT weight tiles stream (4 transforms × N²·e re+im).

use crate::config::{OperatorKind, WorkloadSpec};

/// Analytical profile of one operator invocation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpProfile {
    /// Logical compute ops (MAC = 2 ops; element-wise = 1 op/elem).
    pub ops: u64,
    /// Bytes crossing the DMA boundary (DRAM ↔ scratchpad).
    pub bytes: u64,
}

impl OpProfile {
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0 {
            0.0
        } else {
            self.ops as f64 / self.bytes as f64
        }
    }
}

/// Paper-default Toeplitz band.
pub const TOEPLITZ_BAND: usize = 128;
/// Chunk length for the chunked linear lowering.
pub const LINEAR_CHUNK: usize = 128;

/// Analytical profile for `spec` at `elem_bytes` precision.
pub fn profile(spec: &WorkloadSpec, elem_bytes: u64) -> OpProfile {
    let n = spec.n as u64;
    let d = spec.d_head as u64;
    let r = spec.d_state as u64;
    let e = elem_bytes;
    match spec.op {
        OperatorKind::Causal => OpProfile {
            // QK^T + PV (2 matmuls ⇒ 4·N²·d) + 4-pass softmax.
            ops: 4 * n * n * d + 4 * n * n,
            // Score spill round-trip + Q/K/V/O.
            bytes: 2 * n * n * e + 8 * n * d * e,
        },
        OperatorKind::Retentive => OpProfile {
            // Matmuls + decay epilogue (2 elementwise passes) + softmax.
            ops: 4 * n * n * d + 6 * n * n,
            // 2.5 score-matrix streams (write, decay modify, softmax read)
            // + Q/K/V/O — the paper's 50 Ops/Byte at the default shape.
            bytes: 5 * n * n * e / 2 + 8 * n * d * e,
        },
        OperatorKind::Toeplitz => {
            let b = (TOEPLITZ_BAND as u64).min(n);
            OpProfile {
                // Banded QK^T + PV + decay/softmax over the band.
                ops: 4 * n * b * d + 6 * n * b,
                // Band scores stream once + Q/K/V/O + window overlap refetch.
                bytes: n * b * e + 10 * n * d * e,
            }
        }
        OperatorKind::Linear => {
            let c = (LINEAR_CHUNK as u64).min(n);
            OpProfile {
                // phi projections + intra-chunk (N·C·(r+d)) + state path.
                ops: 4 * n * r * d + 2 * n * c * (r + d) + 6 * n * r,
                // Per-step state stream + Q/K/V/O.
                bytes: 2 * n * r * d * e / (c / 8).max(1) + 8 * n * d * e,
            }
        }
        OperatorKind::Fourier => {
            // *Algorithmic* FFT accounting (the paper's convention): the
            // useful work is 4 transforms × 5·N·log2(N) complex ops per
            // channel + the spectrum product — NOT the 16·N²·d the DFT
            // matmul realization burns. This is why Fourier's measured
            // GOP/s craters (0.34 in Table VII): the NPU executes a
            // quadratic realization of an N·log N algorithm.
            let log_n = (usize::BITS - (spec.n.max(2) - 1).leading_zeros()) as u64;
            OpProfile {
                ops: 4 * 5 * n * log_n * d * 2 + 8 * (n / 2 + 1) * d,
                // Ideal I/O: q/k/v/o + complex spectra round trip.
                bytes: 8 * n * d * e + 4 * n * d * e,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadSpec;

    fn spec(op: OperatorKind, n: usize) -> WorkloadSpec {
        WorkloadSpec::new(op, n)
    }

    #[test]
    fn causal_intensity_matches_paper_value() {
        // Paper Table VII: 61.13 Ops/Byte at N=4096, d_h=64, 16-bit.
        let p = profile(&spec(OperatorKind::Causal, 4096), 2);
        assert!(
            (p.intensity() - 61.13).abs() < 1.0,
            "causal intensity {:.2}",
            p.intensity()
        );
    }

    #[test]
    fn retentive_intensity_near_paper() {
        // Paper: 50.00.
        let p = profile(&spec(OperatorKind::Retentive, 4096), 2);
        assert!((p.intensity() - 50.0).abs() < 2.0, "{:.2}", p.intensity());
    }

    #[test]
    fn intensity_ordering_matches_table7() {
        // Causal > Retentive > Toeplitz > Linear ≈ Fourier.
        let at = |op| profile(&spec(op, 4096), 2).intensity();
        let causal = at(OperatorKind::Causal);
        let retentive = at(OperatorKind::Retentive);
        let toeplitz = at(OperatorKind::Toeplitz);
        let linear = at(OperatorKind::Linear);
        let fourier = at(OperatorKind::Fourier);
        assert!(causal > retentive && retentive > toeplitz);
        assert!(toeplitz > linear.min(fourier));
    }

    #[test]
    fn quadratic_ops_scale_quadratically() {
        let p1 = profile(&spec(OperatorKind::Causal, 1024), 2);
        let p2 = profile(&spec(OperatorKind::Causal, 2048), 2);
        let ratio = p2.ops as f64 / p1.ops as f64;
        assert!((ratio - 4.0).abs() < 0.1);
    }

    #[test]
    fn subquadratic_ops_scale_linearly() {
        for op in [OperatorKind::Toeplitz, OperatorKind::Linear] {
            let p1 = profile(&spec(op, 1024), 2);
            let p2 = profile(&spec(op, 2048), 2);
            let ratio = p2.ops as f64 / p1.ops as f64;
            assert!((ratio - 2.0).abs() < 0.1, "{op:?} ratio {ratio}");
        }
    }

    #[test]
    fn d_state_raises_linear_cost() {
        let lo = profile(&spec(OperatorKind::Linear, 4096).with_d_state(16), 2);
        let hi = profile(&spec(OperatorKind::Linear, 4096).with_d_state(128), 2);
        assert!(hi.ops > lo.ops);
    }

    #[test]
    fn zero_bytes_guard() {
        let p = OpProfile { ops: 10, bytes: 0 };
        assert_eq!(p.intensity(), 0.0);
    }
}
