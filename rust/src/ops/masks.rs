//! Causal mask structure (paper Fig 3): generation + ASCII rendering of the
//! six mask families, plus density accounting used by the lowerings.

use crate::config::OperatorKind;

/// The six structured causal mask families of Fig 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskFamily {
    FullCausal,
    Toeplitz,
    Fourier,
    RetentiveDecay,
    Semiseparable,
    LinearStructured,
}

impl MaskFamily {
    pub const ALL: [MaskFamily; 6] = [
        MaskFamily::FullCausal,
        MaskFamily::Toeplitz,
        MaskFamily::Fourier,
        MaskFamily::RetentiveDecay,
        MaskFamily::Semiseparable,
        MaskFamily::LinearStructured,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MaskFamily::FullCausal => "Full Causal",
            MaskFamily::Toeplitz => "Toeplitz",
            MaskFamily::Fourier => "Fourier",
            MaskFamily::RetentiveDecay => "Retentive Decay",
            MaskFamily::Semiseparable => "Semiseparable",
            MaskFamily::LinearStructured => "Linear Structured",
        }
    }
}

/// Mask weight at (i, j) in [0, 1]; 0 = no attention. `n` is the context,
/// `band`/`gamma`/`rank` parameterize the structured families.
pub fn weight(family: MaskFamily, i: usize, j: usize, n: usize) -> f64 {
    if j > i {
        return 0.0; // causality for all families
    }
    let gamma: f64 = 0.9;
    match family {
        MaskFamily::FullCausal => 1.0,
        MaskFamily::Toeplitz => gamma.powi((i - j) as i32),
        // Fourier: circulant magnitude profile (distance in ring metric).
        MaskFamily::Fourier => {
            let d = (i - j).min(n - (i - j));
            0.2 + 0.8 * (1.0 - d as f64 / (n as f64 / 2.0)).max(0.0)
        }
        MaskFamily::RetentiveDecay => 0.97f64.powi((i - j) as i32),
        // Semiseparable: low-rank off-diagonal blocks + dense band.
        MaskFamily::Semiseparable => {
            if i - j < n / 8 {
                1.0
            } else {
                0.35
            }
        }
        // Linear structured: rank-r outer-product pattern (uniform low-rank
        // coverage of the causal triangle).
        MaskFamily::LinearStructured => 0.5,
    }
}

/// Fraction of non-negligible entries (weight > eps) in the causal triangle
/// — the structural sparsity the NPU lowering can exploit.
pub fn density(family: MaskFamily, n: usize, eps: f64) -> f64 {
    let mut nz = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in 0..=i {
            total += 1;
            if weight(family, i, j, n) > eps {
                nz += 1;
            }
        }
    }
    nz as f64 / total as f64
}

/// ASCII-art rendering of a mask at `n`×`n` (Fig 3 regeneration).
pub fn render(family: MaskFamily, n: usize) -> String {
    let shades = [' ', '.', ':', '+', '#'];
    let mut out = String::with_capacity(n * (n + 1));
    for i in 0..n {
        for j in 0..n {
            let w = weight(family, i, j, n);
            let idx = ((w * (shades.len() - 1) as f64).round() as usize).min(shades.len() - 1);
            out.push(shades[idx]);
        }
        out.push('\n');
    }
    out
}

/// Mask family an operator kind lowers (the Fig 3 ↔ §II-C correspondence).
pub fn family_for(op: OperatorKind) -> MaskFamily {
    match op {
        OperatorKind::Causal => MaskFamily::FullCausal,
        OperatorKind::Retentive => MaskFamily::RetentiveDecay,
        OperatorKind::Toeplitz => MaskFamily::Toeplitz,
        OperatorKind::Linear => MaskFamily::LinearStructured,
        OperatorKind::Fourier => MaskFamily::Fourier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_masks_are_causal() {
        for fam in MaskFamily::ALL {
            for i in 0..16 {
                for j in (i + 1)..16 {
                    assert_eq!(weight(fam, i, j, 16), 0.0, "{fam:?} leaks future");
                }
            }
        }
    }

    #[test]
    fn full_causal_is_dense() {
        assert_eq!(density(MaskFamily::FullCausal, 64, 1e-6), 1.0);
    }

    #[test]
    fn toeplitz_decays_off_diagonal() {
        let near = weight(MaskFamily::Toeplitz, 10, 9, 32);
        let far = weight(MaskFamily::Toeplitz, 31, 0, 32);
        assert!(near > far);
        // Effective band: density under a practical threshold is < 1.
        assert!(density(MaskFamily::Toeplitz, 256, 0.01) < 0.5);
    }

    #[test]
    fn retentive_decay_slower_than_toeplitz() {
        // gamma 0.97 vs 0.9: retentive keeps a longer tail.
        assert!(
            density(MaskFamily::RetentiveDecay, 256, 0.01)
                > density(MaskFamily::Toeplitz, 256, 0.01)
        );
    }

    #[test]
    fn render_is_square() {
        let r = render(MaskFamily::FullCausal, 8);
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 8);
        assert!(lines.iter().all(|l| l.len() == 8));
        // Lower triangle filled, upper empty.
        assert_eq!(lines[0].chars().next().unwrap(), '#');
        assert_eq!(lines[0].chars().nth(7).unwrap(), ' ');
    }

    #[test]
    fn every_operator_has_a_family() {
        for op in OperatorKind::ALL {
            let _ = family_for(op); // total mapping
        }
    }
}
