//! Decode-phase lowering: one autoregressive step (paper §II-A, Eq. 3).
//!
//! The prefill microbenchmarks (Tables II-VIII) process N tokens at once;
//! on-device inference then decodes token-by-token:
//!
//! ```text
//! y_t, C_t = g_theta(x_t, C_{t-1})
//! ```
//!
//! For attention-class operators the step cost grows with the retained
//! context (a 1×N score row against the KV cache, with the matvec using
//! one row of the 128-wide systolic array — the paper's "SSMs underutilize
//! NPU parallelism" observation cuts both ways); for recurrent-state
//! operators the step is O(d·d_state), constant in N. This module lowers
//! one decode step so the coordinator and benches can model sustained
//! tokens/s vs context — the quantity that actually gates on-device chat.

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};

use super::graph::{BufferAccess, EltKind, OpGraph, PrimOp, TransferDir};
use super::tiling::{tiles, Lowering};
use super::toeplitz::band_for;

/// Lower a single decode step at retained context `spec.n`.
pub fn lower_step(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    match spec.op {
        OperatorKind::Causal | OperatorKind::Retentive => kv_decode(spec, hw, sim),
        OperatorKind::Toeplitz => banded_decode(spec, hw, sim),
        OperatorKind::Linear | OperatorKind::Fourier => recurrent_decode(spec, hw, sim),
    }
}

/// Attention decode: q_t · K^T over the whole KV cache + softmax + probs·V.
fn kv_decode(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let t = sim.tile;
    let eb = sim.elem_bytes;
    let tk = tiles(n, t);
    let mut l = Lowering::new(format!("{}-decode N={n}", spec.op.name()), hw, sim);

    let kv_tile_bytes = (t.min(n) * d) as u64 * eb;
    let k_buf = l.b.buffer();
    let v_buf = l.b.buffer();
    let score_buf = l.b.buffer();
    let out_buf = l.b.buffer();

    // KV cache streams from DRAM: at long context it no longer fits the
    // scratchpad next to everything else, and decode touches all of it.
    let k_pulls = l.refill_tiles(k_buf, (n * d) as u64 * eb, tk, vec![]);
    // q_t · K^T : a 1-row matvec — the systolic array runs at 1/128 of its
    // height (the decode-phase underutilization the paper warns about).
    let mut reads = l.reads(k_buf, kv_tile_bytes, tk, false);
    reads.push(BufferAccess::new(score_buf, n as u64 * eb, true));
    let mm = l.b.push(PrimOp::MatMul { m: 1, n, k: d }, k_pulls, reads, vec![
        BufferAccess::new(score_buf, n as u64 * eb, true),
    ]);
    // Retentive adds the decay epilogue on the score row.
    let pre_softmax = if spec.op == OperatorKind::Retentive {
        l.b.push(
            PrimOp::EltWise { kind: EltKind::Exp, elems: 2 * n },
            vec![mm],
            vec![BufferAccess::new(score_buf, n as u64 * eb, true)],
            vec![BufferAccess::new(score_buf, n as u64 * eb, true)],
        )
    } else {
        mm
    };
    let sm = l.b.push(
        PrimOp::Softmax { rows: 1, cols: n },
        vec![pre_softmax],
        vec![BufferAccess::new(score_buf, n as u64 * eb, true)],
        vec![BufferAccess::new(score_buf, n as u64 * eb, true)],
    );
    let v_pulls = l.refill_tiles(v_buf, (n * d) as u64 * eb, tk, vec![sm]);
    let mut reads = l.reads(v_buf, kv_tile_bytes, tk, false);
    reads.push(BufferAccess::new(score_buf, n as u64 * eb, true));
    let pv = l.b.push(PrimOp::MatMul { m: 1, n: d, k: n }, v_pulls, reads, vec![
        BufferAccess::new(out_buf, d as u64 * eb, true),
    ]);
    // Append k_t/v_t to the cache (the O(N·d) memory growth of Fig 1).
    l.b.push(
        PrimOp::Transfer { bytes: 2 * d as u64 * eb, dir: TransferDir::Push, fresh_alloc: false },
        vec![pv],
        vec![],
        vec![],
    );
    l.finish()
}

/// Toeplitz decode: attends to its band only — constant-size window.
fn banded_decode(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let band = band_for(spec).min(spec.n);
    let windowed = WorkloadSpec { n: band, ..*spec };
    let mut g = kv_decode(&windowed, hw, sim);
    g.label = format!("toeplitz-decode N={} band={band}", spec.n);
    g
}

/// Recurrent decode: state update + readout, independent of context.
fn recurrent_decode(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let d = spec.d_head;
    let r = spec.d_state;
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("{}-decode N={}", spec.op.name(), spec.n), hw, sim);

    let state_bytes = (r * d) as u64 * eb;
    let (s_buf, s_pull, _) = l.stage_input(state_bytes);

    // phi(x_t) projection: 1×d · d×r.
    let phi = l.b.push(
        PrimOp::MatMul { m: 1, n: r, k: d },
        vec![s_pull],
        vec![BufferAccess::new(s_buf, (d * r) as u64 * eb, true)],
        vec![],
    );
    let act = l.b.push(PrimOp::EltWise { kind: EltKind::Exp, elems: 2 * r }, vec![phi], vec![], vec![]);
    // State update S += phi(k_t) ⊗ v_t  (outer product, r×d).
    let upd = l.b.push(
        PrimOp::MatMul { m: r, n: d, k: 1 },
        vec![act],
        vec![BufferAccess::new(s_buf, state_bytes, true)],
        vec![BufferAccess::new(s_buf, state_bytes, true)],
    );
    // Readout y_t = phi(q_t) · S + normalize.
    let read = l.b.push(
        PrimOp::MatMul { m: 1, n: d, k: r },
        vec![upd],
        vec![BufferAccess::new(s_buf, state_bytes, true)],
        vec![],
    );
    let norm = l.b.push(
        PrimOp::EltWise { kind: EltKind::Simple, elems: 2 * d },
        vec![read],
        vec![],
        vec![],
    );
    l.b.push(
        PrimOp::Transfer { bytes: d as u64 * eb, dir: TransferDir::Push, fresh_alloc: false },
        vec![norm],
        vec![],
        vec![],
    );
    l.finish()
}

/// Sustained decode throughput (tokens/s) at retained context `n`.
pub fn tokens_per_second(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> f64 {
    let g = lower_step(spec, hw, sim);
    let r = crate::npu::run(&g, hw, sim);
    1e9 / r.span_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::npu;

    fn step(op: OperatorKind, n: usize) -> npu::ExecReport {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let spec = WorkloadSpec::new(op, n);
        let g = lower_step(&spec, &hw, &sim);
        g.validate().unwrap();
        npu::run(&g, &hw, &sim)
    }

    #[test]
    fn kv_decode_cost_grows_with_context() {
        let a = step(OperatorKind::Causal, 1024).span_ns;
        let b = step(OperatorKind::Causal, 8192).span_ns;
        assert!(b > 3.0 * a, "decode against a bigger cache must cost more: {a} vs {b}");
    }

    #[test]
    fn recurrent_decode_is_context_independent() {
        let a = step(OperatorKind::Linear, 1024).span_ns;
        let b = step(OperatorKind::Linear, 65536).span_ns;
        assert_eq!(a, b, "O(d·r) decode step is flat in N");
    }

    #[test]
    fn banded_decode_plateaus_at_band() {
        let a = step(OperatorKind::Toeplitz, 256).span_ns;
        let b = step(OperatorKind::Toeplitz, 8192).span_ns;
        // Band caps the window: beyond N=band the cost is flat.
        let c = step(OperatorKind::Toeplitz, 16384).span_ns;
        assert!(b <= a * 2.0, "band caps decode cost");
        assert_eq!(b, c);
    }

    #[test]
    fn recurrent_beats_kv_decode_at_long_context() {
        // The memory-state tradeoff pays off at decode time (paper §II-A).
        let kv = step(OperatorKind::Causal, 16384).span_ns;
        let ssm = step(OperatorKind::Linear, 16384).span_ns;
        assert!(kv / ssm > 10.0, "kv {kv} vs ssm {ssm}");
    }

    #[test]
    fn retentive_decode_pays_decay_on_shave() {
        let causal = step(OperatorKind::Causal, 4096);
        let ret = step(OperatorKind::Retentive, 4096);
        assert!(ret.busy_ns[1] > causal.busy_ns[1], "decay epilogue adds SHAVE work");
    }

    #[test]
    fn tokens_per_second_sane() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let tps = tokens_per_second(&WorkloadSpec::new(OperatorKind::Linear, 8192), &hw, &sim);
        assert!(tps > 1000.0, "recurrent decode should sustain kHz: {tps}");
        let tps_kv =
            tokens_per_second(&WorkloadSpec::new(OperatorKind::Causal, 8192), &hw, &sim);
        assert!(tps_kv < tps);
    }
}
