//! Shared lowering machinery: scratchpad planning, tiled transfer emission,
//! and access-tag helpers used by all five operator lowerings.

use crate::config::{NpuConfig, SimConfig};
use crate::npu::scratchpad::{Placement, Scratchpad};

use super::graph::{BufferAccess, BufferId, GraphBuilder, NodeId, PrimOp, TransferDir};

/// Number of tiles covering `n` elements at tile edge `t`.
pub fn tiles(n: usize, t: usize) -> usize {
    n.div_ceil(t)
}

/// Lowering context: DAG builder + scratchpad plan + policy.
pub struct Lowering {
    pub b: GraphBuilder,
    pub spad: Scratchpad,
    pub sim: SimConfig,
    /// Bytes per element (16-bit default).
    pub eb: u64,
    pub tile: usize,
}

impl Lowering {
    pub fn new(label: impl Into<String>, hw: &NpuConfig, sim: &SimConfig) -> Self {
        Lowering {
            b: GraphBuilder::new(label),
            spad: Scratchpad::new(hw.scratchpad_bytes),
            eb: sim.elem_bytes,
            tile: sim.tile,
            sim: sim.clone(),
        }
    }

    /// Stage a model input (q/k/v/weights) into the scratchpad: one pull
    /// transfer into a *persistent* staging buffer (the runtime reuses I/O
    /// buffers across invocations, so no allocation penalty) and a pin
    /// attempt. Returns (buffer, pull node, resident?). Non-resident inputs
    /// are *streamed*: later tile accesses must be tagged misses.
    pub fn stage_input(&mut self, bytes: u64) -> (BufferId, NodeId, bool) {
        let buf = self.b.buffer();
        let resident = self.spad.pin(buf, bytes) == Placement::Resident;
        let pull = self.b.push(
            PrimOp::Transfer { bytes, dir: TransferDir::Pull, fresh_alloc: false },
            vec![],
            vec![],
            vec![BufferAccess::new(buf, bytes, false)],
        );
        (buf, pull, resident)
    }

    /// Emit a spill of `bytes` to DRAM as `count` tile-granular push
    /// descriptors (strided tiles of a larger matrix each need their own
    /// descriptor + buffer allocation — the §V alloc/dealloc overhead).
    pub fn spill_tiles(
        &mut self,
        buf: BufferId,
        bytes: u64,
        count: usize,
        deps: Vec<NodeId>,
    ) -> Vec<NodeId> {
        let per = (bytes / count.max(1) as u64).max(1);
        (0..count)
            .map(|_| {
                self.b.push(
                    PrimOp::Transfer { bytes: per, dir: TransferDir::Push, fresh_alloc: true },
                    deps.clone(),
                    vec![],
                    vec![BufferAccess::new(buf, per, false)],
                )
            })
            .collect()
    }

    /// Emit tile-granular pulls of a previously spilled / DRAM-resident
    /// region (no fresh allocation: the staging buffers are recycled).
    pub fn refill_tiles(
        &mut self,
        buf: BufferId,
        bytes: u64,
        count: usize,
        deps: Vec<NodeId>,
    ) -> Vec<NodeId> {
        let per = (bytes / count.max(1) as u64).max(1);
        (0..count)
            .map(|_| {
                self.b.push(
                    PrimOp::Transfer { bytes: per, dir: TransferDir::Pull, fresh_alloc: false },
                    deps.clone(),
                    vec![BufferAccess::new(buf, per, false)],
                    vec![],
                )
            })
            .collect()
    }

    /// Access-tag helper: `count` tile reads of a buffer, RLE-compressed
    /// into a single entry (see EXPERIMENTS.md §Perf: the flat encoding
    /// allocated ~1.6M access structs for causal N=8192).
    pub fn reads(&self, buf: BufferId, tile_bytes: u64, count: usize, hit: bool) -> Vec<BufferAccess> {
        if count == 0 {
            return Vec::new();
        }
        vec![BufferAccess::counted(buf, tile_bytes, hit, count as u32)]
    }

    pub fn finish(self) -> super::graph::OpGraph {
        self.b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Lowering {
        Lowering::new("t", &NpuConfig::default(), &SimConfig::default())
    }

    #[test]
    fn tiles_rounds_up() {
        assert_eq!(tiles(128, 128), 1);
        assert_eq!(tiles(129, 128), 2);
        assert_eq!(tiles(8192, 128), 64);
    }

    #[test]
    fn stage_input_pins_when_fits() {
        let mut l = ctx();
        let (_, _, resident) = l.stage_input(1 << 20);
        assert!(resident);
        let (_, _, resident2) = l.stage_input(16 << 20); // 16 MiB > 4 MiB
        assert!(!resident2);
    }

    #[test]
    fn spill_and_refill_emit_tile_descriptors() {
        let mut l = ctx();
        let buf = l.b.buffer();
        let pushes = l.spill_tiles(buf, 64 * 1024, 4, vec![]);
        assert_eq!(pushes.len(), 4);
        let pulls = l.refill_tiles(buf, 64 * 1024, 4, vec![pushes[3]]);
        assert_eq!(pulls.len(), 4);
        let g = l.finish();
        g.validate().unwrap();
        // 4 pushes + 4 pulls, 16 KiB each.
        assert_eq!(g.dma_bytes(), 8 * 16 * 1024);
    }

    #[test]
    fn reads_tag_hits() {
        let l = ctx();
        let accs = l.reads(3, 1024, 5, true);
        assert_eq!(accs.len(), 1);
        assert_eq!(accs[0].count, 5);
        assert!(accs[0].hit && accs[0].buffer == 3);
    }
}
