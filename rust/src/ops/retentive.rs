//! Retentive decay attention (the paper's DRA) lowering.
//!
//! Fused tile-wise schedule: K/V pinned in scratchpad, each 128×128 score
//! tile is produced on the DPU, decay-weighted (exp-class element-wise) and
//! consumed in place — no DRAM spill, hence the paper's 0 % DMA column.
//! The cost: every score element takes an extra exp-class SHAVE pass, and
//! row softmax over long contexts needs hierarchical merge passes that
//! re-traverse scratchpad tiles. That is exactly the Table II story —
//! DPU-bound at short N, **SHAVE-bound** past N ≈ 1024 (65-76 % SHAVE).

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};

use super::graph::{BufferAccess, EltKind, OpGraph, PrimOp, TransferDir};
use super::tiling::{tiles, Lowering};

pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let t = sim.tile;
    let tq = tiles(n, t);
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("retentive N={n} d={d}"), hw, sim);

    let qkv_bytes = (n * d) as u64 * eb;
    let tile_rows_bytes = (t * d) as u64 * eb;
    let score_tile_bytes = (t * t) as u64 * eb;

    // All three operands pinned (3·N·d·e ≤ 3 MiB at N = 8192, d = 64).
    let (q_buf, q_pull, _) = l.stage_input(qkv_bytes);
    let (k_buf, k_pull, _) = l.stage_input(qkv_bytes);
    let (v_buf, v_pull, _) = l.stage_input(qkv_bytes);
    let score_buf = l.b.buffer();
    let out_buf = l.b.buffer();

    // Bytes above which a score row-block overflows the SHAVE-local
    // working set: every re-traversal of a rewritten tile then counts as a
    // cache miss ("partial-result churn"). 128-row blocks cross this at
    // cols > 1024 — exactly where the paper's cache efficiency collapses.
    const CHURN_BYTES: u64 = 256 * 1024;

    let mut prev_decay: Option<super::graph::NodeId> = None;
    for qi in 0..tq {
        let kt = qi + 1; // causal: only k-tiles j <= i
        let cols = kt * t.min(n);
        let churn = (t.min(n) * cols) as u64 * eb > CHURN_BYTES;
        let mut tile_chain = Vec::with_capacity(kt * 2);
        for _kj in 0..kt {
            // Score tile on the DPU: q-tile (hit) × k-tile (hit). A single
            // staging tile ping-pongs between DPU and SHAVE: the next score
            // tile cannot start until the previous decay pass drained it —
            // the serialization behind the paper's 94.8 % stall row.
            let mut deps = vec![q_pull, k_pull];
            if let Some(p) = prev_decay {
                deps.push(p);
            }
            let mm = l.b.push(
                PrimOp::MatMul { m: t.min(n), n: t.min(n), k: d },
                deps,
                vec![
                    BufferAccess::new(q_buf, tile_rows_bytes, true),
                    BufferAccess::new(k_buf, tile_rows_bytes, true),
                ],
                vec![BufferAccess::new(score_buf, score_tile_bytes, true)],
            );
            // Decay epilogue gamma^(i-j) = exp((i-j)·ln γ): computing the
            // exponent plane + exp + multiply is two exp-class passes.
            let decay = l.b.push(
                PrimOp::EltWise { kind: EltKind::Exp, elems: 2 * t.min(n) * t.min(n) },
                vec![mm],
                vec![BufferAccess::new(score_buf, score_tile_bytes, !churn)],
                vec![BufferAccess::new(score_buf, score_tile_bytes, !churn)],
            );
            prev_decay = Some(decay);
            tile_chain.push(decay);
        }
        // Row softmax across the whole (i+1)·128-wide row block: re-reads
        // every rewritten score tile (churn misses past the threshold).
        let sm = l.b.push(
            PrimOp::Softmax { rows: t.min(n), cols },
            tile_chain,
            l.reads(score_buf, score_tile_bytes, kt, !churn),
            vec![BufferAccess::new(score_buf, score_tile_bytes, !churn)],
        );
        // PV over the row block: probabilities re-read post-rewrite, V pinned.
        let mut reads = l.reads(score_buf, score_tile_bytes, kt, !churn);
        reads.extend(l.reads(v_buf, tile_rows_bytes, kt, true));
        let pv = l.b.push(
            PrimOp::MatMul { m: t.min(n), n: d, k: cols },
            vec![sm, v_pull],
            reads,
            vec![BufferAccess::new(out_buf, tile_rows_bytes, true)],
        );
        l.b.push(
            PrimOp::Transfer { bytes: tile_rows_bytes, dir: TransferDir::Push, fresh_alloc: false },
            vec![pv],
            vec![],
            vec![],
        );
    }

    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::npu;
    use crate::ops::Engine;

    fn run(n: usize) -> npu::ExecReport {
        let spec = WorkloadSpec::new(OperatorKind::Retentive, n);
        let g = lower(&spec, &NpuConfig::default(), &SimConfig::default());
        g.validate().unwrap();
        npu::run(&g, &NpuConfig::default(), &SimConfig::default())
    }

    #[test]
    fn dma_share_is_negligible() {
        // Paper Table II: DMA 0.0 % for Retentive at every context.
        let r = run(2048);
        let [_, dma, _] = r.utilization();
        assert!(dma < 0.05, "retentive DMA share {dma}");
    }

    #[test]
    fn becomes_shave_bound_at_long_context() {
        // Paper: DPU-bound ≤512, SHAVE-bound ≥1024 (65-76 % SHAVE).
        let short = run(256);
        let long = run(8192);
        let [_, _, shave_short] = short.utilization();
        let [_, _, shave_long] = long.utilization();
        assert!(shave_long > shave_short, "SHAVE share must grow with N");
        assert!(shave_long > 0.5, "long-context SHAVE share {shave_long}");
    }

    #[test]
    fn latency_grows_superlinearly() {
        let r1 = run(2048);
        let r2 = run(4096);
        let ratio = r2.span_ns / r1.span_ns;
        assert!(ratio > 2.5, "quadratic-ish growth expected: {ratio}");
    }

    #[test]
    fn faster_than_causal_at_long_context() {
        let sim = SimConfig::default();
        let hw = NpuConfig::default();
        let causal = {
            let spec = WorkloadSpec::new(OperatorKind::Causal, 4096);
            npu::run(&super::super::causal::lower(&spec, &hw, &sim), &hw, &sim)
        };
        let ret = run(4096);
        assert!(
            ret.span_ns < causal.span_ns,
            "fused retentive ({}) must beat spilling causal ({})",
            ret.span_ns,
            causal.span_ns
        );
    }

    #[test]
    fn high_stall_from_cross_engine_dependencies() {
        // Table V: 94.8 % at N=8192 — DPU and SHAVE ping-pong on tiles.
        let r = run(4096);
        assert!(r.stall.stall_frac() > 0.4, "stall {}", r.stall.stall_frac());
    }

    #[test]
    fn engine_mix_has_all_three() {
        let spec = WorkloadSpec::new(OperatorKind::Retentive, 1024);
        let g = lower(&spec, &NpuConfig::default(), &SimConfig::default());
        let [dpu, shave, dma, _] = g.engine_counts();
        assert!(dpu > 0 && shave > 0 && dma > 0);
        let _ = Engine::ALL;
    }
}
