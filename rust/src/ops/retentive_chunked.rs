//! Chunkwise-recurrent retentive attention — the co-design ablation.
//!
//! The paper's DRA kernel computes the full quadratic score matrix with a
//! decay epilogue and goes SHAVE-bound past N = 1024 (Table II). RetNet's
//! *chunkwise* form is the hardware-aware alternative the paper's §V
//! co-design insights point at: per 128-row chunk,
//!
//! ```text
//! y = (Q_c K_c^T ⊙ D) V_c            intra-chunk, one systolic tile
//!   + (Q_c ⊙ decay) S                cross-chunk state readout
//! S = gamma^C S + (K_c ⊙ decay)^T V_c  state update, r = d
//! ```
//!
//! Compute drops from O(N²·d) to O(N·C·d), the decay work shrinks from N²
//! to N·C elements, and nothing spills. The `ablation_offload` bench and
//! `integration_reproduction` compare this against the paper's quadratic
//! kernel — the quantitative version of the paper's conclusion that
//! "throughput gains come from co-designing causal operators".

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};

use super::graph::{BufferAccess, EltKind, NodeId, OpGraph, PrimOp, TransferDir};
use super::tiling::Lowering;

/// Chunk rows (one systolic tile).
pub const CHUNK: usize = 128;

pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let c = CHUNK.min(n);
    let chunks = n.div_ceil(c);
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("retentive-chunked N={n} d={d}"), hw, sim);

    let chunk_bytes = (c * d) as u64 * eb;
    let state_bytes = (d * d) as u64 * eb; // S : d×d retention state

    let s_buf = l.b.buffer();
    let q_buf = l.b.buffer();
    let k_buf = l.b.buffer();
    let v_buf = l.b.buffer();
    let a_buf = l.b.buffer();
    let out_buf = l.b.buffer();

    let mut state_dep: Option<NodeId> = None;
    for _ in 0..chunks {
        let mut pulls = Vec::with_capacity(3);
        for buf in [q_buf, k_buf, v_buf] {
            pulls.push(l.b.push(
                PrimOp::Transfer { bytes: chunk_bytes, dir: TransferDir::Pull, fresh_alloc: false },
                state_dep.map(|s| vec![s]).unwrap_or_default(),
                vec![BufferAccess::new(buf, chunk_bytes, false)],
                vec![],
            ));
        }
        // Intra-chunk: Q_c K_c^T (one c×c tile) ⊙ decay mask, then ·V_c.
        let qk = l.b.push(
            PrimOp::MatMul { m: c, n: c, k: d },
            pulls.clone(),
            vec![
                BufferAccess::new(q_buf, chunk_bytes, true),
                BufferAccess::new(k_buf, chunk_bytes, true),
            ],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
        );
        // Decay mask within the chunk: c² exp-class elements (vs N² in the
        // quadratic kernel — this is the whole trick).
        let decay = l.b.push(
            PrimOp::EltWise { kind: EltKind::Exp, elems: 2 * c * c },
            vec![qk],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
        );
        let av = l.b.push(
            PrimOp::MatMul { m: c, n: d, k: c },
            vec![decay],
            vec![
                BufferAccess::new(a_buf, (c * c) as u64 * eb, true),
                BufferAccess::new(v_buf, chunk_bytes, true),
            ],
            vec![],
        );
        // Cross-chunk readout Q_c · S and per-row decay scale.
        let mut deps = vec![qk];
        if let Some(s) = state_dep {
            deps.push(s);
        }
        let read = l.b.push(
            PrimOp::MatMul { m: c, n: d, k: d },
            deps.clone(),
            vec![BufferAccess::new(s_buf, state_bytes, true)],
            vec![],
        );
        let mix = l.b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: 2 * c * d },
            vec![av, read],
            vec![],
            vec![BufferAccess::new(out_buf, chunk_bytes, true)],
        );
        // State update: S = gamma^C·S + (K_c ⊙ decay)^T V_c.
        let k_scale = l.b.push(
            PrimOp::EltWise { kind: EltKind::Exp, elems: c * d },
            deps,
            vec![BufferAccess::new(k_buf, chunk_bytes, true)],
            vec![],
        );
        let s_up = l.b.push(
            PrimOp::MatMul { m: d, n: d, k: c },
            vec![k_scale],
            vec![
                BufferAccess::new(v_buf, chunk_bytes, true),
                BufferAccess::new(s_buf, state_bytes, true),
            ],
            vec![BufferAccess::new(s_buf, state_bytes, true)],
        );
        let push = l.b.push(
            PrimOp::Transfer { bytes: chunk_bytes, dir: TransferDir::Push, fresh_alloc: false },
            vec![mix],
            vec![],
            vec![],
        );
        let _ = push;
        state_dep = Some(s_up);
    }

    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::npu;

    fn run(n: usize) -> npu::ExecReport {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let spec = WorkloadSpec::new(OperatorKind::Retentive, n);
        let g = lower(&spec, &hw, &sim);
        g.validate().unwrap();
        npu::run(&g, &hw, &sim)
    }

    fn run_quadratic(n: usize) -> npu::ExecReport {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let spec = WorkloadSpec::new(OperatorKind::Retentive, n);
        let g = super::super::retentive::lower(&spec, &hw, &sim);
        npu::run(&g, &hw, &sim)
    }

    #[test]
    fn scales_linearly_not_quadratically() {
        let ratio = run(8192).span_ns / run(2048).span_ns;
        assert!((3.0..6.0).contains(&ratio), "chunkwise is ~linear: {ratio}");
    }

    #[test]
    fn beats_quadratic_kernel_at_long_context() {
        // The co-design payoff: >10x at 8K context.
        let chunked = run(8192).span_ns;
        let quadratic = run_quadratic(8192).span_ns;
        assert!(
            quadratic / chunked > 10.0,
            "chunkwise {chunked} vs quadratic {quadratic}"
        );
    }

    #[test]
    fn no_longer_shave_bound() {
        // The SHAVE wall disappears once decay work is O(N·C).
        let [_, _, shave] = run(8192).utilization();
        assert!(shave < 0.6, "SHAVE share {shave}");
    }

    #[test]
    fn comparable_at_short_context() {
        // At one chunk the two forms do the same work (within overheads).
        let chunked = run(128).span_ns;
        let quadratic = run_quadratic(128).span_ns;
        assert!(quadratic / chunked < 4.0);
    }
}
