//! Causal linear attention (CLA) lowering — chunked, state-carrying.
//!
//! phi(x) = elu(x·P)+1 with a low-rank projection P : (d, r = d_state).
//! The sequence is processed in 128-row chunks; each chunk does a small
//! intra-chunk masked product plus a rank-r state update (S : r×d,
//! z : r) — the O(d) persistent-state end of the paper's memory-state
//! tradeoff (Fig 1). The serial state dependency chains chunks, which is
//! why linear attention shows a *moderate* stall rate (55.2 % in Table V)
//! despite minimal DMA traffic: compute engines ping-pong along the chain.

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};

use super::flops::LINEAR_CHUNK;
use super::graph::{BufferAccess, EltKind, NodeId, OpGraph, PrimOp, TransferDir};
use super::tiling::Lowering;

pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let r = spec.d_state;
    let c = LINEAR_CHUNK.min(n);
    let chunks = n.div_ceil(c);
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("linear N={n} d={d} r={r}"), hw, sim);

    let chunk_bytes = (c * d) as u64 * eb;
    let state_bytes = (r * d) as u64 * eb;

    // Projection P and state S/z live in scratchpad for the whole run.
    let (p_buf, p_pull, _) = l.stage_input((d * r) as u64 * eb);
    let s_buf = l.b.buffer();
    let z_buf = l.b.buffer();
    let q_buf = l.b.buffer();
    let k_buf = l.b.buffer();
    let v_buf = l.b.buffer();
    let a_buf = l.b.buffer(); // intra-chunk score tile (on-chip)
    let out_buf = l.b.buffer();

    let mut state_dep: Option<NodeId> = None;
    for _ci in 0..chunks {
        // Stream this chunk's q/k/v into recycled ring buffers.
        let mut pulls = Vec::with_capacity(3);
        for buf in [q_buf, k_buf, v_buf] {
            pulls.push(l.b.push(
                PrimOp::Transfer { bytes: chunk_bytes, dir: TransferDir::Pull, fresh_alloc: false },
                state_dep.map(|s| vec![s]).unwrap_or_default(),
                vec![BufferAccess::new(buf, chunk_bytes, false)],
                vec![],
            ));
        }
        let mut deps = pulls.clone();
        deps.push(p_pull);
        // phi projections: two (c×r) = (c×d)·(d×r) matmuls + elu epilogue.
        let phi_q = l.b.push(
            PrimOp::MatMul { m: c, n: r, k: d },
            deps.clone(),
            vec![
                BufferAccess::new(q_buf, chunk_bytes, true),
                BufferAccess::new(p_buf, (d * r) as u64 * eb, true),
            ],
            vec![],
        );
        let phi_k = l.b.push(
            PrimOp::MatMul { m: c, n: r, k: d },
            deps,
            vec![
                BufferAccess::new(k_buf, chunk_bytes, true),
                BufferAccess::new(p_buf, (d * r) as u64 * eb, true),
            ],
            vec![],
        );
        let elu = l.b.push(
            PrimOp::EltWise { kind: EltKind::Exp, elems: 2 * c * r },
            vec![phi_q, phi_k],
            vec![],
            vec![],
        );
        // Intra-chunk: A = phi_q · phi_k^T (c×c), causal-masked, A·V.
        let intra = l.b.push(
            PrimOp::MatMul { m: c, n: c, k: r },
            vec![elu],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
        );
        let mask = l.b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: c * c },
            vec![intra],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
            vec![BufferAccess::new(a_buf, (c * c) as u64 * eb, true)],
        );
        let av = l.b.push(
            PrimOp::MatMul { m: c, n: d, k: c },
            vec![mask],
            vec![
                BufferAccess::new(a_buf, (c * c) as u64 * eb, true),
                BufferAccess::new(v_buf, chunk_bytes, true),
            ],
            vec![],
        );
        // Inter-chunk: y += phi_q · S; normalizer via z.
        let mut deps = vec![elu];
        if let Some(sdep) = state_dep {
            deps.push(sdep);
        }
        let inter = l.b.push(
            PrimOp::MatMul { m: c, n: d, k: r },
            deps.clone(),
            vec![BufferAccess::new(s_buf, state_bytes, true)],
            vec![],
        );
        // Normalize: cumulative z + row divide (2 simple passes).
        let norm = l.b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: 2 * c * d },
            vec![av, inter],
            vec![BufferAccess::new(z_buf, (r) as u64 * 4, true)],
            vec![BufferAccess::new(out_buf, chunk_bytes, true)],
        );
        // State update: S += phi_k^T · V, z += sum(phi_k).
        let s_up = l.b.push(
            PrimOp::MatMul { m: r, n: d, k: c },
            deps,
            vec![
                BufferAccess::new(v_buf, chunk_bytes, true),
                BufferAccess::new(s_buf, state_bytes, true),
            ],
            vec![BufferAccess::new(s_buf, state_bytes, true)],
        );
        let z_up = l.b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: c * r },
            vec![s_up],
            vec![BufferAccess::new(z_buf, r as u64 * 4, true)],
            vec![BufferAccess::new(z_buf, r as u64 * 4, true)],
        );
        l.b.push(
            PrimOp::Transfer { bytes: chunk_bytes, dir: TransferDir::Push, fresh_alloc: false },
            vec![norm],
            vec![],
            vec![],
        );
        state_dep = Some(z_up);
    }

    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::npu;

    fn run_spec(spec: WorkloadSpec) -> npu::ExecReport {
        let g = lower(&spec, &NpuConfig::default(), &SimConfig::default());
        g.validate().unwrap();
        npu::run(&g, &NpuConfig::default(), &SimConfig::default())
    }

    fn run(n: usize) -> npu::ExecReport {
        run_spec(WorkloadSpec::new(OperatorKind::Linear, n))
    }

    #[test]
    fn latency_scales_linearly() {
        let r1 = run(2048);
        let r2 = run(8192);
        let ratio = r2.span_ns / r1.span_ns;
        assert!((3.2..4.8).contains(&ratio), "4x context => ~4x latency: {ratio}");
    }

    #[test]
    fn cache_efficiency_is_high() {
        // Table V: 83.8 % — only chunk first-touches miss.
        let r = run(8192);
        assert!(
            (0.6..0.95).contains(&r.cache.efficiency()),
            "cache eff {}",
            r.cache.efficiency()
        );
    }

    #[test]
    fn moderate_stall_from_state_chain() {
        // Table V: 55.2 % — serial state dependency ping-pongs engines.
        let r = run(8192);
        assert!(
            (0.25..0.80).contains(&r.stall.stall_frac()),
            "stall {}",
            r.stall.stall_frac()
        );
    }

    #[test]
    fn d_state_sweep_mild_growth() {
        // Table VI: 2.39 -> 3.37 ms (x1.4) for d_state 16 -> 128.
        let lo = run_spec(WorkloadSpec::new(OperatorKind::Linear, 4096));
        let hi = run_spec(WorkloadSpec::new(OperatorKind::Linear, 4096).with_d_state(128));
        let ratio = hi.span_ns / lo.span_ns;
        assert!((1.05..2.5).contains(&ratio), "d_state ratio {ratio}");
    }

    #[test]
    fn dma_traffic_is_linear_in_n() {
        let spec = |n| WorkloadSpec::new(OperatorKind::Linear, n);
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let g1 = lower(&spec(2048), &hw, &sim);
        let g2 = lower(&spec(4096), &hw, &sim);
        let ratio = g2.dma_bytes() as f64 / g1.dma_bytes() as f64;
        assert!((1.8..2.2).contains(&ratio), "traffic ratio {ratio}");
    }

    #[test]
    fn fastest_of_the_quadratic_alternatives() {
        // Table IV at N=8192: Linear 3.81 ms vs Causal 251 ms.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let causal = {
            let spec = WorkloadSpec::new(OperatorKind::Causal, 2048);
            npu::run(&super::super::causal::lower(&spec, &hw, &sim), &hw, &sim)
        };
        let lin = run(2048);
        assert!(causal.span_ns / lin.span_ns > 5.0);
    }
}
