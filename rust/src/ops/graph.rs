//! The primitive-op DAG the NPU simulator executes.

/// Execution engines on the heterogeneous NPU (paper Fig 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Engine {
    /// Data Path Unit — 128×128 systolic array (matmul).
    Dpu,
    /// SHAVE vector cores (element-wise, softmax, activations).
    Shave,
    /// DMA engine (global memory ↔ scratchpad).
    Dma,
    /// Host CPU (only used by the §V offload ablation).
    Cpu,
}

impl Engine {
    pub const ALL: [Engine; 4] = [Engine::Dpu, Engine::Shave, Engine::Dma, Engine::Cpu];

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Dpu => "DPU",
            Engine::Shave => "SHAVE",
            Engine::Dma => "DMA",
            Engine::Cpu => "CPU",
        }
    }
}

/// Element-wise op class (cost class on SHAVE).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EltKind {
    /// mul/add/scale/mask — 1 cycle/elem class.
    Simple,
    /// exp/log/elu — transcendental class.
    Exp,
}

/// Transfer direction — determines whether the alloc penalty applies and
/// how the cache model classifies the traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferDir {
    /// DRAM → scratchpad (the pipeline's "pull" stage).
    Pull,
    /// Scratchpad → DRAM (spill / result writeback, "push" stage).
    Push,
}

/// Primitive operation, the unit of scheduling.
#[derive(Clone, Debug, PartialEq)]
pub enum PrimOp {
    /// Dense matmul `m×k · k×n` on the DPU.
    MatMul { m: usize, n: usize, k: usize },
    /// Element-wise op over `elems` elements on SHAVE.
    EltWise { kind: EltKind, elems: usize },
    /// Row softmax over a `rows×cols` tile on SHAVE (max/sub-exp/sum/div).
    Softmax { rows: usize, cols: usize },
    /// DMA transfer of `bytes`; `fresh_alloc` charges the §V
    /// allocation/deallocation penalty.
    Transfer { bytes: u64, dir: TransferDir, fresh_alloc: bool },
    /// DMA-driven tensor concat (Fourier state management): modeled as a
    /// gather of `bytes` into a freshly allocated contiguous buffer.
    Concat { bytes: u64 },
    /// Host-CPU byte-moving op (offload ablation).
    HostOp { bytes: u64 },
}

impl PrimOp {
    /// Which engine executes this primitive.
    pub fn engine(&self) -> Engine {
        match self {
            PrimOp::MatMul { .. } => Engine::Dpu,
            PrimOp::EltWise { .. } | PrimOp::Softmax { .. } => Engine::Shave,
            PrimOp::Transfer { .. } | PrimOp::Concat { .. } => Engine::Dma,
            PrimOp::HostOp { .. } => Engine::Cpu,
        }
    }

    /// Logical ops performed (for achieved-GOP/s accounting): 2·m·n·k for
    /// matmul, one op/elem for element-wise work, 0 for pure data movement.
    pub fn logical_ops(&self) -> u64 {
        match self {
            PrimOp::MatMul { m, n, k } => 2 * (*m as u64) * (*n as u64) * (*k as u64),
            PrimOp::EltWise { elems, .. } => *elems as u64,
            PrimOp::Softmax { rows, cols } => 4 * (*rows as u64) * (*cols as u64),
            _ => 0,
        }
    }
}

/// Buffer identity for cache/reuse accounting.
pub type BufferId = usize;

/// One operand access: `hit` means the scratchpad allocator found the
/// buffer resident (no DMA needed); misses always have a companion
/// `Transfer` node in the DAG.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BufferAccess {
    pub buffer: BufferId,
    /// Bytes per individual access (one tile).
    pub bytes: u64,
    pub hit: bool,
    /// Run-length: how many identical tile accesses this entry stands for.
    /// (Access lists are RLE-compressed — §Perf in EXPERIMENTS.md.)
    pub count: u32,
}

impl BufferAccess {
    pub fn new(buffer: BufferId, bytes: u64, hit: bool) -> Self {
        Self { buffer, bytes, hit, count: 1 }
    }

    pub fn counted(buffer: BufferId, bytes: u64, hit: bool, count: u32) -> Self {
        Self { buffer, bytes, hit, count }
    }
}

pub type NodeId = usize;

/// A scheduled node: primitive + dependencies + operand accesses.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub prim: PrimOp,
    pub deps: Vec<NodeId>,
    pub reads: Vec<BufferAccess>,
    pub writes: Vec<BufferAccess>,
}

/// The lowered DAG for one operator invocation.
#[derive(Clone, Debug, Default)]
pub struct OpGraph {
    pub nodes: Vec<Node>,
    /// Total logical ops (numerator of achieved GOP/s).
    pub logical_ops: u64,
    /// Human label, e.g. "causal N=4096".
    pub label: String,
}

impl OpGraph {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Sum of DMA bytes moved (denominator of achieved intensity).
    pub fn dma_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n.prim {
                PrimOp::Transfer { bytes, .. } | PrimOp::Concat { bytes } => bytes,
                _ => 0,
            })
            .sum()
    }

    /// Validate DAG shape: ids are dense, deps point backwards (the
    /// builders emit nodes in a valid topological order).
    pub fn validate(&self) -> Result<(), String> {
        for (i, node) in self.nodes.iter().enumerate() {
            if node.id != i {
                return Err(format!("node {i} has id {}", node.id));
            }
            for &d in &node.deps {
                if d >= i {
                    return Err(format!("node {i} depends on later/self node {d}"));
                }
            }
        }
        Ok(())
    }

    /// Per-engine node counts (sanity in tests and reports).
    pub fn engine_counts(&self) -> [usize; 4] {
        let mut c = [0usize; 4];
        for n in &self.nodes {
            match n.prim.engine() {
                Engine::Dpu => c[0] += 1,
                Engine::Shave => c[1] += 1,
                Engine::Dma => c[2] += 1,
                Engine::Cpu => c[3] += 1,
            }
        }
        c
    }
}

/// Incremental DAG builder used by the per-operator lowerings.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    nodes: Vec<Node>,
    next_buffer: BufferId,
    label: String,
}

impl GraphBuilder {
    pub fn new(label: impl Into<String>) -> Self {
        Self { nodes: Vec::new(), next_buffer: 0, label: label.into() }
    }

    /// Reserve a fresh buffer id.
    pub fn buffer(&mut self) -> BufferId {
        let id = self.next_buffer;
        self.next_buffer += 1;
        id
    }

    /// Append a node; `deps` must refer to already-added nodes.
    pub fn push(
        &mut self,
        prim: PrimOp,
        deps: Vec<NodeId>,
        reads: Vec<BufferAccess>,
        writes: Vec<BufferAccess>,
    ) -> NodeId {
        let id = self.nodes.len();
        debug_assert!(deps.iter().all(|&d| d < id), "deps must precede node");
        self.nodes.push(Node { id, prim, deps, reads, writes });
        id
    }

    /// Append a node with no buffer metadata (pure scheduling edges).
    pub fn push_simple(&mut self, prim: PrimOp, deps: Vec<NodeId>) -> NodeId {
        self.push(prim, deps, Vec::new(), Vec::new())
    }

    pub fn finish(self) -> OpGraph {
        let logical_ops = self.nodes.iter().map(|n| n.prim.logical_ops()).sum();
        OpGraph { nodes: self.nodes, logical_ops, label: self.label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm(m: usize, n: usize, k: usize) -> PrimOp {
        PrimOp::MatMul { m, n, k }
    }

    #[test]
    fn engines_assigned_by_prim() {
        assert_eq!(mm(1, 1, 1).engine(), Engine::Dpu);
        assert_eq!(
            PrimOp::Softmax { rows: 2, cols: 2 }.engine(),
            Engine::Shave
        );
        assert_eq!(
            PrimOp::Transfer { bytes: 8, dir: TransferDir::Pull, fresh_alloc: false }
                .engine(),
            Engine::Dma
        );
        assert_eq!(PrimOp::HostOp { bytes: 8 }.engine(), Engine::Cpu);
    }

    #[test]
    fn logical_ops_matmul() {
        assert_eq!(mm(128, 128, 128).logical_ops(), 2 * 128 * 128 * 128);
        assert_eq!(PrimOp::Softmax { rows: 4, cols: 8 }.logical_ops(), 4 * 32);
        assert_eq!(
            PrimOp::Transfer { bytes: 64, dir: TransferDir::Push, fresh_alloc: true }
                .logical_ops(),
            0
        );
    }

    #[test]
    fn builder_produces_valid_topological_graph() {
        let mut b = GraphBuilder::new("test");
        let t0 = b.push_simple(
            PrimOp::Transfer { bytes: 100, dir: TransferDir::Pull, fresh_alloc: true },
            vec![],
        );
        let m0 = b.push_simple(mm(128, 128, 128), vec![t0]);
        let _s0 = b.push_simple(PrimOp::Softmax { rows: 128, cols: 128 }, vec![m0]);
        let g = b.finish();
        assert_eq!(g.len(), 3);
        g.validate().unwrap();
        assert_eq!(g.logical_ops, 2 * 128 * 128 * 128 + 4 * 128 * 128);
        assert_eq!(g.dma_bytes(), 100);
        assert_eq!(g.engine_counts(), [1, 1, 1, 0]);
    }

    #[test]
    fn validate_rejects_forward_dep() {
        let g = OpGraph {
            nodes: vec![Node {
                id: 0,
                prim: mm(1, 1, 1),
                deps: vec![5],
                reads: vec![],
                writes: vec![],
            }],
            logical_ops: 0,
            label: String::new(),
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn buffer_ids_are_unique() {
        let mut b = GraphBuilder::new("buf");
        let ids: Vec<_> = (0..10).map(|_| b.buffer()).collect();
        let mut dedup = ids.clone();
        dedup.dedup();
        assert_eq!(ids, dedup);
    }
}
