//! Full Causal Mask attention lowering — the quadratic baseline.
//!
//! Mirrors the vendor kernel the paper measured: **phase-separated** and
//! cache-naive. QK^T materializes the full N×N score matrix; when it no
//! longer fits the scratchpad (beyond N ≈ 512 at 16-bit on the 4 MB part)
//! every score tile is spilled to DRAM with a fresh buffer allocation and
//! re-pulled twice (softmax pass, PV pass), and K/V are re-streamed per
//! query block with no software cache. This is the structure behind the
//! paper's Table V row: 96.7 % pipeline stalls, 7.7 % cache efficiency,
//! ~120 ms state-reuse latency at N = 8192. The residency check makes the
//! lowering scratchpad-aware, so `--hw scratchpad_bytes=...` what-if runs
//! show when a bigger scratchpad would rescue the quadratic kernel.

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};

use super::graph::{BufferAccess, EltKind, NodeId, OpGraph, PrimOp};
use super::tiling::{tiles, Lowering};

pub fn lower(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let t = sim.tile;
    let tq = tiles(n, t); // query blocks
    let tk = tiles(n, t); // key blocks (score tiles per row)
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("causal N={n} d={d}"), hw, sim);

    let qkv_bytes = (n * d) as u64 * eb;
    let tile_rows_bytes = (t * d) as u64 * eb; // one 128-row operand block
    let score_tile_bytes = (t * t) as u64 * eb;

    // Phase separation materializes scores AND probabilities: resident only
    // when both N×N planes fit next to the staged inputs.
    let score_plane_bytes = (n * n) as u64 * eb;
    if 2 * score_plane_bytes + 3 * qkv_bytes <= hw.scratchpad_bytes {
        return lower_resident(spec, hw, sim);
    }

    // Q stays resident (1/3 the footprint of K+V); K/V stream per q-block.
    let (q_buf, q_pull, _q_res) = l.stage_input(qkv_bytes);
    let k_buf = l.b.buffer();
    let v_buf = l.b.buffer();
    let score_buf = l.b.buffer(); // the spilled N×N score matrix
    let prob_buf = l.b.buffer(); // post-softmax probabilities (also spilled)
    let out_buf = l.b.buffer();

    // ---- Phase 1: QK^T, spill scores ----------------------------------
    let mut phase1_tail: Vec<NodeId> = Vec::new();
    for _qi in 0..tq {
        // Naive kernel: re-pull all of K for this query block.
        let k_pulls = l.refill_tiles(k_buf, qkv_bytes, tk, vec![q_pull]);
        let mut reads = vec![BufferAccess::new(q_buf, tile_rows_bytes, true)];
        reads.extend(l.reads(k_buf, tile_rows_bytes, tk, false));
        let mm = l.b.push(
            PrimOp::MatMul { m: t.min(n), n, k: d },
            k_pulls,
            reads,
            vec![BufferAccess::new(score_buf, (t * n) as u64 * eb, false)],
        );
        // Spill each score tile with a fresh allocation (§V alloc churn).
        let spills = l.spill_tiles(score_buf, (t.min(n) * n) as u64 * eb, tk, vec![mm]);
        phase1_tail.extend(spills.last().copied());
    }

    // ---- Phase 2: softmax over re-pulled scores, spill probabilities ---
    let mut phase2_tail: Vec<NodeId> = Vec::new();
    for _qi in 0..tq {
        let pulls = l.refill_tiles(score_buf, (t.min(n) * n) as u64 * eb, tk, phase1_tail.clone());
        let mut reads = l.reads(score_buf, score_tile_bytes, tk, false);
        reads.push(BufferAccess::new(q_buf, tile_rows_bytes, true));
        let sm = l.b.push(PrimOp::Softmax { rows: t.min(n), cols: n }, pulls, reads, vec![
            BufferAccess::new(prob_buf, (t * n) as u64 * eb, false),
        ]);
        let spills = l.spill_tiles(prob_buf, (t.min(n) * n) as u64 * eb, tk, vec![sm]);
        phase2_tail.extend(spills.last().copied());
    }

    // ---- Phase 3: PV with re-pulled probabilities and streamed V -------
    for _qi in 0..tq {
        let p_pulls = l.refill_tiles(prob_buf, (t.min(n) * n) as u64 * eb, tk, phase2_tail.clone());
        let v_pulls = l.refill_tiles(v_buf, qkv_bytes, tk, phase2_tail.clone());
        let mut deps = p_pulls;
        deps.extend(v_pulls);
        let mut reads = l.reads(prob_buf, score_tile_bytes, tk, false);
        reads.extend(l.reads(v_buf, tile_rows_bytes, tk, false));
        let mm = l.b.push(
            PrimOp::MatMul { m: t.min(n), n: d, k: n },
            deps,
            reads,
            vec![BufferAccess::new(out_buf, tile_rows_bytes, true)],
        );
        // Scale epilogue (1/sqrt(d) folded here as an elementwise pass).
        let scale = l.b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: t.min(n) * d },
            vec![mm],
            vec![BufferAccess::new(out_buf, tile_rows_bytes, true)],
            vec![BufferAccess::new(out_buf, tile_rows_bytes, true)],
        );
        l.b.push(
            PrimOp::Transfer {
                bytes: tile_rows_bytes,
                dir: super::graph::TransferDir::Push,
                fresh_alloc: false,
            },
            vec![scale],
            vec![],
            vec![],
        );
    }

    l.finish()
}

/// Scratchpad-resident path: everything (Q/K/V + both score planes) lives
/// on-chip; no spills, no K/V re-streaming. This is what a larger
/// scratchpad buys the quadratic kernel.
fn lower_resident(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
    let n = spec.n;
    let d = spec.d_head;
    let t = sim.tile;
    let tq = tiles(n, t);
    let tk = tiles(n, t);
    let eb = sim.elem_bytes;
    let mut l = Lowering::new(format!("causal-resident N={n} d={d}"), hw, sim);

    let qkv_bytes = (n * d) as u64 * eb;
    let tile_rows_bytes = (t.min(n) * d) as u64 * eb;
    let score_tile_bytes = (t.min(n) * t.min(n)) as u64 * eb;

    let (q_buf, q_pull, _) = l.stage_input(qkv_bytes);
    let (k_buf, k_pull, _) = l.stage_input(qkv_bytes);
    let (v_buf, v_pull, _) = l.stage_input(qkv_bytes);
    let score_buf = l.b.buffer();
    let out_buf = l.b.buffer();

    for _qi in 0..tq {
        let mut reads = vec![BufferAccess::new(q_buf, tile_rows_bytes, true)];
        reads.extend(l.reads(k_buf, tile_rows_bytes, tk, true));
        let mm = l.b.push(
            PrimOp::MatMul { m: t.min(n), n, k: d },
            vec![q_pull, k_pull],
            reads,
            vec![BufferAccess::new(score_buf, (t.min(n) * n) as u64 * eb, true)],
        );
        let sm = l.b.push(
            PrimOp::Softmax { rows: t.min(n), cols: n },
            vec![mm],
            l.reads(score_buf, score_tile_bytes, tk, true),
            vec![BufferAccess::new(score_buf, (t.min(n) * n) as u64 * eb, true)],
        );
        let mut reads = l.reads(score_buf, score_tile_bytes, tk, true);
        reads.extend(l.reads(v_buf, tile_rows_bytes, tk, true));
        let pv = l.b.push(
            PrimOp::MatMul { m: t.min(n), n: d, k: n },
            vec![sm, v_pull],
            reads,
            vec![BufferAccess::new(out_buf, tile_rows_bytes, true)],
        );
        l.b.push(
            PrimOp::Transfer {
                bytes: tile_rows_bytes,
                dir: super::graph::TransferDir::Push,
                fresh_alloc: false,
            },
            vec![pv],
            vec![],
            vec![],
        );
    }
    l.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::npu;

    fn graph(n: usize) -> OpGraph {
        let spec = WorkloadSpec::new(OperatorKind::Causal, n);
        lower(&spec, &NpuConfig::default(), &SimConfig::default())
    }

    #[test]
    fn graph_is_valid() {
        graph(512).validate().unwrap();
        graph(2048).validate().unwrap();
    }

    #[test]
    fn node_count_scales_quadratically() {
        let a = graph(1024).len();
        let b = graph(2048).len();
        let ratio = b as f64 / a as f64;
        assert!((3.0..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn dma_traffic_dominated_by_score_spills() {
        let g = graph(4096);
        // Score matrix round trips ≈ 4·N²·e bytes; q/k/v are megabytes.
        let n = 4096u64;
        let score_rt = 4 * n * n * 2;
        let traffic = g.dma_bytes();
        assert!(
            traffic > score_rt / 2 && traffic < score_rt * 2,
            "traffic {traffic} vs score round-trip {score_rt}"
        );
    }

    #[test]
    fn simulated_latency_scales_quadratically() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r1 = npu::run(&graph(1024), &hw, &sim);
        let r2 = npu::run(&graph(2048), &hw, &sim);
        let ratio = r2.span_ns / r1.span_ns;
        assert!((2.8..5.5).contains(&ratio), "latency ratio {ratio}");
    }

    #[test]
    fn cache_efficiency_is_poor() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = npu::run(&graph(4096), &hw, &sim);
        assert!(
            r.cache.efficiency() < 0.20,
            "causal must be cache-hostile: {}",
            r.cache.efficiency()
        );
    }

    #[test]
    fn stalls_dominate_at_long_context() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = npu::run(&graph(4096), &hw, &sim);
        assert!(r.stall.stall_frac() > 0.7, "stall {}", r.stall.stall_frac());
    }
}
