//! Thin wrapper over the `xla` crate: HLO text → compile → execute.
//!
//! One [`HloRuntime`] owns the PJRT CPU client and a name→executable cache.
//! PJRT handles are raw pointers (`!Send`), so the runtime is confined to
//! one thread; [`super::executor`] provides the channel-based handle the
//! multi-threaded coordinator uses.

use std::collections::HashMap;
use std::path::Path;
// lint:allow(no-wall-clock, "PJRT execute() reports measured device wall time")
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{Manifest, Tensor};

/// PJRT-backed executor for AOT artifacts.
pub struct HloRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl HloRuntime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Execute an artifact on host tensors; returns outputs + wall time.
    ///
    /// aot.py lowers with `return_tuple=True`, so the root is always a
    /// tuple; it is decomposed into one `Tensor` per output.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<(Vec<Tensor>, f64)> {
        self.load(name)?;
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
        if entry.input_shapes.len() != inputs.len() {
            anyhow::bail!(
                "{name}: expected {} inputs, got {}",
                entry.input_shapes.len(),
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshaping input to {dims:?}: {e}"))
            })
            .collect::<Result<_>>()?;

        let exe =
            self.cache.get(name).ok_or_else(|| anyhow!("executable {name} not loaded"))?;
        // lint:allow(no-wall-clock, "PJRT execute() reports measured device wall time")
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let elapsed_ns = t0.elapsed().as_nanos() as f64;

        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e}"))?;
        let parts = root.to_tuple().map_err(|e| anyhow!("decomposing tuple: {e}"))?;
        let outputs: Vec<Tensor> = parts
            .into_iter()
            .zip(&entry.output_shapes)
            .map(|(lit, shape)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading output values: {e}"))?;
                Tensor::new(shape.clone(), data).context("output shape mismatch")
            })
            .collect::<Result<_>>()?;
        Ok((outputs, elapsed_ns))
    }

    /// Validate an artifact against its golden I/O; returns max |Δ|.
    pub fn validate(&mut self, name: &str) -> Result<f32> {
        let golden = super::artifacts::Golden::load(self.manifest.golden_path(name))?;
        let (outputs, _) = self.execute(name, &golden.inputs)?;
        let mut max_diff = 0.0f32;
        for (got, want) in outputs.iter().zip(&golden.outputs) {
            max_diff = max_diff.max(got.max_abs_diff(want));
        }
        Ok(max_diff)
    }
}
