//! Artifact registry: the manifest and golden I/O files written by
//! `python/compile/aot.py`.
//!
//! Formats (line-oriented text, one artifact per `.hlo.txt`):
//!
//! ```text
//! manifest.txt: <name> kind=<operator|block> op=<op> n=<N> d=<D>
//!               inputs=<s0;s1;...> outputs=<s0;...>   (shapes "d0,d1")
//! golden.txt:   artifact <name>
//!               inputs <k>    then k× (tensor <rank> <dims...> / values)
//!               outputs <m>   then m× tensors
//! ```

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// A dense f32 tensor (host-side).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            bail!("shape {shape:?} wants {want} elements, got {}", data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    /// Max |a-b| against another tensor (validation metric).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

/// One manifest row.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub op: String,
    pub n: usize,
    pub d: usize,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed `manifest.txt` + directory handle.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    /// Name → index into `entries`. BTreeMap so any future iteration
    /// over the index is in name order (lint: nondet-iteration).
    by_name: BTreeMap<String, usize>,
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    s.split(';')
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.split(',')
                .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut fields = line.split_whitespace();
            let name = fields
                .next()
                .ok_or_else(|| anyhow!("manifest line {}: empty", lineno + 1))?
                .to_string();
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for f in fields {
                if let Some((k, v)) = f.split_once('=') {
                    kv.insert(k, v);
                }
            }
            let get = |k: &str| {
                kv.get(k)
                    .copied()
                    .ok_or_else(|| anyhow!("manifest line {}: missing {k}=", lineno + 1))
            };
            entries.push(ArtifactEntry {
                name,
                kind: get("kind")?.to_string(),
                op: get("op")?.to_string(),
                n: get("n")?.parse()?,
                d: get("d")?.parse()?,
                input_shapes: parse_shapes(get("inputs")?)?,
                output_shapes: parse_shapes(get("outputs")?)?,
            });
        }
        let by_name =
            entries.iter().enumerate().map(|(i, e)| (e.name.clone(), i)).collect();
        Ok(Self { dir, entries, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.by_name.get(name).and_then(|&i| self.entries.get(i))
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn golden_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.golden.txt"))
    }

    /// Entries of a given kind ("operator" / "block").
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> + 'a {
        self.entries.iter().filter(move |e| e.kind == kind)
    }
}

/// Golden inputs/outputs for one artifact.
#[derive(Clone, Debug)]
pub struct Golden {
    pub name: String,
    pub inputs: Vec<Tensor>,
    pub outputs: Vec<Tensor>,
}

impl Golden {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading golden {:?}", path.as_ref()))?;
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow!("empty golden file"))?;
        let name = header
            .strip_prefix("artifact ")
            .ok_or_else(|| anyhow!("bad golden header {header:?}"))?
            .to_string();

        let read_block = |lines: &mut std::str::Lines<'_>, tag: &str| -> Result<Vec<Tensor>> {
            let hdr = lines.next().ok_or_else(|| anyhow!("missing {tag} header"))?;
            let count: usize = hdr
                .strip_prefix(tag)
                .and_then(|s| s.trim().parse().ok())
                .ok_or_else(|| anyhow!("bad {tag} header {hdr:?}"))?;
            let mut tensors = Vec::with_capacity(count);
            for _ in 0..count {
                let meta = lines.next().ok_or_else(|| anyhow!("missing tensor header"))?;
                let mut parts = meta.split_whitespace();
                if parts.next() != Some("tensor") {
                    bail!("bad tensor header {meta:?}");
                }
                let rank: usize =
                    parts.next().ok_or_else(|| anyhow!("missing rank"))?.parse()?;
                let shape: Vec<usize> = (0..rank)
                    .map(|_| {
                        parts
                            .next()
                            .ok_or_else(|| anyhow!("missing dim"))
                            .and_then(|d| d.parse().map_err(|e| anyhow!("bad dim: {e}")))
                    })
                    .collect::<Result<_>>()?;
                let values = lines.next().ok_or_else(|| anyhow!("missing values line"))?;
                let data: Vec<f32> = values
                    .split_whitespace()
                    .map(|v| v.parse::<f32>().map_err(|e| anyhow!("bad value {v:?}: {e}")))
                    .collect::<Result<_>>()?;
                tensors.push(Tensor::new(shape, data)?);
            }
            Ok(tensors)
        };

        let inputs = read_block(&mut lines, "inputs")?;
        let outputs = read_block(&mut lines, "outputs")?;
        Ok(Self { name, inputs, outputs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(content: &str, name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("npuperf-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn tensor_max_abs_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 3.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn manifest_parses_rows() {
        let dir = std::env::temp_dir().join(format!("npuperf-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "causal_n128_d64 kind=operator op=causal n=128 d=64 \
             inputs=128,64;128,64;128,64 outputs=128,64\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("causal_n128_d64").unwrap();
        assert_eq!(e.n, 128);
        assert_eq!(e.input_shapes.len(), 3);
        assert_eq!(e.output_shapes[0], vec![128, 64]);
        assert_eq!(m.of_kind("operator").count(), 1);
        assert_eq!(m.of_kind("block").count(), 0);
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/nowhere").is_err());
    }

    #[test]
    fn golden_roundtrip() {
        let path = write_tmp(
            "artifact demo\ninputs 1\ntensor 2 2 2\n1 2 3 4\noutputs 1\ntensor 1 2\n5 6\n",
            "demo.golden.txt",
        );
        let g = Golden::load(&path).unwrap();
        assert_eq!(g.name, "demo");
        assert_eq!(g.inputs[0].shape, vec![2, 2]);
        assert_eq!(g.inputs[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(g.outputs[0].data, vec![5.0, 6.0]);
    }

    #[test]
    fn golden_rejects_malformed() {
        let path = write_tmp("not a golden\n", "bad.golden.txt");
        assert!(Golden::load(&path).is_err());
    }
}
