//! Channel-based executor: confines the (!Send) PJRT runtime to a
//! dedicated worker thread and hands out a cloneable [`ExecutorHandle`]
//! that the multi-threaded coordinator can call from anywhere.
//!
//! The executor addresses work by **artifact name**
//! (`WorkloadSpec::artifact_name`, `<op>_n<N>_d<D>`), the compiled-side
//! mirror of the operator registry's names: the coordinator resolves a
//! batch's operator through the registry and hands this executor only the
//! artifact string, so the PJRT path stays operator-agnostic too. When the
//! runtime is built against the vendored `xla` stub (no PJRT native
//! library), [`Executor::spawn`] fails fast and the router keeps every
//! request on the simulator backend.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::artifacts::Tensor;
use super::client::HloRuntime;

/// Result of one executed request.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    pub outputs: Vec<Tensor>,
    /// Device-side execute wall time, ns.
    pub exec_ns: f64,
}

enum Cmd {
    Execute { name: String, inputs: Vec<Tensor>, reply: mpsc::Sender<Result<ExecOutcome>> },
    Warmup { name: String, reply: mpsc::Sender<Result<()>> },
    Validate { name: String, reply: mpsc::Sender<Result<f32>> },
    Shutdown,
}

/// Cloneable handle to the executor thread.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Cmd>,
}

/// Owner of the executor thread; dropping it shuts the worker down.
pub struct Executor {
    handle: ExecutorHandle,
    join: Option<JoinHandle<()>>,
}

impl Executor {
    /// Spawn the worker over `artifact_dir`. Fails fast if the runtime
    /// cannot be constructed (missing artifacts, PJRT failure).
    pub fn spawn(artifact_dir: impl Into<PathBuf>) -> Result<Executor> {
        let dir = artifact_dir.into();
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let mut rt = match HloRuntime::new(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Execute { name, inputs, reply } => {
                            let res = rt.execute(&name, &inputs).map(|(outputs, exec_ns)| {
                                ExecOutcome { outputs, exec_ns }
                            });
                            let _ = reply.send(res);
                        }
                        Cmd::Warmup { name, reply } => {
                            let _ = reply.send(rt.load(&name));
                        }
                        Cmd::Validate { name, reply } => {
                            let _ = reply.send(rt.validate(&name));
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(Executor { handle: ExecutorHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecutorHandle {
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<ExecOutcome> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Execute { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Pre-compile an artifact (hides compile latency from first request).
    pub fn warmup(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Warmup { name: name.to_string(), reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Golden-validate an artifact; returns max |Δ| vs the oracle.
    pub fn validate(&self, name: &str) -> Result<f32> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Validate { name: name.to_string(), reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }
}
