//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them on the request path.
//!
//! Python never runs here: the interchange format is HLO *text* (jax ≥ 0.5
//! emits 64-bit-id protos that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids — see DESIGN.md and python/compile/aot.py).

pub mod artifacts;
pub mod client;
pub mod executor;

pub use artifacts::{Golden, Manifest, Tensor};
pub use client::HloRuntime;
pub use executor::{ExecOutcome, ExecutorHandle};
