//! Dynamic batcher: groups pending requests by workload signature
//! (operator, context, dims) so the executor runs cache-hot executables
//! and the simulator amortizes lowering.
//!
//! Policy: a signature's batch is released when it reaches `max_batch` or
//! its oldest entry has waited `max_wait_ns` (measured on a caller-supplied
//! clock so tests are deterministic). Expired batches release **oldest
//! waiter first** — signature order is only a tie-break — so no signature
//! can starve behind one that merely sorts earlier. When the caller knows
//! which sessions are resident in the session-memory pool
//! ([`Batcher::poll_expired_prefer`]), batches whose sessions are already
//! paged in dispatch ahead of cold ones at equal pressure, saving refill
//! traffic while the cold batch's sessions are paged in anyway when its
//! turn comes.
//!
//! The signature key is the whole [`WorkloadSpec`], so batching is
//! operator-agnostic: any kind the [operator
//! registry](crate::ops::registry) can dispatch batches here without
//! batcher changes, and one released [`Batch`] is always lowered exactly
//! once on the simulate path regardless of its size.

use std::collections::HashMap;

use crate::config::WorkloadSpec;

/// A group of request ids sharing one workload signature.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub spec: WorkloadSpec,
    pub request_ids: Vec<u64>,
    /// Session of each request, parallel to `request_ids` (used for
    /// residency-aware release ordering).
    pub sessions: Vec<u64>,
}

#[derive(Debug)]
struct Pending {
    ids: Vec<u64>,
    sessions: Vec<u64>,
    oldest_ns: u64,
}

/// Signature-keyed dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait_ns: u64,
    pending: HashMap<WorkloadSpec, Pending>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait_ns: u64) -> Self {
        assert!(max_batch > 0);
        Self { max_batch, max_wait_ns, pending: HashMap::new() }
    }

    /// Number of queued (unreleased) requests.
    pub fn queued(&self) -> usize {
        // lint:allow(nondet-iteration, "order-insensitive sum over queue depths")
        self.pending.values().map(|p| p.ids.len()).sum()
    }

    /// Enqueue a request; returns a batch immediately if it filled one.
    pub fn push(&mut self, id: u64, spec: WorkloadSpec, session: u64, now_ns: u64) -> Option<Batch> {
        let entry = self.pending.entry(spec).or_insert_with(|| Pending {
            ids: Vec::new(),
            sessions: Vec::new(),
            oldest_ns: now_ns,
        });
        if entry.ids.is_empty() {
            entry.oldest_ns = now_ns;
        }
        entry.ids.push(id);
        entry.sessions.push(session);
        if entry.ids.len() >= self.max_batch {
            return self
                .pending
                .remove(&spec)
                .map(|p| Batch { spec, request_ids: p.ids, sessions: p.sessions });
        }
        None
    }

    /// Release every batch whose oldest entry exceeded the wait budget,
    /// oldest waiter first (signature as deterministic tie-break).
    pub fn poll_expired(&mut self, now_ns: u64) -> Vec<Batch> {
        self.poll_expired_prefer(now_ns, |_| true)
    }

    /// Like [`Batcher::poll_expired`], but orders the released batches by
    /// session residency first: batches whose sessions are all resident
    /// in the session-memory pool dispatch before ones that would have to
    /// page state in, with wait age deciding among equals (oldest-waiter
    /// wins at equal pressure).
    pub fn poll_expired_prefer(
        &mut self,
        now_ns: u64,
        is_resident: impl Fn(u64) -> bool,
    ) -> Vec<Batch> {
        let mut due: Vec<(usize, u64, WorkloadSpec)> = self
            .pending
            .iter()
            .filter(|(_, p)| now_ns.saturating_sub(p.oldest_ns) >= self.max_wait_ns)
            .map(|(s, p)| {
                let cold = p.sessions.iter().filter(|&&sess| !is_resident(sess)).count();
                (cold, p.oldest_ns, *s)
            })
            .collect();
        due.sort_by_key(|(cold, oldest, s)| (*cold, *oldest, s.op, s.n, s.d_head, s.d_state));
        due.into_iter()
            .filter_map(|(_, _, spec)| {
                let p = self.pending.remove(&spec)?;
                Some(Batch { spec, request_ids: p.ids, sessions: p.sessions })
            })
            .collect()
    }

    /// Flush everything regardless of age (shutdown / test helper).
    pub fn flush(&mut self) -> Vec<Batch> {
        let mut specs: Vec<WorkloadSpec> = self.pending.keys().copied().collect();
        specs.sort_by_key(|s| (s.op, s.n, s.d_head, s.d_state));
        specs
            .into_iter()
            .filter_map(|spec| {
                let p = self.pending.remove(&spec)?;
                Some(Batch { spec, request_ids: p.ids, sessions: p.sessions })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::util::check::{forall, Rng};

    fn spec(op: OperatorKind, n: usize) -> WorkloadSpec {
        WorkloadSpec::new(op, n)
    }

    #[test]
    fn fills_batch_at_max() {
        let mut b = Batcher::new(3, 1_000_000);
        assert!(b.push(1, spec(OperatorKind::Causal, 128), 1, 0).is_none());
        assert!(b.push(2, spec(OperatorKind::Causal, 128), 2, 10).is_none());
        let batch = b.push(3, spec(OperatorKind::Causal, 128), 3, 20).unwrap();
        assert_eq!(batch.request_ids, vec![1, 2, 3]);
        assert_eq!(batch.sessions, vec![1, 2, 3]);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn different_signatures_do_not_mix() {
        let mut b = Batcher::new(2, 1_000_000);
        b.push(1, spec(OperatorKind::Causal, 128), 1, 0);
        assert!(b.push(2, spec(OperatorKind::Linear, 128), 2, 0).is_none());
        assert!(b.push(3, spec(OperatorKind::Causal, 256), 3, 0).is_none());
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn expiry_releases_old_batches() {
        let mut b = Batcher::new(10, 100);
        b.push(1, spec(OperatorKind::Toeplitz, 128), 1, 0);
        b.push(2, spec(OperatorKind::Toeplitz, 128), 2, 50);
        assert!(b.poll_expired(99).is_empty());
        let out = b.poll_expired(100);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].request_ids, vec![1, 2]);
    }

    #[test]
    fn expiry_timer_resets_after_release() {
        let mut b = Batcher::new(10, 100);
        b.push(1, spec(OperatorKind::Linear, 128), 1, 0);
        assert_eq!(b.poll_expired(150).len(), 1);
        b.push(2, spec(OperatorKind::Linear, 128), 2, 160);
        assert!(b.poll_expired(200).is_empty(), "new batch must not inherit age");
        assert_eq!(b.poll_expired(260).len(), 1);
    }

    #[test]
    fn oldest_waiter_released_first_at_equal_pressure() {
        // Starvation guard: Linear sorts *after* Causal by signature, but
        // it has waited longer, so it must release first.
        let mut b = Batcher::new(10, 100);
        b.push(1, spec(OperatorKind::Linear, 128), 1, 0);
        b.push(2, spec(OperatorKind::Causal, 128), 2, 50);
        let out = b.poll_expired(500);
        assert_eq!(out.len(), 2);
        assert_eq!(
            out[0].spec.op,
            OperatorKind::Linear,
            "oldest waiter wins, not signature order"
        );
        assert_eq!(out[1].spec.op, OperatorKind::Causal);
    }

    #[test]
    fn expiry_prefers_resident_sessions_then_age() {
        let mut b = Batcher::new(10, 100);
        b.push(1, spec(OperatorKind::Causal, 128), 11, 0); // older, cold
        b.push(2, spec(OperatorKind::Linear, 128), 22, 10); // newer, resident
        let out = b.poll_expired_prefer(500, |s| s == 22);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].spec.op, OperatorKind::Linear, "resident batch first");
        assert_eq!(out[1].spec.op, OperatorKind::Causal);
    }

    #[test]
    fn flush_returns_all_sorted() {
        let mut b = Batcher::new(10, u64::MAX);
        b.push(1, spec(OperatorKind::Fourier, 128), 1, 0);
        b.push(2, spec(OperatorKind::Causal, 128), 2, 0);
        let out = b.flush();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].spec.op, OperatorKind::Causal, "deterministic order");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        forall(
            "batcher conservation",
            30,
            |rng: &mut Rng| {
                let n = rng.range(1, 60) as usize;
                let ops = [OperatorKind::Causal, OperatorKind::Linear, OperatorKind::Toeplitz];
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            spec(*rng.choose(&ops), *rng.choose(&[128usize, 256])),
                            rng.range(0, 1000),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            |events| {
                let mut b = Batcher::new(4, 100);
                let mut seen = Vec::new();
                let mut t = 0;
                for &(id, s, dt) in events {
                    t += dt;
                    if let Some(batch) = b.push(id, s, id, t) {
                        seen.extend(batch.request_ids);
                    }
                    for batch in b.poll_expired(t) {
                        seen.extend(batch.request_ids);
                    }
                }
                for batch in b.flush() {
                    seen.extend(batch.request_ids);
                }
                seen.sort();
                let want: Vec<u64> = (0..events.len() as u64).collect();
                if seen == want {
                    Ok(())
                } else {
                    Err(format!("ids {seen:?} != {want:?}"))
                }
            },
        );
    }

    #[test]
    fn property_batches_are_signature_pure() {
        forall(
            "batch purity",
            20,
            |rng: &mut Rng| {
                (0..40)
                    .map(|i| {
                        let op = *rng.choose(&[OperatorKind::Causal, OperatorKind::Retentive]);
                        (i as u64, spec(op, *rng.choose(&[128usize, 256, 512])))
                    })
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let mut b = Batcher::new(3, u64::MAX);
                let mut specs_by_id: std::collections::HashMap<u64, WorkloadSpec> =
                    Default::default();
                let mut batches = Vec::new();
                for &(id, s) in reqs {
                    specs_by_id.insert(id, s);
                    if let Some(batch) = b.push(id, s, id, 0) {
                        batches.push(batch);
                    }
                }
                batches.extend(b.flush());
                for batch in &batches {
                    for id in &batch.request_ids {
                        if specs_by_id[id] != batch.spec {
                            return Err(format!("request {id} in wrong batch"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
