//! Chunked-prefill scheduler (paper §V "Chunked Prefill for Memory
//! Scaling").
//!
//! A prefill of N tokens is split into chunks of C; each chunk attends to
//! the full prefix. The scratchpad working set per chunk is
//!
//! ```text
//! W(C) = 3·C·d·e  (chunk q/k/v)  +  C²·e/4  (streamed score quarter-block)
//!        + S_state
//! ```
//!
//! While W(C) fits the 4 MB scratchpad, bigger chunks amortize dispatch
//! and DMA setup; beyond it, chunk eviction triggers super-linear
//! DMA-induced latency — which is why the paper finds the optimum at
//! C = 2048 and an ~8× peak-memory reduction vs monolithic processing.

use crate::config::NpuConfig;

/// One planned prefill chunk schedule.
#[derive(Clone, Debug)]
pub struct ChunkPlan {
    pub n: usize,
    pub chunk: usize,
    pub chunks: usize,
    /// Peak scratchpad working set, bytes.
    pub peak_bytes: u64,
    /// Predicted prefill latency, ms (dispatch + compute + DMA model).
    pub latency_ms: f64,
    /// Whether the working set overflows the scratchpad (eviction regime).
    pub overflows: bool,
}

/// Scratchpad working set of one chunk at head dim `d`, `e`-byte elements.
pub fn working_set_bytes(chunk: usize, d: usize, elem_bytes: u64) -> u64 {
    let c = chunk as u64;
    3 * c * d as u64 * elem_bytes + c * c * elem_bytes / 4 + 64 * 1024
}

/// Per-chunk command-list rebuild + weight/pipeline re-staging overhead.
/// Each prefill chunk is a separate NPU graph dispatch: the DSP rebuilds
/// descriptor lists and the DPU re-stages weights — ~150 µs on the class
/// of NPU in Table I. This is what big chunks amortize (and why the paper
/// does not simply use tiny chunks).
const CHUNK_DISPATCH_NS: f64 = 150_000.0;

/// Plan a chunked prefill of `n` tokens with chunk size `chunk`.
pub fn plan(n: usize, chunk: usize, d: usize, hw: &NpuConfig) -> ChunkPlan {
    let e = 2u64;
    let chunks = n.div_ceil(chunk);
    let peak = working_set_bytes(chunk, d, e);
    let overflows = peak > hw.scratchpad_bytes;

    // Latency model per chunk i (prefix length p_i = i·C):
    //   compute: score+PV matmuls at the effective tile rate;
    //   dma: chunk + prefix KV streaming at nominal bandwidth + per-chunk
    //        descriptor setup;
    //   eviction penalty: super-linear once W(C) overflows (every spilled
    //   score tile pays the alloc round trip).
    let mut total_ns = 0.0;
    let tile_ns = {
        // effective per-128³-tile time (fill+stream+drain at fp16).
        let cyc = hw.dpu_cycle_ns();
        (hw.dpu_fill_cycles + hw.dpu_drain_cycles) as f64 * cyc + 128.0 / hw.fp16_rate * cyc
    };
    for i in 0..chunks {
        let c = chunk.min(n - i * chunk);
        let prefix = (i * chunk + c) as f64;
        // Causal kernels skip fully-masked tiles: the chunk's own block
        // contributes its lower triangle only (c/2 effective columns).
        let eff_cols = prefix - c as f64 / 2.0;
        let score_tiles = (c as f64 / 128.0).ceil() * (eff_cols / 128.0).ceil();
        let compute = 2.0 * score_tiles * tile_ns + hw.dpu_issue_ns;
        let kv_bytes = 2.0 * prefix * d as f64 * e as f64;
        let mut dma = kv_bytes / hw.dma_bytes_per_ns() + hw.dma_setup_ns * 4.0;
        if overflows {
            // Eviction regime: each spilled score tile round-trips with a
            // fresh allocation — the §V "super-linear" DMA growth.
            let spill_frac =
                (peak - hw.scratchpad_bytes) as f64 / peak.max(1) as f64;
            dma += score_tiles * spill_frac * (hw.dma_alloc_ns + hw.dma_setup_ns + 2.0 * 32768.0 / hw.dma_bytes_per_ns());
        }
        total_ns += compute.max(dma) + hw.shave_issue_ns + CHUNK_DISPATCH_NS;
    }
    ChunkPlan {
        n,
        chunk,
        chunks,
        peak_bytes: peak,
        latency_ms: total_ns / 1e6,
        overflows,
    }
}

/// Sweep power-of-two chunk sizes and return the latency-optimal plan.
pub fn optimal_chunk(n: usize, d: usize, hw: &NpuConfig) -> ChunkPlan {
    let candidates = [256usize, 512, 1024, 2048, 4096, 8192];
    candidates
        .iter()
        .filter(|&&c| c <= n.max(256))
        .map(|&c| plan(n, c, d, hw))
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
        .expect("non-empty candidate set")
}

/// Peak-memory reduction of chunked vs monolithic prefill (paper: ~8×).
pub fn peak_memory_reduction(n: usize, chunk: usize, d: usize) -> f64 {
    let mono = working_set_bytes(n, d, 2) as f64;
    let chunked = working_set_bytes(chunk, d, 2) as f64;
    mono / chunked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_chunk_is_2048_at_paper_shape() {
        // §V: "optimal chunk sizes (2048 tokens) ... within the NPU's 4 MB
        // scratchpad".
        let hw = NpuConfig::default();
        let best = optimal_chunk(16_384, 64, &hw);
        assert_eq!(best.chunk, 2048, "best plan: {best:?}");
        assert!(!best.overflows);
    }

    #[test]
    fn working_set_fits_at_2048_overflows_at_4096() {
        let hw = NpuConfig::default();
        assert!(working_set_bytes(2048, 64, 2) <= hw.scratchpad_bytes);
        assert!(working_set_bytes(4096, 64, 2) > hw.scratchpad_bytes);
    }

    #[test]
    fn overflow_latency_grows_superlinearly() {
        let hw = NpuConfig::default();
        let ok = plan(16_384, 2048, 64, &hw);
        let over = plan(16_384, 8192, 64, &hw);
        assert!(over.overflows);
        assert!(
            over.latency_ms > 1.5 * ok.latency_ms,
            "eviction must dominate: {} vs {}",
            over.latency_ms,
            ok.latency_ms
        );
    }

    #[test]
    fn peak_memory_reduction_near_paper_8x() {
        // §V: "intelligent chunking reduces peak memory pressure by 8x
        // versus monolithic processing" (N=16K monolithic vs C=2048).
        let r = peak_memory_reduction(16_384, 2048, 64);
        assert!((4.0..100.0).contains(&r), "reduction {r:.1}x");
    }

    #[test]
    fn chunk_count_covers_context() {
        let hw = NpuConfig::default();
        let p = plan(10_000, 2048, 64, &hw);
        assert_eq!(p.chunks, 5);
        assert_eq!(p.n, 10_000);
    }

    #[test]
    fn tiny_context_single_chunk() {
        let hw = NpuConfig::default();
        let best = optimal_chunk(256, 64, &hw);
        assert_eq!(best.chunks, 1);
    }
}
