//! Batch dispatch: run one batch on one [`Device`].
//!
//! This is the execution stage of the serve pipeline, extracted from the
//! ~200-line closure that used to live inside `serve_loop`. The
//! [`Dispatcher`] owns the routing policy, the (optional) PJRT executor
//! handle, and the injected [`Clock`]; each [`Dispatcher::dispatch`] call
//! takes the batch the placement stage assigned plus a mutable [`Device`]
//! and runs the whole per-batch path on it — registry lowering, NPU
//! simulation (or PJRT execution), session-memory admission against the
//! *device's* pool, tracing, metrics (labeled with the device), replies —
//! then extends the device's model-time timeline by the batch's cost.
//!
//! Nothing here panics on the serving thread: a kind missing from a
//! custom registry, a degenerate PJRT input shape, or an admission
//! refusal each turn into an error reply for the affected requests.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::npu::{self, ExecReport};
use crate::obs::{engine_spans, Tracer};
use crate::ops::registry;
use crate::runtime::executor::ExecutorHandle;
use crate::runtime::Tensor;

use super::batcher::Batch;
use super::device::Device;
use super::metrics::{Clock, Metrics};
use super::router::{BackendKind, Router};
use super::server::{Job, Response};

/// Runs batches on devices: the execution stage of the serve pipeline.
#[derive(Debug)]
pub struct Dispatcher {
    router: Router,
    exec: Option<ExecutorHandle>,
    clock: Arc<dyn Clock>,
    /// Per-device cap on tracked sessions; the dispatcher GCs the
    /// device's pool bookkeeping after every batch.
    max_tracked_sessions: usize,
}

impl Dispatcher {
    pub fn new(
        router: Router,
        exec: Option<ExecutorHandle>,
        clock: Arc<dyn Clock>,
        max_tracked_sessions: usize,
    ) -> Self {
        Self { router, exec, clock, max_tracked_sessions }
    }

    /// The routing policy (placement and reports read it too).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Run `batch` on `device`: resolve jobs out of `jobs`, reply to each
    /// request, record device-labeled metrics and trace stages, and
    /// advance the device's model-time timeline.
    pub fn dispatch(
        &self,
        batch: Batch,
        device: &mut Device,
        jobs: &mut BTreeMap<u64, Job>,
        metrics: &mut Metrics,
        tracer: &mut Tracer,
    ) {
        let dispatch_ns = self.clock.now_ns();
        let backend = self.router.route(&batch.spec);
        let size = batch.request_ids.len();
        metrics.record_batch(batch.spec.op, device.label, size);
        // Model time this batch occupies the device: the simulated (or
        // PJRT) backend span plus every admission's spill/refill charge.
        let mut model_ns: f64 = 0.0;
        let mut served: u64 = 0;
        // Simulate path: resolve the batch's operator through the registry
        // and lower once per batch signature against **this device's**
        // hardware model. A kind missing from a custom registry leaves
        // this as None and each request in the batch gets an error reply —
        // never a panic on the long-lived serving thread. The PJRT path
        // never touches the registry: it executes a precompiled artifact
        // keyed by the workload kind.
        let sim = if backend == BackendKind::Simulate {
            registry::global().try_for_kind(batch.spec.op).map(|op_impl| {
                let lower_start_ns = self.clock.now_ns();
                let g = op_impl.lower(&batch.spec, &device.hw, &device.sim);
                let strace = npu::simulate(&g, &device.hw, &device.sim);
                let report = ExecReport::from_trace(&g, &strace);
                let lower_end_ns = self.clock.now_ns();
                metrics.record_sim(batch.spec.op, device.label, &report, &device.ceilings);
                let spans =
                    if tracer.enabled() { engine_spans(&g, &strace) } else { Vec::new() };
                (op_impl.name(), report, spans, lower_start_ns, lower_end_ns)
            })
        } else {
            None
        };
        if let Some((_, report, _, _, _)) = &sim {
            model_ns += report.span_ns;
        }
        for id in batch.request_ids {
            let Some(job) = jobs.remove(&id) else { continue };
            let spec = job.request.spec;
            let queue_ns = dispatch_ns.saturating_sub(job.enqueued_ns);
            tracer.stage(id, "queued", job.enqueued_ns, dispatch_ns);
            tracer.set_device(id, device.label);
            // The request timeline cursor: real clock until the backend,
            // then dilated by model time (spill charge, simulated
            // makespan) so nested engine spans tile their stage exactly.
            let mut cursor = dispatch_ns;
            if let Some((_, _, _, l0, l1)) = &sim {
                tracer.stage(id, "lower", *l0, *l1);
                cursor = *l1;
            }
            // Admission control: page the session's state in before the
            // request runs (`admit` never evicts the session it is
            // admitting; explicit pinning is the hook for concurrent
            // dispatchers and latency-critical sessions, not needed on
            // this serial path). A footprint the pool can never hold is
            // shed with an error instead of growing state without bound.
            // A session that just migrated here additionally owes its
            // cross-device transfer time.
            let session = job.request.session;
            let migration_ns = device.take_migration_debt(session);
            device.state.open(session, spec.op, spec.d_head, spec.d_state);
            let spill_ns = match device.state.touch(session, spec.n) {
                Ok(adm) => {
                    let ns = adm.total_ns() + migration_ns;
                    tracer.stage(id, "admission", cursor, cursor + ns as u64);
                    cursor += ns as u64;
                    model_ns += ns;
                    ns
                }
                Err(e) => {
                    metrics.record_shed(spec.op, device.label);
                    tracer.stage(id, "admission", cursor, cursor);
                    tracer.finish(id, "shed");
                    let _ = job.reply.send(Err(anyhow!(
                        "request shed by session-memory admission control: {e}"
                    )));
                    continue;
                }
            };
            let result = match backend {
                BackendKind::Pjrt => self.execute_pjrt(
                    &job, id, device, spec, size, spill_ns, queue_ns, &mut cursor, tracer,
                ),
                BackendKind::Simulate => match &sim {
                    Some((operator, report, spans, _, _)) => {
                        let operator = *operator;
                        tracer.set_operator(id, operator);
                        tracer.stage(id, "npu-simulate", cursor, cursor + report.span_ns as u64);
                        tracer.attach_engine_spans(id, cursor, spans);
                        cursor += report.span_ns as u64;
                        Ok(Response {
                            spec,
                            operator,
                            backend,
                            device: device.id,
                            backend_ns: report.span_ns,
                            spill_ns,
                            queue_ns,
                            trace_id: id,
                            outputs: None,
                            sim_report: Some(report.clone()),
                            batch_size: size,
                        })
                    }
                    None => Err(anyhow!(
                        "no operator registered for workload kind {}",
                        spec.op
                    )),
                },
            };
            if let Ok(r) = &result {
                if backend == BackendKind::Pjrt {
                    model_ns += r.backend_ns;
                }
            }
            tracer.stage(id, "respond", cursor, cursor);
            match &result {
                Ok(_) => {
                    let latency_ns =
                        self.clock.now_ns().saturating_sub(job.enqueued_ns).max(queue_ns) as f64;
                    metrics.record_request(
                        spec.op,
                        backend,
                        device.label,
                        queue_ns,
                        spill_ns,
                        latency_ns,
                    );
                    tracer.finish(id, "served");
                    served += 1;
                }
                Err(_) => tracer.finish(id, "error"),
            }
            let _ = job.reply.send(result);
        }
        device.note_batch(served);
        device.advance(dispatch_ns, model_ns as u64);
        // Keep the session map bounded: forget LRU spilled sessions once
        // the tracked count exceeds the configured cap.
        let _ = device.state.gc(self.max_tracked_sessions);
    }

    /// PJRT leg of one request. Default inputs are built fallibly — a
    /// degenerate spec turns into an error reply, never a panic on the
    /// serving thread.
    #[allow(clippy::too_many_arguments)]
    fn execute_pjrt(
        &self,
        job: &Job,
        id: u64,
        device: &Device,
        spec: crate::config::WorkloadSpec,
        size: usize,
        spill_ns: f64,
        queue_ns: u64,
        cursor: &mut u64,
        tracer: &mut Tracer,
    ) -> Result<Response> {
        let inputs = match job.request.inputs.clone() {
            Some(inputs) => inputs,
            None => {
                // Deterministic constants when the caller only wants timing.
                let t = Tensor::new(vec![spec.n, spec.d_head], vec![0.1; spec.n * spec.d_head])
                    .map_err(|e| {
                        anyhow!(
                            "cannot build default PJRT inputs for {} N={}: {e}",
                            spec.op,
                            spec.n
                        )
                    })?;
                vec![t; 3]
            }
        };
        let out = self
            .exec
            .as_ref()
            .ok_or_else(|| anyhow!("PJRT backend routed without an executor"))?
            .execute(&spec.artifact_name(), inputs)?;
        tracer.set_operator(id, spec.op.name());
        tracer.stage(id, "pjrt-execute", *cursor, *cursor + out.exec_ns as u64);
        *cursor += out.exec_ns as u64;
        Ok(Response {
            spec,
            // The artifact is a precompiled build of the kind's kernel
            // family, independent of which lowering the registry
            // currently maps the kind to — attribute it as such.
            operator: spec.op.name(),
            backend: BackendKind::Pjrt,
            device: device.id,
            backend_ns: out.exec_ns,
            spill_ns,
            queue_ns,
            trace_id: id,
            outputs: Some(out.outputs),
            sim_report: None,
            batch_size: size,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OperatorKind, WorkloadSpec};
    use crate::coordinator::server::CoordinatorConfig;
    use crate::coordinator::ManualClock;
    use crate::coordinator::Request;

    fn job(spec: WorkloadSpec, session: u64) -> (Job, mpsc::Receiver<Result<Response>>) {
        let (reply, rx) = mpsc::channel();
        (Job { request: Request { spec, session, inputs: None }, reply, enqueued_ns: 0 }, rx)
    }

    fn batch(spec: WorkloadSpec, ids: Vec<u64>, sessions: Vec<u64>) -> Batch {
        Batch { spec, request_ids: ids, sessions }
    }

    #[test]
    fn dispatch_runs_one_batch_on_one_device() {
        let cfg = CoordinatorConfig::default();
        let clock: Arc<dyn Clock> = Arc::new(ManualClock::new());
        let d = Dispatcher::new(Router::simulate_only(), None, clock, 1024);
        let mut device = Device::new(0, &cfg);
        let mut jobs = BTreeMap::new();
        let mut metrics = Metrics::new();
        let mut tracer = Tracer::new(false, 0);
        let spec = WorkloadSpec::new(OperatorKind::Linear, 1024);
        let (j, rx) = job(spec, 9);
        jobs.insert(0, j);
        let b = batch(spec, vec![0], vec![9]);
        d.dispatch(b, &mut device, &mut jobs, &mut metrics, &mut tracer);
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.device, 0);
        assert_eq!(r.backend, BackendKind::Simulate);
        assert!(r.backend_ns > 0.0);
        assert_eq!(device.served(), 1);
        assert_eq!(device.batches(), 1);
        assert!(device.busy_until_ns() > 0, "model time extends the timeline");
        assert_eq!(metrics.total_served(), 1);
        assert_eq!(device.state.len(), 1, "session opened on the device's own pool");
    }

    #[test]
    fn pjrt_route_without_executor_is_an_error_reply() {
        // Router says PJRT but no executor handle exists: the request
        // must get an error reply, not panic the dispatcher.
        let cfg = CoordinatorConfig::default();
        let clock: Arc<dyn Clock> = Arc::new(ManualClock::new());
        let d = Dispatcher::new(Router::standard(), None, clock, 1024);
        let mut device = Device::new(0, &cfg);
        let mut jobs = BTreeMap::new();
        let mut metrics = Metrics::new();
        let mut tracer = Tracer::new(false, 0);
        let spec = WorkloadSpec::new(OperatorKind::Causal, 256); // artifact context
        let (j, rx) = job(spec, 1);
        jobs.insert(0, j);
        let b = batch(spec, vec![0], vec![1]);
        d.dispatch(b, &mut device, &mut jobs, &mut metrics, &mut tracer);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("without an executor"), "{err}");
        assert_eq!(metrics.total_served(), 0);
    }

    #[test]
    fn shed_request_reports_the_admission_error() {
        let cfg = CoordinatorConfig {
            state_budget_bytes: 64 * 1024, // pool far below a long KV footprint
            ..CoordinatorConfig::default()
        };
        let clock: Arc<dyn Clock> = Arc::new(ManualClock::new());
        let d = Dispatcher::new(Router::simulate_only(), None, clock, 1024);
        let mut device = Device::new(0, &cfg);
        let mut jobs = BTreeMap::new();
        let mut metrics = Metrics::new();
        let mut tracer = Tracer::new(false, 0);
        let spec = WorkloadSpec::new(OperatorKind::Causal, 65_536);
        let (j, rx) = job(spec, 4);
        jobs.insert(0, j);
        let b = batch(spec, vec![0], vec![4]);
        d.dispatch(b, &mut device, &mut jobs, &mut metrics, &mut tracer);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("admission control"), "{err}");
        assert_eq!(metrics.shed_requests(), 1);
    }
}
