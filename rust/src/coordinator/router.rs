//! Request router: pick the backend for a workload.
//!
//! Contexts with a compiled AOT artifact run on the **PJRT runtime** (real
//! numerics); longer contexts run on the **NPU simulator** (the paper's
//! microbenchmark regime, 1024-8192, where compiling interpret-mode Pallas
//! HLO is neither needed nor meaningful on CPU). The router also exposes
//! the cost-model advice the §V co-design discussion calls for: given a
//! context length, which operator family is expected to be fastest.

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::{npu, ops};

/// Execution backend for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Real execution through the PJRT CPU client.
    Pjrt,
    /// Cycle-approximate NPU simulation.
    Simulate,
}

/// Routing policy over the artifact inventory.
#[derive(Clone, Debug)]
pub struct Router {
    /// Context lengths with compiled operator artifacts (sorted).
    artifact_contexts: Vec<usize>,
    artifact_d_head: usize,
}

impl Router {
    pub fn new(mut artifact_contexts: Vec<usize>, artifact_d_head: usize) -> Self {
        artifact_contexts.sort_unstable();
        Self { artifact_contexts, artifact_d_head }
    }

    /// Router for the standard `make artifacts` inventory.
    pub fn standard() -> Self {
        Self::new(vec![128, 256, 512], 64)
    }

    /// Simulation-only router (no artifacts available).
    pub fn simulate_only() -> Self {
        Self::new(Vec::new(), 0)
    }

    pub fn route(&self, spec: &WorkloadSpec) -> BackendKind {
        if self.artifact_contexts.binary_search(&spec.n).is_ok()
            && spec.d_head == self.artifact_d_head
            && spec.d_state == 16
        {
            BackendKind::Pjrt
        } else {
            BackendKind::Simulate
        }
    }

    /// Cost-model advice (§V co-design): simulate every operator at `n` and
    /// rank by latency. Returns (operator, predicted ms) sorted fastest
    /// first.
    pub fn rank_operators(
        &self,
        n: usize,
        hw: &NpuConfig,
        sim: &SimConfig,
    ) -> Vec<(OperatorKind, f64)> {
        let mut ranked: Vec<(OperatorKind, f64)> = OperatorKind::ALL
            .iter()
            .map(|&op| {
                let spec = WorkloadSpec::new(op, n);
                let g = ops::lower(&spec, hw, sim);
                let r = npu::run(&g, hw, sim);
                (op, r.latency_ms())
            })
            .collect();
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        ranked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_route_to_pjrt() {
        let r = Router::standard();
        let spec = WorkloadSpec::new(OperatorKind::Causal, 256);
        assert_eq!(r.route(&spec), BackendKind::Pjrt);
    }

    #[test]
    fn long_context_routes_to_simulator() {
        let r = Router::standard();
        for n in [1024, 4096, 8192] {
            let spec = WorkloadSpec::new(OperatorKind::Causal, n);
            assert_eq!(r.route(&spec), BackendKind::Simulate, "N={n}");
        }
    }

    #[test]
    fn nonstandard_dims_route_to_simulator() {
        let r = Router::standard();
        let spec = WorkloadSpec::new(OperatorKind::Linear, 256).with_d_state(128);
        assert_eq!(r.route(&spec), BackendKind::Simulate);
        let spec = WorkloadSpec::new(OperatorKind::Linear, 256).with_d_head(32);
        assert_eq!(r.route(&spec), BackendKind::Simulate);
    }

    #[test]
    fn simulate_only_never_routes_pjrt() {
        let r = Router::simulate_only();
        let spec = WorkloadSpec::new(OperatorKind::Toeplitz, 128);
        assert_eq!(r.route(&spec), BackendKind::Simulate);
    }

    #[test]
    fn ranking_prefers_structured_operators_at_long_context() {
        // Paper conclusion: Toeplitz/Linear win the long-context regime.
        let r = Router::standard();
        let ranked = r.rank_operators(4096, &NpuConfig::default(), &SimConfig::default());
        let top2: Vec<OperatorKind> = ranked[..2].iter().map(|x| x.0).collect();
        assert!(top2.contains(&OperatorKind::Toeplitz));
        assert!(top2.contains(&OperatorKind::Linear));
        assert_eq!(ranked.last().unwrap().0, OperatorKind::Fourier, "worst scaler");
    }
}
