//! Request router: pick the backend for a workload.
//!
//! Contexts with a compiled AOT artifact run on the **PJRT runtime** (real
//! numerics); longer contexts run on the **NPU simulator** (the paper's
//! microbenchmark regime, 1024-8192, where compiling interpret-mode Pallas
//! HLO is neither needed nor meaningful on CPU). The router also exposes
//! the cost-model advice the §V co-design discussion calls for — given a
//! context length, which operator is expected to be fastest — via
//! [`CausalOperator::predict_ms`]: [`Router::rank_operators`] ranks the
//! **dispatchable** set (the registry's canonical kernel per kind, i.e.
//! exactly what a kind-keyed request will be served), while
//! [`Router::rank_all`] ranks the whole registry including co-design
//! variants like `retentive-chunked` for exploration.

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::ops::registry::{self, CausalOperator};

/// Shared ranking body: predict latency for each operator at context `n`
/// and sort fastest first.
fn rank(
    ops: impl Iterator<Item = &'static dyn CausalOperator>,
    n: usize,
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<(&'static dyn CausalOperator, f64)> {
    let mut ranked: Vec<(&'static dyn CausalOperator, f64)> = ops
        .map(|op| {
            let spec = WorkloadSpec::new(op.kind(), n);
            (op, op.predict_ms(&spec, hw, sim))
        })
        .collect();
    ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    ranked
}

/// Execution backend for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Real execution through the PJRT CPU client.
    Pjrt,
    /// Cycle-approximate NPU simulation.
    Simulate,
}

/// Routing policy over the artifact inventory.
#[derive(Clone, Debug)]
pub struct Router {
    /// Context lengths with compiled operator artifacts (sorted).
    artifact_contexts: Vec<usize>,
    artifact_d_head: usize,
}

impl Router {
    pub fn new(mut artifact_contexts: Vec<usize>, artifact_d_head: usize) -> Self {
        artifact_contexts.sort_unstable();
        Self { artifact_contexts, artifact_d_head }
    }

    /// Router for the standard `make artifacts` inventory.
    pub fn standard() -> Self {
        Self::new(vec![128, 256, 512], 64)
    }

    /// Simulation-only router (no artifacts available).
    pub fn simulate_only() -> Self {
        Self::new(Vec::new(), 0)
    }

    /// Choose the backend for one request.
    pub fn route(&self, spec: &WorkloadSpec) -> BackendKind {
        if self.artifact_contexts.binary_search(&spec.n).is_ok()
            && spec.d_head == self.artifact_d_head
            && spec.d_state == 16
        {
            BackendKind::Pjrt
        } else {
            BackendKind::Simulate
        }
    }

    /// Cost-model advice (§V co-design): rank the operators the serving
    /// stack will actually dispatch — the registry's canonical entry per
    /// [`OperatorKind`] — at context `n` by predicted latency. Returns
    /// (operator, predicted ms) sorted fastest first. Every entry here is
    /// directly actionable: submitting a request with that kind serves
    /// exactly that operator.
    pub fn rank_operators(
        &self,
        n: usize,
        hw: &NpuConfig,
        sim: &SimConfig,
    ) -> Vec<(&'static dyn CausalOperator, f64)> {
        let reg = registry::global();
        rank(OperatorKind::ALL.iter().map(move |&kind| reg.for_kind(kind)), n, hw, sim)
    }

    /// Exploration ranking over the **whole** registry, including variants
    /// that share a kind with a canonical kernel (e.g.
    /// `retentive-chunked`). Variants are not addressable through
    /// kind-keyed serving requests — run them by registry name
    /// (`npuperf simulate retentive-chunked <N>`) or promote one to
    /// canonical by registration order in a custom registry.
    pub fn rank_all(
        &self,
        n: usize,
        hw: &NpuConfig,
        sim: &SimConfig,
    ) -> Vec<(&'static dyn CausalOperator, f64)> {
        rank(registry::global().iter(), n, hw, sim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;

    #[test]
    fn artifacts_route_to_pjrt() {
        let r = Router::standard();
        let spec = WorkloadSpec::new(OperatorKind::Causal, 256);
        assert_eq!(r.route(&spec), BackendKind::Pjrt);
    }

    #[test]
    fn long_context_routes_to_simulator() {
        let r = Router::standard();
        for n in [1024, 4096, 8192] {
            let spec = WorkloadSpec::new(OperatorKind::Causal, n);
            assert_eq!(r.route(&spec), BackendKind::Simulate, "N={n}");
        }
    }

    #[test]
    fn nonstandard_dims_route_to_simulator() {
        let r = Router::standard();
        let spec = WorkloadSpec::new(OperatorKind::Linear, 256).with_d_state(128);
        assert_eq!(r.route(&spec), BackendKind::Simulate);
        let spec = WorkloadSpec::new(OperatorKind::Linear, 256).with_d_head(32);
        assert_eq!(r.route(&spec), BackendKind::Simulate);
    }

    #[test]
    fn simulate_only_never_routes_pjrt() {
        let r = Router::simulate_only();
        let spec = WorkloadSpec::new(OperatorKind::Toeplitz, 128);
        assert_eq!(r.route(&spec), BackendKind::Simulate);
    }

    #[test]
    fn ranking_prefers_structured_operators_at_long_context() {
        // Paper conclusion: Toeplitz/Linear win the long-context regime.
        let r = Router::standard();
        let ranked = r.rank_operators(4096, &NpuConfig::default(), &SimConfig::default());
        assert_eq!(ranked.len(), OperatorKind::ALL.len(), "one entry per servable kind");
        let top2: Vec<&str> = ranked[..2].iter().map(|x| x.0.name()).collect();
        assert!(top2.contains(&"toeplitz"), "{top2:?}");
        assert!(top2.contains(&"linear"), "{top2:?}");
        assert_eq!(ranked.last().unwrap().0.name(), "fourier", "worst scaler");
    }

    #[test]
    fn rank_operators_only_recommends_dispatchable_kernels() {
        // Serving requests are kind-keyed: advice must match what
        // for_kind() will dispatch, so variants never appear here.
        let r = Router::simulate_only();
        let ranked = r.rank_operators(1024, &NpuConfig::default(), &SimConfig::default());
        for (op, _) in &ranked {
            assert_eq!(
                registry::global().for_kind(op.kind()).name(),
                op.name(),
                "ranked operator is exactly the one serving would dispatch"
            );
        }
    }

    #[test]
    fn rank_all_includes_registered_variants() {
        let r = Router::simulate_only();
        let ranked = r.rank_all(2048, &NpuConfig::default(), &SimConfig::default());
        assert_eq!(ranked.len(), registry::global().len(), "full registry ranked");
        let names: Vec<&str> = ranked.iter().map(|x| x.0.name()).collect();
        assert!(names.contains(&"retentive-chunked"), "{names:?}");
        // The co-design variant must beat its quadratic sibling.
        let pos = |n: &str| names.iter().position(|x| *x == n).unwrap();
        assert!(pos("retentive-chunked") < pos("retentive"));
    }
}
