//! The serving stack's only wall-clock boundary.
//!
//! Every time-derived number in the coordinator (uptime, throughput,
//! queue ages, batching windows) is read off the injectable [`Clock`]
//! trait rather than `Instant::now()` directly, so tests and the
//! `--deterministic` serve mode can drive a [`ManualClock`] and assert
//! exact values; production uses the monotonic [`WallClock`].
//!
//! This module is the *single* place in the crate allowed to touch
//! `std::time`'s clock sources: the `no-wall-clock` rule of
//! `npuperf lint` (see `docs/LINTS.md`) flags `Instant`/`SystemTime`
//! anywhere else under `rust/src/`, which is what keeps seeded replays
//! bit-identical — nothing off this boundary can observe host time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotonic nanosecond time source for the serving stack.
///
/// The coordinator never calls `Instant::now()` itself — it reads this,
/// so a test can substitute a [`ManualClock`] and make queue ages,
/// uptime, and throughput deterministic.
pub trait Clock: std::fmt::Debug + Send + Sync {
    /// Nanoseconds since an arbitrary per-clock epoch (monotonic).
    fn now_ns(&self) -> u64;
}

/// Production clock: monotonic nanoseconds since construction.
#[derive(Clone, Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Test clock: advances only when told to. Cloning shares the underlying
/// counter, so the copy handed to the coordinator and the one kept by the
/// test tick together.
#[derive(Clone, Debug, Default)]
pub struct ManualClock {
    ns: Arc<AtomicU64>,
}

impl ManualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_ns(&self, delta: u64) {
        self.ns.fetch_add(delta, Ordering::SeqCst);
    }

    pub fn set_ns(&self, ns: u64) {
        self.ns.store(ns, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_clones_share_the_counter() {
        let c = ManualClock::new();
        let shared = c.clone();
        c.advance_ns(250);
        assert_eq!(shared.now_ns(), 250);
        shared.set_ns(7);
        assert_eq!(c.now_ns(), 7);
    }
}
