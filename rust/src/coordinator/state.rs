//! Session state manager: the memory-state tradeoff of paper Fig 1,
//! enforced by the paged session-memory subsystem (`crate::memory`).
//!
//! Attention-class sessions keep an explicit KV cache that grows O(N·d)
//! with context; retention/SSM-class sessions compress to a fixed-size
//! recurrent state, O(d·d_state); banded operators keep an O(band·d)
//! ring buffer. Each session's growth curve comes from its operator's
//! [`CausalOperator::state_footprint`](crate::ops::CausalOperator::state_footprint)
//! via the registry, so a new operator is charged correctly with zero
//! manager changes. The manager no longer *destroys* sessions under
//! pressure: the pool spills the LRU unpinned victim's pages out (priced
//! with the calibrated DMA ceiling) and pages them back in when the
//! session is next served — evictions cost nanoseconds, not correctness.

use std::collections::HashMap;

use crate::config::{NpuConfig, OperatorKind, WorkloadSpec};
use crate::memory::{Admission, AdmitError, MemStats, MemoryConfig, SessionMemory};
use crate::ops::registry;

/// Context-retention class of an operator (Fig 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionKind {
    /// Explicit KV cache: O(N·d) persistent bytes (Toeplitz's banded
    /// window is the capped variant of this class).
    KvCache,
    /// Compressed recurrent state: O(d·d_state) persistent bytes.
    RecurrentState,
}

impl SessionKind {
    /// Classification per paper §II-A: attention-style operators retain
    /// K/V; retention, linear attention and SSM-inspired operators carry
    /// a fixed decayed/outer-product state across steps.
    pub fn for_operator(op: OperatorKind) -> Self {
        match op {
            OperatorKind::Causal | OperatorKind::Toeplitz => SessionKind::KvCache,
            OperatorKind::Retentive | OperatorKind::Linear | OperatorKind::Fourier => {
                SessionKind::RecurrentState
            }
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct SessionMeta {
    op: OperatorKind,
    d_head: usize,
    d_state: usize,
    tokens: usize,
}

/// The operator's persistent-state growth curve, resolved through the
/// registry — the single source every layer (serving pool, capacity
/// report, deploy planner) prices state with. A kind absent from a
/// custom registry falls back to the class defaults so accounting never
/// panics on the serving thread.
pub fn footprint_for(op: OperatorKind, tokens: usize, d_head: usize, d_state: usize) -> u64 {
    let spec = WorkloadSpec { op, n: tokens.max(1), d_head, d_state };
    match registry::global().try_for_kind(op) {
        Some(entry) => entry.state_footprint(&spec, tokens),
        // Mirror of the builtin curves for registries that dropped a
        // kind (such a kind cannot be served — dispatch errors — but its
        // accounting must still match what the builtins would charge).
        None => match op {
            OperatorKind::Causal => 2 * tokens as u64 * d_head as u64 * 2,
            OperatorKind::Toeplitz => {
                2 * tokens.min(crate::ops::toeplitz::band_for(&spec)) as u64
                    * d_head as u64
                    * 2
            }
            OperatorKind::Retentive => (d_head * d_head) as u64 * 4,
            OperatorKind::Linear => (d_head * d_state) as u64 * 4,
            OperatorKind::Fourier => 2 * (d_head * d_state) as u64 * 4,
        },
    }
}

/// KV / recurrent state manager over the paged session-memory pool.
#[derive(Debug)]
pub struct StateManager {
    mem: SessionMemory,
    meta: HashMap<u64, SessionMeta>,
}

impl StateManager {
    /// Manager with a `budget_bytes` pool and default page geometry /
    /// spill pricing (tests, examples). Serving deployments should use
    /// [`StateManager::with_config`] with a calibrated [`MemoryConfig`].
    pub fn new(budget_bytes: u64) -> Self {
        Self::with_config(
            MemoryConfig::from_hw(&NpuConfig::default()).with_pool_bytes(budget_bytes),
        )
    }

    pub fn with_config(cfg: MemoryConfig) -> Self {
        Self { mem: SessionMemory::new(cfg), meta: HashMap::new() }
    }

    /// Open a session for `op`, or continue it. Re-opening an id with
    /// the **same** operator and dims is a no-op — the session's context
    /// keeps accumulating across requests, and state that was spilled in
    /// between pages back in (priced) on the next
    /// [`StateManager::touch`]. Re-opening with a **different** shape
    /// restarts the context at zero and returns the previously resident
    /// pages to the pool, keeping logical and resident accounting in
    /// sync (no spill is priced: discarding state on reshape is the
    /// owner's choice, not an eviction).
    pub fn open(&mut self, id: u64, op: OperatorKind, d_head: usize, d_state: usize) {
        match self.meta.get(&id) {
            Some(m) if m.op == op && m.d_head == d_head && m.d_state == d_state => {}
            Some(_) => {
                self.meta.insert(id, SessionMeta { op, d_head, d_state, tokens: 0 });
                self.mem.reset(id);
            }
            None => {
                self.meta.insert(id, SessionMeta { op, d_head, d_state, tokens: 0 });
                self.mem.open(id);
            }
        }
    }

    /// Append `tokens` of context and make the session's state resident,
    /// returning the priced [`Admission`]. On error the session keeps its
    /// previous size — an over-pool footprint is the caller's admission
    /// -control signal, not a state mutation.
    pub fn touch(&mut self, id: u64, tokens: usize) -> Result<Admission, AdmitError> {
        let meta = *self.meta.get(&id).ok_or(AdmitError::UnknownSession(id))?;
        let grown = meta.tokens + tokens;
        let footprint = footprint_for(meta.op, grown, meta.d_head, meta.d_state);
        let adm = self.mem.admit(id, footprint)?;
        if let Some(m) = self.meta.get_mut(&id) {
            m.tokens = grown;
        }
        Ok(adm)
    }

    /// Legacy convenience: [`StateManager::touch`] collapsed to success
    /// /failure.
    pub fn append(&mut self, id: u64, tokens: usize) -> bool {
        self.touch(id, tokens).is_ok()
    }

    /// Protect a session from eviction while it is being served.
    pub fn pin(&mut self, id: u64) -> bool {
        self.mem.pin(id)
    }

    pub fn unpin(&mut self, id: u64) -> bool {
        self.mem.unpin(id)
    }

    pub fn close(&mut self, id: u64) {
        self.meta.remove(&id);
        self.mem.close(id);
    }

    /// Bound bookkeeping on a long-lived server: close least-recently
    /// -touched *spilled* sessions until at most `max_sessions` remain
    /// tracked. Resident and pinned sessions are never dropped, so GC
    /// stops early (and returns what it closed) rather than touch live
    /// state.
    pub fn gc(&mut self, max_sessions: usize) -> Vec<u64> {
        let mut closed = Vec::new();
        while self.meta.len() > max_sessions {
            match self.mem.shed_spilled_lru() {
                Some(id) => {
                    self.meta.remove(&id);
                    closed.push(id);
                }
                None => break,
            }
        }
        closed
    }

    /// Logical persistent bytes of one session (resident or spilled).
    pub fn session_bytes(&self, id: u64) -> Option<u64> {
        let m = self.meta.get(&id)?;
        Some(footprint_for(m.op, m.tokens, m.d_head, m.d_state))
    }

    /// Sum of logical persistent bytes across open sessions.
    pub fn total_bytes(&self) -> u64 {
        self.meta
            // lint:allow(nondet-iteration, "order-insensitive sum of per-session footprints")
            .values()
            .map(|m| footprint_for(m.op, m.tokens, m.d_head, m.d_state))
            .sum()
    }

    /// Pool bytes currently backing resident state (page-granular).
    pub fn resident_bytes(&self) -> u64 {
        self.mem.resident_bytes()
    }

    pub fn is_resident(&self, id: u64) -> bool {
        self.mem.is_resident(id)
    }

    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    pub fn resident_sessions(&self) -> usize {
        self.mem.resident_sessions()
    }

    /// Sessions spilled out under pressure so far.
    pub fn evictions(&self) -> u64 {
        self.mem.stats().evictions
    }

    pub fn stats(&self) -> &MemStats {
        self.mem.stats()
    }

    pub fn memory(&self) -> &SessionMemory {
        &self.mem
    }

    /// Session-memory pool pages currently in use (metrics passthrough).
    pub fn pages_in_use(&self) -> u64 {
        self.mem.pages_in_use()
    }

    /// Session-memory pool page capacity (metrics passthrough).
    pub fn pool_pages(&self) -> u64 {
        self.mem.pool().total_pages()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, Rng};

    fn pooled(pool_bytes: u64) -> StateManager {
        StateManager::with_config(
            MemoryConfig::from_hw(&NpuConfig::default()).with_pool_bytes(pool_bytes),
        )
    }

    #[test]
    fn kv_cache_grows_linearly_with_context() {
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.append(1, 1024);
        let b1 = m.session_bytes(1).unwrap();
        m.append(1, 1024);
        let b2 = m.session_bytes(1).unwrap();
        assert_eq!(b2, 2 * b1, "KV bytes ∝ context");
        assert_eq!(b1, 2 * 1024 * 64 * 2);
    }

    #[test]
    fn recurrent_state_is_constant() {
        // Fig 1: Mamba-style state does not grow with context.
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Linear, 64, 16);
        m.append(1, 1024);
        let b1 = m.session_bytes(1).unwrap();
        m.append(1, 100_000);
        assert_eq!(m.session_bytes(1).unwrap(), b1);
        assert_eq!(b1, 64 * 16 * 4);
    }

    #[test]
    fn retention_state_is_constant() {
        // The acceptance story of the capacity model: retention carries a
        // d×d accumulator, not a growing KV scan.
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Retentive, 64, 16);
        m.append(1, 1024);
        let b1 = m.session_bytes(1).unwrap();
        m.append(1, 1_000_000);
        assert_eq!(m.session_bytes(1).unwrap(), b1);
        assert_eq!(b1, 64 * 64 * 4);
    }

    #[test]
    fn toeplitz_retention_capped_by_band() {
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Toeplitz, 64, 16);
        m.append(1, 100_000);
        assert_eq!(m.session_bytes(1).unwrap(), 2 * 128 * 64 * 2);
    }

    #[test]
    fn kv_dwarfs_recurrent_at_long_context() {
        // The 30x claim of §I, scaled to one layer/head.
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.open(2, OperatorKind::Linear, 64, 16);
        m.append(1, 16_384);
        m.append(2, 16_384);
        let kv = m.session_bytes(1).unwrap();
        let ssm = m.session_bytes(2).unwrap();
        assert!(kv > 100 * ssm, "kv {kv} vs ssm {ssm}");
    }

    #[test]
    fn lru_spill_under_pool_pressure() {
        // Pool holds 9 pages; three 4-page KV sessions cannot all stay
        // resident, so the LRU one spills — but survives.
        let mut m = pooled(600 * 1024);
        for id in 1..=3u64 {
            m.open(id, OperatorKind::Causal, 64, 16);
            assert!(m.append(id, 1024), "256 KiB = 4 pages each");
        }
        assert_eq!(m.len(), 3, "spilled sessions stay open");
        assert_eq!(m.resident_sessions(), 2);
        assert_eq!(m.evictions(), 1);
        assert!(!m.is_resident(1), "session 1 was LRU -> spilled");
        assert!(m.is_resident(3));
        assert!(m.resident_bytes() <= 600 * 1024);
        assert!(m.session_bytes(1).is_some(), "spill is not destruction");
    }

    #[test]
    fn spilled_session_refills_with_cost() {
        let mut m = pooled(600 * 1024);
        for id in 1..=3u64 {
            m.open(id, OperatorKind::Causal, 64, 16);
            m.append(id, 1024);
        }
        assert!(!m.is_resident(1));
        let adm = m.touch(1, 0).unwrap();
        assert!(adm.refill_ns > 0.0, "paging cold state back in costs ns");
        assert_eq!(adm.evicted, vec![2], "next LRU makes room");
        assert!(m.is_resident(1));
        assert!(m.stats().total_spill_ns() > 0.0);
    }

    #[test]
    fn pinned_session_never_evicted() {
        let mut m = pooled(600 * 1024);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.append(1, 1024);
        m.pin(1);
        for id in 2..=3u64 {
            m.open(id, OperatorKind::Causal, 64, 16);
            m.append(id, 1024);
        }
        assert!(m.is_resident(1), "pinned LRU session survives pressure");
        assert!(!m.is_resident(2), "pressure fell on the next LRU instead");
    }

    #[test]
    fn same_shape_reopen_continues_the_session() {
        let mut m = pooled(u64::MAX);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.append(1, 1024);
        let before = m.session_bytes(1).unwrap();
        m.open(1, OperatorKind::Causal, 64, 16); // next request, same shape
        assert_eq!(m.session_bytes(1), Some(before), "context is kept, not reset");
        m.append(1, 1024);
        assert_eq!(m.session_bytes(1), Some(2 * before), "and keeps accumulating");
    }

    #[test]
    fn reshaped_reopen_releases_previous_state() {
        let mut m = pooled(600 * 1024);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.append(1, 1024); // 4 pages resident
        assert!(m.resident_bytes() > 0);
        m.pin(1);
        m.open(1, OperatorKind::Causal, 128, 16); // new shape -> fresh context
        assert_eq!(m.resident_bytes(), 0, "reset returns pages to the pool");
        assert_eq!(m.session_bytes(1), Some(0), "logical and resident stay in sync");
        assert_eq!(m.evictions(), 0, "a reshape is not an eviction");
        assert_eq!(m.gc(0), vec![1], "stale pin was cleared, so GC can reach it");
    }

    #[test]
    fn gc_bounds_tracking_without_touching_residents() {
        let mut m = pooled(600 * 1024);
        for id in 1..=5u64 {
            m.open(id, OperatorKind::Causal, 64, 16);
            m.append(id, 1024);
        }
        // 9-page pool, 4 pages/session: 2 resident, 3 spilled.
        assert_eq!(m.len(), 5);
        let closed = m.gc(3);
        assert_eq!(closed, vec![1, 2], "LRU spilled sessions dropped first");
        assert_eq!(m.len(), 3);
        assert_eq!(m.resident_sessions(), 2, "residents untouched");
        let closed = m.gc(1);
        assert_eq!(closed, vec![3], "GC stops at residents instead of evicting them");
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn oversized_session_rejected_not_grown() {
        let mut m = pooled(100 * 1024);
        m.open(1, OperatorKind::Causal, 64, 16);
        assert!(!m.append(1, 100_000), "footprint larger than the pool is refused");
        assert_eq!(m.len(), 1, "session survives at its previous size");
        assert_eq!(m.session_bytes(1), Some(0), "failed growth did not commit");
    }

    #[test]
    fn property_total_is_sum_of_sessions() {
        forall(
            "state accounting",
            25,
            |rng: &mut Rng| {
                (0..rng.range(1, 20))
                    .map(|i| {
                        let ops = [
                            OperatorKind::Causal,
                            OperatorKind::Linear,
                            OperatorKind::Toeplitz,
                            OperatorKind::Retentive,
                            OperatorKind::Fourier,
                        ];
                        (i, *rng.choose(&ops), rng.range(1, 4096) as usize)
                    })
                    .collect::<Vec<_>>()
            },
            |sessions| {
                let mut m = StateManager::new(u64::MAX);
                for &(id, op, tokens) in sessions {
                    m.open(id, op, 64, 16);
                    m.append(id, tokens);
                }
                let sum: u64 =
                    sessions.iter().filter_map(|&(id, _, _)| m.session_bytes(id)).sum();
                if sum == m.total_bytes() {
                    Ok(())
                } else {
                    Err(format!("sum {sum} != total {}", m.total_bytes()))
                }
            },
        );
    }
}
