//! Session state manager: the memory-state tradeoff of paper Fig 1.
//!
//! Attention-class sessions keep an explicit KV cache that grows
//! O(N·d) with context; SSM-class sessions compress to a fixed-size
//! recurrent state, O(d·d_state). The manager enforces the global memory
//! budget (Table I: 32 GB LPDDR5X) with LRU eviction and reports the
//! per-class footprints the paper's Fig 1 contrasts.

use std::collections::HashMap;

use crate::config::OperatorKind;

/// Context-retention class of an operator (Fig 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionKind {
    /// Explicit KV cache: O(N·d) persistent bytes.
    KvCache,
    /// Compressed recurrent state: O(d·d_state) persistent bytes.
    RecurrentState,
}

impl SessionKind {
    /// Classification per paper §II-A: attention-style operators retain
    /// K/V; linear attention & SSM-inspired operators carry a fixed state.
    /// (Toeplitz's banded window retains only `band` rows — we classify it
    /// KV but its growth is capped by the band.)
    pub fn for_operator(op: OperatorKind) -> Self {
        match op {
            OperatorKind::Causal | OperatorKind::Retentive | OperatorKind::Toeplitz => {
                SessionKind::KvCache
            }
            OperatorKind::Linear | OperatorKind::Fourier => SessionKind::RecurrentState,
        }
    }
}

#[derive(Clone, Debug)]
struct Session {
    op: OperatorKind,
    kind: SessionKind,
    tokens: usize,
    d_model: usize,
    d_state: usize,
    elem_bytes: u64,
    last_touch: u64,
}

impl Session {
    /// Persistent bytes this session pins in global memory.
    fn bytes(&self, band_cap: usize) -> u64 {
        match self.kind {
            SessionKind::KvCache => {
                let retained = if self.op == OperatorKind::Toeplitz {
                    self.tokens.min(band_cap)
                } else {
                    self.tokens
                };
                2 * retained as u64 * self.d_model as u64 * self.elem_bytes
            }
            SessionKind::RecurrentState => {
                (self.d_model * self.d_state) as u64 * 4 // f32 state
            }
        }
    }
}

/// KV / recurrent state manager with a global byte budget.
#[derive(Debug)]
pub struct StateManager {
    budget_bytes: u64,
    band_cap: usize,
    sessions: HashMap<u64, Session>,
    clock: u64,
    pub evictions: u64,
}

impl StateManager {
    pub fn new(budget_bytes: u64) -> Self {
        Self {
            budget_bytes,
            band_cap: 128,
            sessions: HashMap::new(),
            clock: 0,
            evictions: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Open a session for `op`; returns the session id provided.
    pub fn open(&mut self, id: u64, op: OperatorKind, d_model: usize, d_state: usize) {
        let t = self.tick();
        self.sessions.insert(
            id,
            Session {
                op,
                kind: SessionKind::for_operator(op),
                tokens: 0,
                d_model,
                d_state,
                elem_bytes: 2,
                last_touch: t,
            },
        );
        self.enforce_budget(Some(id));
    }

    /// Append `tokens` of context to a session (prefill or decode).
    pub fn append(&mut self, id: u64, tokens: usize) -> bool {
        let t = self.tick();
        let Some(s) = self.sessions.get_mut(&id) else { return false };
        s.tokens += tokens;
        s.last_touch = t;
        self.enforce_budget(Some(id));
        self.sessions.contains_key(&id)
    }

    pub fn close(&mut self, id: u64) {
        self.sessions.remove(&id);
    }

    pub fn session_bytes(&self, id: u64) -> Option<u64> {
        self.sessions.get(&id).map(|s| s.bytes(self.band_cap))
    }

    pub fn total_bytes(&self) -> u64 {
        self.sessions.values().map(|s| s.bytes(self.band_cap)).sum()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Evict least-recently-used sessions until under budget, never
    /// evicting `protect` (the session being served).
    fn enforce_budget(&mut self, protect: Option<u64>) {
        while self.total_bytes() > self.budget_bytes {
            let victim = self
                .sessions
                .iter()
                .filter(|(id, _)| Some(**id) != protect)
                .min_by_key(|(_, s)| s.last_touch)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    self.sessions.remove(&id);
                    self.evictions += 1;
                }
                None => break, // only the protected session remains
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, Rng};

    #[test]
    fn kv_cache_grows_linearly_with_context() {
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.append(1, 1024);
        let b1 = m.session_bytes(1).unwrap();
        m.append(1, 1024);
        let b2 = m.session_bytes(1).unwrap();
        assert_eq!(b2, 2 * b1, "KV bytes ∝ context");
        assert_eq!(b1, 2 * 1024 * 64 * 2);
    }

    #[test]
    fn recurrent_state_is_constant() {
        // Fig 1: Mamba-style state does not grow with context.
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Linear, 64, 16);
        m.append(1, 1024);
        let b1 = m.session_bytes(1).unwrap();
        m.append(1, 100_000);
        assert_eq!(m.session_bytes(1).unwrap(), b1);
        assert_eq!(b1, 64 * 16 * 4);
    }

    #[test]
    fn toeplitz_retention_capped_by_band() {
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Toeplitz, 64, 16);
        m.append(1, 100_000);
        assert_eq!(m.session_bytes(1).unwrap(), 2 * 128 * 64 * 2);
    }

    #[test]
    fn kv_dwarfs_recurrent_at_long_context() {
        // The 30x claim of §I, scaled to one layer/head.
        let mut m = StateManager::new(u64::MAX);
        m.open(1, OperatorKind::Causal, 64, 16);
        m.open(2, OperatorKind::Linear, 64, 16);
        m.append(1, 16_384);
        m.append(2, 16_384);
        let kv = m.session_bytes(1).unwrap();
        let ssm = m.session_bytes(2).unwrap();
        assert!(kv > 100 * ssm, "kv {kv} vs ssm {ssm}");
    }

    #[test]
    fn lru_eviction_under_budget_pressure() {
        // Budget fits two small KV sessions, not three.
        let mut m = StateManager::new(600 * 1024);
        for id in 1..=3u64 {
            m.open(id, OperatorKind::Causal, 64, 16);
            m.append(id, 1024); // 256 KiB each
        }
        assert!(m.total_bytes() <= 600 * 1024);
        assert_eq!(m.len(), 2);
        assert_eq!(m.evictions, 1);
        // Session 1 was LRU ⇒ evicted.
        assert!(m.session_bytes(1).is_none());
        assert!(m.session_bytes(3).is_some());
    }

    #[test]
    fn active_session_never_self_evicts() {
        let mut m = StateManager::new(100 * 1024);
        m.open(1, OperatorKind::Causal, 64, 16);
        assert!(m.append(1, 100_000), "grows past budget but survives");
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn property_total_is_sum_of_sessions() {
        forall(
            "state accounting",
            25,
            |rng: &mut Rng| {
                (0..rng.range(1, 20))
                    .map(|i| {
                        let ops = [
                            OperatorKind::Causal,
                            OperatorKind::Linear,
                            OperatorKind::Toeplitz,
                            OperatorKind::Retentive,
                            OperatorKind::Fourier,
                        ];
                        (i, *rng.choose(&ops), rng.range(1, 4096) as usize)
                    })
                    .collect::<Vec<_>>()
            },
            |sessions| {
                let mut m = StateManager::new(u64::MAX);
                for &(id, op, tokens) in sessions {
                    m.open(id, op, 64, 16);
                    m.append(id, tokens);
                }
                let sum: u64 =
                    sessions.iter().filter_map(|&(id, _, _)| m.session_bytes(id)).sum();
                if sum == m.total_bytes() {
                    Ok(())
                } else {
                    Err(format!("sum {sum} != total {}", m.total_bytes()))
                }
            },
        );
    }
}
