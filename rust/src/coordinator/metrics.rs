//! Serving metrics: per-operator latency summaries + throughput counters.
//!
//! All time-derived numbers (uptime, throughput) are read off a [`Clock`]
//! rather than `Instant::now()` directly, so tests drive a [`ManualClock`]
//! and assert exact throughput/uptime values; production uses the
//! monotonic [`WallClock`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::OperatorKind;
use crate::util::stats::Summary;

/// Monotonic nanosecond time source for the serving stack.
///
/// The coordinator never calls `Instant::now()` itself — it reads this,
/// so a test can substitute a [`ManualClock`] and make queue ages,
/// uptime, and throughput deterministic.
pub trait Clock: std::fmt::Debug + Send + Sync {
    /// Nanoseconds since an arbitrary per-clock epoch (monotonic).
    fn now_ns(&self) -> u64;
}

/// Production clock: monotonic nanoseconds since construction.
#[derive(Clone, Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Test clock: advances only when told to. Cloning shares the underlying
/// counter, so the copy handed to the coordinator and the one kept by the
/// test tick together.
#[derive(Clone, Debug, Default)]
pub struct ManualClock {
    ns: Arc<AtomicU64>,
}

impl ManualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_ns(&self, delta: u64) {
        self.ns.fetch_add(delta, Ordering::SeqCst);
    }

    pub fn set_ns(&self, ns: u64) {
        self.ns.store(ns, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

/// Registry of per-operator serving metrics.
#[derive(Debug)]
pub struct Metrics {
    clock: Arc<dyn Clock>,
    start_ns: u64,
    latency_ns: HashMap<OperatorKind, Summary>,
    served: HashMap<OperatorKind, u64>,
    pub batches: u64,
    pub pjrt_requests: u64,
    pub simulated_requests: u64,
    /// Requests refused because their state footprint could not be paged
    /// into the session-memory pool. (Eviction/spill counters live in
    /// [`crate::memory::MemStats`] — one source of truth, surfaced by
    /// the coordinator's snapshot.)
    pub shed_requests: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::with_clock(Arc::new(WallClock::new()))
    }

    /// Metrics driven by an external time source (tests: [`ManualClock`]).
    pub fn with_clock(clock: Arc<dyn Clock>) -> Self {
        let start_ns = clock.now_ns();
        Self {
            clock,
            start_ns,
            latency_ns: HashMap::new(),
            served: HashMap::new(),
            batches: 0,
            pjrt_requests: 0,
            simulated_requests: 0,
            shed_requests: 0,
        }
    }

    /// Current clock reading (same source throughput uses).
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Nanoseconds since construction, on the injected clock.
    pub fn uptime_ns(&self) -> u64 {
        self.clock.now_ns().saturating_sub(self.start_ns)
    }

    pub fn record(&mut self, op: OperatorKind, latency_ns: f64) {
        self.latency_ns.entry(op).or_default().push(latency_ns);
        *self.served.entry(op).or_insert(0) += 1;
    }

    pub fn served(&self, op: OperatorKind) -> u64 {
        self.served.get(&op).copied().unwrap_or(0)
    }

    pub fn total_served(&self) -> u64 {
        self.served.values().sum()
    }

    pub fn latency(&self, op: OperatorKind) -> Option<&Summary> {
        self.latency_ns.get(&op)
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.uptime_ns() as f64 / 1e9;
        if secs == 0.0 {
            0.0
        } else {
            self.total_served() as f64 / secs
        }
    }

    /// Human-readable snapshot (one line per operator).
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        let mut ops: Vec<_> = self.latency_ns.keys().copied().collect();
        ops.sort();
        for op in ops {
            let s = &self.latency_ns[&op];
            out += &format!(
                "{:<10} served={:<5} mean={:.3} ms  p50={:.3} ms  p99={:.3} ms\n",
                op.name(),
                self.served(op),
                s.mean() / 1e6,
                s.median() / 1e6,
                s.percentile(99.0) / 1e6,
            );
        }
        out += &format!(
            "batches={} pjrt={} simulated={} total={} shed={} uptime_ms={:.3} rps={:.2}\n",
            self.batches,
            self.pjrt_requests,
            self.simulated_requests,
            self.total_served(),
            self.shed_requests,
            self.uptime_ns() as f64 / 1e6,
            self.throughput_rps(),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record(OperatorKind::Causal, 1e6);
        m.record(OperatorKind::Causal, 3e6);
        m.record(OperatorKind::Linear, 5e5);
        assert_eq!(m.served(OperatorKind::Causal), 2);
        assert_eq!(m.total_served(), 3);
        let s = m.latency(OperatorKind::Causal).unwrap();
        assert_eq!(s.mean(), 2e6);
    }

    #[test]
    fn snapshot_mentions_all_ops() {
        let mut m = Metrics::new();
        m.record(OperatorKind::Toeplitz, 1e5);
        m.record(OperatorKind::Fourier, 2e5);
        let snap = m.snapshot();
        assert!(snap.contains("toeplitz"));
        assert!(snap.contains("fourier"));
        assert!(snap.contains("total=2"));
    }

    #[test]
    fn snapshot_reports_shed_requests() {
        let mut m = Metrics::new();
        m.shed_requests = 1;
        let snap = m.snapshot();
        assert!(snap.contains("shed=1"), "{snap}");
    }

    #[test]
    fn empty_metrics_are_sane() {
        let m = Metrics::new();
        assert_eq!(m.total_served(), 0);
        assert!(m.latency(OperatorKind::Causal).is_none());
    }

    #[test]
    fn manual_clock_gives_exact_throughput() {
        let clock = ManualClock::new();
        let mut m = Metrics::with_clock(Arc::new(clock.clone()));
        m.record(OperatorKind::Causal, 1e6);
        m.record(OperatorKind::Causal, 1e6);
        m.record(OperatorKind::Linear, 1e6);
        assert_eq!(m.throughput_rps(), 0.0, "no time has passed");
        clock.advance_ns(2_000_000_000);
        assert_eq!(m.uptime_ns(), 2_000_000_000);
        assert_eq!(m.throughput_rps(), 1.5);
        let snap = m.snapshot();
        assert!(snap.contains("uptime_ms=2000.000"), "{snap}");
        assert!(snap.contains("rps=1.50"), "{snap}");
    }

    #[test]
    fn manual_clock_starts_where_it_is_set() {
        let clock = ManualClock::new();
        clock.set_ns(5_000);
        let m = Metrics::with_clock(Arc::new(clock.clone()));
        assert_eq!(m.uptime_ns(), 0, "uptime is measured from construction");
        clock.advance_ns(1_000);
        assert_eq!(m.uptime_ns(), 1_000);
    }

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }
}
