//! Serving metrics: per-operator latency summaries + throughput counters.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::OperatorKind;
use crate::util::stats::Summary;

/// Registry of per-operator serving metrics.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    latency_ns: HashMap<OperatorKind, Summary>,
    served: HashMap<OperatorKind, u64>,
    pub batches: u64,
    pub pjrt_requests: u64,
    pub simulated_requests: u64,
    /// Requests refused because their state footprint could not be paged
    /// into the session-memory pool. (Eviction/spill counters live in
    /// [`crate::memory::MemStats`] — one source of truth, surfaced by
    /// the coordinator's snapshot.)
    pub shed_requests: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            latency_ns: HashMap::new(),
            served: HashMap::new(),
            batches: 0,
            pjrt_requests: 0,
            simulated_requests: 0,
            shed_requests: 0,
        }
    }

    pub fn record(&mut self, op: OperatorKind, latency_ns: f64) {
        self.latency_ns.entry(op).or_default().push(latency_ns);
        *self.served.entry(op).or_insert(0) += 1;
    }

    pub fn served(&self, op: OperatorKind) -> u64 {
        self.served.get(&op).copied().unwrap_or(0)
    }

    pub fn total_served(&self) -> u64 {
        self.served.values().sum()
    }

    pub fn latency(&self, op: OperatorKind) -> Option<&Summary> {
        self.latency_ns.get(&op)
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.total_served() as f64 / secs
        }
    }

    /// Human-readable snapshot (one line per operator).
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        let mut ops: Vec<_> = self.latency_ns.keys().copied().collect();
        ops.sort();
        for op in ops {
            let s = &self.latency_ns[&op];
            out += &format!(
                "{:<10} served={:<5} mean={:.3} ms  p50={:.3} ms  p99={:.3} ms\n",
                op.name(),
                self.served(op),
                s.mean() / 1e6,
                s.median() / 1e6,
                s.percentile(99.0) / 1e6,
            );
        }
        out += &format!(
            "batches={} pjrt={} simulated={} total={} shed={}\n",
            self.batches,
            self.pjrt_requests,
            self.simulated_requests,
            self.total_served(),
            self.shed_requests
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record(OperatorKind::Causal, 1e6);
        m.record(OperatorKind::Causal, 3e6);
        m.record(OperatorKind::Linear, 5e5);
        assert_eq!(m.served(OperatorKind::Causal), 2);
        assert_eq!(m.total_served(), 3);
        let s = m.latency(OperatorKind::Causal).unwrap();
        assert_eq!(s.mean(), 2e6);
    }

    #[test]
    fn snapshot_mentions_all_ops() {
        let mut m = Metrics::new();
        m.record(OperatorKind::Toeplitz, 1e5);
        m.record(OperatorKind::Fourier, 2e5);
        let snap = m.snapshot();
        assert!(snap.contains("toeplitz"));
        assert!(snap.contains("fourier"));
        assert!(snap.contains("total=2"));
    }

    #[test]
    fn snapshot_reports_shed_requests() {
        let mut m = Metrics::new();
        m.shed_requests = 1;
        let snap = m.snapshot();
        assert!(snap.contains("shed=1"), "{snap}");
    }

    #[test]
    fn empty_metrics_are_sane() {
        let m = Metrics::new();
        assert_eq!(m.total_served(), 0);
        assert!(m.latency(OperatorKind::Causal).is_none());
    }
}
