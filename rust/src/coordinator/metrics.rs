//! Serving metrics: a facade over the [`crate::obs`] metrics registry.
//!
//! All time-derived numbers (uptime, throughput, queue ages) are read off
//! a [`Clock`] rather than `Instant::now()` directly, so tests drive a
//! [`ManualClock`] and assert exact values; production uses the monotonic
//! [`WallClock`].
//!
//! Every serving metric lives in one [`MetricsRegistry`]: the human
//! snapshot, the Prometheus exposition ([`Metrics::prometheus`]), and the
//! JSON dump ([`Metrics::json`]) all render from the same store and so
//! cannot disagree. Latency distributions are log-bucketed
//! [`crate::obs::Histogram`]s — bounded memory per series, unlike the
//! full-sample `Summary` vectors this module used to keep per operator.

use std::sync::Arc;

use crate::config::OperatorKind;
use crate::model::Ceilings;
use crate::npu::ExecReport;
use crate::obs::{self, Histogram, MetricsRegistry};
use crate::ops::registry::classify;

use super::device::Fleet;
use super::router::BackendKind;

pub use super::clock::{Clock, ManualClock, WallClock};

/// Canonical metric names (labels noted per metric). Exported so tests
/// and the `npuperf obs` command reference the same strings.
pub mod names {
    /// Counter `{operator, backend, device}`.
    pub const SERVED: &str = "npuperf_requests_served_total";
    /// Counter `{operator, device}`.
    pub const SHED: &str = "npuperf_requests_shed_total";
    /// Counter `{operator, device}`.
    pub const BATCHES: &str = "npuperf_batches_total";
    /// Histogram `{operator}` — requests per dispatched batch.
    /// Distributions aggregate across the fleet (per-device breakdowns
    /// live on the counters/gauges, which carry a `device` label).
    pub const BATCH_SIZE: &str = "npuperf_batch_size";
    /// Histogram `{operator}` — enqueue-to-reply, ns (fleet-aggregate).
    pub const LATENCY: &str = "npuperf_request_latency_ns";
    /// Histogram `{operator}` — enqueue-to-dispatch, ns (fleet-aggregate).
    pub const QUEUE: &str = "npuperf_request_queue_ns";
    /// Histogram `{operator}` — session-memory spill/refill charge, ns
    /// (fleet-aggregate).
    pub const SPILL: &str = "npuperf_request_spill_ns";
    /// Histogram `{operator, class}` — simulated makespan per batch, ns.
    pub const SIM_SPAN: &str = "npuperf_sim_span_ns";
    /// Counter `{operator, class, device}` — DMA traffic of simulated
    /// batches.
    pub const DMA_BYTES: &str = "npuperf_npu_dma_bytes_total";
    /// Counter `{operator, class, device}` — logical ops of simulated
    /// batches.
    pub const LOGICAL_OPS: &str = "npuperf_npu_logical_ops_total";
    /// Gauge `{operator, class, device}` — achieved GOP/s over the
    /// roofline ceiling at the batch's operational intensity.
    pub const ROOFLINE_UTIL: &str = "npuperf_npu_roofline_utilization";
    /// Gauge `{device}` — total model time the device has executed, ns
    /// (the occupancy numerator).
    pub const DEVICE_BUSY_NS: &str = "npuperf_device_busy_ns";
    /// Gauge `{device}` — end of the device's model-time timeline, ns.
    pub const DEVICE_BUSY_UNTIL_NS: &str = "npuperf_device_busy_until_ns";
    /// Gauge (unlabeled) — devices in the fleet.
    pub const FLEET_DEVICES: &str = "npuperf_fleet_devices";
    /// Gauge (unlabeled) — latest device timeline end: the fleet's
    /// aggregate model-time makespan, ns.
    pub const FLEET_MAKESPAN_NS: &str = "npuperf_fleet_makespan_ns";
    /// Counter `{device}` (plus an unlabeled fleet total) — sessions
    /// migrated onto the device, paying the cross-device state transfer.
    pub const MIGRATIONS: &str = "npuperf_sessions_migrated_total";
    /// Gauges mirrored from the session-memory pools. Unlabeled series
    /// are fleet-wide aggregates; the same names also carry per-device
    /// `{device}` series on multi-pool fleets.
    pub const MEM_SESSIONS: &str = "npuperf_mem_sessions";
    pub const MEM_RESIDENT_SESSIONS: &str = "npuperf_mem_resident_sessions";
    pub const MEM_STATE_BYTES: &str = "npuperf_mem_state_bytes";
    pub const MEM_RESIDENT_BYTES: &str = "npuperf_mem_resident_bytes";
    pub const MEM_PAGES_USED: &str = "npuperf_mem_pool_pages_used";
    pub const MEM_PAGES_TOTAL: &str = "npuperf_mem_pool_pages_total";
    pub const MEM_SPILL_NS: &str = "npuperf_mem_spill_ns";
    /// Counters mirrored absolutely from [`crate::memory::MemStats`] —
    /// the pool keeps the running totals; the registry never double
    /// counts.
    pub const MEM_EVICTIONS: &str = "npuperf_mem_evictions_total";
    pub const MEM_SPILLED_BYTES: &str = "npuperf_mem_spilled_bytes_total";
    pub const MEM_REFILLED_BYTES: &str = "npuperf_mem_refilled_bytes_total";
    pub const MEM_REJECTED: &str = "npuperf_mem_rejected_total";
    pub const MEM_SHED_SESSIONS: &str = "npuperf_mem_shed_sessions_total";
    /// Gauges derived from the injected clock at export time.
    pub const UPTIME_NS: &str = "npuperf_uptime_ns";
    pub const RPS: &str = "npuperf_throughput_rps";
}

fn backend_label(b: BackendKind) -> &'static str {
    match b {
        BackendKind::Pjrt => "pjrt",
        BackendKind::Simulate => "simulate",
    }
}

/// Registry of serving metrics, fed by the serve loop.
#[derive(Debug)]
pub struct Metrics {
    clock: Arc<dyn Clock>,
    start_ns: u64,
    registry: MetricsRegistry,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::with_clock(Arc::new(WallClock::new()))
    }

    /// Metrics driven by an external time source (tests: [`ManualClock`]).
    pub fn with_clock(clock: Arc<dyn Clock>) -> Self {
        let start_ns = clock.now_ns();
        let mut registry = MetricsRegistry::new();
        registry.describe(names::SERVED, "Requests served, by operator and backend");
        registry.describe(names::SHED, "Requests shed by session-memory admission control");
        registry.describe(names::BATCHES, "Batches dispatched, by operator");
        registry.describe(names::BATCH_SIZE, "Requests per dispatched batch");
        registry.describe(names::LATENCY, "Enqueue-to-reply latency, ns");
        registry.describe(names::QUEUE, "Enqueue-to-dispatch queue age, ns");
        registry.describe(names::SPILL, "Session-memory spill/refill charge per request, ns");
        registry.describe(names::SIM_SPAN, "Simulated NPU makespan per batch, ns");
        registry.describe(names::DMA_BYTES, "DMA bytes moved by simulated batches");
        registry.describe(names::LOGICAL_OPS, "Logical ops executed by simulated batches");
        registry
            .describe(names::ROOFLINE_UTIL, "Achieved GOP/s over the roofline ceiling (0..1)");
        registry.describe(names::DEVICE_BUSY_NS, "Model time executed per device, ns");
        registry
            .describe(names::DEVICE_BUSY_UNTIL_NS, "End of the device's model-time timeline, ns");
        registry.describe(names::FLEET_DEVICES, "Execution devices in the fleet");
        registry.describe(names::FLEET_MAKESPAN_NS, "Fleet model-time makespan, ns");
        registry.describe(names::MIGRATIONS, "Sessions migrated between devices");
        registry.describe(names::MEM_SESSIONS, "Tracked sessions (resident + spilled)");
        registry.describe(names::MEM_RESIDENT_SESSIONS, "Sessions resident in the pool");
        registry.describe(names::MEM_STATE_BYTES, "Total tracked session-state bytes");
        registry.describe(names::MEM_RESIDENT_BYTES, "Resident session-state bytes");
        registry.describe(names::MEM_PAGES_USED, "Session-memory pool pages in use");
        registry.describe(names::MEM_PAGES_TOTAL, "Session-memory pool page capacity");
        registry.describe(names::MEM_SPILL_NS, "Cumulative spill+refill DMA time, ns");
        registry.describe(names::MEM_EVICTIONS, "Sessions spilled out under pressure");
        registry.describe(names::MEM_SPILLED_BYTES, "Bytes written out by evictions");
        registry.describe(names::MEM_REFILLED_BYTES, "Bytes paged back in on refills");
        registry.describe(names::MEM_REJECTED, "Admissions refused by the pool");
        registry.describe(names::MEM_SHED_SESSIONS, "Spilled sessions dropped by capacity GC");
        registry.describe(names::UPTIME_NS, "Serve-loop uptime on the injected clock, ns");
        registry.describe(names::RPS, "Requests per second since startup");
        Self { clock, start_ns, registry }
    }

    /// Current clock reading (same source throughput uses).
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Nanoseconds since construction, on the injected clock.
    pub fn uptime_ns(&self) -> u64 {
        self.clock.now_ns().saturating_sub(self.start_ns)
    }

    /// One dispatched batch of `size` requests on `device`.
    pub fn record_batch(&mut self, op: OperatorKind, device: &'static str, size: usize) {
        self.registry.inc(names::BATCHES, &[("device", device), ("operator", op.name())], 1);
        self.registry.observe(names::BATCH_SIZE, &[("operator", op.name())], size as f64);
    }

    /// One served request: queue age, spill charge, end-to-end latency.
    /// Counters carry the serving device; latency distributions stay
    /// fleet-aggregate per operator.
    pub fn record_request(
        &mut self,
        op: OperatorKind,
        backend: BackendKind,
        device: &'static str,
        queue_ns: u64,
        spill_ns: f64,
        latency_ns: f64,
    ) {
        let op_label = [("operator", op.name())];
        self.registry.inc(
            names::SERVED,
            &[
                ("operator", op.name()),
                ("backend", backend_label(backend)),
                ("device", device),
            ],
            1,
        );
        self.registry.observe(names::LATENCY, &op_label, latency_ns);
        self.registry.observe(names::QUEUE, &op_label, queue_ns as f64);
        self.registry.observe(names::SPILL, &op_label, spill_ns);
    }

    /// One request refused by session-memory admission control.
    pub fn record_shed(&mut self, op: OperatorKind, device: &'static str) {
        self.registry.inc(names::SHED, &[("device", device), ("operator", op.name())], 1);
    }

    /// Cost-model metrics for one simulated batch: DMA traffic, logical
    /// ops, makespan, and achieved-vs-roofline utilization, labeled by
    /// operator, the paper's [`crate::ops::BoundClass`] taxonomy, and the
    /// device the batch ran on.
    pub fn record_sim(
        &mut self,
        op: OperatorKind,
        device: &'static str,
        report: &ExecReport,
        ceilings: &Ceilings,
    ) {
        let class = classify(report).label();
        let labels = [("class", class), ("device", device), ("operator", op.name())];
        self.registry.inc(names::DMA_BYTES, &labels, report.dma_bytes);
        self.registry.inc(names::LOGICAL_OPS, &labels, report.logical_ops);
        self.registry.observe(
            names::SIM_SPAN,
            &[("class", class), ("operator", op.name())],
            report.span_ns,
        );
        self.registry.set_gauge(
            names::ROOFLINE_UTIL,
            &labels,
            report.roofline_utilization(ceilings.pi_eff_gops, ceilings.beta_eff_gbps),
        );
    }

    /// Mirror the device fleet into the registry: per-device occupancy
    /// gauges and session-memory series (`device="dN"`), plus unlabeled
    /// fleet-wide aggregates under the historical single-device names.
    /// [`MemStats`] keeps the running totals; this copies them absolutely
    /// ([`MetricsRegistry::set_counter`]) so there is exactly one
    /// counting site for spills and evictions.
    ///
    /// [`MemStats`]: crate::memory::MemStats
    pub fn observe_fleet(&mut self, fleet: &Fleet) {
        let mut sessions = 0u64;
        let mut resident_sessions = 0u64;
        let mut state_bytes = 0u64;
        let mut resident_bytes = 0u64;
        let mut pages_used = 0u64;
        let mut pages_total = 0u64;
        let mut spill_ns = 0.0f64;
        let mut evictions = 0u64;
        let mut spilled_bytes = 0u64;
        let mut refilled_bytes = 0u64;
        let mut rejected = 0u64;
        let mut shed_sessions = 0u64;
        let multi = fleet.len() > 1;
        for d in fleet.devices() {
            let state = &d.state;
            let stats = state.stats();
            let dev = [("device", d.label)];
            self.registry.set_gauge(names::DEVICE_BUSY_NS, &dev, d.busy_ns_total() as f64);
            self.registry
                .set_gauge(names::DEVICE_BUSY_UNTIL_NS, &dev, d.busy_until_ns() as f64);
            self.registry.set_counter(names::MIGRATIONS, &dev, d.migrations_in());
            if multi {
                // Per-pool breakdowns only earn their exposition bytes on
                // a real fleet; single-device deployments read the
                // aggregates below.
                self.registry.set_gauge(names::MEM_SESSIONS, &dev, state.len() as f64);
                self.registry.set_gauge(
                    names::MEM_RESIDENT_SESSIONS,
                    &dev,
                    state.resident_sessions() as f64,
                );
                self.registry
                    .set_gauge(names::MEM_RESIDENT_BYTES, &dev, state.resident_bytes() as f64);
                self.registry
                    .set_gauge(names::MEM_PAGES_USED, &dev, state.pages_in_use() as f64);
            }
            sessions += state.len() as u64;
            resident_sessions += state.resident_sessions() as u64;
            state_bytes += state.total_bytes();
            resident_bytes += state.resident_bytes();
            pages_used += state.pages_in_use();
            pages_total += state.pool_pages();
            spill_ns += stats.total_spill_ns();
            evictions += stats.evictions;
            spilled_bytes += stats.spilled_bytes;
            refilled_bytes += stats.refilled_bytes;
            rejected += stats.rejected;
            shed_sessions += stats.shed_sessions;
        }
        self.registry.set_gauge(names::MEM_SESSIONS, &[], sessions as f64);
        self.registry.set_gauge(names::MEM_RESIDENT_SESSIONS, &[], resident_sessions as f64);
        self.registry.set_gauge(names::MEM_STATE_BYTES, &[], state_bytes as f64);
        self.registry.set_gauge(names::MEM_RESIDENT_BYTES, &[], resident_bytes as f64);
        self.registry.set_gauge(names::MEM_PAGES_USED, &[], pages_used as f64);
        self.registry.set_gauge(names::MEM_PAGES_TOTAL, &[], pages_total as f64);
        self.registry.set_gauge(names::MEM_SPILL_NS, &[], spill_ns);
        self.registry.set_counter(names::MEM_EVICTIONS, &[], evictions);
        self.registry.set_counter(names::MEM_SPILLED_BYTES, &[], spilled_bytes);
        self.registry.set_counter(names::MEM_REFILLED_BYTES, &[], refilled_bytes);
        self.registry.set_counter(names::MEM_REJECTED, &[], rejected);
        self.registry.set_counter(names::MEM_SHED_SESSIONS, &[], shed_sessions);
        self.registry.set_gauge(names::FLEET_DEVICES, &[], fleet.len() as f64);
        self.registry.set_gauge(names::FLEET_MAKESPAN_NS, &[], fleet.makespan_ns() as f64);
        self.registry.set_counter(names::MIGRATIONS, &[], fleet.migrations());
    }

    /// Refresh the clock-derived gauges (uptime, throughput) so an export
    /// reflects the moment it was taken.
    fn sync_derived(&mut self) {
        self.registry.set_gauge(names::UPTIME_NS, &[], self.uptime_ns() as f64);
        self.registry.set_gauge(names::RPS, &[], self.throughput_rps());
    }

    pub fn served(&self, op: OperatorKind) -> u64 {
        self.registry.sum_counters(names::SERVED, &[("operator", op.name())])
    }

    pub fn total_served(&self) -> u64 {
        self.registry.sum_counters(names::SERVED, &[])
    }

    pub fn batches(&self) -> u64 {
        self.registry.sum_counters(names::BATCHES, &[])
    }

    pub fn shed_requests(&self) -> u64 {
        self.registry.sum_counters(names::SHED, &[])
    }

    pub fn pjrt_requests(&self) -> u64 {
        self.registry.sum_counters(names::SERVED, &[("backend", "pjrt")])
    }

    pub fn simulated_requests(&self) -> u64 {
        self.registry.sum_counters(names::SERVED, &[("backend", "simulate")])
    }

    /// Latency histogram for one operator (None before its first reply).
    pub fn latency(&self, op: OperatorKind) -> Option<&Histogram> {
        self.registry.histogram(names::LATENCY, &[("operator", op.name())])
    }

    /// The underlying registry (conformance tests assert the expositions
    /// against it directly).
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.uptime_ns() as f64 / 1e9;
        if secs == 0.0 {
            0.0
        } else {
            self.total_served() as f64 / secs
        }
    }

    /// Prometheus text exposition of every metric (refreshes the derived
    /// gauges first).
    pub fn prometheus(&mut self) -> String {
        self.sync_derived();
        obs::export::prometheus(&self.registry)
    }

    /// JSON snapshot of every metric (refreshes the derived gauges
    /// first).
    pub fn json(&mut self) -> String {
        self.sync_derived();
        obs::export::json(&self.registry)
    }

    /// Human-readable snapshot: one aligned latency row per operator
    /// (mean/p50/p95/p99/max in ms), the throughput totals line, and —
    /// once [`Metrics::observe_fleet`] has run — the session-memory and
    /// fleet lines, single-sourced from [`crate::memory::MemStats`] and
    /// the device timelines.
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        let ops = self.registry.histogram_label_values(names::LATENCY, "operator");
        if !ops.is_empty() {
            out += &format!(
                "{:<10} {:>6} {:>10} {:>10} {:>10} {:>10} {:>10}\n",
                "operator", "served", "mean ms", "p50 ms", "p95 ms", "p99 ms", "max ms"
            );
        }
        for op in &ops {
            let Some(h) = self.registry.histogram(names::LATENCY, &[("operator", op)]) else {
                continue;
            };
            out += &format!(
                "{:<10} {:>6} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}\n",
                op,
                h.count(),
                h.mean() / 1e6,
                h.quantile(50.0) / 1e6,
                h.quantile(95.0) / 1e6,
                h.quantile(99.0) / 1e6,
                h.max() / 1e6,
            );
        }
        out += &format!(
            "batches={} pjrt={} simulated={} total={} shed={} uptime_ms={:.3} rps={:.2}\n",
            self.batches(),
            self.pjrt_requests(),
            self.simulated_requests(),
            self.total_served(),
            self.shed_requests(),
            self.uptime_ns() as f64 / 1e6,
            self.throughput_rps(),
        );
        if self.registry.gauge(names::MEM_SESSIONS, &[]).is_some() {
            let g = |name| self.registry.gauge(name, &[]).unwrap_or(0.0);
            out += &format!(
                "sessions={} resident={} state_bytes={} resident_bytes={} pages={}/{} \
                 evictions={} spill_ms={:.3}\n",
                g(names::MEM_SESSIONS) as u64,
                g(names::MEM_RESIDENT_SESSIONS) as u64,
                g(names::MEM_STATE_BYTES) as u64,
                g(names::MEM_RESIDENT_BYTES) as u64,
                g(names::MEM_PAGES_USED) as u64,
                g(names::MEM_PAGES_TOTAL) as u64,
                self.registry.counter(names::MEM_EVICTIONS, &[]),
                g(names::MEM_SPILL_NS) / 1e6,
            );
        }
        if let Some(devices) = self.registry.gauge(names::FLEET_DEVICES, &[]) {
            out += &format!(
                "devices={} makespan_ms={:.3} migrations={}\n",
                devices as u64,
                self.registry.gauge(names::FLEET_MAKESPAN_NS, &[]).unwrap_or(0.0) / 1e6,
                self.registry.counter(names::MIGRATIONS, &[]),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 0, 0.0, 1e6);
        m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 0, 0.0, 3e6);
        m.record_request(OperatorKind::Linear, BackendKind::Simulate, "d0", 0, 0.0, 5e5);
        assert_eq!(m.served(OperatorKind::Causal), 2);
        assert_eq!(m.total_served(), 3);
        assert_eq!(m.simulated_requests(), 3);
        assert_eq!(m.pjrt_requests(), 0);
        let h = m.latency(OperatorKind::Causal).unwrap();
        assert_eq!(h.mean(), 2e6);
        assert_eq!(h.max(), 3e6);
    }

    #[test]
    fn snapshot_rows_are_aligned_and_complete() {
        let mut m = Metrics::new();
        m.record_request(OperatorKind::Toeplitz, BackendKind::Simulate, "d0", 0, 0.0, 1e5);
        m.record_request(OperatorKind::Fourier, BackendKind::Simulate, "d0", 0, 0.0, 2e5);
        let snap = m.snapshot();
        let header = snap.lines().next().unwrap();
        assert!(header.starts_with("operator"), "{snap}");
        for col in ["served", "mean ms", "p50 ms", "p95 ms", "p99 ms", "max ms"] {
            assert!(header.contains(col), "missing column {col}: {snap}");
        }
        // Operators render in sorted order, one aligned row each, all
        // rows the same width as the header.
        let rows: Vec<&str> = snap.lines().skip(1).take(2).collect();
        assert!(rows[0].starts_with("fourier"), "{snap}");
        assert!(rows[1].starts_with("toeplitz"), "{snap}");
        for row in rows {
            assert_eq!(row.len(), header.len(), "misaligned row: {row:?}");
        }
        assert!(snap.contains("total=2"), "{snap}");
    }

    #[test]
    fn snapshot_reports_shed_requests() {
        let mut m = Metrics::new();
        m.record_shed(OperatorKind::Causal, "d0");
        let snap = m.snapshot();
        assert!(snap.contains("shed=1"), "{snap}");
    }

    #[test]
    fn snapshot_surfaces_quantiles_per_operator() {
        let mut m = Metrics::new();
        for _ in 0..10 {
            // Equal samples make every reported quantile exact: 7 ms.
            m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 0, 0.0, 7e6);
        }
        let snap = m.snapshot();
        let row = snap.lines().find(|l| l.starts_with("causal")).unwrap();
        let cols: Vec<&str> = row.split_whitespace().collect();
        assert_eq!(cols[0], "causal");
        assert_eq!(cols[1], "10");
        for c in &cols[2..] {
            assert_eq!(*c, "7.000", "mean/p50/p95/p99/max all exact: {row}");
        }
    }

    #[test]
    fn empty_metrics_are_sane() {
        let m = Metrics::new();
        assert_eq!(m.total_served(), 0);
        assert!(m.latency(OperatorKind::Causal).is_none());
        let snap = m.snapshot();
        assert!(!snap.contains("operator "), "no table without samples: {snap}");
        assert!(snap.contains("total=0"), "{snap}");
    }

    #[test]
    fn manual_clock_gives_exact_throughput() {
        let clock = ManualClock::new();
        let mut m = Metrics::with_clock(Arc::new(clock.clone()));
        m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 0, 0.0, 1e6);
        m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 0, 0.0, 1e6);
        m.record_request(OperatorKind::Linear, BackendKind::Simulate, "d0", 0, 0.0, 1e6);
        assert_eq!(m.throughput_rps(), 0.0, "no time has passed");
        clock.advance_ns(2_000_000_000);
        assert_eq!(m.uptime_ns(), 2_000_000_000);
        assert_eq!(m.throughput_rps(), 1.5);
        let snap = m.snapshot();
        assert!(snap.contains("uptime_ms=2000.000"), "{snap}");
        assert!(snap.contains("rps=1.50"), "{snap}");
    }

    #[test]
    fn manual_clock_starts_where_it_is_set() {
        let clock = ManualClock::new();
        clock.set_ns(5_000);
        let m = Metrics::with_clock(Arc::new(clock.clone()));
        assert_eq!(m.uptime_ns(), 0, "uptime is measured from construction");
        clock.advance_ns(1_000);
        assert_eq!(m.uptime_ns(), 1_000);
    }

    #[test]
    fn prometheus_and_snapshot_read_the_same_registry() {
        let clock = ManualClock::new();
        let mut m = Metrics::with_clock(Arc::new(clock.clone()));
        m.record_batch(OperatorKind::Causal, "d0", 2);
        m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 10, 0.0, 1e6);
        m.record_request(OperatorKind::Causal, BackendKind::Simulate, "d0", 10, 0.0, 1e6);
        clock.advance_ns(1_000_000_000);
        let prom = m.prometheus();
        assert!(
            prom.contains(
                r#"npuperf_requests_served_total{backend="simulate",device="d0",operator="causal"} 2"#
            ),
            "{prom}"
        );
        assert!(
            prom.contains(r#"npuperf_batches_total{device="d0",operator="causal"} 1"#),
            "{prom}"
        );
        assert!(prom.contains("npuperf_uptime_ns 1000000000"), "{prom}");
        assert!(prom.contains("npuperf_throughput_rps 2"), "{prom}");
        crate::obs::lint_prometheus(&prom).expect("exposition lints clean");
        let json = m.json();
        crate::obs::validate_json(&json).expect("json snapshot parses");
    }

    #[test]
    fn sim_metrics_carry_bound_class_labels() {
        let hw = crate::config::NpuConfig::default();
        let sim = crate::config::SimConfig::default();
        let spec = crate::config::WorkloadSpec::new(OperatorKind::Causal, 1024);
        let report = crate::npu::run(&crate::ops::lower(&spec, &hw, &sim), &hw, &sim);
        let ceilings = crate::model::calibrate(&hw, &sim);
        let mut m = Metrics::new();
        m.record_sim(OperatorKind::Causal, "d0", &report, &ceilings);
        let class = classify(&report).label();
        let labels = [("class", class), ("device", "d0"), ("operator", "causal")];
        assert_eq!(m.registry().counter(names::DMA_BYTES, &labels), report.dma_bytes);
        assert_eq!(m.registry().counter(names::LOGICAL_OPS, &labels), report.logical_ops);
        let util = m.registry().gauge(names::ROOFLINE_UTIL, &labels).unwrap();
        assert!(util > 0.0 && util <= 1.5, "roofline utilization plausible: {util}");
    }
}
