//! The coordinator itself: request intake → dynamic batching → routed
//! dispatch (PJRT executor thread or NPU simulator) → metrics.
//!
//! Synchronous request API over a background serving thread: callers get a
//! [`Response`] per request; the serving loop owns the batcher, router,
//! state manager and metrics. The PJRT runtime (when artifacts are
//! available) is confined to its own executor thread — the coordinator
//! only holds the cloneable channel handle.
//!
//! Simulated batches are lowered through the [operator
//! registry](crate::ops::registry): the serve loop resolves the batch's
//! workload kind to its registered [`crate::ops::CausalOperator`] and
//! dispatches that — no operator `match` in the serving path. A
//! deployment that installs its own registry
//! ([`crate::ops::registry::init_global`] at startup) therefore changes
//! what every kind serves — including swapping in a new operator — with
//! zero coordinator changes.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::npu::{self, ExecReport};
use crate::ops::registry;
use crate::runtime::executor::{Executor, ExecutorHandle};
use crate::runtime::Tensor;

use super::batcher::Batcher;
use super::metrics::{Clock, Metrics, WallClock};
use super::router::{BackendKind, Router};
use super::state::StateManager;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub spec: WorkloadSpec,
    /// Session carrying KV / recurrent state (opened on first use).
    pub session: u64,
    /// q/k/v tensors for PJRT-backed execution; `None` ⇒ simulate only.
    pub inputs: Option<Vec<Tensor>>,
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub spec: WorkloadSpec,
    /// What served the request: the registry name of the lowering that
    /// ran (simulate path), or the precompiled artifact's kernel family —
    /// the workload kind's name — on the PJRT path.
    pub operator: &'static str,
    pub backend: BackendKind,
    /// Real outputs (PJRT path only).
    pub outputs: Option<Vec<Tensor>>,
    /// Wall-clock time inside the backend, ns.
    pub backend_ns: f64,
    /// Session-memory time charged to this request, ns: spilling LRU
    /// victims out to admit this session's state plus paging its own
    /// previously spilled state back in (priced at the calibrated
    /// effective DMA ceiling). Zero when the pool is uncontended.
    pub spill_ns: f64,
    /// Full simulator report (simulate path only).
    pub sim_report: Option<ExecReport>,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub hw: NpuConfig,
    pub sim: SimConfig,
    /// Artifact directory; `None` ⇒ simulation-only deployment.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Pre-compile every artifact at startup so first requests do not pay
    /// PJRT compile latency (§Perf: compiles dominated cold-start serving).
    pub warmup: bool,
    pub max_batch: usize,
    pub max_wait_ns: u64,
    /// Session-memory pool capacity (defaults to the state-reserved
    /// fraction of Table I's 32 GB; page geometry and spill pricing come
    /// from `hw` via [`crate::memory::MemoryConfig`]).
    pub state_budget_bytes: u64,
    /// Upper bound on *tracked* sessions (resident + spilled). Beyond
    /// it, the bookkeeping of LRU spilled sessions is garbage-collected
    /// after each batch — they re-prefill if they return — so a
    /// long-lived server's session map stays bounded.
    pub max_tracked_sessions: usize,
    /// Time source for queue ages, batching windows, uptime and
    /// throughput. `None` ⇒ monotonic [`WallClock`]; tests inject a
    /// [`super::ManualClock`] for deterministic latency/throughput
    /// assertions.
    pub clock: Option<Arc<dyn Clock>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self::for_hw(NpuConfig::default(), SimConfig::default())
    }
}

impl CoordinatorConfig {
    /// Config for a specific device: the session-memory pool is sized
    /// from **this** `hw` (its `dram_bytes × state_pool_frac`), not from
    /// the default device — use this instead of
    /// `CoordinatorConfig { hw, ..Default::default() }`, which would
    /// keep a pool sized for the default 32 GB part.
    pub fn for_hw(hw: NpuConfig, sim: SimConfig) -> Self {
        Self {
            state_budget_bytes: (hw.dram_bytes as f64 * hw.state_pool_frac) as u64,
            hw,
            sim,
            artifact_dir: None,
            warmup: false,
            max_batch: 8,
            max_wait_ns: 2_000_000, // 2 ms batching window
            max_tracked_sessions: 65_536,
            clock: None,
        }
    }
}

struct Job {
    request: Request,
    reply: mpsc::Sender<Result<Response>>,
    /// Serve-loop clock reading at intake (stamped by the serving thread,
    /// which owns the clock — the submitting thread leaves it zero).
    enqueued_ns: u64,
}

enum Ctl {
    Submit(Job),
    Snapshot(mpsc::Sender<String>),
    Shutdown,
}

/// The L3 coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Ctl>,
    join: Option<JoinHandle<()>>,
    /// Keeps the executor thread alive for the coordinator's lifetime.
    _executor: Option<Executor>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Self> {
        let (executor, exec_handle, router) = match &cfg.artifact_dir {
            Some(dir) => {
                let executor = Executor::spawn(dir.clone())?;
                let handle = executor.handle();
                if cfg.warmup {
                    let manifest = crate::runtime::Manifest::load(dir)?;
                    for entry in &manifest.entries {
                        handle.warmup(&entry.name)?;
                    }
                }
                (Some(executor), Some(handle), Router::standard())
            }
            None => (None, None, Router::simulate_only()),
        };
        let (tx, rx) = mpsc::channel::<Ctl>();
        let join = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || serve_loop(cfg, rx, exec_handle, router))?;
        Ok(Self { tx, join: Some(join), _executor: executor })
    }

    /// Submit a request and wait for its response.
    pub fn submit(&self, request: Request) -> Result<Response> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Ctl::Submit(Job { request, reply, enqueued_ns: 0 }))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))?
    }

    /// Submit many requests concurrently; preserves input order.
    pub fn submit_all(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let mut rxs = Vec::with_capacity(requests.len());
        for request in requests {
            let (reply, rx) = mpsc::channel();
            self.tx
                .send(Ctl::Submit(Job { request, reply, enqueued_ns: 0 }))
                .map_err(|_| anyhow!("coordinator stopped"))?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))?)
            .collect()
    }

    /// Metrics snapshot (formatted).
    pub fn metrics_snapshot(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Ctl::Snapshot(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Ctl::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Ctl>,
    exec: Option<ExecutorHandle>,
    router: Router,
) {
    let clock: Arc<dyn Clock> = cfg.clock.clone().unwrap_or_else(|| Arc::new(WallClock::new()));
    let mut batcher = Batcher::new(cfg.max_batch, cfg.max_wait_ns);
    let mut metrics = Metrics::with_clock(clock.clone());
    // Spills/refills are priced with the same calibrated beta_eff the
    // roofline reports, so eviction time on responses is commensurate
    // with simulated operator latencies.
    let mut state = StateManager::with_config(
        crate::memory::MemoryConfig::calibrated(&cfg.hw, &cfg.sim)
            .with_pool_bytes(cfg.state_budget_bytes),
    );
    let mut jobs: std::collections::HashMap<u64, Job> = Default::default();
    let mut next_id: u64 = 0;
    let t0 = clock.now_ns();

    let clock_d = clock.clone();
    let dispatch = |batch: super::batcher::Batch,
                    jobs: &mut std::collections::HashMap<u64, Job>,
                    metrics: &mut Metrics,
                    state: &mut StateManager| {
        metrics.batches += 1;
        let backend = router.route(&batch.spec);
        let size = batch.request_ids.len();
        // Simulate path: resolve the batch's operator through the registry
        // and lower once per batch signature. A kind missing from a custom
        // registry leaves this as None and each request in the batch gets
        // an error reply — never a panic on the long-lived serving thread.
        // The PJRT path never touches the registry: it executes a
        // precompiled artifact keyed by the workload kind.
        let (sim_operator, sim_report) = if backend == BackendKind::Simulate {
            match registry::global().try_for_kind(batch.spec.op) {
                Some(op_impl) => {
                    let g = op_impl.lower(&batch.spec, &cfg.hw, &cfg.sim);
                    (Some(op_impl.name()), Some(npu::run(&g, &cfg.hw, &cfg.sim)))
                }
                None => (None, None),
            }
        } else {
            (None, None)
        };
        for id in batch.request_ids {
            let Some(job) = jobs.remove(&id) else { continue };
            let spec = job.request.spec;
            // Admission control: page the session's state in before the
            // request runs (`admit` never evicts the session it is
            // admitting; explicit pinning is the hook for concurrent
            // dispatchers and latency-critical sessions, not needed on
            // this serial path). A footprint the pool can never hold is
            // shed with an error instead of growing state without bound.
            let session = job.request.session;
            state.open(session, spec.op, spec.d_head, spec.d_state);
            let spill_ns = match state.touch(session, spec.n) {
                Ok(adm) => adm.total_ns(),
                Err(e) => {
                    metrics.shed_requests += 1;
                    let _ = job.reply.send(Err(anyhow!(
                        "request shed by session-memory admission control: {e}"
                    )));
                    continue;
                }
            };
            let result = match backend {
                BackendKind::Pjrt => {
                    let inputs = job.request.inputs.clone().unwrap_or_else(|| {
                        // Deterministic zeros when the caller only wants timing.
                        let shape = vec![spec.n, spec.d_head];
                        vec![
                            Tensor::new(shape.clone(), vec![0.1; spec.n * spec.d_head]).unwrap();
                            3
                        ]
                    });
                    match exec.as_ref().expect("router gated on artifacts").execute(
                        &spec.artifact_name(),
                        inputs,
                    ) {
                        Ok(out) => {
                            metrics.pjrt_requests += 1;
                            Ok(Response {
                                spec,
                                // The artifact is a precompiled build of the
                                // kind's kernel family, independent of which
                                // lowering the registry currently maps the
                                // kind to — attribute it as such.
                                operator: spec.op.name(),
                                backend,
                                backend_ns: out.exec_ns,
                                spill_ns,
                                outputs: Some(out.outputs),
                                sim_report: None,
                                batch_size: size,
                            })
                        }
                        Err(e) => Err(e),
                    }
                }
                BackendKind::Simulate => match (sim_operator, sim_report.as_ref()) {
                    (Some(operator), Some(report)) => {
                        metrics.simulated_requests += 1;
                        Ok(Response {
                            spec,
                            operator,
                            backend,
                            backend_ns: report.span_ns,
                            spill_ns,
                            outputs: None,
                            sim_report: Some(report.clone()),
                            batch_size: size,
                        })
                    }
                    _ => Err(anyhow!(
                        "no operator registered for workload kind {}",
                        spec.op
                    )),
                },
            };
            metrics.record(spec.op, clock_d.now_ns().saturating_sub(job.enqueued_ns) as f64);
            let _ = job.reply.send(result);
        }
        // Keep the session map bounded: forget LRU spilled sessions once
        // the tracked count exceeds the configured cap.
        let _ = state.gc(cfg.max_tracked_sessions);
    };

    loop {
        // Wait up to the batching window for the next control message.
        let msg = rx.recv_timeout(std::time::Duration::from_nanos(cfg.max_wait_ns));
        let now_ns = clock.now_ns().saturating_sub(t0);
        match msg {
            Ok(Ctl::Submit(mut job)) => {
                job.enqueued_ns = clock.now_ns();
                let id = next_id;
                next_id += 1;
                let spec = job.request.spec;
                let session = job.request.session;
                jobs.insert(id, job);
                if let Some(batch) = batcher.push(id, spec, session, now_ns) {
                    dispatch(batch, &mut jobs, &mut metrics, &mut state);
                }
            }
            Ok(Ctl::Snapshot(tx)) => {
                let mut snap = metrics.snapshot();
                snap += &format!(
                    "sessions={} resident={} state_bytes={} resident_bytes={} \
                     evictions={} spill_ms={:.3}\n",
                    state.len(),
                    state.resident_sessions(),
                    state.total_bytes(),
                    state.resident_bytes(),
                    state.evictions(),
                    state.stats().total_spill_ns() / 1e6
                );
                let _ = tx.send(snap);
            }
            Ok(Ctl::Shutdown) => {
                for batch in batcher.flush() {
                    dispatch(batch, &mut jobs, &mut metrics, &mut state);
                }
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        // Release expired batches, dispatching ones whose sessions are
        // already resident in the state pool first (cold batches pay
        // their refill when their turn comes; age breaks ties so no
        // signature starves).
        let due = batcher
            .poll_expired_prefer(clock.now_ns().saturating_sub(t0), |s| state.is_resident(s));
        for batch in due {
            dispatch(batch, &mut jobs, &mut metrics, &mut state);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;

    fn sim_only() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            max_wait_ns: 100_000, // short window for fast tests
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn simulated_request_roundtrip() {
        let c = sim_only();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Toeplitz, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        assert_eq!(r.backend, BackendKind::Simulate);
        assert!(r.sim_report.is_some());
        assert!(r.backend_ns > 0.0);
    }

    #[test]
    fn batch_groups_same_signature() {
        // Wide batching window so all 8 same-signature requests coalesce
        // regardless of scheduler jitter.
        let c = Coordinator::new(CoordinatorConfig {
            max_wait_ns: 200_000_000,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 2048),
                session: i,
                inputs: None,
            })
            .collect();
        let responses = c.submit_all(reqs).unwrap();
        assert_eq!(responses.len(), 8);
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "same-signature requests should coalesce: sizes {:?}",
            responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
        );
    }

    #[test]
    fn mixed_signatures_complete() {
        let c = sim_only();
        let mut reqs = Vec::new();
        for (i, op) in OperatorKind::ALL.iter().enumerate() {
            reqs.push(Request {
                spec: WorkloadSpec::new(*op, 1024),
                session: i as u64,
                inputs: None,
            });
        }
        let responses = c.submit_all(reqs).unwrap();
        assert_eq!(responses.len(), 5);
        for (r, op) in responses.iter().zip(OperatorKind::ALL) {
            assert_eq!(r.spec.op, op, "responses preserve submission order");
        }
    }

    #[test]
    fn metrics_snapshot_counts_requests() {
        let c = sim_only();
        for _ in 0..3 {
            c.submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        }
        let snap = c.metrics_snapshot().unwrap();
        assert!(snap.contains("causal"), "{snap}");
        assert!(snap.contains("total=3"), "{snap}");
        assert!(snap.contains("sessions=1"), "{snap}");
    }

    #[test]
    fn response_names_the_registry_operator() {
        let c = sim_only();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        assert_eq!(r.operator, "linear", "registry attribution on the response");
    }

    #[test]
    fn manual_clock_makes_throughput_deterministic() {
        use super::super::metrics::ManualClock;
        let clock = ManualClock::new();
        let c = Coordinator::new(CoordinatorConfig {
            max_batch: 1, // dispatch on push: no dependence on the frozen clock
            max_wait_ns: 100_000,
            clock: Some(Arc::new(clock.clone())),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        for i in 0..3 {
            c.submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 512),
                session: i,
                inputs: None,
            })
            .unwrap();
        }
        clock.advance_ns(2_000_000_000);
        let snap = c.metrics_snapshot().unwrap();
        assert!(snap.contains("uptime_ms=2000.000"), "{snap}");
        assert!(snap.contains("rps=1.50"), "{snap}");
        // The clock never ticked while requests were in flight, so the
        // measured queue latency is exactly zero.
        assert!(snap.contains("mean=0.000 ms"), "{snap}");
    }

    #[test]
    fn structured_ops_serve_faster_than_quadratic_in_sim() {
        let c = sim_only();
        let lat = |op| {
            c.submit(Request {
                spec: WorkloadSpec::new(op, 4096),
                session: 99,
                inputs: None,
            })
            .unwrap()
            .backend_ns
        };
        assert!(lat(OperatorKind::Toeplitz) < lat(OperatorKind::Causal) / 10.0);
    }
}
