//! The coordinator itself: request intake → dynamic batching → placement
//! on the device fleet → dispatch (PJRT executor thread or NPU simulator)
//! → metrics + tracing.
//!
//! Synchronous request API over a background serving thread: callers get a
//! [`Response`] per request; the serving loop owns the batcher, the
//! [`Fleet`] of execution [`Device`](super::device::Device)s, the
//! [`Dispatcher`], metrics, and the per-request [`Tracer`]. The PJRT
//! runtime (when artifacts are available) is confined to its own executor
//! thread — the coordinator only holds the cloneable channel handle.
//!
//! The serve pipeline is staged: **intake** stamps and batches requests,
//! **placement** ([`Fleet::place`]) picks a device — session affinity
//! first (KV / recurrent state is device-resident; moving it pays the
//! spill transfer), then least-loaded by model-time `busy_until_ns` —
//! and **execution** ([`Dispatcher::dispatch`]) runs the batch on that
//! device. All three stages read time only through the injected
//! [`Clock`], so a frozen [`super::ManualClock`] makes the whole
//! pipeline, placement included, exactly replayable; a 1-device fleet
//! reproduces the historical single-device loop bit for bit.
//!
//! Simulated batches are lowered through the [operator
//! registry](crate::ops::registry): the dispatcher resolves the batch's
//! workload kind to its registered [`crate::ops::CausalOperator`] and
//! dispatches that — no operator `match` in the serving path. A
//! deployment that installs its own registry
//! ([`crate::ops::registry::init_global`] at startup) therefore changes
//! what every kind serves — including swapping in a new operator — with
//! zero coordinator changes.
//!
//! With `trace: true` every request accrues a span tree (queued → lower →
//! admission → backend → respond, stamped on the injected [`Clock`], with
//! the simulator's per-engine spans nested under the backend stage and
//! the serving device stamped on the trace);
//! [`Coordinator::traces`] hands the completed traces out for
//! [`crate::obs::export::chrome`] to merge into one timeline.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::npu::ExecReport;
use crate::obs::{RequestTrace, Tracer};
use crate::runtime::executor::{Executor, ExecutorHandle};
use crate::runtime::Tensor;

use super::batcher::Batcher;
use super::device::{DeviceStat, Fleet};
use super::dispatch::Dispatcher;
use super::metrics::{Clock, Metrics, WallClock};
use super::router::{BackendKind, Router};

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub spec: WorkloadSpec,
    /// Session carrying KV / recurrent state (opened on first use).
    pub session: u64,
    /// q/k/v tensors for PJRT-backed execution; `None` ⇒ simulate only.
    pub inputs: Option<Vec<Tensor>>,
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub spec: WorkloadSpec,
    /// What served the request: the registry name of the lowering that
    /// ran (simulate path), or the precompiled artifact's kernel family —
    /// the workload kind's name — on the PJRT path.
    pub operator: &'static str,
    pub backend: BackendKind,
    /// Fleet device the request executed on (0 on a single-device
    /// deployment; label `"d<id>"` in metrics and traces).
    pub device: usize,
    /// Real outputs (PJRT path only).
    pub outputs: Option<Vec<Tensor>>,
    /// Wall-clock time inside the backend, ns.
    pub backend_ns: f64,
    /// Session-memory time charged to this request, ns: spilling LRU
    /// victims out to admit this session's state plus paging its own
    /// previously spilled state back in (priced at the calibrated
    /// effective DMA ceiling), plus — if the session just migrated to a
    /// different device — the cross-device state transfer. Zero when the
    /// pool is uncontended and the session stayed put.
    pub spill_ns: f64,
    /// Enqueue-to-dispatch age on the injected [`Clock`], ns — how long
    /// the request sat in the batching window. Exactly assertable under
    /// a [`super::ManualClock`].
    pub queue_ns: u64,
    /// Trace identity of this request (also the span-tree key when the
    /// coordinator runs with `trace: true`).
    pub trace_id: u64,
    /// Full simulator report (simulate path only).
    pub sim_report: Option<ExecReport>,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub hw: NpuConfig,
    pub sim: SimConfig,
    /// Artifact directory; `None` ⇒ simulation-only deployment.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Pre-compile every artifact at startup so first requests do not pay
    /// PJRT compile latency (§Perf: compiles dominated cold-start serving).
    pub warmup: bool,
    /// Execution devices in the fleet (clamped to ≥ 1). Each device gets
    /// its own simulated NPU, calibrated ceilings, and session-memory
    /// pool of `state_budget_bytes`; placement is session-affinity first,
    /// then least-loaded.
    pub devices: usize,
    pub max_batch: usize,
    pub max_wait_ns: u64,
    /// Session-memory pool capacity **per device** (defaults to the
    /// state-reserved fraction of Table I's 32 GB; page geometry and
    /// spill pricing come from `hw` via [`crate::memory::MemoryConfig`]).
    pub state_budget_bytes: u64,
    /// Upper bound on *tracked* sessions (resident + spilled) per device.
    /// Beyond it, the bookkeeping of LRU spilled sessions is garbage
    /// -collected after each batch — they re-prefill if they return — so
    /// a long-lived server's session map stays bounded.
    pub max_tracked_sessions: usize,
    /// Collect per-request span trees (see [`Coordinator::traces`]).
    /// Off by default: the untraced serve path pays one branch.
    pub trace: bool,
    /// Completed traces kept in memory; older requests beyond this are
    /// counted as dropped rather than stored.
    pub trace_capacity: usize,
    /// Time source for queue ages, batching windows, uptime and
    /// throughput. `None` ⇒ monotonic [`WallClock`]; tests inject a
    /// [`super::ManualClock`] for deterministic latency/throughput
    /// assertions.
    pub clock: Option<Arc<dyn Clock>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self::for_hw(NpuConfig::default(), SimConfig::default())
    }
}

impl CoordinatorConfig {
    /// Config for a specific device model: the per-device session-memory
    /// pool is sized from **this** `hw` (its `dram_bytes ×
    /// state_pool_frac`), not from the default device — use this instead
    /// of `CoordinatorConfig { hw, ..Default::default() }`, which would
    /// keep a pool sized for the default 32 GB part.
    pub fn for_hw(hw: NpuConfig, sim: SimConfig) -> Self {
        Self {
            state_budget_bytes: (hw.dram_bytes as f64 * hw.state_pool_frac) as u64,
            hw,
            sim,
            artifact_dir: None,
            warmup: false,
            devices: 1,
            max_batch: 8,
            max_wait_ns: 2_000_000, // 2 ms batching window
            max_tracked_sessions: 65_536,
            trace: false,
            trace_capacity: 4096,
            clock: None,
        }
    }
}

pub(crate) struct Job {
    pub(crate) request: Request,
    pub(crate) reply: mpsc::Sender<Result<Response>>,
    /// Serve-loop clock reading at intake (stamped by the serving thread,
    /// which owns the clock — the submitting thread leaves it zero).
    pub(crate) enqueued_ns: u64,
}

enum Ctl {
    Submit(Job),
    Snapshot(mpsc::Sender<String>),
    Prometheus(mpsc::Sender<String>),
    JsonMetrics(mpsc::Sender<String>),
    Traces(mpsc::Sender<Vec<RequestTrace>>),
    Fleet(mpsc::Sender<Vec<DeviceStat>>),
    Shutdown,
}

/// An in-flight request handed back by [`Coordinator::submit_async`].
pub struct Pending {
    rx: mpsc::Receiver<Result<Response>>,
}

impl Pending {
    /// Block until the serve loop replies.
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))?
    }
}

/// The L3 coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Ctl>,
    join: Option<JoinHandle<()>>,
    /// Keeps the executor thread alive for the coordinator's lifetime.
    _executor: Option<Executor>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Self> {
        let (executor, exec_handle, router) = match &cfg.artifact_dir {
            Some(dir) => {
                let executor = Executor::spawn(dir.clone())?;
                let handle = executor.handle();
                if cfg.warmup {
                    let manifest = crate::runtime::Manifest::load(dir)?;
                    for entry in &manifest.entries {
                        handle.warmup(&entry.name)?;
                    }
                }
                (Some(executor), Some(handle), Router::standard())
            }
            None => (None, None, Router::simulate_only()),
        };
        let (tx, rx) = mpsc::channel::<Ctl>();
        let join = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || serve_loop(cfg, rx, exec_handle, router))?;
        Ok(Self { tx, join: Some(join), _executor: executor })
    }

    /// Submit a request and wait for its response.
    pub fn submit(&self, request: Request) -> Result<Response> {
        self.submit_async(request)?.wait()
    }

    /// Submit a request without waiting: the caller holds a [`Pending`]
    /// and can keep driving the clock (or submitting) while the request
    /// sits in the batching window.
    pub fn submit_async(&self, request: Request) -> Result<Pending> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Ctl::Submit(Job { request, reply, enqueued_ns: 0 }))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit many requests concurrently; preserves input order.
    pub fn submit_all(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let mut pending = Vec::with_capacity(requests.len());
        for request in requests {
            pending.push(self.submit_async(request)?);
        }
        pending.into_iter().map(|p| p.wait()).collect()
    }

    /// Metrics snapshot (formatted for humans).
    pub fn metrics_snapshot(&self) -> Result<String> {
        self.fetch(Ctl::Snapshot)
    }

    /// Prometheus text exposition of every serving metric.
    pub fn metrics_prometheus(&self) -> Result<String> {
        self.fetch(Ctl::Prometheus)
    }

    /// JSON snapshot of every serving metric.
    pub fn metrics_json(&self) -> Result<String> {
        self.fetch(Ctl::JsonMetrics)
    }

    /// Completed request traces (empty unless configured with
    /// `trace: true`).
    pub fn traces(&self) -> Result<Vec<RequestTrace>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Ctl::Traces(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }

    /// Per-device execution stats: model-time timelines, served/batch
    /// counts, resident sessions, migrations. One entry per fleet device,
    /// in id order.
    pub fn fleet(&self) -> Result<Vec<DeviceStat>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Ctl::Fleet(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }

    fn fetch(&self, make: impl FnOnce(mpsc::Sender<String>) -> Ctl) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(make(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Ctl::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Ctl>,
    exec: Option<ExecutorHandle>,
    router: Router,
) {
    let clock: Arc<dyn Clock> = cfg.clock.clone().unwrap_or_else(|| Arc::new(WallClock::new()));
    let mut batcher = Batcher::new(cfg.max_batch, cfg.max_wait_ns);
    let mut metrics = Metrics::with_clock(clock.clone());
    let mut tracer = Tracer::new(cfg.trace, cfg.trace_capacity);
    // The execution layer: one Device per fleet slot, each with its own
    // hardware model, calibrated ceilings, and session-memory pool; the
    // Dispatcher runs one placed batch on one device.
    let mut fleet = Fleet::new(&cfg);
    let dispatcher = Dispatcher::new(router, exec, clock.clone(), cfg.max_tracked_sessions);
    // BTreeMap so anything that ever iterates the in-flight table (e.g. a
    // future drain-and-report path) sees request-id order (lint:
    // nondet-iteration).
    let mut jobs: std::collections::BTreeMap<u64, Job> = Default::default();
    let mut next_id: u64 = 0;
    let t0 = clock.now_ns();

    // Placement + execution for one released batch.
    let dispatch = |batch: super::batcher::Batch,
                    fleet: &mut Fleet,
                    jobs: &mut std::collections::BTreeMap<u64, Job>,
                    metrics: &mut Metrics,
                    tracer: &mut Tracer| {
        let d = fleet.place(&batch.sessions);
        dispatcher.dispatch(batch, fleet.device_mut(d), jobs, metrics, tracer);
    };

    loop {
        // Wait up to the batching window for the next control message.
        let msg = rx.recv_timeout(std::time::Duration::from_nanos(cfg.max_wait_ns));
        let now_ns = clock.now_ns().saturating_sub(t0);
        match msg {
            Ok(Ctl::Submit(mut job)) => {
                job.enqueued_ns = clock.now_ns();
                let id = next_id;
                next_id += 1;
                let spec = job.request.spec;
                let session = job.request.session;
                if tracer.enabled() {
                    tracer.begin(id, session, format!("{} N={}", spec.op.name(), spec.n));
                }
                jobs.insert(id, job);
                if let Some(batch) = batcher.push(id, spec, session, now_ns) {
                    dispatch(batch, &mut fleet, &mut jobs, &mut metrics, &mut tracer);
                }
            }
            Ok(Ctl::Snapshot(tx)) => {
                metrics.observe_fleet(&fleet);
                let _ = tx.send(metrics.snapshot());
            }
            Ok(Ctl::Prometheus(tx)) => {
                metrics.observe_fleet(&fleet);
                let _ = tx.send(metrics.prometheus());
            }
            Ok(Ctl::JsonMetrics(tx)) => {
                metrics.observe_fleet(&fleet);
                let _ = tx.send(metrics.json());
            }
            Ok(Ctl::Traces(tx)) => {
                let _ = tx.send(tracer.snapshot());
            }
            Ok(Ctl::Fleet(tx)) => {
                let _ = tx.send(fleet.stats());
            }
            Ok(Ctl::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Drain the batcher on *both* exits: a dropped control
                // channel (every Coordinator handle gone) must not
                // silently discard queued requests that the Shutdown
                // path would have dispatched — their Pending receivers
                // may still be alive and waiting.
                for batch in batcher.flush() {
                    dispatch(batch, &mut fleet, &mut jobs, &mut metrics, &mut tracer);
                }
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
        // Release expired batches, dispatching ones whose sessions are
        // already resident on their device's state pool first (cold
        // batches pay their refill when their turn comes; age breaks
        // ties so no signature starves).
        let due = batcher
            .poll_expired_prefer(clock.now_ns().saturating_sub(t0), |s| fleet.is_resident(s));
        for batch in due {
            dispatch(batch, &mut fleet, &mut jobs, &mut metrics, &mut tracer);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;
    use crate::coordinator::ManualClock;

    fn sim_only() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            max_wait_ns: 100_000, // short window for fast tests
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn simulated_request_roundtrip() {
        let c = sim_only();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Toeplitz, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        assert_eq!(r.backend, BackendKind::Simulate);
        assert!(r.sim_report.is_some());
        assert!(r.backend_ns > 0.0);
        assert_eq!(r.device, 0, "single-device fleet serves on d0");
    }

    #[test]
    fn batch_groups_same_signature() {
        // Wide batching window so all 8 same-signature requests coalesce
        // regardless of scheduler jitter.
        let c = Coordinator::new(CoordinatorConfig {
            max_wait_ns: 200_000_000,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 2048),
                session: i,
                inputs: None,
            })
            .collect();
        let responses = c.submit_all(reqs).unwrap();
        assert_eq!(responses.len(), 8);
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "same-signature requests should coalesce: sizes {:?}",
            responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
        );
    }

    #[test]
    fn mixed_signatures_complete() {
        let c = sim_only();
        let mut reqs = Vec::new();
        for (i, op) in OperatorKind::ALL.iter().enumerate() {
            reqs.push(Request {
                spec: WorkloadSpec::new(*op, 1024),
                session: i as u64,
                inputs: None,
            });
        }
        let responses = c.submit_all(reqs).unwrap();
        assert_eq!(responses.len(), 5);
        for (r, op) in responses.iter().zip(OperatorKind::ALL) {
            assert_eq!(r.spec.op, op, "responses preserve submission order");
        }
    }

    #[test]
    fn metrics_snapshot_counts_requests() {
        let c = sim_only();
        for _ in 0..3 {
            c.submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        }
        let snap = c.metrics_snapshot().unwrap();
        assert!(snap.contains("causal"), "{snap}");
        assert!(snap.contains("total=3"), "{snap}");
        assert!(snap.contains("sessions=1"), "{snap}");
        assert!(snap.contains("pages="), "{snap}");
        assert!(snap.contains("devices=1"), "fleet line present: {snap}");
    }

    #[test]
    fn response_names_the_registry_operator() {
        let c = sim_only();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        assert_eq!(r.operator, "linear", "registry attribution on the response");
    }

    #[test]
    fn manual_clock_makes_throughput_deterministic() {
        let clock = ManualClock::new();
        let c = Coordinator::new(CoordinatorConfig {
            max_batch: 1, // dispatch on push: no dependence on the frozen clock
            max_wait_ns: 100_000,
            clock: Some(Arc::new(clock.clone())),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        for i in 0..3 {
            let r = c
                .submit(Request {
                    spec: WorkloadSpec::new(OperatorKind::Linear, 512),
                    session: i,
                    inputs: None,
                })
                .unwrap();
            // The clock never ticked while the request was in flight.
            assert_eq!(r.queue_ns, 0, "frozen clock: no queue age");
        }
        clock.advance_ns(2_000_000_000);
        let snap = c.metrics_snapshot().unwrap();
        assert!(snap.contains("uptime_ms=2000.000"), "{snap}");
        assert!(snap.contains("rps=1.50"), "{snap}");
        // Frozen clock ⇒ measured latency is exactly zero, in every column.
        let row = snap.lines().find(|l| l.starts_with("linear")).unwrap();
        let cols: Vec<&str> = row.split_whitespace().collect();
        assert_eq!(cols[1], "3", "served count: {row}");
        for col in &cols[2..] {
            assert_eq!(*col, "0.000", "zero latency in every column: {row}");
        }
    }

    #[test]
    fn prometheus_and_traces_endpoints_respond() {
        let c = Coordinator::new(CoordinatorConfig {
            max_wait_ns: 100_000,
            trace: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 512),
                session: 1,
                inputs: None,
            })
            .unwrap();
        let prom = c.metrics_prometheus().unwrap();
        assert!(
            prom.contains(
                r#"npuperf_requests_served_total{backend="simulate",device="d0",operator="causal"} 1"#
            ),
            "{prom}"
        );
        crate::obs::lint_prometheus(&prom).expect("exposition lints");
        let json = c.metrics_json().unwrap();
        crate::obs::validate_json(&json).expect("json parses");
        let traces = c.traces().unwrap();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.trace_id, r.trace_id);
        assert_eq!(t.outcome, "served");
        assert_eq!(t.operator, Some("causal"));
        assert_eq!(t.device, Some("d0"), "serving device stamped on the trace");
        let names: Vec<&str> = t.stages.iter().map(|s| s.name).collect();
        for want in ["queued", "lower", "admission", "npu-simulate", "respond"] {
            assert!(names.contains(&want), "missing stage {want}: {names:?}");
        }
        assert!(!t.engine_spans.is_empty(), "engine spans nested under the request");
        // Engine spans sit inside the backend stage's extent.
        let backend = t.stages.iter().find(|s| s.name == "npu-simulate").unwrap();
        for es in &t.engine_spans {
            assert!(es.start_ns >= backend.start_ns as f64 - 1e-6);
            assert!(es.start_ns + es.dur_ns <= backend.end_ns as f64 + 1.0);
        }
    }

    #[test]
    fn untraced_coordinator_returns_no_traces() {
        let c = sim_only();
        c.submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Linear, 256),
            session: 1,
            inputs: None,
        })
        .unwrap();
        assert!(c.traces().unwrap().is_empty());
    }

    #[test]
    fn structured_ops_serve_faster_than_quadratic_in_sim() {
        let c = sim_only();
        let lat = |op| {
            c.submit(Request {
                spec: WorkloadSpec::new(op, 4096),
                session: 99,
                inputs: None,
            })
            .unwrap()
            .backend_ns
        };
        assert!(lat(OperatorKind::Toeplitz) < lat(OperatorKind::Causal) / 10.0);
    }

    #[test]
    fn dropped_handle_flushes_queued_requests() {
        // Regression (satellite bug): a Disconnected control channel must
        // drain the batcher exactly like Shutdown does. Frozen clock +
        // oversized batch + huge window mean neither fill nor expiry can
        // dispatch the queued request — only the exit path can.
        let clock = ManualClock::new();
        let cfg = CoordinatorConfig {
            max_batch: 8,                // never fills
            max_wait_ns: 60_000_000_000, // never expires on a frozen clock
            clock: Some(Arc::new(clock)),
            ..CoordinatorConfig::default()
        };
        let (tx, rx) = mpsc::channel::<Ctl>();
        let join = std::thread::spawn(move || serve_loop(cfg, rx, None, Router::simulate_only()));
        let (reply, resp_rx) = mpsc::channel();
        tx.send(Ctl::Submit(Job {
            request: Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 512),
                session: 1,
                inputs: None,
            },
            reply,
            enqueued_ns: 0,
        }))
        .unwrap();
        drop(tx); // every handle gone: Disconnected, never Shutdown
        join.join().unwrap();
        let resp = resp_rx
            .recv()
            .expect("queued request must be flushed, not silently dropped")
            .unwrap();
        assert_eq!(resp.batch_size, 1);
        assert_eq!(resp.device, 0);
    }

    #[test]
    fn multi_device_fleet_spreads_sessions_and_keeps_affinity() {
        let c = Coordinator::new(CoordinatorConfig {
            devices: 2,
            max_batch: 1, // dispatch on push: one batch per request
            max_wait_ns: 100_000,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let mut seen = std::collections::BTreeMap::new();
        for round in 0..3 {
            for (session, n) in [(1u64, 1024usize), (2, 2048)] {
                let r = c
                    .submit(Request {
                        spec: WorkloadSpec::new(OperatorKind::Causal, n),
                        session,
                        inputs: None,
                    })
                    .unwrap();
                let d = *seen.entry(session).or_insert(r.device);
                assert_eq!(d, r.device, "session stays on its resident device (round {round})");
            }
        }
        assert_ne!(seen[&1], seen[&2], "distinct sessions spread across the fleet");
        let stats = c.fleet().unwrap();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|d| d.served == 3), "{stats:?}");
        assert!(stats.iter().all(|d| d.busy_until_ns > 0), "{stats:?}");
    }
}
