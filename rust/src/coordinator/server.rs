//! The coordinator itself: request intake → dynamic batching → routed
//! dispatch (PJRT executor thread or NPU simulator) → metrics + tracing.
//!
//! Synchronous request API over a background serving thread: callers get a
//! [`Response`] per request; the serving loop owns the batcher, router,
//! state manager, metrics, and the per-request [`Tracer`]. The PJRT
//! runtime (when artifacts are available) is confined to its own executor
//! thread — the coordinator only holds the cloneable channel handle.
//!
//! Simulated batches are lowered through the [operator
//! registry](crate::ops::registry): the serve loop resolves the batch's
//! workload kind to its registered [`crate::ops::CausalOperator`] and
//! dispatches that — no operator `match` in the serving path. A
//! deployment that installs its own registry
//! ([`crate::ops::registry::init_global`] at startup) therefore changes
//! what every kind serves — including swapping in a new operator — with
//! zero coordinator changes.
//!
//! With `trace: true` every request accrues a span tree (queued → lower →
//! admission → backend → respond, stamped on the injected [`Clock`], with
//! the simulator's per-engine spans nested under the backend stage);
//! [`Coordinator::traces`] hands the completed traces out for
//! [`crate::obs::export::chrome`] to merge into one timeline.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::model;
use crate::npu::{self, ExecReport};
use crate::obs::{engine_spans, RequestTrace, Tracer};
use crate::ops::registry;
use crate::runtime::executor::{Executor, ExecutorHandle};
use crate::runtime::Tensor;

use super::batcher::Batcher;
use super::metrics::{Clock, Metrics, WallClock};
use super::router::{BackendKind, Router};
use super::state::StateManager;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub spec: WorkloadSpec,
    /// Session carrying KV / recurrent state (opened on first use).
    pub session: u64,
    /// q/k/v tensors for PJRT-backed execution; `None` ⇒ simulate only.
    pub inputs: Option<Vec<Tensor>>,
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub spec: WorkloadSpec,
    /// What served the request: the registry name of the lowering that
    /// ran (simulate path), or the precompiled artifact's kernel family —
    /// the workload kind's name — on the PJRT path.
    pub operator: &'static str,
    pub backend: BackendKind,
    /// Real outputs (PJRT path only).
    pub outputs: Option<Vec<Tensor>>,
    /// Wall-clock time inside the backend, ns.
    pub backend_ns: f64,
    /// Session-memory time charged to this request, ns: spilling LRU
    /// victims out to admit this session's state plus paging its own
    /// previously spilled state back in (priced at the calibrated
    /// effective DMA ceiling). Zero when the pool is uncontended.
    pub spill_ns: f64,
    /// Enqueue-to-dispatch age on the injected [`Clock`], ns — how long
    /// the request sat in the batching window. Exactly assertable under
    /// a [`super::ManualClock`].
    pub queue_ns: u64,
    /// Trace identity of this request (also the span-tree key when the
    /// coordinator runs with `trace: true`).
    pub trace_id: u64,
    /// Full simulator report (simulate path only).
    pub sim_report: Option<ExecReport>,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub hw: NpuConfig,
    pub sim: SimConfig,
    /// Artifact directory; `None` ⇒ simulation-only deployment.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Pre-compile every artifact at startup so first requests do not pay
    /// PJRT compile latency (§Perf: compiles dominated cold-start serving).
    pub warmup: bool,
    pub max_batch: usize,
    pub max_wait_ns: u64,
    /// Session-memory pool capacity (defaults to the state-reserved
    /// fraction of Table I's 32 GB; page geometry and spill pricing come
    /// from `hw` via [`crate::memory::MemoryConfig`]).
    pub state_budget_bytes: u64,
    /// Upper bound on *tracked* sessions (resident + spilled). Beyond
    /// it, the bookkeeping of LRU spilled sessions is garbage-collected
    /// after each batch — they re-prefill if they return — so a
    /// long-lived server's session map stays bounded.
    pub max_tracked_sessions: usize,
    /// Collect per-request span trees (see [`Coordinator::traces`]).
    /// Off by default: the untraced serve path pays one branch.
    pub trace: bool,
    /// Completed traces kept in memory; older requests beyond this are
    /// counted as dropped rather than stored.
    pub trace_capacity: usize,
    /// Time source for queue ages, batching windows, uptime and
    /// throughput. `None` ⇒ monotonic [`WallClock`]; tests inject a
    /// [`super::ManualClock`] for deterministic latency/throughput
    /// assertions.
    pub clock: Option<Arc<dyn Clock>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self::for_hw(NpuConfig::default(), SimConfig::default())
    }
}

impl CoordinatorConfig {
    /// Config for a specific device: the session-memory pool is sized
    /// from **this** `hw` (its `dram_bytes × state_pool_frac`), not from
    /// the default device — use this instead of
    /// `CoordinatorConfig { hw, ..Default::default() }`, which would
    /// keep a pool sized for the default 32 GB part.
    pub fn for_hw(hw: NpuConfig, sim: SimConfig) -> Self {
        Self {
            state_budget_bytes: (hw.dram_bytes as f64 * hw.state_pool_frac) as u64,
            hw,
            sim,
            artifact_dir: None,
            warmup: false,
            max_batch: 8,
            max_wait_ns: 2_000_000, // 2 ms batching window
            max_tracked_sessions: 65_536,
            trace: false,
            trace_capacity: 4096,
            clock: None,
        }
    }
}

struct Job {
    request: Request,
    reply: mpsc::Sender<Result<Response>>,
    /// Serve-loop clock reading at intake (stamped by the serving thread,
    /// which owns the clock — the submitting thread leaves it zero).
    enqueued_ns: u64,
}

enum Ctl {
    Submit(Job),
    Snapshot(mpsc::Sender<String>),
    Prometheus(mpsc::Sender<String>),
    JsonMetrics(mpsc::Sender<String>),
    Traces(mpsc::Sender<Vec<RequestTrace>>),
    Shutdown,
}

/// An in-flight request handed back by [`Coordinator::submit_async`].
pub struct Pending {
    rx: mpsc::Receiver<Result<Response>>,
}

impl Pending {
    /// Block until the serve loop replies.
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))?
    }
}

/// The L3 coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Ctl>,
    join: Option<JoinHandle<()>>,
    /// Keeps the executor thread alive for the coordinator's lifetime.
    _executor: Option<Executor>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Result<Self> {
        let (executor, exec_handle, router) = match &cfg.artifact_dir {
            Some(dir) => {
                let executor = Executor::spawn(dir.clone())?;
                let handle = executor.handle();
                if cfg.warmup {
                    let manifest = crate::runtime::Manifest::load(dir)?;
                    for entry in &manifest.entries {
                        handle.warmup(&entry.name)?;
                    }
                }
                (Some(executor), Some(handle), Router::standard())
            }
            None => (None, None, Router::simulate_only()),
        };
        let (tx, rx) = mpsc::channel::<Ctl>();
        let join = std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || serve_loop(cfg, rx, exec_handle, router))?;
        Ok(Self { tx, join: Some(join), _executor: executor })
    }

    /// Submit a request and wait for its response.
    pub fn submit(&self, request: Request) -> Result<Response> {
        self.submit_async(request)?.wait()
    }

    /// Submit a request without waiting: the caller holds a [`Pending`]
    /// and can keep driving the clock (or submitting) while the request
    /// sits in the batching window.
    pub fn submit_async(&self, request: Request) -> Result<Pending> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Ctl::Submit(Job { request, reply, enqueued_ns: 0 }))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit many requests concurrently; preserves input order.
    pub fn submit_all(&self, requests: Vec<Request>) -> Result<Vec<Response>> {
        let mut pending = Vec::with_capacity(requests.len());
        for request in requests {
            pending.push(self.submit_async(request)?);
        }
        pending.into_iter().map(|p| p.wait()).collect()
    }

    /// Metrics snapshot (formatted for humans).
    pub fn metrics_snapshot(&self) -> Result<String> {
        self.fetch(Ctl::Snapshot)
    }

    /// Prometheus text exposition of every serving metric.
    pub fn metrics_prometheus(&self) -> Result<String> {
        self.fetch(Ctl::Prometheus)
    }

    /// JSON snapshot of every serving metric.
    pub fn metrics_json(&self) -> Result<String> {
        self.fetch(Ctl::JsonMetrics)
    }

    /// Completed request traces (empty unless configured with
    /// `trace: true`).
    pub fn traces(&self) -> Result<Vec<RequestTrace>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Ctl::Traces(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }

    fn fetch(&self, make: impl FnOnce(mpsc::Sender<String>) -> Ctl) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(make(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped reply"))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Ctl::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Ctl>,
    exec: Option<ExecutorHandle>,
    router: Router,
) {
    let clock: Arc<dyn Clock> = cfg.clock.clone().unwrap_or_else(|| Arc::new(WallClock::new()));
    let mut batcher = Batcher::new(cfg.max_batch, cfg.max_wait_ns);
    let mut metrics = Metrics::with_clock(clock.clone());
    let mut tracer = Tracer::new(cfg.trace, cfg.trace_capacity);
    // Roofline ceilings for the achieved-utilization gauge, calibrated
    // once against this deployment's hardware model.
    let ceilings = model::calibrate(&cfg.hw, &cfg.sim);
    // Spills/refills are priced with the same calibrated beta_eff the
    // roofline reports, so eviction time on responses is commensurate
    // with simulated operator latencies.
    let mut state = StateManager::with_config(
        crate::memory::MemoryConfig::calibrated(&cfg.hw, &cfg.sim)
            .with_pool_bytes(cfg.state_budget_bytes),
    );
    let mut jobs: std::collections::HashMap<u64, Job> = Default::default();
    let mut next_id: u64 = 0;
    let t0 = clock.now_ns();

    let clock_d = clock.clone();
    let dispatch = |batch: super::batcher::Batch,
                    jobs: &mut std::collections::HashMap<u64, Job>,
                    metrics: &mut Metrics,
                    state: &mut StateManager,
                    tracer: &mut Tracer| {
        let dispatch_ns = clock_d.now_ns();
        let backend = router.route(&batch.spec);
        let size = batch.request_ids.len();
        metrics.record_batch(batch.spec.op, size);
        // Simulate path: resolve the batch's operator through the registry
        // and lower once per batch signature. A kind missing from a custom
        // registry leaves this as None and each request in the batch gets
        // an error reply — never a panic on the long-lived serving thread.
        // The PJRT path never touches the registry: it executes a
        // precompiled artifact keyed by the workload kind.
        let sim = if backend == BackendKind::Simulate {
            registry::global().try_for_kind(batch.spec.op).map(|op_impl| {
                let lower_start_ns = clock_d.now_ns();
                let g = op_impl.lower(&batch.spec, &cfg.hw, &cfg.sim);
                let strace = npu::simulate(&g, &cfg.hw, &cfg.sim);
                let report = ExecReport::from_trace(&g, &strace);
                let lower_end_ns = clock_d.now_ns();
                metrics.record_sim(batch.spec.op, &report, &ceilings);
                let spans =
                    if tracer.enabled() { engine_spans(&g, &strace) } else { Vec::new() };
                (op_impl.name(), report, spans, lower_start_ns, lower_end_ns)
            })
        } else {
            None
        };
        for id in batch.request_ids {
            let Some(job) = jobs.remove(&id) else { continue };
            let spec = job.request.spec;
            let queue_ns = dispatch_ns.saturating_sub(job.enqueued_ns);
            tracer.stage(id, "queued", job.enqueued_ns, dispatch_ns);
            // The request timeline cursor: real clock until the backend,
            // then dilated by model time (spill charge, simulated
            // makespan) so nested engine spans tile their stage exactly.
            let mut cursor = dispatch_ns;
            if let Some((_, _, _, l0, l1)) = &sim {
                tracer.stage(id, "lower", *l0, *l1);
                cursor = *l1;
            }
            // Admission control: page the session's state in before the
            // request runs (`admit` never evicts the session it is
            // admitting; explicit pinning is the hook for concurrent
            // dispatchers and latency-critical sessions, not needed on
            // this serial path). A footprint the pool can never hold is
            // shed with an error instead of growing state without bound.
            let session = job.request.session;
            state.open(session, spec.op, spec.d_head, spec.d_state);
            let spill_ns = match state.touch(session, spec.n) {
                Ok(adm) => {
                    let ns = adm.total_ns();
                    tracer.stage(id, "admission", cursor, cursor + ns as u64);
                    cursor += ns as u64;
                    ns
                }
                Err(e) => {
                    metrics.record_shed(spec.op);
                    tracer.stage(id, "admission", cursor, cursor);
                    tracer.finish(id, "shed");
                    let _ = job.reply.send(Err(anyhow!(
                        "request shed by session-memory admission control: {e}"
                    )));
                    continue;
                }
            };
            let result = match backend {
                BackendKind::Pjrt => {
                    let inputs = job.request.inputs.clone().unwrap_or_else(|| {
                        // Deterministic zeros when the caller only wants timing.
                        let shape = vec![spec.n, spec.d_head];
                        vec![
                            Tensor::new(shape.clone(), vec![0.1; spec.n * spec.d_head]).unwrap();
                            3
                        ]
                    });
                    match exec.as_ref().expect("router gated on artifacts").execute(
                        &spec.artifact_name(),
                        inputs,
                    ) {
                        Ok(out) => {
                            tracer.set_operator(id, spec.op.name());
                            tracer.stage(id, "pjrt-execute", cursor, cursor + out.exec_ns as u64);
                            cursor += out.exec_ns as u64;
                            Ok(Response {
                                spec,
                                // The artifact is a precompiled build of the
                                // kind's kernel family, independent of which
                                // lowering the registry currently maps the
                                // kind to — attribute it as such.
                                operator: spec.op.name(),
                                backend,
                                backend_ns: out.exec_ns,
                                spill_ns,
                                queue_ns,
                                trace_id: id,
                                outputs: Some(out.outputs),
                                sim_report: None,
                                batch_size: size,
                            })
                        }
                        Err(e) => Err(e),
                    }
                }
                BackendKind::Simulate => match &sim {
                    Some((operator, report, spans, _, _)) => {
                        let operator = *operator;
                        tracer.set_operator(id, operator);
                        tracer.stage(id, "npu-simulate", cursor, cursor + report.span_ns as u64);
                        tracer.attach_engine_spans(id, cursor, spans);
                        cursor += report.span_ns as u64;
                        Ok(Response {
                            spec,
                            operator,
                            backend,
                            backend_ns: report.span_ns,
                            spill_ns,
                            queue_ns,
                            trace_id: id,
                            outputs: None,
                            sim_report: Some(report.clone()),
                            batch_size: size,
                        })
                    }
                    None => Err(anyhow!(
                        "no operator registered for workload kind {}",
                        spec.op
                    )),
                },
            };
            tracer.stage(id, "respond", cursor, cursor);
            match &result {
                Ok(_) => {
                    let latency_ns =
                        clock_d.now_ns().saturating_sub(job.enqueued_ns).max(queue_ns) as f64;
                    metrics.record_request(spec.op, backend, queue_ns, spill_ns, latency_ns);
                    tracer.finish(id, "served");
                }
                Err(_) => tracer.finish(id, "error"),
            }
            let _ = job.reply.send(result);
        }
        // Keep the session map bounded: forget LRU spilled sessions once
        // the tracked count exceeds the configured cap.
        let _ = state.gc(cfg.max_tracked_sessions);
    };

    loop {
        // Wait up to the batching window for the next control message.
        let msg = rx.recv_timeout(std::time::Duration::from_nanos(cfg.max_wait_ns));
        let now_ns = clock.now_ns().saturating_sub(t0);
        match msg {
            Ok(Ctl::Submit(mut job)) => {
                job.enqueued_ns = clock.now_ns();
                let id = next_id;
                next_id += 1;
                let spec = job.request.spec;
                let session = job.request.session;
                if tracer.enabled() {
                    tracer.begin(id, session, format!("{} N={}", spec.op.name(), spec.n));
                }
                jobs.insert(id, job);
                if let Some(batch) = batcher.push(id, spec, session, now_ns) {
                    dispatch(batch, &mut jobs, &mut metrics, &mut state, &mut tracer);
                }
            }
            Ok(Ctl::Snapshot(tx)) => {
                metrics.observe_memory(&state);
                let _ = tx.send(metrics.snapshot());
            }
            Ok(Ctl::Prometheus(tx)) => {
                metrics.observe_memory(&state);
                let _ = tx.send(metrics.prometheus());
            }
            Ok(Ctl::JsonMetrics(tx)) => {
                metrics.observe_memory(&state);
                let _ = tx.send(metrics.json());
            }
            Ok(Ctl::Traces(tx)) => {
                let _ = tx.send(tracer.snapshot());
            }
            Ok(Ctl::Shutdown) => {
                for batch in batcher.flush() {
                    dispatch(batch, &mut jobs, &mut metrics, &mut state, &mut tracer);
                }
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        // Release expired batches, dispatching ones whose sessions are
        // already resident in the state pool first (cold batches pay
        // their refill when their turn comes; age breaks ties so no
        // signature starves).
        let due = batcher
            .poll_expired_prefer(clock.now_ns().saturating_sub(t0), |s| state.is_resident(s));
        for batch in due {
            dispatch(batch, &mut jobs, &mut metrics, &mut state, &mut tracer);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;

    fn sim_only() -> Coordinator {
        Coordinator::new(CoordinatorConfig {
            max_wait_ns: 100_000, // short window for fast tests
            ..CoordinatorConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn simulated_request_roundtrip() {
        let c = sim_only();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Toeplitz, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        assert_eq!(r.backend, BackendKind::Simulate);
        assert!(r.sim_report.is_some());
        assert!(r.backend_ns > 0.0);
    }

    #[test]
    fn batch_groups_same_signature() {
        // Wide batching window so all 8 same-signature requests coalesce
        // regardless of scheduler jitter.
        let c = Coordinator::new(CoordinatorConfig {
            max_wait_ns: 200_000_000,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 2048),
                session: i,
                inputs: None,
            })
            .collect();
        let responses = c.submit_all(reqs).unwrap();
        assert_eq!(responses.len(), 8);
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "same-signature requests should coalesce: sizes {:?}",
            responses.iter().map(|r| r.batch_size).collect::<Vec<_>>()
        );
    }

    #[test]
    fn mixed_signatures_complete() {
        let c = sim_only();
        let mut reqs = Vec::new();
        for (i, op) in OperatorKind::ALL.iter().enumerate() {
            reqs.push(Request {
                spec: WorkloadSpec::new(*op, 1024),
                session: i as u64,
                inputs: None,
            });
        }
        let responses = c.submit_all(reqs).unwrap();
        assert_eq!(responses.len(), 5);
        for (r, op) in responses.iter().zip(OperatorKind::ALL) {
            assert_eq!(r.spec.op, op, "responses preserve submission order");
        }
    }

    #[test]
    fn metrics_snapshot_counts_requests() {
        let c = sim_only();
        for _ in 0..3 {
            c.submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        }
        let snap = c.metrics_snapshot().unwrap();
        assert!(snap.contains("causal"), "{snap}");
        assert!(snap.contains("total=3"), "{snap}");
        assert!(snap.contains("sessions=1"), "{snap}");
        assert!(snap.contains("pages="), "{snap}");
    }

    #[test]
    fn response_names_the_registry_operator() {
        let c = sim_only();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Linear, 1024),
                session: 1,
                inputs: None,
            })
            .unwrap();
        assert_eq!(r.operator, "linear", "registry attribution on the response");
    }

    #[test]
    fn manual_clock_makes_throughput_deterministic() {
        use super::super::metrics::ManualClock;
        let clock = ManualClock::new();
        let c = Coordinator::new(CoordinatorConfig {
            max_batch: 1, // dispatch on push: no dependence on the frozen clock
            max_wait_ns: 100_000,
            clock: Some(Arc::new(clock.clone())),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        for i in 0..3 {
            let r = c
                .submit(Request {
                    spec: WorkloadSpec::new(OperatorKind::Linear, 512),
                    session: i,
                    inputs: None,
                })
                .unwrap();
            // The clock never ticked while the request was in flight.
            assert_eq!(r.queue_ns, 0, "frozen clock: no queue age");
        }
        clock.advance_ns(2_000_000_000);
        let snap = c.metrics_snapshot().unwrap();
        assert!(snap.contains("uptime_ms=2000.000"), "{snap}");
        assert!(snap.contains("rps=1.50"), "{snap}");
        // Frozen clock ⇒ measured latency is exactly zero, in every column.
        let row = snap.lines().find(|l| l.starts_with("linear")).unwrap();
        let cols: Vec<&str> = row.split_whitespace().collect();
        assert_eq!(cols[1], "3", "served count: {row}");
        for col in &cols[2..] {
            assert_eq!(*col, "0.000", "zero latency in every column: {row}");
        }
    }

    #[test]
    fn prometheus_and_traces_endpoints_respond() {
        let c = Coordinator::new(CoordinatorConfig {
            max_wait_ns: 100_000,
            trace: true,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let r = c
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 512),
                session: 1,
                inputs: None,
            })
            .unwrap();
        let prom = c.metrics_prometheus().unwrap();
        assert!(
            prom.contains(
                r#"npuperf_requests_served_total{backend="simulate",operator="causal"} 1"#
            ),
            "{prom}"
        );
        crate::obs::lint_prometheus(&prom).expect("exposition lints");
        let json = c.metrics_json().unwrap();
        crate::obs::validate_json(&json).expect("json parses");
        let traces = c.traces().unwrap();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.trace_id, r.trace_id);
        assert_eq!(t.outcome, "served");
        assert_eq!(t.operator, Some("causal"));
        let names: Vec<&str> = t.stages.iter().map(|s| s.name).collect();
        for want in ["queued", "lower", "admission", "npu-simulate", "respond"] {
            assert!(names.contains(&want), "missing stage {want}: {names:?}");
        }
        assert!(!t.engine_spans.is_empty(), "engine spans nested under the request");
        // Engine spans sit inside the backend stage's extent.
        let backend = t.stages.iter().find(|s| s.name == "npu-simulate").unwrap();
        for es in &t.engine_spans {
            assert!(es.start_ns >= backend.start_ns as f64 - 1e-6);
            assert!(es.start_ns + es.dur_ns <= backend.end_ns as f64 + 1.0);
        }
    }

    #[test]
    fn untraced_coordinator_returns_no_traces() {
        let c = sim_only();
        c.submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Linear, 256),
            session: 1,
            inputs: None,
        })
        .unwrap();
        assert!(c.traces().unwrap().is_empty());
    }

    #[test]
    fn structured_ops_serve_faster_than_quadratic_in_sim() {
        let c = sim_only();
        let lat = |op| {
            c.submit(Request {
                spec: WorkloadSpec::new(op, 4096),
                session: 99,
                inputs: None,
            })
            .unwrap()
            .backend_ns
        };
        assert!(lat(OperatorKind::Toeplitz) < lat(OperatorKind::Causal) / 10.0);
    }
}
