//! Serving workload generation: deterministic request traces shaped like
//! the paper's §I motivating deployments (document understanding,
//! conversational AI, real-time decision systems).
//!
//! Each profile fixes the mix of operators and the context-length
//! distribution; generation is seeded so benches are reproducible.

use crate::config::{OperatorKind, WorkloadSpec};
use crate::util::check::Rng;

/// Deployment-shaped workload profiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Conversational AI: many short/medium contexts, decode-heavy mix.
    Chat,
    /// Document understanding: long-context prefill dominated.
    Documents,
    /// Mixed fleet: uniform over operators and contexts.
    Mixed,
}

/// One generated request (the coordinator adds sessions/inputs).
#[derive(Clone, Copy, Debug)]
pub struct GenRequest {
    pub spec: WorkloadSpec,
    /// Inter-arrival gap to the previous request, ns.
    pub gap_ns: u64,
}

/// Generate a deterministic trace of `count` requests.
pub fn generate(profile: Profile, count: usize, seed: u64) -> Vec<GenRequest> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let (op, n, gap_ns) = match profile {
            Profile::Chat => {
                // Short contexts, bursty arrivals, operator mix biased to
                // the structured ops a production stack would deploy.
                let ops = [
                    OperatorKind::Toeplitz,
                    OperatorKind::Linear,
                    OperatorKind::Linear,
                    OperatorKind::Causal,
                ];
                let contexts = [128usize, 256, 256, 512, 1024];
                let gap = if rng.f64() < 0.7 { rng.range(0, 200_000) } else { rng.range(2_000_000, 10_000_000) };
                (*rng.choose(&ops), *rng.choose(&contexts), gap)
            }
            Profile::Documents => {
                let ops = [
                    OperatorKind::Causal,
                    OperatorKind::Retentive,
                    OperatorKind::Toeplitz,
                    OperatorKind::Linear,
                    OperatorKind::Fourier,
                ];
                let contexts = [2048usize, 4096, 4096, 8192];
                (*rng.choose(&ops), *rng.choose(&contexts), rng.range(500_000, 5_000_000))
            }
            Profile::Mixed => {
                let contexts = [128usize, 256, 512, 1024, 2048, 4096, 8192];
                (
                    *rng.choose(&OperatorKind::ALL),
                    *rng.choose(&contexts),
                    rng.range(0, 2_000_000),
                )
            }
        };
        out.push(GenRequest { spec: WorkloadSpec::new(op, n), gap_ns });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = generate(Profile::Mixed, 50, 42);
        let b = generate(Profile::Mixed, 50, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.spec, y.spec);
            assert_eq!(x.gap_ns, y.gap_ns);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = generate(Profile::Mixed, 50, 1);
        let b = generate(Profile::Mixed, 50, 2);
        assert!(a.iter().zip(&b).any(|(x, y)| x.spec != y.spec));
    }

    #[test]
    fn chat_profile_is_short_context() {
        let reqs = generate(Profile::Chat, 200, 7);
        assert!(reqs.iter().all(|r| r.spec.n <= 1024));
        // Mostly structured operators.
        let structured = reqs
            .iter()
            .filter(|r| {
                matches!(r.spec.op, OperatorKind::Toeplitz | OperatorKind::Linear)
            })
            .count();
        assert!(structured as f64 > 0.5 * reqs.len() as f64);
    }

    #[test]
    fn documents_profile_is_long_context() {
        let reqs = generate(Profile::Documents, 200, 7);
        assert!(reqs.iter().all(|r| r.spec.n >= 2048));
    }

    #[test]
    fn requested_count_honored() {
        assert_eq!(generate(Profile::Mixed, 123, 0).len(), 123);
    }
}
