//! L3 coordinator: the serving layer that drives operators end-to-end.
//!
//! The paper's contribution is a characterization + performance model, so
//! L3 is the *consumer* of that model: a request router + dynamic batcher
//! that serves causal-operator invocations, backed by
//!
//! - the **PJRT runtime** (real numerics) for contexts with AOT artifacts,
//! - the **NPU simulator** (performance) for the long-context regime,
//!
//! plus the §V co-design machinery: a chunked-prefill scheduler bounded by
//! the 4 MB scratchpad and a KV/recurrent-state manager implementing the
//! memory-state tradeoff of Fig 1 on top of the paged session-memory
//! subsystem (`crate::memory`): per-request admission control, LRU-with
//! -pinning eviction, and spill/refill time charged to responses at the
//! calibrated DMA ceiling.
//!
//! Operator dispatch is registry-driven end to end: the [`Router`] ranks
//! whatever the [operator registry](crate::ops::registry) enumerates, the
//! [`Batcher`] keys on the full workload signature, and the [`Coordinator`]
//! serve loop resolves each batch's kind to its registered
//! [`crate::ops::CausalOperator`] — so a new operator becomes servable by
//! implementing one trait and registering it, with no coordinator changes.
//!
//! Execution is staged over a first-class device fleet: each [`Device`]
//! owns its simulated-NPU config, calibrated ceilings, session-memory
//! pool, and model-time timeline; the serve loop places every batch
//! ([`Fleet::place`]: session-affinity first, then least-loaded) and a
//! [`Dispatcher`] runs it on the chosen device.

pub mod batcher;
pub mod chunking;
pub mod clock;
pub mod device;
pub mod dispatch;
pub mod metrics;
pub mod router;
pub mod server;
pub mod state;
pub mod workload_gen;

pub use batcher::{Batch, Batcher};
pub use chunking::{optimal_chunk, ChunkPlan};
pub use device::{device_label, Device, DeviceStat, Fleet};
pub use dispatch::Dispatcher;
pub use clock::{Clock, ManualClock, WallClock};
pub use metrics::Metrics;
pub use router::{BackendKind, Router};
pub use server::{Coordinator, CoordinatorConfig, Pending, Request, Response};
pub use state::{SessionKind, StateManager};
pub use workload_gen::{generate, GenRequest, Profile};
