//! First-class device fleet: the execution layer under the coordinator.
//!
//! The paper models a *single* NPU; the serving stack's north star is a
//! fleet of them. A [`Device`] owns everything execution needs that used
//! to live implicitly in `serve_loop`'s locals: its simulated-NPU
//! hardware model, the roofline [`Ceilings`] calibrated against it, its
//! own paged [`StateManager`] session-memory pool (KV / recurrent state
//! is **device-resident**), and a model-time `busy_until_ns` timeline
//! that accumulates the simulated/backend nanoseconds of every batch it
//! runs. The [`Fleet`] adds the placement policy on top:
//!
//! 1. **Session affinity first** — a batch lands on the device already
//!    holding its sessions' state, because moving a session means paying
//!    the [`crate::memory::SpillModel`] transfer cost twice (spill out of
//!    the old pool, refill into the new one).
//! 2. **Least-loaded otherwise** — a batch with no resident sessions
//!    goes to the device whose `busy_until_ns` timeline ends earliest,
//!    lowest id breaking ties.
//!
//! Both rules are pure functions of submission order and the injected
//! [`crate::coordinator::Clock`] — no map-iteration order, no wall time —
//! so testkit replays stay exactly deterministic, and a 1-device fleet
//! reproduces the old single-device loop bit for bit.

// lint:allow-file(panic-reachability, "device ids are dense Vec indices assigned at fleet construction; placement only ever returns ids the fleet created")

use std::collections::HashMap;

use crate::config::{NpuConfig, SimConfig};
use crate::memory::{MemoryConfig, SpillModel};
use crate::model::{self, Ceilings};

use super::server::CoordinatorConfig;
use super::state::StateManager;

/// Stable `device="dN"` label for metrics and traces. Ids 0..16 are
/// interned constants; larger fleets leak one small string per device,
/// once, at construction.
pub fn device_label(id: usize) -> &'static str {
    const LABELS: [&str; 16] = [
        "d0", "d1", "d2", "d3", "d4", "d5", "d6", "d7", "d8", "d9", "d10", "d11", "d12",
        "d13", "d14", "d15",
    ];
    match LABELS.get(id) {
        Some(l) => l,
        None => Box::leak(format!("d{id}").into_boxed_str()),
    }
}

/// One execution device: hardware model, calibrated ceilings, resident
/// session state, and a model-time occupancy timeline.
#[derive(Debug)]
pub struct Device {
    /// Fleet index (also the `Response::device` attribution).
    pub id: usize,
    /// Interned `"dN"` metric/trace label.
    pub label: &'static str,
    /// This device's simulated-NPU hardware model.
    pub hw: NpuConfig,
    /// Simulator knobs paired with `hw`.
    pub sim: SimConfig,
    /// Roofline ceilings calibrated once against `hw`/`sim`.
    pub ceilings: Ceilings,
    /// Device-resident session-memory pool (KV / recurrent state).
    pub state: StateManager,
    /// Spill pricing for cross-device session migration.
    spill: SpillModel,
    /// Migration charges owed by sessions that just moved here, drained
    /// into the next request's `spill_ns` by the dispatcher.
    migration_debt: HashMap<u64, f64>,
    /// End of this device's model-time timeline, ns on the serve clock.
    busy_until_ns: u64,
    /// Total model time executed (occupancy numerator), ns.
    busy_ns_total: u64,
    served: u64,
    batches: u64,
    migrations_in: u64,
}

impl Device {
    /// Build device `id` for a deployment. Every device gets its own
    /// session-memory pool of `cfg.state_budget_bytes` — the budget is
    /// per device, mirroring per-device DRAM.
    pub fn new(id: usize, cfg: &CoordinatorConfig) -> Self {
        let mem = MemoryConfig::calibrated(&cfg.hw, &cfg.sim)
            .with_pool_bytes(cfg.state_budget_bytes);
        let spill = SpillModel { beta_eff_gbps: mem.beta_eff_gbps, setup_ns: mem.spill_setup_ns };
        Self {
            id,
            label: device_label(id),
            ceilings: model::calibrate(&cfg.hw, &cfg.sim),
            state: StateManager::with_config(mem),
            spill,
            migration_debt: HashMap::new(),
            hw: cfg.hw.clone(),
            sim: cfg.sim.clone(),
            busy_until_ns: 0,
            busy_ns_total: 0,
            served: 0,
            batches: 0,
            migrations_in: 0,
        }
    }

    /// End of this device's model-time timeline (ns on the serve clock).
    pub fn busy_until_ns(&self) -> u64 {
        self.busy_until_ns
    }

    /// Total model time this device has executed, ns.
    pub fn busy_ns_total(&self) -> u64 {
        self.busy_ns_total
    }

    pub fn served(&self) -> u64 {
        self.served
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Sessions migrated onto this device from elsewhere in the fleet.
    pub fn migrations_in(&self) -> u64 {
        self.migrations_in
    }

    /// Extend the timeline by one batch's model time: the batch starts at
    /// `dispatch_ns` or when the previous batch ends, whichever is later.
    pub fn advance(&mut self, dispatch_ns: u64, model_ns: u64) {
        self.busy_until_ns = self.busy_until_ns.max(dispatch_ns).saturating_add(model_ns);
        self.busy_ns_total = self.busy_ns_total.saturating_add(model_ns);
    }

    /// Accounting hook for the dispatcher: one batch, `served` replies.
    pub(crate) fn note_batch(&mut self, served: u64) {
        self.batches += 1;
        self.served += served;
    }

    /// Drain the migration transfer charge owed by `session` (ns). Zero
    /// for sessions that did not just migrate here.
    pub(crate) fn take_migration_debt(&mut self, session: u64) -> f64 {
        self.migration_debt.remove(&session).unwrap_or(0.0)
    }

    fn owe_migration(&mut self, session: u64, bytes: u64) {
        // Spill out of the old pool + refill into this one: two
        // transfers at the calibrated DMA ceiling.
        self.migrations_in += 1;
        *self.migration_debt.entry(session).or_insert(0.0) +=
            2.0 * self.spill.transfer_ns(bytes);
    }

    /// Read-only stat snapshot for exports and reports.
    pub fn stat(&self) -> DeviceStat {
        DeviceStat {
            id: self.id,
            label: self.label,
            busy_until_ns: self.busy_until_ns,
            busy_ns_total: self.busy_ns_total,
            served: self.served,
            batches: self.batches,
            sessions: self.state.len(),
            resident_sessions: self.state.resident_sessions(),
            migrations_in: self.migrations_in,
        }
    }
}

/// Read-only per-device snapshot handed out by
/// [`crate::coordinator::Coordinator::fleet`].
#[derive(Clone, Debug)]
pub struct DeviceStat {
    pub id: usize,
    pub label: &'static str,
    /// End of the device's model-time timeline, ns.
    pub busy_until_ns: u64,
    /// Total model time executed, ns (occupancy numerator).
    pub busy_ns_total: u64,
    pub served: u64,
    pub batches: u64,
    /// Sessions tracked by the device's pool (resident + spilled).
    pub sessions: usize,
    pub resident_sessions: usize,
    pub migrations_in: u64,
}

/// The device fleet plus the placement policy and session→device
/// affinity map.
#[derive(Debug)]
pub struct Fleet {
    devices: Vec<Device>,
    /// Which device currently holds each session's state.
    affinity: HashMap<u64, usize>,
    migrations: u64,
}

impl Fleet {
    /// A fleet of `cfg.devices.max(1)` identical devices.
    pub fn new(cfg: &CoordinatorConfig) -> Self {
        let count = cfg.devices.max(1);
        Self {
            devices: (0..count).map(|id| Device::new(id, cfg)).collect(),
            affinity: HashMap::new(),
            migrations: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn device_mut(&mut self, id: usize) -> &mut Device {
        &mut self.devices[id]
    }

    /// Sessions moved between devices so far (fleet-wide).
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// End of the latest device timeline — the fleet's aggregate
    /// model-time makespan, ns.
    pub fn makespan_ns(&self) -> u64 {
        self.devices.iter().map(|d| d.busy_until_ns).max().unwrap_or(0)
    }

    /// Is `session`'s state resident on its affine device's pool?
    pub fn is_resident(&self, session: u64) -> bool {
        self.affinity
            .get(&session)
            .is_some_and(|&d| self.devices[d].state.is_resident(session))
    }

    /// Place one batch: session affinity first (majority vote over the
    /// batch's sessions, in submission order; lowest device id breaks
    /// ties), else least-loaded by `busy_until_ns` (lowest id on ties).
    /// Sessions landing away from their previous device are migrated:
    /// their state leaves the old pool and the transfer cost is owed to
    /// the next request on the new device. Deterministic: votes are
    /// tallied in a dense per-device array, never by map iteration.
    pub fn place(&mut self, sessions: &[u64]) -> usize {
        let mut votes = vec![0usize; self.devices.len()];
        for s in sessions {
            if let Some(&d) = self.affinity.get(s) {
                votes[d] += 1;
            }
        }
        let mut chosen = None;
        let mut best = 0usize;
        for (id, &v) in votes.iter().enumerate() {
            if v > best {
                best = v;
                chosen = Some(id);
            }
        }
        let chosen = chosen.unwrap_or_else(|| self.least_loaded());
        for &s in sessions {
            match self.affinity.insert(s, chosen) {
                Some(prev) if prev != chosen => {
                    let bytes = self.devices[prev].state.session_bytes(s).unwrap_or(0);
                    self.devices[prev].state.close(s);
                    self.devices[chosen].owe_migration(s, bytes);
                    self.migrations += 1;
                }
                _ => {}
            }
        }
        chosen
    }

    fn least_loaded(&self) -> usize {
        let mut best = 0;
        for (id, d) in self.devices.iter().enumerate().skip(1) {
            if d.busy_until_ns < self.devices[best].busy_until_ns {
                best = id;
            }
        }
        best
    }

    /// Per-device stat snapshots, in device-id order.
    pub fn stats(&self) -> Vec<DeviceStat> {
        self.devices.iter().map(Device::stat).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;

    fn fleet(n: usize) -> Fleet {
        Fleet::new(&CoordinatorConfig { devices: n, ..CoordinatorConfig::default() })
    }

    #[test]
    fn labels_are_stable_and_interned() {
        assert_eq!(device_label(0), "d0");
        assert_eq!(device_label(15), "d15");
        assert_eq!(device_label(40), "d40");
    }

    #[test]
    fn single_device_fleet_places_everything_on_d0() {
        let mut f = fleet(1);
        for s in 0..20u64 {
            assert_eq!(f.place(&[s]), 0);
        }
        assert_eq!(f.migrations(), 0);
    }

    #[test]
    fn least_loaded_spreads_distinct_sessions() {
        // Satellite: four idle devices, four fresh sessions — each lands
        // on the earliest-ending (then lowest-id) device, so busy work
        // spreads round-robin as timelines grow.
        let mut f = fleet(4);
        for s in 0..4u64 {
            let d = f.place(&[s]);
            assert_eq!(d, s as usize, "fresh session {s} takes the idle lowest id");
            f.device_mut(d).advance(0, 1_000 * (s + 1));
        }
        // Next fresh session goes to the device that frees up first (d0
        // ends at 1000 ns, the earliest).
        assert_eq!(f.place(&[99]), 0);
    }

    #[test]
    fn session_affinity_beats_load() {
        let mut f = fleet(2);
        assert_eq!(f.place(&[7]), 0);
        // Load d0 far beyond d1: affinity still wins for session 7.
        f.device_mut(0).advance(0, 1_000_000);
        assert_eq!(f.place(&[7]), 0, "resident state keeps the session on d0");
        // A fresh session avoids the loaded device.
        assert_eq!(f.place(&[8]), 1);
        assert_eq!(f.migrations(), 0);
    }

    #[test]
    fn majority_vote_migrates_the_minority_session() {
        let mut f = fleet(2);
        f.place(&[1]); // d0
        f.device_mut(0).advance(0, 10);
        f.place(&[2]); // d1 (least loaded)
        // Open real state for session 2 on d1 so migration has bytes.
        f.device_mut(1).state.open(2, OperatorKind::Causal, 64, 16);
        f.device_mut(1).state.append(2, 1024);
        // A batch with two d0-affine sessions and one d1 session: the
        // majority pins it to d0 and session 2 migrates, owing transfer.
        let chosen = f.place(&[1, 1, 2]);
        assert_eq!(chosen, 0, "majority affinity wins");
        assert_eq!(f.migrations(), 1);
        let debt = f.device_mut(0).take_migration_debt(2);
        assert!(debt > 0.0, "migrated session owes the 2x transfer cost: {debt}");
        assert_eq!(f.device_mut(0).take_migration_debt(2), 0.0, "debt drains once");
        assert_eq!(f.devices()[1].state.session_bytes(2), None, "state left the old pool");
    }

    #[test]
    fn makespan_is_the_latest_timeline() {
        let mut f = fleet(3);
        f.device_mut(0).advance(0, 500);
        f.device_mut(2).advance(100, 900);
        assert_eq!(f.makespan_ns(), 1_000);
        let stats = f.stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats[2].busy_until_ns, 1_000);
        assert_eq!(stats[2].busy_ns_total, 900);
        assert_eq!(stats[1].busy_until_ns, 0);
    }

    #[test]
    fn advance_queues_behind_the_running_batch() {
        let mut d = Device::new(0, &CoordinatorConfig::default());
        d.advance(100, 50); // idle device: starts at dispatch time
        assert_eq!(d.busy_until_ns(), 150);
        d.advance(120, 30); // dispatched while busy: queues behind
        assert_eq!(d.busy_until_ns(), 180);
        assert_eq!(d.busy_ns_total(), 80);
    }

    #[test]
    fn zero_devices_clamps_to_one() {
        let f = fleet(0);
        assert_eq!(f.len(), 1);
    }
}
