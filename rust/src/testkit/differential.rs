//! Differential checker: the batched serve path vs. direct lowering.
//!
//! The coordinator's serve loop and the `ops::lower`/`lower_decode` entry
//! points are two roads to the same simulated cost; a refactor that bends
//! one but not the other silently invalidates every serving-layer number.
//! [`check`] lowers every workload kind through **both** and asserts the
//! simulated cycle counts ([`ExecReport::span_ns`]) and the paper-taxonomy
//! [`crate::ops::BoundClass`] agree *exactly* — the simulator is
//! deterministic, so any
//! difference is a real divergence, not noise. Registry entries that are
//! not their kind's canonical lowering (e.g. `retentive-chunked`) are not
//! reachable through kind-keyed serving, so for those — and for decode
//! graphs, which have no serve path — the checker verifies graph validity
//! and lowering determinism instead.
//!
//! [`check_against`] runs the serve and direct sides on *different*
//! hardware configs. With identical configs it is the conformance check;
//! with a perturbed config on one side it must report divergences — the
//! suite's proof that the harness has teeth (see
//! `rust/tests/conformance.rs`).

use std::sync::Arc;

use anyhow::Result;

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::coordinator::{Clock, Coordinator, CoordinatorConfig, ManualClock, Request};
use crate::npu::{self, ExecReport};
use crate::ops;
use crate::ops::registry::{self, classify};

use super::workload::{deterministic_coordinator, replay, stream, Outcome, StreamConfig};

/// One disagreement between the serve path and direct lowering.
#[derive(Clone, Debug)]
pub struct Divergence {
    pub operator: String,
    pub n: usize,
    pub what: String,
}

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} at N={}: {}", self.operator, self.n, self.what)
    }
}

/// Result of a differential run.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Individual comparisons performed.
    pub cases: usize,
    pub divergences: Vec<Divergence>,
}

impl DiffReport {
    pub fn is_clean(&self) -> bool {
        self.divergences.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "differential check: {} cases, {} divergences\n",
            self.cases,
            self.divergences.len()
        );
        for d in &self.divergences {
            out += &format!("  {d}\n");
        }
        out
    }
}

/// Run the differential check with one config for both sides — the
/// conformance configuration; a clean report means serve and direct
/// agree on every registry operator.
pub fn check(hw: &NpuConfig, sim: &SimConfig, contexts: &[usize]) -> Result<DiffReport> {
    check_against(hw, sim, hw, sim, contexts)
}

/// Run the serve path on `(hw_serve, sim_serve)` and the direct path on
/// `(hw_direct, sim_direct)`. Identical configs must produce a clean
/// report; a perturbed direct config must not.
pub fn check_against(
    hw_serve: &NpuConfig,
    sim_serve: &SimConfig,
    hw_direct: &NpuConfig,
    sim_direct: &SimConfig,
    contexts: &[usize],
) -> Result<DiffReport> {
    let reg = registry::global();
    let mut rep = DiffReport::default();
    // Budget sized for the grid: state admission must never shed here —
    // a shed response has no sim_report to compare.
    let coord = deterministic_coordinator(hw_serve, sim_serve, 1 << 30)?;
    let mut session = 0u64;

    // Serve path vs direct kind-canonical lowering, every kind x context.
    for &kind in &OperatorKind::ALL {
        let canonical = reg.for_kind(kind).name();
        for &n in contexts {
            let spec = WorkloadSpec::new(kind, n);
            session += 1;
            let resp = coord.submit(Request { spec, session, inputs: None })?;
            let direct = npu::run(&ops::lower(&spec, hw_direct, sim_direct), hw_direct, sim_direct);
            rep.cases += 1;
            let mut diverge = |what: String| {
                rep.divergences.push(Divergence { operator: canonical.into(), n, what });
            };
            if resp.operator != canonical {
                diverge(format!(
                    "serve path attributed `{}`, registry canon is `{canonical}`",
                    resp.operator
                ));
                continue;
            }
            let Some(served) = resp.sim_report.as_ref() else {
                diverge("serve path returned no simulator report".into());
                continue;
            };
            compare_reports(served, &direct, &mut diverge);
            if resp.backend_ns != served.span_ns {
                diverge(format!(
                    "response backend_ns {} != its own report span {}",
                    resp.backend_ns, served.span_ns
                ));
            }
        }
    }

    // Every registry entry (canonical or variant): prefill + decode
    // graphs validate, simulate to positive spans, and lower
    // deterministically; canonical entries must also match the module
    // entry points they claim to be.
    for op in reg.iter() {
        let canonical = reg.for_kind(op.kind()).name() == op.name();
        for &n in contexts {
            let spec = WorkloadSpec::new(op.kind(), n);
            rep.cases += 1;
            let mut diverge = |what: String| {
                rep.divergences.push(Divergence { operator: op.name().into(), n, what });
            };
            for (phase, graph, again) in [
                (
                    "prefill",
                    op.lower(&spec, hw_direct, sim_direct),
                    op.lower(&spec, hw_direct, sim_direct),
                ),
                (
                    "decode",
                    op.lower_decode(&spec, hw_direct, sim_direct),
                    op.lower_decode(&spec, hw_direct, sim_direct),
                ),
            ] {
                if let Err(e) = graph.validate() {
                    diverge(format!("{phase} graph invalid: {e}"));
                    continue;
                }
                let r1 = npu::run(&graph, hw_direct, sim_direct);
                let r2 = npu::run(&again, hw_direct, sim_direct);
                if r1.span_ns <= 0.0 {
                    diverge(format!("{phase} span is not positive: {}", r1.span_ns));
                }
                if r1.span_ns != r2.span_ns {
                    diverge(format!(
                        "{phase} lowering not deterministic: {} vs {}",
                        r1.span_ns, r2.span_ns
                    ));
                }
                if canonical {
                    let via_module = match phase {
                        "prefill" => ops::lower(&spec, hw_direct, sim_direct),
                        _ => ops::lower_decode(&spec, hw_direct, sim_direct),
                    };
                    let rm = npu::run(&via_module, hw_direct, sim_direct);
                    if rm.span_ns != r1.span_ns {
                        diverge(format!(
                            "{phase}: ops module entry point disagrees with the \
                             registry entry ({} vs {})",
                            rm.span_ns, r1.span_ns
                        ));
                    }
                }
            }
        }
    }
    Ok(rep)
}

/// Deterministic coordinator over an `devices`-wide fleet on a *frozen*
/// [`ManualClock`], so metric expositions are byte-comparable across
/// runs (uptime and queue ages are exactly zero).
fn frozen_fleet(hw: &NpuConfig, sim: &SimConfig, devices: usize) -> Result<Coordinator> {
    Coordinator::new(CoordinatorConfig {
        max_batch: 1,
        max_wait_ns: 100_000,
        state_budget_bytes: 1 << 30,
        devices,
        clock: Some(Arc::new(ManualClock::new()) as Arc<dyn Clock>),
        ..CoordinatorConfig::for_hw(hw.clone(), sim.clone())
    })
}

/// Fleet-parity check for the device-fleet execution layer:
///
/// 1. a 1-device fleet replayed twice produces identical outcomes AND a
///    byte-identical Prometheus exposition (the single-device byte-compat
///    pin the refactor promised);
/// 2. an N-device fleet preserves per-request semantics — the same
///    operator attribution, the same simulated span, the same shed
///    decisions — even though placement spreads sessions across pools.
///
/// Spill charges are deliberately *not* compared across fleet sizes:
/// per-device pools see less pressure than one shared pool, so spill
/// timing may legitimately improve with more devices.
pub fn fleet_parity(
    hw: &NpuConfig,
    sim: &SimConfig,
    seed: u64,
    devices: usize,
) -> Result<DiffReport> {
    let mut rep = DiffReport::default();
    let cfg = StreamConfig { requests: 24, ..StreamConfig::new(seed) };
    let reqs = stream(&cfg);
    let run = |n: usize| -> Result<(Vec<Outcome>, String)> {
        let coord = frozen_fleet(hw, sim, n)?;
        let outcomes = replay(&coord, &reqs);
        let prom = coord.metrics_prometheus()?;
        Ok((outcomes, prom))
    };

    let (base_a, prom_a) = run(1)?;
    let (base_b, prom_b) = run(1)?;
    rep.cases += 1;
    if prom_a != prom_b {
        rep.divergences.push(Divergence {
            operator: "fleet".into(),
            n: 1,
            what: "single-device exposition is not byte-stable across replays".into(),
        });
    }
    for (i, (x, y)) in base_a.iter().zip(&base_b).enumerate() {
        rep.cases += 1;
        if x != y {
            rep.divergences.push(Divergence {
                operator: "fleet".into(),
                n: 1,
                what: format!("request {i} differs across identical replays: {x:?} vs {y:?}"),
            });
        }
    }

    let (multi, _) = run(devices)?;
    for (i, (x, y)) in base_a.iter().zip(&multi).enumerate() {
        rep.cases += 1;
        let same = match (x, y) {
            (
                Outcome::Served { operator: oa, backend_ns: ba, .. },
                Outcome::Served { operator: ob, backend_ns: bb, .. },
            ) => oa == ob && ba == bb,
            (Outcome::Shed(a), Outcome::Shed(b)) => a == b,
            _ => false,
        };
        if !same {
            rep.divergences.push(Divergence {
                operator: "fleet".into(),
                n: devices,
                what: format!("request {i}: {devices}-device outcome {y:?} != 1-device {x:?}"),
            });
        }
    }
    Ok(rep)
}

fn compare_reports(served: &ExecReport, direct: &ExecReport, diverge: &mut impl FnMut(String)) {
    if served.span_ns != direct.span_ns {
        diverge(format!(
            "cycle counts differ: serve {} ns vs direct {} ns",
            served.span_ns, direct.span_ns
        ));
    }
    if classify(served) != classify(direct) {
        diverge(format!(
            "BoundClass differs: serve {} vs direct {}",
            classify(served),
            classify(direct)
        ));
    }
    if served.dma_bytes != direct.dma_bytes {
        diverge(format!(
            "DMA bytes differ: serve {} vs direct {}",
            served.dma_bytes, direct.dma_bytes
        ));
    }
    if served.logical_ops != direct.logical_ops {
        diverge(format!(
            "logical ops differ: serve {} vs direct {}",
            served.logical_ops, direct.logical_ops
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_grid_is_clean() {
        let rep = check(&NpuConfig::default(), &SimConfig::default(), &[128]).unwrap();
        assert!(rep.is_clean(), "{}", rep.render());
        // 5 kinds + 6 registry entries, one context each.
        assert_eq!(rep.cases, 11);
    }

    #[test]
    fn fleet_parity_holds_on_defaults() {
        let rep =
            fleet_parity(&NpuConfig::default(), &SimConfig::default(), 1, 4).unwrap();
        assert!(rep.is_clean(), "{}", rep.render());
        // 1 exposition comparison + 24 replay pairs + 24 fleet pairs.
        assert_eq!(rep.cases, 49);
    }

    #[test]
    fn perturbed_dma_setup_is_detected() {
        let hw = NpuConfig::default();
        let mut bent = hw.clone();
        bent.dma_setup_ns *= 2.0;
        let rep = check_against(&hw, &SimConfig::default(), &bent, &SimConfig::default(), &[256])
            .unwrap();
        assert!(
            !rep.is_clean(),
            "doubling dma_setup_ns must diverge serve from direct"
        );
    }

    #[test]
    fn divergences_render_with_context() {
        let d = Divergence { operator: "causal".into(), n: 512, what: "boom".into() };
        assert_eq!(d.to_string(), "causal at N=512: boom");
    }
}
