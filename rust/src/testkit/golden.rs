//! Golden-fixture facility: snapshot report output for pinned seeds and
//! diff it against checked-in fixtures.
//!
//! Workflow:
//!
//! - A **missing** fixture is written (blessed) on first run and the
//!   comparison passes with a notice — so a fresh checkout that gained a
//!   new golden test never fails spuriously; the generated file is then
//!   committed to pin the behavior.
//! - A **present** fixture must match exactly (modulo a trailing-newline
//!   normalization). A mismatch renders a line diff and the bless hint.
//! - Regeneration after an *intentional* behavior change:
//!   `npuperf selftest --bless`, or `NPUPERF_BLESS=1 cargo test` — both
//!   rewrite the fixture with current output; review the `git diff` and
//!   commit.
//!
//! CI guards the committed fixtures with `git diff --exit-code -- \
//! rust/tests/golden` after the suite runs: drift in a tracked fixture
//! fails the build, while freshly blessed (untracked) files do not.

use std::fs;
use std::path::{Path, PathBuf};

/// How a comparison concluded (both variants pass the test).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Fixture existed and matched.
    Match,
    /// Fixture was written from current output (missing, or bless mode).
    Blessed,
}

/// The checked-in fixture directory: `rust/tests/golden/` at the repo
/// root, resolved from the crate manifest so tests work from any cwd.
pub fn default_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("tests").join("golden")
}

fn env_bless() -> bool {
    std::env::var("NPUPERF_BLESS").map(|v| v == "1").unwrap_or(false)
}

/// Trailing-whitespace-insensitive form used for the equality check, so a
/// fixture edited by tools that strip or add a final newline still
/// matches.
fn normalize(s: &str) -> String {
    let mut out: String = s.lines().map(|l| l.trim_end()).collect::<Vec<_>>().join("\n");
    out.push('\n');
    out
}

/// Compare `actual` against the fixture `name` inside `dir`.
///
/// Returns `Ok` on match or bless (see [`Outcome`]); `Err` carries a
/// rendered diff when a present fixture disagrees and blessing is off.
pub fn compare_in(dir: &Path, name: &str, actual: &str, bless: bool) -> Result<Outcome, String> {
    let path = dir.join(name);
    let want = normalize(actual);
    match fs::read_to_string(&path) {
        Ok(existing) if normalize(&existing) == want => Ok(Outcome::Match),
        Ok(_) if bless || env_bless() => {
            write_fixture(&path, &want)?;
            Ok(Outcome::Blessed)
        }
        Ok(existing) => Err(render_diff(&path, &normalize(&existing), &want)),
        Err(_) => {
            // First run: bless the fixture so new golden tests are
            // adoptable without a bootstrap step; commit the file to pin.
            write_fixture(&path, &want)?;
            Ok(Outcome::Blessed)
        }
    }
}

/// [`compare_in`] against the default checked-in fixture directory.
pub fn compare(name: &str, actual: &str, bless: bool) -> Result<Outcome, String> {
    compare_in(&default_dir(), name, actual, bless)
}

fn write_fixture(path: &Path, content: &str) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent).map_err(|e| format!("creating {parent:?}: {e}"))?;
    }
    fs::write(path, content).map_err(|e| format!("writing {path:?}: {e}"))
}

fn render_diff(path: &Path, expected: &str, actual: &str) -> String {
    let exp: Vec<&str> = expected.lines().collect();
    let act: Vec<&str> = actual.lines().collect();
    let mut out = format!("golden mismatch: {}\n", path.display());
    let mut shown = 0;
    for i in 0..exp.len().max(act.len()) {
        let (e, a) = (exp.get(i).copied(), act.get(i).copied());
        if e != a {
            out += &format!(
                "  line {}:\n    fixture: {}\n    actual:  {}\n",
                i + 1,
                e.unwrap_or("<missing>"),
                a.unwrap_or("<missing>"),
            );
            shown += 1;
            if shown == 8 {
                out += "  ... (further differences elided)\n";
                break;
            }
        }
    }
    if exp.len() != act.len() {
        out += &format!("  line counts differ: fixture {} vs actual {}\n", exp.len(), act.len());
    }
    out += "  re-bless after an intentional change: `npuperf selftest --bless` \
            or NPUPERF_BLESS=1, then commit the fixture\n";
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("npuperf-golden-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn missing_fixture_is_blessed_then_matches() {
        let dir = scratch("bless");
        assert_eq!(compare_in(&dir, "a.txt", "hello\n", false), Ok(Outcome::Blessed));
        assert_eq!(compare_in(&dir, "a.txt", "hello\n", false), Ok(Outcome::Match));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatch_renders_a_line_diff() {
        let dir = scratch("diff");
        compare_in(&dir, "a.txt", "one\ntwo\n", false).unwrap();
        let err = compare_in(&dir, "a.txt", "one\nTWO\n", false).unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("fixture: two"), "{err}");
        assert!(err.contains("actual:  TWO"), "{err}");
        assert!(err.contains("bless"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bless_flag_rewrites_a_present_fixture() {
        let dir = scratch("rebless");
        compare_in(&dir, "a.txt", "old\n", false).unwrap();
        assert_eq!(compare_in(&dir, "a.txt", "new\n", true), Ok(Outcome::Blessed));
        assert_eq!(compare_in(&dir, "a.txt", "new\n", false), Ok(Outcome::Match));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn trailing_newline_is_not_significant() {
        let dir = scratch("newline");
        compare_in(&dir, "a.txt", "x\ny", false).unwrap();
        assert_eq!(compare_in(&dir, "a.txt", "x\ny\n", false), Ok(Outcome::Match));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn default_dir_points_into_the_repo() {
        assert!(default_dir().ends_with("rust/tests/golden"));
    }
}
