//! SplitMix64 PRNG for workload generation.
//!
//! Distinct from [`crate::util::check::Rng`] (xorshift64*): SplitMix64's
//! state advances by a fixed odd constant, so *every* 64-bit seed — zero
//! included — yields a full-period, well-mixed stream, which matters here
//! because conformance seeds are user-supplied (`npuperf selftest --seeds`)
//! and must never be silently remapped. No wall-clock input anywhere: the
//! same seed always produces the same request stream.

/// SplitMix64 generator (Steele, Lea & Flood; the JDK `SplittableRandom`
/// mixer).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_a_valid_stream() {
        // Unlike xorshift, zero is not a fixed point: the stream must be
        // non-degenerate without any seed nudging.
        let mut r = SplitMix64::new(0);
        let xs: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
        assert!(xs.iter().any(|&x| x != 0));
    }

    #[test]
    fn adjacent_seeds_decorrelate() {
        let (mut a, mut b) = (SplitMix64::new(1), SplitMix64::new(2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0, "adjacent seeds must not share outputs");
    }

    #[test]
    fn below_and_range_respect_bounds() {
        let mut r = SplitMix64::new(7);
        let (mut lo, mut hi) = (false, false);
        for _ in 0..2000 {
            assert!(r.below(13) < 13);
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            lo |= x == 3;
            hi |= x == 5;
        }
        assert!(lo && hi, "range endpoints should both occur");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn choose_covers_the_slice() {
        let mut r = SplitMix64::new(5);
        let xs = [10, 20, 30];
        let mut seen = [false; 3];
        for _ in 0..200 {
            let v = *r.choose(&xs);
            seen[xs.iter().position(|&x| x == v).unwrap()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
