//! Reusable invariant checkers for the serving stack.
//!
//! Three families, each usable standalone from any test and all driven by
//! `npuperf selftest`:
//!
//! - **Session-memory conservation** ([`memory_conservation`],
//!   [`memory_workout`]): page accounting balances (resident page sum ==
//!   pool pages in use), the pool never exceeds capacity, pinned sessions
//!   are never evicted, and every eviction picks the true LRU victim —
//!   verified against an independent oracle built from
//!   [`SessionMemory::audit`] *pre-state*, not from the manager's own
//!   post-hoc claims.
//! - **Batcher fairness** ([`batcher_fairness`]): expired batches release
//!   oldest waiter first, nothing eligible is left behind (no
//!   starvation), nothing releases early, and no request is lost or
//!   duplicated.
//! - **Footprint monotonicity** ([`footprint_monotonicity`],
//!   [`footprint_table`]): every operator's state curve is monotone in
//!   position, and the built-ins keep their paper shapes — KV grows
//!   O(N·d), retention/SSM state stays constant, Toeplitz is band-capped.

use std::collections::{HashMap, HashSet};

use crate::config::{OperatorKind, WorkloadSpec};
use crate::coordinator::Batcher;
use crate::memory::{AdmitError, MemoryConfig, SessionAudit, SessionMemory};
use crate::ops::registry::OperatorRegistry;

use super::prng::SplitMix64;

// ---- Session-memory conservation ---------------------------------------

/// Check the page-accounting invariants of `mem`'s current state.
///
/// Cheap enough to run after every mutation in a workout loop.
pub fn memory_conservation(mem: &SessionMemory) -> Result<(), String> {
    let cfg = mem.config();
    let pool = mem.pool();
    let rows = mem.audit();

    if pool.used_pages() > pool.total_pages() {
        return Err(format!(
            "pool over capacity: {} used of {} pages",
            pool.used_pages(),
            pool.total_pages()
        ));
    }
    let resident_sum: u64 = rows.iter().filter(|r| r.resident).map(|r| r.resident_pages).sum();
    if resident_sum != pool.used_pages() {
        return Err(format!(
            "page leak: sessions hold {resident_sum} pages but the pool has {} in use",
            pool.used_pages()
        ));
    }
    for r in &rows {
        if r.resident && r.resident_pages == 0 {
            return Err(format!("session {} resident with zero pages", r.id));
        }
        if !r.resident && r.resident_pages != 0 {
            return Err(format!(
                "session {} spilled but still holds {} pages",
                r.id, r.resident_pages
            ));
        }
        if r.resident && r.resident_pages != cfg.pages_for(r.logical_bytes).max(1) {
            return Err(format!(
                "session {}: {} resident pages for {} logical bytes (want {})",
                r.id,
                r.resident_pages,
                r.logical_bytes,
                cfg.pages_for(r.logical_bytes).max(1)
            ));
        }
    }
    let resident_rows = rows.iter().filter(|r| r.resident).count();
    if resident_rows != mem.resident_sessions() {
        return Err(format!(
            "resident-session count drift: audit {} vs manager {}",
            resident_rows,
            mem.resident_sessions()
        ));
    }
    if mem.stats().peak_resident_bytes > pool.total_bytes() {
        return Err(format!(
            "peak resident {} exceeds pool capacity {}",
            mem.stats().peak_resident_bytes,
            pool.total_bytes()
        ));
    }
    Ok(())
}

/// LRU oracle over a pre-mutation audit: the victim the policy *must*
/// pick next, excluding sessions already evicted this admission.
fn lru_from_audit(rows: &[SessionAudit], excluded: &HashSet<u64>) -> Option<u64> {
    rows.iter()
        .filter(|r| !excluded.contains(&r.id) && r.resident && !r.pinned && r.resident_pages > 0)
        .min_by_key(|r| (r.last_touch, r.id))
        .map(|r| r.id)
}

/// Seeded random workout of [`SessionMemory`]: `steps` mixed
/// open/admit/pin/unpin/reset/close/shed operations over a small pool,
/// checking after every step that conservation holds, that no pinned
/// session is ever evicted, and that each eviction matches the
/// independent LRU oracle.
pub fn memory_workout(seed: u64, steps: usize) -> Result<String, String> {
    const PAGE: u64 = 64 * 1024;
    let mut mem = SessionMemory::new(MemoryConfig {
        page_bytes: PAGE,
        pool_bytes: 16 * PAGE, // small pool so eviction pressure is constant
        beta_eff_gbps: 3.2,
        spill_setup_ns: 1_500.0,
    });
    let mut rng = SplitMix64::new(seed);
    let ids: [u64; 8] = [1, 2, 3, 4, 5, 6, 7, 8];
    let mut open: HashSet<u64> = HashSet::new();
    let mut pinned: HashSet<u64> = HashSet::new();
    let (mut admits, mut evictions, mut rejections) = (0u64, 0u64, 0u64);

    for step in 0..steps {
        let id = *rng.choose(&ids);
        let ctx = |what: &str| format!("seed {seed} step {step} session {id}: {what}");
        match rng.below(100) {
            0..=54 => {
                if !open.contains(&id) {
                    mem.open(id);
                    open.insert(id);
                }
                let bytes = rng.below(6) * PAGE + rng.below(PAGE);
                let pre = mem.audit();
                match mem.admit(id, bytes) {
                    Ok(adm) => {
                        admits += 1;
                        evictions += adm.evicted.len() as u64;
                        let mut excluded: HashSet<u64> = HashSet::from([id]);
                        for &victim in &adm.evicted {
                            if pinned.contains(&victim) {
                                return Err(ctx(&format!("evicted pinned session {victim}")));
                            }
                            let expect = lru_from_audit(&pre, &excluded);
                            if expect != Some(victim) {
                                return Err(ctx(&format!(
                                    "evicted {victim} but the LRU oracle says {expect:?}"
                                )));
                            }
                            if mem.is_resident(victim) {
                                return Err(ctx(&format!(
                                    "victim {victim} still resident after eviction"
                                )));
                            }
                            excluded.insert(victim);
                        }
                        if !mem.is_resident(id) {
                            return Err(ctx("admitted session is not resident"));
                        }
                    }
                    Err(AdmitError::FootprintExceedsPool { .. }) => rejections += 1,
                    Err(AdmitError::PoolPinned { .. }) => {
                        rejections += 1;
                        if pinned.is_empty() {
                            return Err(ctx("PoolPinned rejection with no pinned session"));
                        }
                    }
                    Err(e) => return Err(ctx(&format!("unexpected admit error: {e}"))),
                }
            }
            55..=64 => {
                if mem.pin(id) {
                    pinned.insert(id);
                }
            }
            65..=74 => {
                if mem.unpin(id) {
                    pinned.remove(&id);
                }
            }
            75..=82 => {
                // Reset clears the pin: a fresh context does not inherit
                // latency-critical status.
                mem.reset(id);
                pinned.remove(&id);
            }
            83..=90 => {
                mem.close(id);
                open.remove(&id);
                pinned.remove(&id);
            }
            _ => {
                if let Some(shed) = mem.shed_spilled_lru() {
                    if pinned.contains(&shed) {
                        return Err(ctx(&format!("GC shed pinned session {shed}")));
                    }
                    open.remove(&shed);
                }
            }
        }
        memory_conservation(&mem).map_err(|e| ctx(&e))?;
    }
    Ok(format!(
        "{steps} steps: {admits} admits, {evictions} evictions, {rejections} rejections"
    ))
}

// ---- Batcher fairness ---------------------------------------------------

/// Seeded random workout of the [`Batcher`]: checks that expired batches
/// release **oldest waiter first**, that every release waited at least the
/// configured window, that no eligible batch is left queued after a poll
/// (no starvation), and that every pushed request id is released exactly
/// once.
pub fn batcher_fairness(seed: u64, events: usize) -> Result<String, String> {
    let mut rng = SplitMix64::new(seed);
    let max_batch = rng.range(2, 6) as usize;
    let max_wait = rng.range(50, 200);
    let mut b = Batcher::new(max_batch, max_wait);

    // Independent oracle: per-signature oldest queued push time.
    let mut oldest: HashMap<WorkloadSpec, u64> = HashMap::new();
    let mut released: Vec<u64> = Vec::new();
    let mut pushed: u64 = 0;
    let mut t: u64 = 0;
    let contexts = [128usize, 256, 512];

    for step in 0..events {
        t += rng.below(40);
        let ctx = |what: &str| format!("seed {seed} step {step} t={t}: {what}");
        if rng.below(100) < 70 {
            let spec = WorkloadSpec::new(*rng.choose(&OperatorKind::ALL), *rng.choose(&contexts));
            let id = pushed;
            pushed += 1;
            oldest.entry(spec).or_insert(t);
            if let Some(batch) = b.push(id, spec, id, t) {
                if batch.request_ids.len() != max_batch {
                    return Err(ctx("push released a non-full batch"));
                }
                oldest.remove(&batch.spec);
                released.extend(batch.request_ids);
            }
        } else {
            let mut prev_oldest = 0u64;
            for batch in b.poll_expired(t) {
                let Some(&o) = oldest.get(&batch.spec) else {
                    return Err(ctx("released a batch the oracle never saw"));
                };
                if t.saturating_sub(o) < max_wait {
                    return Err(ctx(&format!(
                        "released after only {} ns of a {} ns window",
                        t.saturating_sub(o),
                        max_wait
                    )));
                }
                if o < prev_oldest {
                    return Err(ctx(&format!(
                        "younger batch (queued at {prev_oldest}) released before \
                         older one (queued at {o})"
                    )));
                }
                prev_oldest = o;
                oldest.remove(&batch.spec);
                released.extend(batch.request_ids);
            }
            // Starvation check: everything due must have been released.
            for (spec, &o) in &oldest {
                if t.saturating_sub(o) >= max_wait {
                    return Err(ctx(&format!(
                        "starved: {spec:?} queued at {o} still waiting after poll"
                    )));
                }
            }
        }
    }
    for batch in b.flush() {
        released.extend(batch.request_ids);
    }
    released.sort_unstable();
    let want: Vec<u64> = (0..pushed).collect();
    if released != want {
        return Err(format!(
            "seed {seed}: request ids lost or duplicated ({} released of {pushed})",
            released.len()
        ));
    }
    Ok(format!(
        "{events} events, max_batch={max_batch}, max_wait={max_wait} ns, \
         {pushed} requests conserved"
    ))
}

// ---- Footprint monotonicity --------------------------------------------

/// Check every registered operator's state-footprint curve: monotone
/// non-decreasing in position, and — for the built-in names — the paper's
/// shape: `causal` grows linearly (O(N·d) KV), `retentive` /
/// `retentive-chunked` / `linear` / `fourier` are context-constant, and
/// `toeplitz` saturates at its band. Unknown (custom) operators get the
/// monotonicity check only.
pub fn footprint_monotonicity(reg: &OperatorRegistry) -> Result<String, String> {
    let positions: [usize; 11] = [0, 1, 16, 64, 128, 256, 512, 1024, 4096, 16384, 1 << 20];
    for op in reg.iter() {
        let spec = WorkloadSpec::new(op.kind(), 4096);
        let fp = |p: usize| op.state_footprint(&spec, p);
        let mut prev = 0u64;
        for &p in &positions {
            let f = fp(p);
            if f < prev {
                return Err(format!(
                    "{}: footprint shrinks with position ({} at {p} < {prev})",
                    op.name(),
                    f
                ));
            }
            prev = f;
        }
        match op.name() {
            "causal" => {
                if fp(2048) != 2 * fp(1024) || fp(8192) != 8 * fp(1024) {
                    return Err(format!(
                        "causal KV must grow O(N·d): fp(1024)={} fp(2048)={} fp(8192)={}",
                        fp(1024),
                        fp(2048),
                        fp(8192)
                    ));
                }
            }
            "retentive" | "retentive-chunked" | "linear" | "fourier" => {
                if fp(1) != fp(1 << 20) {
                    return Err(format!(
                        "{} state must be context-constant: fp(1)={} fp(2^20)={}",
                        op.name(),
                        fp(1),
                        fp(1 << 20)
                    ));
                }
            }
            "toeplitz" => {
                if fp(1 << 20) != fp(4096) {
                    return Err(format!(
                        "toeplitz state must saturate at the band: fp(4096)={} fp(2^20)={}",
                        fp(4096),
                        fp(1 << 20)
                    ));
                }
                if fp(16) >= fp(1 << 20) {
                    return Err(format!(
                        "toeplitz ring buffer should still grow below the band: \
                         fp(16)={} fp(2^20)={}",
                        fp(16),
                        fp(1 << 20)
                    ));
                }
            }
            _ => {}
        }
    }
    Ok(format!("{} operators x {} positions", reg.len(), positions.len()))
}

/// Hand-checkable footprint table over the pinned conformance grid —
/// every entry is closed-form arithmetic from the operator definitions,
/// so the checked-in fixture (`rust/tests/golden/footprints.txt`) can be
/// verified with pencil and paper.
pub fn footprint_table(reg: &OperatorRegistry) -> String {
    let mut out = String::new();
    for op in reg.iter() {
        for n in [256usize, 1024, 8192] {
            let spec = WorkloadSpec::new(op.kind(), n);
            out += &format!("{} n={} bytes={}\n", op.name(), n, op.state_footprint(&spec, n));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::registry;

    #[test]
    fn memory_workout_passes_pinned_seeds() {
        for seed in [0, 1, 42] {
            memory_workout(seed, 300).unwrap();
        }
    }

    #[test]
    fn batcher_fairness_passes_pinned_seeds() {
        for seed in [0, 1, 42] {
            batcher_fairness(seed, 300).unwrap();
        }
    }

    #[test]
    fn builtin_footprints_are_monotone_and_shaped() {
        footprint_monotonicity(registry::global()).unwrap();
    }

    #[test]
    fn footprint_table_is_closed_form() {
        let table = footprint_table(registry::global());
        // causal KV at n=1024: 2 sides * 1024 tokens * 64 dims * 2 B fp16.
        assert!(table.contains("causal n=1024 bytes=262144"), "{table}");
        // retentive d*d f32 accumulator: 64*64*4, context-independent.
        assert!(table.contains("retentive n=8192 bytes=16384"), "{table}");
        // toeplitz band cap: 2 * 128 * 64 * 2 at every n >= band.
        assert!(table.contains("toeplitz n=8192 bytes=32768"), "{table}");
    }

    #[test]
    fn conservation_accepts_a_fresh_manager() {
        let mem = SessionMemory::new(MemoryConfig {
            page_bytes: 64 * 1024,
            pool_bytes: 1024 * 1024,
            beta_eff_gbps: 3.2,
            spill_setup_ns: 1_500.0,
        });
        memory_conservation(&mem).unwrap();
    }
}
