//! Deterministic verification subsystem for the serving stack.
//!
//! The paper's claim is that operator cost classes are *predictable* from
//! the analytical model; this module is the machinery that keeps the
//! implementation honest about it, every CI run:
//!
//! - [`prng`] — a SplitMix64 PRNG with no wall-clock input, so every
//!   workload here is a pure function of its seed;
//! - [`workload`] — seeded request streams replayed through the
//!   coordinator with exact-equality outcome comparison;
//! - [`differential`] — the batched serve path vs. direct
//!   `ops::lower`/`lower_decode`, asserting simulated cycle counts and
//!   [`crate::ops::BoundClass`] agree;
//! - [`invariants`] — reusable checkers for session-memory conservation,
//!   batcher fairness, and state-footprint monotonicity;
//! - [`golden`] — fixture snapshot/diff with a bless path
//!   (`npuperf selftest --bless` / `NPUPERF_BLESS=1`).
//!
//! [`selftest`] composes all of it into the on-device conformance suite
//! behind `npuperf selftest`; `rust/tests/conformance.rs` runs the same
//! sections under `cargo test` plus the harness-has-teeth proof (a
//! perturbed cost constant must make the differential check fail).

pub mod differential;
pub mod golden;
pub mod invariants;
pub mod prng;
pub mod workload;

pub use differential::{check as differential_check, fleet_parity, DiffReport, Divergence};
pub use golden::Outcome as GoldenOutcome;
pub use prng::SplitMix64;

use crate::config::{NpuConfig, SimConfig};
use crate::ops::registry;

/// Options for one [`selftest`] run.
#[derive(Clone, Debug)]
pub struct SelftestOptions {
    /// Seeds for the randomized sections; each runs once per seed.
    pub seeds: Vec<u64>,
    /// Context grid for the differential section.
    pub contexts: Vec<usize>,
    /// Rewrite golden fixtures from current output instead of diffing.
    pub bless: bool,
    /// Fixture directory override (tests); `None` = the checked-in
    /// `rust/tests/golden/`.
    pub golden_dir: Option<std::path::PathBuf>,
}

impl Default for SelftestOptions {
    fn default() -> Self {
        Self {
            seeds: vec![1, 2, 3],
            contexts: vec![256, 1024, 4096],
            bless: false,
            golden_dir: None,
        }
    }
}

/// One suite section's result.
#[derive(Clone, Debug)]
pub struct Section {
    pub name: &'static str,
    /// `Ok(detail)` or `Err(failure)`.
    pub result: Result<String, String>,
}

/// Full selftest outcome.
#[derive(Clone, Debug)]
pub struct SelftestReport {
    pub sections: Vec<Section>,
}

impl SelftestReport {
    pub fn passed(&self) -> bool {
        self.sections.iter().all(|s| s.result.is_ok())
    }

    pub fn render(&self) -> String {
        let mut out = String::from("npuperf selftest — deterministic conformance suite\n");
        for s in &self.sections {
            match &s.result {
                Ok(detail) => out += &format!("  [ok]   {:<22} {detail}\n", s.name),
                Err(e) => out += &format!("  [FAIL] {:<22} {e}\n", s.name),
            }
        }
        let failed = self.sections.iter().filter(|s| s.result.is_err()).count();
        out += &if failed == 0 {
            format!("result: PASS ({} sections)\n", self.sections.len())
        } else {
            format!("result: FAIL ({failed} of {} sections)\n", self.sections.len())
        };
        out
    }
}

/// Pinned context grid for the golden snapshots — independent of
/// [`SelftestOptions::contexts`] so every invocation compares against the
/// same fixtures.
const GOLDEN_CONTEXTS: [usize; 2] = [512, 2048];

/// Run the full conformance suite: differential serve-vs-direct check,
/// seeded memory/batcher invariant workouts, footprint shape checks,
/// replay determinism, and (on the default config) golden-fixture
/// comparisons.
pub fn selftest(hw: &NpuConfig, sim: &SimConfig, opts: &SelftestOptions) -> SelftestReport {
    let reg = registry::global();
    let mut sections = Vec::new();
    let mut section = |name: &'static str, result: Result<String, String>| {
        sections.push(Section { name, result });
    };

    section(
        "differential",
        match differential::check(hw, sim, &opts.contexts) {
            Ok(rep) if rep.is_clean() => Ok(format!("{} cases, 0 divergences", rep.cases)),
            Ok(rep) => Err(rep.render()),
            Err(e) => Err(format!("checker failed to run: {e}")),
        },
    );

    section("memory-invariants", {
        opts.seeds
            .iter()
            .try_for_each(|&seed| invariants::memory_workout(seed, 400).map(|_| ()))
            .map(|()| format!("seeds {:?}, 400 steps each", opts.seeds))
    });

    section("batcher-fairness", {
        opts.seeds
            .iter()
            .try_for_each(|&seed| invariants::batcher_fairness(seed, 400).map(|_| ()))
            .map(|()| format!("seeds {:?}, 400 events each", opts.seeds))
    });

    section("footprint-shapes", invariants::footprint_monotonicity(reg));

    section("replay-determinism", replay_section(hw, sim, &opts.seeds));

    section("fleet-parity", fleet_section(hw, sim, &opts.seeds));

    section("obs-conformance", obs_section(hw, sim, &opts.seeds));

    section("lint-conformance", crate::analysis::selftest_section());

    section("semantic-lint-conformance", crate::analysis::semantic_selftest_section());

    // Golden fixtures capture *default-config* output; with hardware
    // overrides in play the snapshot legitimately differs, so skip
    // rather than fail (the differential sections above still ran on the
    // overridden config).
    if *hw == NpuConfig::default() && *sim == SimConfig::default() {
        let dir = opts.golden_dir.clone().unwrap_or_else(golden::default_dir);
        let golden_detail = |o: golden::Outcome| match o {
            golden::Outcome::Match => "matches pinned fixture".to_string(),
            golden::Outcome::Blessed => "blessed — fixture (re)written, commit it".to_string(),
        };
        section(
            "golden-footprints",
            golden::compare_in(
                &dir,
                "footprints.txt",
                &invariants::footprint_table(reg),
                opts.bless,
            )
            .map(golden_detail),
        );
        section(
            "golden-cycles",
            golden::compare_in(
                &dir,
                "selftest_cycles.txt",
                &crate::report::sweep::conformance_snapshot(reg, &GOLDEN_CONTEXTS, hw, sim),
                opts.bless,
            )
            .map(golden_detail),
        );
    } else {
        section(
            "golden-fixtures",
            Ok("skipped: non-default hardware/sim config".to_string()),
        );
    }

    SelftestReport { sections }
}

fn replay_section(hw: &NpuConfig, sim: &SimConfig, seeds: &[u64]) -> Result<String, String> {
    let mut served = 0usize;
    let mut shed = 0usize;
    for &seed in seeds {
        // Small pool (8 MiB) so the replay exercises spills under
        // contention, not just the happy path.
        let cfg = workload::StreamConfig::new(seed);
        let reqs = workload::stream(&cfg);
        let run = |label: &str| -> Result<Vec<workload::Outcome>, String> {
            let coord = workload::deterministic_coordinator(hw, sim, 8 * 1024 * 1024)
                .map_err(|e| format!("seed {seed}: {label} coordinator: {e}"))?;
            Ok(workload::replay(&coord, &reqs))
        };
        let (a, b) = (run("first")?, run("second")?);
        if a != b {
            let diff = a
                .iter()
                .zip(&b)
                .position(|(x, y)| x != y)
                .map(|i| format!("first divergence at request {i}: {:?} vs {:?}", a[i], b[i]))
                .unwrap_or_else(|| "outcome lengths differ".to_string());
            return Err(format!("seed {seed}: replays disagree — {diff}"));
        }
        let ok = a
            .iter()
            .filter(|o| matches!(o, workload::Outcome::Served { .. }))
            .count();
        served += ok;
        shed += a.len() - ok;
    }
    let total = served + shed;
    Ok(format!(
        "{} seeds x 2 replays, {served}/{total} served, {shed} shed, outcomes identical",
        seeds.len()
    ))
}

/// Fleet parity: per seed, a 1-device fleet must be byte-stable across
/// replays and a 4-device fleet must preserve per-request semantics
/// (see [`differential::fleet_parity`]).
fn fleet_section(hw: &NpuConfig, sim: &SimConfig, seeds: &[u64]) -> Result<String, String> {
    let mut cases = 0usize;
    for &seed in seeds {
        match differential::fleet_parity(hw, sim, seed, 4) {
            Ok(rep) if rep.is_clean() => cases += rep.cases,
            Ok(rep) => return Err(format!("seed {seed}: {}", rep.render())),
            Err(e) => return Err(format!("seed {seed}: checker failed to run: {e}")),
        }
    }
    Ok(format!("{} seeds x 1-vs-4 devices, {cases} cases, 0 divergences", seeds.len()))
}

/// Observability conformance: replay a traced stream on a frozen
/// [`ManualClock`](crate::coordinator::ManualClock) and check every
/// export surface — the merged Chrome timeline parses, the JSONL event
/// log parses line by line, the Prometheus exposition lints, and its
/// served counters agree with the replay's outcomes exactly.
fn obs_section(hw: &NpuConfig, sim: &SimConfig, seeds: &[u64]) -> Result<String, String> {
    use crate::coordinator::{Coordinator, CoordinatorConfig, ManualClock};
    let mut spans = 0usize;
    for &seed in seeds {
        let cfg = workload::StreamConfig { requests: 12, ..workload::StreamConfig::new(seed) };
        let coord = Coordinator::new(CoordinatorConfig {
            max_batch: 1,
            max_wait_ns: 100_000,
            trace: true,
            clock: Some(std::sync::Arc::new(ManualClock::new())),
            ..CoordinatorConfig::for_hw(hw.clone(), sim.clone())
        })
        .map_err(|e| format!("seed {seed}: coordinator: {e}"))?;
        let outcomes = workload::replay(&coord, &workload::stream(&cfg));
        let served = outcomes
            .iter()
            .filter(|o| matches!(o, workload::Outcome::Served { .. }))
            .count();
        let traces = coord.traces().map_err(|e| format!("seed {seed}: traces: {e}"))?;
        if traces.len() != outcomes.len() {
            return Err(format!(
                "seed {seed}: {} traces for {} requests",
                traces.len(),
                outcomes.len()
            ));
        }
        let timeline = crate::obs::chrome(&traces);
        crate::obs::validate_json(&timeline)
            .map_err(|e| format!("seed {seed}: merged timeline: {e}"))?;
        for line in crate::obs::jsonl(&traces).lines() {
            crate::obs::validate_json(line).map_err(|e| format!("seed {seed}: event log: {e}"))?;
        }
        let prom = coord.metrics_prometheus().map_err(|e| format!("seed {seed}: {e}"))?;
        crate::obs::lint_prometheus(&prom)
            .map_err(|e| format!("seed {seed}: exposition: {e}"))?;
        let served_prefix = format!("{}{{", crate::coordinator::metrics::names::SERVED);
        let total: u64 = prom
            .lines()
            .filter(|l| l.starts_with(&served_prefix))
            .filter_map(|l| l.rsplit_once(' ').and_then(|(_, v)| v.parse::<u64>().ok()))
            .sum();
        if total != served as u64 {
            return Err(format!(
                "seed {seed}: exposition counts {total} served, replay saw {served}"
            ));
        }
        spans += timeline.matches("\"ph\":\"X\"").count();
    }
    Ok(format!(
        "{} seeds, merged timelines valid, {spans} spans, expositions lint clean",
        seeds.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selftest_passes_on_defaults_with_scratch_goldens() {
        let dir = std::env::temp_dir().join(format!("npuperf-selftest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let opts = SelftestOptions {
            seeds: vec![1],
            contexts: vec![128],
            golden_dir: Some(dir.clone()),
            ..SelftestOptions::default()
        };
        let rep = selftest(&NpuConfig::default(), &SimConfig::default(), &opts);
        assert!(rep.passed(), "{}", rep.render());
        assert!(rep.render().contains("blessed"), "{}", rep.render());
        // Second run diffs against the just-blessed fixtures.
        let rep2 = selftest(&NpuConfig::default(), &SimConfig::default(), &opts);
        assert!(rep2.passed(), "{}", rep2.render());
        assert!(rep2.render().contains("matches pinned fixture"), "{}", rep2.render());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_default_config_skips_goldens() {
        let hw = NpuConfig {
            dma_setup_ns: 2.0 * NpuConfig::default().dma_setup_ns,
            ..Default::default()
        };
        let opts = SelftestOptions { seeds: vec![1], contexts: vec![128], ..Default::default() };
        let rep = selftest(&hw, &SimConfig::default(), &opts);
        assert!(rep.passed(), "{}", rep.render());
        assert!(rep.render().contains("skipped: non-default"), "{}", rep.render());
    }
}
