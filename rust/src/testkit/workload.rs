//! Seeded request-stream generation and coordinator replay.
//!
//! [`stream`] derives a request sequence purely from a [`SplitMix64`]
//! seed — no wall clock anywhere — and [`replay`] drives it through a
//! [`Coordinator`] one request at a time. With the deterministic
//! coordinator configuration ([`deterministic_coordinator`]: batch size
//! 1, so every request dispatches immediately in submission order) the
//! full outcome sequence — operator attribution, simulated span, spill
//! charging, shed decisions — is a pure function of the seed, which is
//! what lets the conformance suite assert *exact* equality between two
//! replays of the same stream.

use anyhow::Result;

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::coordinator::{Coordinator, CoordinatorConfig, Request};

use super::prng::SplitMix64;

/// Shape of a generated request stream.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub seed: u64,
    pub requests: usize,
    /// Session ids are drawn from `[0, sessions)`.
    pub sessions: u64,
    pub contexts: Vec<usize>,
}

impl StreamConfig {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            requests: 48,
            sessions: 12,
            contexts: vec![128, 256, 512, 1024, 2048],
        }
    }
}

/// Generate the deterministic request stream for `cfg`.
pub fn stream(cfg: &StreamConfig) -> Vec<Request> {
    let mut rng = SplitMix64::new(cfg.seed);
    (0..cfg.requests)
        .map(|_| Request {
            spec: WorkloadSpec::new(*rng.choose(&OperatorKind::ALL), *rng.choose(&cfg.contexts)),
            session: rng.below(cfg.sessions),
            inputs: None,
        })
        .collect()
}

/// What one replayed request produced. `PartialEq` over the *exact*
/// simulated numbers: the simulator is deterministic, so two replays of
/// one stream must agree bit-for-bit, not approximately.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    Served {
        operator: &'static str,
        backend_ns: f64,
        spill_ns: f64,
        batch_size: usize,
    },
    /// Refused (session-memory admission control, or a serve error).
    Shed(String),
}

/// A coordinator whose replay outcomes depend only on the request stream:
/// batch size 1 dispatches each request immediately at submission order,
/// so batching composition — and therefore session LRU order and spill
/// charging — cannot vary with thread timing. `state_budget_bytes`
/// bounds the session pool to make spills/sheds reachable in-test.
pub fn deterministic_coordinator(
    hw: &NpuConfig,
    sim: &SimConfig,
    state_budget_bytes: u64,
) -> Result<Coordinator> {
    deterministic_fleet(hw, sim, state_budget_bytes, 1)
}

/// [`deterministic_coordinator`] over an N-device fleet: placement is a
/// pure function of the request stream (session-affinity, then
/// least-loaded with lowest-id ties), so multi-device replays stay
/// exactly reproducible.
pub fn deterministic_fleet(
    hw: &NpuConfig,
    sim: &SimConfig,
    state_budget_bytes: u64,
    devices: usize,
) -> Result<Coordinator> {
    Coordinator::new(CoordinatorConfig {
        max_batch: 1,
        max_wait_ns: 100_000,
        state_budget_bytes,
        devices,
        ..CoordinatorConfig::for_hw(hw.clone(), sim.clone())
    })
}

/// Replay `requests` through `coord` sequentially, capturing outcomes.
pub fn replay(coord: &Coordinator, requests: &[Request]) -> Vec<Outcome> {
    requests
        .iter()
        .map(|r| match coord.submit(r.clone()) {
            Ok(resp) => Outcome::Served {
                operator: resp.operator,
                backend_ns: resp.backend_ns,
                spill_ns: resp.spill_ns,
                batch_size: resp.batch_size,
            },
            Err(e) => Outcome::Shed(e.to_string()),
        })
        .collect()
}

/// Stable one-line-per-request rendering of a replay (for reports and
/// golden snapshots).
pub fn signature(outcomes: &[Outcome]) -> String {
    let mut out = String::new();
    for (i, o) in outcomes.iter().enumerate() {
        match o {
            Outcome::Served { operator, backend_ns, spill_ns, batch_size } => {
                out += &format!(
                    "{i}: ok op={operator} span_ns={backend_ns:.3} \
                     spill_ns={spill_ns:.3} batch={batch_size}\n"
                );
            }
            Outcome::Shed(why) => out += &format!("{i}: shed {why}\n"),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic_per_seed() {
        let cfg = StreamConfig::new(7);
        let (a, b) = (stream(&cfg), stream(&cfg));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.spec, y.spec);
            assert_eq!(x.session, y.session);
        }
    }

    #[test]
    fn seeds_change_the_stream() {
        let a = stream(&StreamConfig::new(1));
        let b = stream(&StreamConfig::new(2));
        assert!(a.iter().zip(&b).any(|(x, y)| x.spec != y.spec || x.session != y.session));
    }

    #[test]
    fn stream_respects_the_context_menu() {
        let cfg = StreamConfig::new(3);
        for r in stream(&cfg) {
            assert!(cfg.contexts.contains(&r.spec.n));
            assert!(r.session < cfg.sessions);
        }
    }

    #[test]
    fn replay_serves_a_small_stream() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let coord = deterministic_coordinator(&hw, &sim, 64 * 1024 * 1024).unwrap();
        let cfg = StreamConfig { requests: 8, ..StreamConfig::new(5) };
        let outcomes = replay(&coord, &stream(&cfg));
        assert_eq!(outcomes.len(), 8);
        for o in &outcomes {
            match o {
                Outcome::Served { backend_ns, batch_size, .. } => {
                    assert!(*backend_ns > 0.0);
                    assert_eq!(*batch_size, 1);
                }
                Outcome::Shed(why) => panic!("unexpected shed: {why}"),
            }
        }
    }

    #[test]
    fn over_pool_footprints_are_shed() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        // 4-page pool (256 KiB): causal at n=8192 needs 2 MiB of KV.
        let coord = deterministic_coordinator(&hw, &sim, 256 * 1024).unwrap();
        let out = replay(
            &coord,
            &[Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 8192),
                session: 1,
                inputs: None,
            }],
        );
        match &out[0] {
            Outcome::Shed(why) => assert!(why.contains("admission control"), "{why}"),
            other => panic!("expected shed, got {other:?}"),
        }
    }
}
