//! npuperf — reproduction of "Context-Driven Performance Modeling for
//! Causal Inference Operators on Neural Processing Units".
pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod memory;
pub mod model;
pub mod npu;
pub mod obs;
pub mod ops;
pub mod report;
pub mod runtime;
pub mod testkit;
pub mod util;
