//! Spill/refill cost model.
//!
//! Evicting a session writes its resident pages out over DMA; touching a
//! spilled session pages them back in. Both transfers are priced with the
//! *effective* DMA ceiling β_eff from the roofline calibration (paper
//! §IV-A: ~5 % of the nominal 64 GB/s, i.e. ~3.2 GB/s) plus one DMA
//! descriptor-setup charge — so an eviction caused by memory pressure
//! shows up as real nanoseconds on the request that caused it, not as a
//! free bookkeeping event.

/// DMA transfer pricing for state spills and refills.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpillModel {
    /// Effective DMA bandwidth, GB/s (== bytes/ns).
    pub beta_eff_gbps: f64,
    /// Descriptor-setup overhead charged once per spill/refill, ns.
    pub setup_ns: f64,
}

impl SpillModel {
    /// Nanoseconds to move `bytes` of state across the DMA at the
    /// effective ceiling. Zero bytes cost nothing (no descriptor issued).
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.setup_ns + bytes as f64 / self.beta_eff_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pricing_is_linear_in_bytes_past_setup() {
        let m = SpillModel { beta_eff_gbps: 3.2, setup_ns: 1_500.0 };
        let one = m.transfer_ns(1 << 20);
        let two = m.transfer_ns(2 << 20);
        assert!((two - one - (1 << 20) as f64 / 3.2).abs() < 1e-6);
        assert!(one > 1_500.0);
    }

    #[test]
    fn zero_bytes_cost_nothing() {
        let m = SpillModel { beta_eff_gbps: 3.2, setup_ns: 1_500.0 };
        assert_eq!(m.transfer_ns(0), 0.0);
    }

    #[test]
    fn effective_ceiling_dominates_nominal() {
        // A 256 KiB KV spill at 3.2 GB/s is ~82 us — visible against
        // millisecond-scale operator latencies, which is the point.
        let m = SpillModel { beta_eff_gbps: 3.2, setup_ns: 1_500.0 };
        let ns = m.transfer_ns(256 * 1024);
        assert!((80_000.0..90_000.0).contains(&ns), "{ns}");
    }
}
