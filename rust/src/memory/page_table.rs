//! Per-session page-table entries.
//!
//! One [`PageTable`] per open session records how much logical state the
//! session has accumulated (the operator's growth curve), how many pool
//! pages back it while resident, and the eviction bookkeeping (last touch,
//! pin). A *spilled* session keeps its logical size — that is what the
//! refill transfer will have to page back in — but holds zero pool pages.

/// Page-table entry for one session.
#[derive(Clone, Debug)]
pub struct PageTable {
    /// Logical persistent-state bytes (the operator's footprint curve).
    pub logical_bytes: u64,
    /// Pool pages backing the state while resident; 0 when spilled.
    pub resident_pages: u64,
    /// Whether the state currently lives in the pool.
    pub resident: bool,
    /// Pinned entries are never chosen as eviction victims (the session
    /// is being served, or the deployment marked it latency-critical).
    pub pinned: bool,
    /// Logical clock of the last admission touch (LRU key).
    pub last_touch: u64,
}

impl PageTable {
    pub fn new(now: u64) -> Self {
        Self {
            logical_bytes: 0,
            resident_pages: 0,
            resident: false,
            pinned: false,
            last_touch: now,
        }
    }

    /// Pool bytes this entry holds (page-granular; 0 when spilled).
    pub fn resident_bytes(&self, page_bytes: u64) -> u64 {
        self.resident_pages * page_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_entry_is_empty_and_unpinned() {
        let t = PageTable::new(7);
        assert_eq!(t.logical_bytes, 0);
        assert_eq!(t.resident_pages, 0);
        assert!(!t.resident);
        assert!(!t.pinned);
        assert_eq!(t.last_touch, 7);
    }

    #[test]
    fn resident_bytes_are_page_granular() {
        let mut t = PageTable::new(0);
        t.resident_pages = 3;
        assert_eq!(t.resident_bytes(64 * 1024), 3 * 64 * 1024);
    }
}
