//! Fixed-capacity page pool backing all session state.
//!
//! The pool models the device-memory partition reserved for persistent
//! session state (KV caches, recurrent states, ring buffers) as a fixed
//! number of equally-sized pages. Page *identity* is irrelevant to a
//! performance model — what capacity planning and spill accounting need
//! is conservation: pages allocated never exceed the pool, and every
//! eviction returns exactly the pages the victim held. The pool therefore
//! tracks extents (counts), not addresses, which also keeps an
//! effectively-unbounded test pool (`pool_bytes = u64::MAX`) O(1).

/// Fixed pool of equally-sized state pages.
#[derive(Clone, Debug)]
pub struct PagePool {
    page_bytes: u64,
    total_pages: u64,
    free_pages: u64,
}

impl PagePool {
    /// Pool of `pool_bytes / page_bytes` pages (remainder is unusable,
    /// exactly like a real allocator's slack).
    pub fn new(pool_bytes: u64, page_bytes: u64) -> Self {
        assert!(page_bytes > 0, "page size must be positive");
        let total = pool_bytes / page_bytes;
        Self { page_bytes, total_pages: total, free_pages: total }
    }

    /// Claim `pages` from the free list; `false` (and no change) if the
    /// pool cannot satisfy the request.
    pub fn try_allocate(&mut self, pages: u64) -> bool {
        if pages <= self.free_pages {
            self.free_pages -= pages;
            true
        } else {
            false
        }
    }

    /// Return `pages` to the free list.
    pub fn release(&mut self, pages: u64) {
        debug_assert!(
            self.free_pages + pages <= self.total_pages,
            "released more pages than were allocated"
        );
        self.free_pages = (self.free_pages + pages).min(self.total_pages);
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn total_pages(&self) -> u64 {
        self.total_pages
    }

    pub fn free_pages(&self) -> u64 {
        self.free_pages
    }

    pub fn used_pages(&self) -> u64 {
        self.total_pages - self.free_pages
    }

    /// Bytes currently backing resident state (page-granular).
    pub fn used_bytes(&self) -> u64 {
        self.used_pages() * self.page_bytes
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_pages * self.page_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_geometry() {
        let p = PagePool::new(640 * 1024, 64 * 1024);
        assert_eq!(p.total_pages(), 10);
        assert_eq!(p.free_pages(), 10);
        assert_eq!(p.page_bytes(), 64 * 1024);
    }

    #[test]
    fn allocate_and_release_conserve_pages() {
        let mut p = PagePool::new(10 * 4096, 4096);
        assert!(p.try_allocate(7));
        assert_eq!(p.free_pages(), 3);
        assert!(!p.try_allocate(4), "over-allocation refused");
        assert_eq!(p.free_pages(), 3, "failed allocation is a no-op");
        p.release(7);
        assert_eq!(p.free_pages(), 10);
    }

    #[test]
    fn slack_bytes_are_unusable() {
        // 9.375 pages of slack-inclusive capacity -> 9 usable pages.
        let p = PagePool::new(600 * 1024, 64 * 1024);
        assert_eq!(p.total_pages(), 9);
        assert_eq!(p.total_bytes(), 9 * 64 * 1024);
    }

    #[test]
    fn huge_pool_is_cheap() {
        let p = PagePool::new(u64::MAX, 64 * 1024);
        assert_eq!(p.total_pages(), u64::MAX / (64 * 1024));
    }
}
