//! The session-memory manager: page pool + page tables + eviction + spill
//! accounting behind one admission API.
//!
//! [`SessionMemory::admit`] is the only way state enters the pool: the
//! caller states the session's *current* logical footprint (from
//! [`crate::ops::CausalOperator::state_footprint`]) and the manager makes
//! it resident — growing its page extent, evicting LRU unpinned victims
//! under pressure, and paging previously spilled state back in — returning
//! an [`Admission`] that prices every byte moved. A footprint that cannot
//! fit the pool even after evicting everything else is refused
//! ([`AdmitError`]), which is the serving layer's admission-control
//! signal: shed the request instead of growing without bound.

use std::collections::HashMap;
use std::fmt;

use super::eviction;
use super::page_table::PageTable;
use super::pool::PagePool;
use super::spill::SpillModel;
use super::MemoryConfig;

/// Cost and effect of one successful admission.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Admission {
    /// Paging this session's own spilled state back in, ns.
    pub refill_ns: f64,
    /// Writing evicted victims out to make room, ns.
    pub spill_ns: f64,
    /// Sessions spilled to make room, in eviction order.
    pub evicted: Vec<u64>,
    /// Pool pages backing the session after admission.
    pub pages: u64,
}

impl Admission {
    /// Total memory-subsystem nanoseconds charged to the request.
    pub fn total_ns(&self) -> f64 {
        self.refill_ns + self.spill_ns
    }
}

/// Why an admission was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The footprint exceeds the whole pool — no eviction schedule can
    /// ever make it resident.
    FootprintExceedsPool { needed_pages: u64, pool_pages: u64 },
    /// Enough pages exist but pinned sessions hold them.
    PoolPinned { needed_pages: u64, free_pages: u64 },
    /// The session was never opened.
    UnknownSession(u64),
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmitError::FootprintExceedsPool { needed_pages, pool_pages } => write!(
                f,
                "state footprint needs {needed_pages} pages but the pool has {pool_pages}"
            ),
            AdmitError::PoolPinned { needed_pages, free_pages } => write!(
                f,
                "need {needed_pages} pages but only {free_pages} free and every \
                 resident session is pinned"
            ),
            AdmitError::UnknownSession(id) => write!(f, "session {id} was never opened"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Lifetime counters for the memory subsystem.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemStats {
    /// Sessions spilled out under pressure.
    pub evictions: u64,
    /// Page-granular bytes written out by evictions.
    pub spilled_bytes: u64,
    /// Page-granular bytes paged back in on refills.
    pub refilled_bytes: u64,
    /// Total eviction DMA time, ns.
    pub spill_ns: f64,
    /// Total refill DMA time, ns.
    pub refill_ns: f64,
    /// Admissions refused (footprint over pool, or pool fully pinned).
    pub rejected: u64,
    /// Spilled sessions whose bookkeeping was dropped by capacity GC
    /// ([`SessionMemory::shed_spilled_lru`]); they re-prefill on return.
    pub shed_sessions: u64,
    /// High-water mark of resident pool bytes.
    pub peak_resident_bytes: u64,
}

impl MemStats {
    /// Total DMA nanoseconds the subsystem charged (spills + refills).
    pub fn total_spill_ns(&self) -> f64 {
        self.spill_ns + self.refill_ns
    }
}

/// Read-only snapshot of one session's page-table row, for external
/// invariant checking ([`crate::testkit::invariants`]) and debugging.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionAudit {
    pub id: u64,
    pub resident: bool,
    pub pinned: bool,
    pub resident_pages: u64,
    pub logical_bytes: u64,
    /// Logical LRU clock value of the last touch (monotonic, not wall time).
    pub last_touch: u64,
}

/// Paged session-memory manager.
#[derive(Clone, Debug)]
pub struct SessionMemory {
    cfg: MemoryConfig,
    pool: PagePool,
    spill: SpillModel,
    tables: HashMap<u64, PageTable>,
    clock: u64,
    stats: MemStats,
}

impl SessionMemory {
    pub fn new(cfg: MemoryConfig) -> Self {
        let pool = PagePool::new(cfg.pool_bytes, cfg.page_bytes);
        let spill = SpillModel { beta_eff_gbps: cfg.beta_eff_gbps, setup_ns: cfg.spill_setup_ns };
        Self { cfg, pool, spill, tables: HashMap::new(), clock: 0, stats: MemStats::default() }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Open a session (idempotent — an existing page table is kept).
    pub fn open(&mut self, id: u64) {
        let t = self.tick();
        self.tables.entry(id).or_insert_with(|| PageTable::new(t));
    }

    /// Make `id`'s state resident at `footprint_bytes`, evicting LRU
    /// unpinned sessions as needed and pricing every transfer.
    pub fn admit(&mut self, id: u64, footprint_bytes: u64) -> Result<Admission, AdmitError> {
        if !self.tables.contains_key(&id) {
            return Err(AdmitError::UnknownSession(id));
        }
        let t = self.tick();
        // Even a zero-byte footprint anchors one page: every resident
        // session must hold pages so eviction and capacity GC can reach
        // it (and capacity planning counts it the same way).
        let need = self.cfg.pages_for(footprint_bytes).max(1);
        if need > self.pool.total_pages() {
            self.stats.rejected += 1;
            return Err(AdmitError::FootprintExceedsPool {
                needed_pages: need,
                pool_pages: self.pool.total_pages(),
            });
        }

        let (was_resident, old_logical, old_pages) = match self.tables.get(&id) {
            Some(table) => (table.resident, table.logical_bytes, table.resident_pages),
            None => return Err(AdmitError::UnknownSession(id)),
        };
        let have = if was_resident { old_pages } else { 0 };

        let mut adm = Admission::default();
        if need <= have {
            // Shrink (or exact fit): give slack pages back, move nothing.
            self.pool.release(have - need);
        } else {
            let want = need - have;
            // Refuse before spilling anyone: if pinned sessions hold too
            // much of the pool, no eviction schedule can make room, and a
            // failed admission must not leave innocent victims spilled.
            let evictable: u64 = self
                .tables
                // lint:allow(nondet-iteration, "order-insensitive sum of evictable resident pages")
                .iter()
                .filter(|(vid, v)| **vid != id && v.resident && !v.pinned)
                .map(|(_, v)| v.resident_pages)
                .sum();
            if self.pool.free_pages() + evictable < want {
                self.stats.rejected += 1;
                return Err(AdmitError::PoolPinned {
                    needed_pages: want,
                    free_pages: self.pool.free_pages(),
                });
            }
            while self.pool.free_pages() < want {
                // The evictable-capacity pre-check above guarantees a victim
                // exists, but the serve path must not panic on a broken
                // invariant — refuse the admission instead.
                let Some(victim) = eviction::lru_victim(&self.tables, id) else {
                    self.stats.rejected += 1;
                    return Err(AdmitError::PoolPinned {
                        needed_pages: want,
                        free_pages: self.pool.free_pages(),
                    });
                };
                adm.spill_ns += self.spill_out(victim);
                adm.evicted.push(victim);
            }
            let ok = self.pool.try_allocate(want);
            debug_assert!(ok, "eviction loop guarantees the allocation fits");
        }

        if !was_resident && old_logical > 0 {
            // Cold state pages back in before the session grows past it.
            let bytes =
                self.cfg.pages_for(old_logical.min(footprint_bytes)) * self.cfg.page_bytes;
            adm.refill_ns = self.spill.transfer_ns(bytes);
            self.stats.refilled_bytes += bytes;
            self.stats.refill_ns += adm.refill_ns;
        }

        // `contains_key` held at entry and nothing above removes `id`.
        let Some(table) = self.tables.get_mut(&id) else {
            return Err(AdmitError::UnknownSession(id));
        };
        table.resident = true;
        table.resident_pages = need;
        table.logical_bytes = footprint_bytes;
        table.last_touch = t;
        adm.pages = need;
        self.stats.peak_resident_bytes =
            self.stats.peak_resident_bytes.max(self.pool.used_bytes());
        Ok(adm)
    }

    /// Spill `victim` out: free its pages, price the write-out.
    fn spill_out(&mut self, victim: u64) -> f64 {
        // Victims come from the LRU oracle over this same map; an unknown
        // id means nothing to spill, which prices as a zero-cost no-op.
        let Some(table) = self.tables.get_mut(&victim) else {
            return 0.0;
        };
        let pages = table.resident_pages;
        table.resident = false;
        table.resident_pages = 0;
        self.pool.release(pages);
        let bytes = pages * self.cfg.page_bytes;
        let ns = self.spill.transfer_ns(bytes);
        self.stats.evictions += 1;
        self.stats.spilled_bytes += bytes;
        self.stats.spill_ns += ns;
        ns
    }

    /// Protect a session from eviction; `false` if it was never opened.
    pub fn pin(&mut self, id: u64) -> bool {
        match self.tables.get_mut(&id) {
            Some(t) => {
                t.pinned = true;
                true
            }
            None => false,
        }
    }

    /// Make a session evictable again; `false` if it was never opened.
    pub fn unpin(&mut self, id: u64) -> bool {
        match self.tables.get_mut(&id) {
            Some(t) => {
                t.pinned = false;
                true
            }
            None => false,
        }
    }

    /// Reset a session's state to empty without closing it: pages return
    /// to the pool, the logical size drops to zero, and any pin is
    /// cleared (a fresh context does not inherit the old one's
    /// latency-critical status). No spill is priced — the owner chose to
    /// discard the state, it was not evicted.
    pub fn reset(&mut self, id: u64) {
        let t = self.tick();
        if let Some(table) = self.tables.get_mut(&id) {
            if table.resident {
                self.pool.release(table.resident_pages);
            }
            table.resident = false;
            table.resident_pages = 0;
            table.logical_bytes = 0;
            table.pinned = false;
            table.last_touch = t;
        }
    }

    /// Capacity GC: drop the bookkeeping of the least-recently-touched
    /// *spilled*, unpinned session, so the session map stays bounded on a
    /// long-lived server (page tables are cheap; "millions of users" are
    /// not). The shed session's state is gone — it re-prefills if it
    /// returns. Returns the id closed, or `None` when every open session
    /// is resident or pinned (nothing is safe to forget).
    pub fn shed_spilled_lru(&mut self) -> Option<u64> {
        let victim = self
            .tables
            .iter()
            .filter(|(_, t)| !t.resident && !t.pinned)
            .min_by_key(|(id, t)| (t.last_touch, **id))
            .map(|(id, _)| *id)?;
        self.tables.remove(&victim);
        self.stats.shed_sessions += 1;
        Some(victim)
    }

    /// Close a session and return its pages to the pool.
    pub fn close(&mut self, id: u64) {
        if let Some(t) = self.tables.remove(&id) {
            if t.resident {
                self.pool.release(t.resident_pages);
            }
        }
    }

    pub fn is_resident(&self, id: u64) -> bool {
        self.tables.get(&id).is_some_and(|t| t.resident)
    }

    /// Logical state bytes of one session (spilled or resident).
    pub fn logical_bytes(&self, id: u64) -> Option<u64> {
        self.tables.get(&id).map(|t| t.logical_bytes)
    }

    /// Open sessions, resident or spilled.
    pub fn sessions(&self) -> usize {
        self.tables.len()
    }

    pub fn resident_sessions(&self) -> usize {
        // lint:allow(nondet-iteration, "order-insensitive count of resident sessions")
        self.tables.values().filter(|t| t.resident).count()
    }

    /// Pool bytes currently backing resident state (page-granular).
    pub fn resident_bytes(&self) -> u64 {
        self.pool.used_bytes()
    }

    /// Sum of logical state bytes across all open sessions.
    pub fn total_logical_bytes(&self) -> u64 {
        // lint:allow(nondet-iteration, "order-insensitive sum of logical bytes")
        self.tables.values().map(|t| t.logical_bytes).sum()
    }

    /// Snapshot every open session's page-table row, sorted by id. The
    /// conformance suite cross-checks these rows against the pool counters
    /// (page conservation, pin safety, LRU order) without reaching into
    /// private state.
    pub fn audit(&self) -> Vec<SessionAudit> {
        let mut rows: Vec<SessionAudit> = self
            .tables
            .iter()
            .map(|(&id, t)| SessionAudit {
                id,
                resident: t.resident,
                pinned: t.pinned,
                resident_pages: t.resident_pages,
                logical_bytes: t.logical_bytes,
                last_touch: t.last_touch,
            })
            .collect();
        rows.sort_by_key(|r| r.id);
        rows
    }

    pub fn stats(&self) -> &MemStats {
        &self.stats
    }

    pub fn config(&self) -> &MemoryConfig {
        &self.cfg
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Pages currently backing resident sessions (metrics convenience;
    /// same number the pool reports).
    pub fn pages_in_use(&self) -> u64 {
        self.pool.used_pages()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: u64 = 64 * 1024;

    fn mem(pages: u64) -> SessionMemory {
        SessionMemory::new(MemoryConfig {
            page_bytes: PAGE,
            pool_bytes: pages * PAGE,
            beta_eff_gbps: 3.2,
            spill_setup_ns: 1_500.0,
        })
    }

    fn admit(m: &mut SessionMemory, id: u64, bytes: u64) -> Admission {
        m.open(id);
        m.admit(id, bytes).unwrap()
    }

    #[test]
    fn growth_allocates_page_granular_extents() {
        let mut m = mem(16);
        let a = admit(&mut m, 1, 1); // 1 byte -> 1 page
        assert_eq!(a.pages, 1);
        let a = admit(&mut m, 1, 5 * PAGE + 1);
        assert_eq!(a.pages, 6);
        assert_eq!(m.resident_bytes(), 6 * PAGE);
        assert_eq!(m.logical_bytes(1), Some(5 * PAGE + 1));
    }

    #[test]
    fn shrink_returns_slack_pages() {
        let mut m = mem(16);
        admit(&mut m, 1, 8 * PAGE);
        admit(&mut m, 1, 2 * PAGE);
        assert_eq!(m.pool().free_pages(), 14);
        assert_eq!(m.resident_bytes(), 2 * PAGE);
    }

    #[test]
    fn pressure_evicts_lru_and_prices_the_spill() {
        let mut m = mem(9);
        admit(&mut m, 1, 4 * PAGE);
        admit(&mut m, 2, 4 * PAGE);
        let a = admit(&mut m, 3, 4 * PAGE);
        assert_eq!(a.evicted, vec![1], "session 1 is LRU");
        let expect =
            SpillModel { beta_eff_gbps: 3.2, setup_ns: 1_500.0 }.transfer_ns(4 * PAGE);
        assert_eq!(a.spill_ns, expect);
        assert!(!m.is_resident(1));
        assert_eq!(m.logical_bytes(1), Some(4 * PAGE), "spilled state keeps its size");
        assert_eq!(m.stats().evictions, 1);
        assert_eq!(m.sessions(), 3);
        assert_eq!(m.resident_sessions(), 2);
    }

    #[test]
    fn refill_charges_the_page_back_in() {
        let mut m = mem(9);
        admit(&mut m, 1, 4 * PAGE);
        admit(&mut m, 2, 4 * PAGE);
        admit(&mut m, 3, 4 * PAGE); // spills 1
        let back = admit(&mut m, 1, 4 * PAGE); // refills 1, spills 2
        assert!(back.refill_ns > 0.0);
        assert_eq!(back.evicted, vec![2]);
        assert!(m.is_resident(1));
        assert_eq!(m.stats().refilled_bytes, 4 * PAGE);
    }

    #[test]
    fn pinned_sessions_survive_pressure() {
        let mut m = mem(9);
        admit(&mut m, 1, 4 * PAGE);
        m.pin(1);
        admit(&mut m, 2, 4 * PAGE);
        let a = admit(&mut m, 3, 4 * PAGE);
        assert_eq!(a.evicted, vec![2], "LRU would be 1, but it is pinned");
        assert!(m.is_resident(1));
    }

    #[test]
    fn zero_footprint_sessions_anchor_one_page() {
        // An empty session still holds a page, so eviction and GC can
        // reach it — otherwise n=0 sessions would accumulate forever.
        let mut m = mem(4);
        let a = admit(&mut m, 1, 0);
        assert_eq!(a.pages, 1);
        assert_eq!(m.resident_bytes(), PAGE);
        admit(&mut m, 2, 3 * PAGE);
        let c = admit(&mut m, 3, PAGE);
        assert_eq!(c.evicted, vec![1], "anchor pages are evictable");
        assert_eq!(m.shed_spilled_lru(), Some(1), "and GC can forget the session");
    }

    #[test]
    fn pinned_shortfall_refuses_without_spilling_innocents() {
        // Pool of 4: A (2 pages, unpinned) + B (2 pages, pinned). C wants
        // 4 pages — even evicting A cannot make room, so the admission
        // must fail *before* A is spilled.
        let mut m = mem(4);
        admit(&mut m, 1, 2 * PAGE);
        admit(&mut m, 2, 2 * PAGE);
        m.pin(2);
        m.open(3);
        let err = m.admit(3, 4 * PAGE).unwrap_err();
        assert!(matches!(err, AdmitError::PoolPinned { .. }), "{err}");
        assert!(m.is_resident(1), "innocent LRU session was not spilled");
        assert_eq!(m.stats().evictions, 0);
    }

    #[test]
    fn fully_pinned_pool_is_an_admission_error() {
        let mut m = mem(4);
        admit(&mut m, 1, 2 * PAGE);
        admit(&mut m, 2, 2 * PAGE);
        m.pin(1);
        m.pin(2);
        m.open(3);
        let err = m.admit(3, 2 * PAGE).unwrap_err();
        assert!(matches!(err, AdmitError::PoolPinned { .. }), "{err}");
        assert_eq!(m.stats().rejected, 1);
    }

    #[test]
    fn over_pool_footprint_is_refused_outright() {
        let mut m = mem(4);
        m.open(1);
        let err = m.admit(1, 5 * PAGE).unwrap_err();
        assert!(matches!(err, AdmitError::FootprintExceedsPool { .. }), "{err}");
        assert_eq!(m.resident_bytes(), 0, "nothing was evicted for a hopeless request");
    }

    #[test]
    fn unknown_session_is_an_error() {
        let mut m = mem(4);
        assert_eq!(m.admit(42, PAGE).unwrap_err(), AdmitError::UnknownSession(42));
    }

    #[test]
    fn gc_sheds_spilled_lru_only() {
        let mut m = mem(9);
        admit(&mut m, 1, 4 * PAGE);
        admit(&mut m, 2, 4 * PAGE);
        admit(&mut m, 3, 4 * PAGE); // spills 1
        assert_eq!(m.shed_spilled_lru(), Some(1), "only the spilled session is shed");
        assert_eq!(m.sessions(), 2);
        assert_eq!(m.stats().shed_sessions, 1);
        assert_eq!(m.shed_spilled_lru(), None, "residents are never GC'd");
        assert!(m.is_resident(2) && m.is_resident(3));
    }

    #[test]
    fn close_returns_pages() {
        let mut m = mem(8);
        admit(&mut m, 1, 3 * PAGE);
        m.close(1);
        assert_eq!(m.pool().free_pages(), 8);
        assert_eq!(m.sessions(), 0);
    }

    #[test]
    fn peak_resident_high_water_mark() {
        let mut m = mem(16);
        admit(&mut m, 1, 10 * PAGE);
        admit(&mut m, 1, 2 * PAGE);
        assert_eq!(m.stats().peak_resident_bytes, 10 * PAGE);
    }
}
