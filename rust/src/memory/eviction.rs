//! Eviction policy: LRU with pinning.
//!
//! Victim selection is least-recently-touched first over the *evictable*
//! set — resident, unpinned sessions other than the one being admitted
//! (admission always protects its own session via `protect`, no pin
//! needed). Pinning is the explicit override on top of that: how a
//! deployment marks latency-critical sessions, or how a concurrent
//! dispatcher keeps an in-flight batch's sessions resident. A fully
//! pinned pool is an admission error, never a deadlocked loop.

use std::collections::HashMap;

use super::page_table::PageTable;

/// Pick the LRU eviction victim among resident, unpinned sessions other
/// than `protect`. Ties on the touch clock break toward the smaller
/// session id so eviction order is deterministic.
pub fn lru_victim(tables: &HashMap<u64, PageTable>, protect: u64) -> Option<u64> {
    tables
        // lint:allow(nondet-iteration, "min_by_key with a total (last_touch, id) key; the winner is order-independent")
        .iter()
        .filter(|(id, t)| **id != protect && t.resident && !t.pinned && t.resident_pages > 0)
        .min_by_key(|(id, t)| (t.last_touch, **id))
        .map(|(id, _)| *id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(touch: u64, pages: u64, pinned: bool) -> PageTable {
        let mut t = PageTable::new(touch);
        t.resident = pages > 0;
        t.resident_pages = pages;
        t.pinned = pinned;
        t
    }

    #[test]
    fn oldest_resident_wins() {
        let mut m = HashMap::new();
        m.insert(1, entry(5, 2, false));
        m.insert(2, entry(3, 2, false));
        m.insert(3, entry(9, 2, false));
        assert_eq!(lru_victim(&m, 0), Some(2));
    }

    #[test]
    fn pinned_and_protected_are_skipped() {
        let mut m = HashMap::new();
        m.insert(1, entry(1, 2, true)); // pinned, oldest
        m.insert(2, entry(2, 2, false)); // protected below
        m.insert(3, entry(3, 2, false));
        assert_eq!(lru_victim(&m, 2), Some(3));
    }

    #[test]
    fn spilled_sessions_are_not_victims() {
        let mut m = HashMap::new();
        m.insert(1, entry(1, 0, false)); // already spilled
        m.insert(2, entry(2, 4, false));
        assert_eq!(lru_victim(&m, 0), Some(2));
    }

    #[test]
    fn empty_or_fully_pinned_pool_has_no_victim() {
        let mut m: HashMap<u64, PageTable> = HashMap::new();
        assert_eq!(lru_victim(&m, 0), None);
        m.insert(1, entry(1, 2, true));
        assert_eq!(lru_victim(&m, 0), None);
    }

    #[test]
    fn touch_ties_break_by_id() {
        let mut m = HashMap::new();
        m.insert(9, entry(4, 1, false));
        m.insert(2, entry(4, 1, false));
        assert_eq!(lru_victim(&m, 0), Some(2));
    }

    #[test]
    fn property_victim_is_evictable_and_true_lru() {
        use crate::util::check::{forall, Rng};
        forall(
            "lru victim",
            60,
            |rng: &mut Rng| {
                let n = rng.range(0, 12);
                let tables: Vec<(u64, u64, u64, bool)> = (0..n)
                    .map(|id| (id, rng.below(6), rng.below(3), rng.bool()))
                    .collect();
                let protect = rng.below(n + 2); // sometimes protects nobody
                (tables, protect)
            },
            |(rows, protect)| {
                let mut m = HashMap::new();
                for &(id, touch, pages, pinned) in rows {
                    m.insert(id, entry(touch, pages, pinned));
                }
                let victim = lru_victim(&m, *protect);
                let evictable: Vec<&(u64, u64, u64, bool)> = rows
                    .iter()
                    .filter(|(id, _, pages, pinned)| id != protect && *pages > 0 && !pinned)
                    .collect();
                match victim {
                    None if evictable.is_empty() => Ok(()),
                    None => Err(format!("no victim despite evictable rows {evictable:?}")),
                    Some(v) => {
                        let Some(&&(_, touch, pages, pinned)) =
                            evictable.iter().find(|r| r.0 == v)
                        else {
                            return Err(format!(
                                "victim {v} is protected, pinned, or holds no pages"
                            ));
                        };
                        debug_assert!(pages > 0 && !pinned);
                        // True LRU: nothing evictable was touched earlier,
                        // and ties break toward the smaller id.
                        for &&(id, t, ..) in &evictable {
                            if (t, id) < (touch, v) {
                                return Err(format!(
                                    "victim {v} (touch {touch}) skipped older \
                                     evictable {id} (touch {t})"
                                ));
                            }
                        }
                        Ok(())
                    }
                }
            },
        );
    }

    #[test]
    fn property_repeated_eviction_drains_in_lru_order() {
        use crate::util::check::{forall, Rng};
        forall(
            "lru drain order",
            40,
            |rng: &mut Rng| {
                let n = rng.range(1, 10);
                (0..n)
                    .map(|id| (id, rng.below(4), rng.below(100) < 25))
                    .collect::<Vec<(u64, u64, bool)>>()
            },
            |rows| {
                let mut m = HashMap::new();
                for &(id, touch, pinned) in rows {
                    m.insert(id, entry(touch, 1, pinned));
                }
                let mut drained = Vec::new();
                while let Some(v) = lru_victim(&m, u64::MAX) {
                    if m[&v].pinned {
                        return Err(format!("evicted pinned session {v}"));
                    }
                    drained.push((m[&v].last_touch, v));
                    if let Some(t) = m.get_mut(&v) {
                        t.resident = false;
                        t.resident_pages = 0;
                    }
                }
                if m.values().any(|t| t.resident && !t.pinned) {
                    return Err("drain stopped with evictable sessions left".into());
                }
                let mut sorted = drained.clone();
                sorted.sort_unstable();
                if drained != sorted {
                    return Err(format!("drain order not LRU: {drained:?}"));
                }
                Ok(())
            },
        );
    }
}
