//! Paged session-memory subsystem: the serving-capacity model.
//!
//! The paper's Fig 1 contrast — attention retains an O(N·d) KV cache
//! while sub-quadratic operators keep O(1)/O(k) state — only matters at
//! scale if the serving stack *enforces* it. This module turns the cost
//! model into a capacity model:
//!
//! - a fixed-capacity [`PagePool`] sized from [`NpuConfig`] (the
//!   state-reserved fraction of global memory, split into pages),
//! - per-session [`PageTable`]s charged by each operator's
//!   [`state_footprint`](crate::ops::CausalOperator::state_footprint)
//!   growth curve,
//! - an LRU-with-pinning [eviction policy](eviction),
//! - a [`SpillModel`] that prices every eviction/refill with the
//!   *calibrated* effective DMA ceiling β_eff (§IV-A), so memory
//!   pressure surfaces as nanoseconds on responses, not as silent OOM.
//!
//! [`SessionMemory`] composes the four behind one admission API; the
//! coordinator's `StateManager` wraps it, and `npuperf capacity` /
//! `report::sweep::capacity_report` answer the planning question: how
//! many concurrent sessions fit, per operator × context length?

pub mod eviction;
pub mod manager;
pub mod page_table;
pub mod pool;
pub mod spill;

pub use manager::{AdmitError, Admission, MemStats, SessionAudit, SessionMemory};
pub use page_table::PageTable;
pub use pool::PagePool;
pub use spill::SpillModel;

use crate::config::{NpuConfig, SimConfig};

/// Fraction of nominal DMA bandwidth a state stream sustains when no
/// calibration run is available (paper §IV-A: effective ceilings land at
/// ~5 % of nominal).
pub const EFFECTIVE_BW_FRACTION: f64 = 0.05;

/// Geometry and pricing of the session-memory pool.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemoryConfig {
    /// State page size, bytes.
    pub page_bytes: u64,
    /// Total pool capacity, bytes (page-rounded down by the pool).
    pub pool_bytes: u64,
    /// Effective DMA bandwidth for spills/refills, GB/s.
    pub beta_eff_gbps: f64,
    /// DMA descriptor setup charged per spill/refill, ns.
    pub spill_setup_ns: f64,
}

impl MemoryConfig {
    /// Pool geometry from the hardware description alone: the
    /// state-reserved fraction of global memory, the configured page
    /// size, and the §IV-A derate applied to nominal DMA bandwidth.
    pub fn from_hw(hw: &NpuConfig) -> Self {
        Self {
            page_bytes: hw.state_page_bytes,
            pool_bytes: (hw.dram_bytes as f64 * hw.state_pool_frac) as u64,
            beta_eff_gbps: hw.dma_bw_gbps * EFFECTIVE_BW_FRACTION,
            spill_setup_ns: hw.dma_setup_ns,
        }
    }

    /// Like [`MemoryConfig::from_hw`], but β_eff comes from the roofline
    /// calibration microbenchmarks run on the simulator — the same number
    /// `npuperf roofline` reports.
    pub fn calibrated(hw: &NpuConfig, sim: &SimConfig) -> Self {
        let ceilings = crate::model::calibrate(hw, sim);
        Self { beta_eff_gbps: ceilings.beta_eff_gbps, ..Self::from_hw(hw) }
    }

    pub fn with_pool_bytes(mut self, pool_bytes: u64) -> Self {
        self.pool_bytes = pool_bytes;
        self
    }

    pub fn with_page_bytes(mut self, page_bytes: u64) -> Self {
        self.page_bytes = page_bytes;
        self
    }

    /// Pages needed to back `bytes` of state.
    pub fn pages_for(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.page_bytes)
    }

    /// Usable pool pages.
    pub fn pool_pages(&self) -> u64 {
        self.pool_bytes / self.page_bytes
    }

    /// Capacity planning: maximum concurrently *resident* sessions of
    /// `footprint_bytes` each. A zero footprint occupies one page slot —
    /// even an empty session needs a page-table anchor.
    pub fn max_sessions(&self, footprint_bytes: u64) -> u64 {
        self.pool_pages() / self.pages_for(footprint_bytes).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_hw_reserves_the_state_fraction() {
        let hw = NpuConfig::default();
        let cfg = MemoryConfig::from_hw(&hw);
        assert_eq!(cfg.page_bytes, hw.state_page_bytes);
        assert_eq!(cfg.pool_bytes, (hw.dram_bytes as f64 * hw.state_pool_frac) as u64);
        // 64 GB/s nominal * 5% derate = the paper's ~3.2 GB/s.
        assert!((cfg.beta_eff_gbps - 3.2).abs() < 1e-9, "{}", cfg.beta_eff_gbps);
    }

    #[test]
    fn calibrated_beta_matches_roofline() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let cfg = MemoryConfig::calibrated(&hw, &sim);
        let c = crate::model::calibrate(&hw, &sim);
        assert_eq!(cfg.beta_eff_gbps, c.beta_eff_gbps);
        assert!((1.5..6.0).contains(&cfg.beta_eff_gbps), "{}", cfg.beta_eff_gbps);
    }

    #[test]
    fn max_sessions_is_pool_over_extent() {
        let cfg = MemoryConfig::from_hw(&NpuConfig::default())
            .with_pool_bytes(1024 * 64 * 1024)
            .with_page_bytes(64 * 1024);
        assert_eq!(cfg.max_sessions(4 * 64 * 1024), 256);
        assert_eq!(cfg.max_sessions(1), 1024, "sub-page footprints round to one page");
        assert_eq!(cfg.max_sessions(0), 1024);
    }
}
