//! Workload descriptions: which causal operator, at what shape.

use std::fmt;
use std::str::FromStr;

/// The five causal inference operators the paper characterizes (§II-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OperatorKind {
    /// Full Causal Mask attention — the quadratic baseline.
    Causal,
    /// Retentive decay attention (DRA) — chunkwise-recurrent lowering.
    Retentive,
    /// Band-limited Toeplitz structured attention.
    Toeplitz,
    /// Causal linear attention with low-rank phi.
    Linear,
    /// Fourier structured attention (frequency-domain product).
    Fourier,
}

impl OperatorKind {
    pub const ALL: [OperatorKind; 5] = [
        OperatorKind::Causal,
        OperatorKind::Retentive,
        OperatorKind::Toeplitz,
        OperatorKind::Linear,
        OperatorKind::Fourier,
    ];

    /// Lower-case name, matching artifact file prefixes.
    pub fn name(&self) -> &'static str {
        match self {
            OperatorKind::Causal => "causal",
            OperatorKind::Retentive => "retentive",
            OperatorKind::Toeplitz => "toeplitz",
            OperatorKind::Linear => "linear",
            OperatorKind::Fourier => "fourier",
        }
    }

    /// Display name used in the paper's tables.
    pub fn paper_name(&self) -> &'static str {
        match self {
            OperatorKind::Causal => "Full Causal",
            OperatorKind::Retentive => "Retentive",
            OperatorKind::Toeplitz => "Toeplitz",
            OperatorKind::Linear => "Linear",
            OperatorKind::Fourier => "Fourier",
        }
    }
}

impl fmt::Display for OperatorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for OperatorKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "causal" | "full" | "full-causal" => Ok(OperatorKind::Causal),
            "retentive" | "dra" => Ok(OperatorKind::Retentive),
            "toeplitz" | "tsa" => Ok(OperatorKind::Toeplitz),
            "linear" | "cla" => Ok(OperatorKind::Linear),
            "fourier" | "fsa" => Ok(OperatorKind::Fourier),
            other => Err(format!(
                "unknown operator {other:?}; expected one of \
                 causal|retentive|toeplitz|linear|fourier"
            )),
        }
    }
}

/// One microbenchmark subject: an operator at a concrete shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkloadSpec {
    pub op: OperatorKind,
    /// Context length N.
    pub n: usize,
    /// Head dimension d_h (paper default 64).
    pub d_head: usize,
    /// State dimension d_state (paper default 16; §III-E sweeps to 128).
    pub d_state: usize,
}

impl WorkloadSpec {
    pub fn new(op: OperatorKind, n: usize) -> Self {
        Self { op, n, d_head: 64, d_state: 16 }
    }

    pub fn with_d_state(mut self, d_state: usize) -> Self {
        self.d_state = d_state;
        self
    }

    pub fn with_d_head(mut self, d_head: usize) -> Self {
        self.d_head = d_head;
        self
    }

    /// Artifact name for the PJRT runtime (`<op>_n<N>_d<d_head>`).
    pub fn artifact_name(&self) -> String {
        format!("{}_n{}_d{}", self.op.name(), self.n, self.d_head)
    }
}

impl fmt::Display for WorkloadSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} N={} d_h={} d_state={}",
            self.op.paper_name(),
            self.n,
            self.d_head,
            self.d_state
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_aliases() {
        assert_eq!("causal".parse::<OperatorKind>().unwrap(), OperatorKind::Causal);
        assert_eq!("FSA".parse::<OperatorKind>().unwrap(), OperatorKind::Fourier);
        assert_eq!("dra".parse::<OperatorKind>().unwrap(), OperatorKind::Retentive);
        assert_eq!("TSA".parse::<OperatorKind>().unwrap(), OperatorKind::Toeplitz);
        assert_eq!("cla".parse::<OperatorKind>().unwrap(), OperatorKind::Linear);
        assert!("bogus".parse::<OperatorKind>().is_err());
    }

    #[test]
    fn names_roundtrip() {
        for op in OperatorKind::ALL {
            assert_eq!(op.name().parse::<OperatorKind>().unwrap(), op);
        }
    }

    #[test]
    fn artifact_name_matches_manifest_convention() {
        let w = WorkloadSpec::new(OperatorKind::Linear, 256);
        assert_eq!(w.artifact_name(), "linear_n256_d64");
    }

    #[test]
    fn builders() {
        let w = WorkloadSpec::new(OperatorKind::Fourier, 4096).with_d_state(128);
        assert_eq!(w.d_state, 128);
        assert_eq!(w.d_head, 64);
    }
}
