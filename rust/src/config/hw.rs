//! NPU hardware model parameters — paper Table I plus the microarchitectural
//! cost constants the event-driven simulator charges.
//!
//! The defaults describe the paper's testbed: a 10 TOPS @ 35 W NPU with a
//! 128×128 INT8 systolic DPU, 8 SHAVE vector cores @ 1.4 GHz, a 4 MB
//! software-managed scratchpad and a 64 GB/s DMA engine into 32 GB LPDDR5X.
//!
//! Overhead constants (issue/dispatch, DMA descriptor setup, buffer
//! allocation penalties, systolic fill/drain) are what produce the paper's
//! *effective* ceilings (§IV-A: ~5 % of nominal); they are calibrated by
//! `model::calibrate` microbenchmarks, not hard-coded into the roofline.

/// Hardware description + cost model constants.
#[derive(Clone, Debug, PartialEq)]
pub struct NpuConfig {
    // ---- Table I headline numbers -------------------------------------
    /// Systolic PE array edge (128 ⇒ 128×128 MACs).
    pub pe_array: usize,
    /// DPU clock in GHz. 0.305 GHz × 128×128 MACs × 2 ops ≈ 10 TOPS INT8.
    pub dpu_clock_ghz: f64,
    /// SHAVE core count.
    pub shave_cores: usize,
    /// SHAVE clock in GHz.
    pub shave_clock_ghz: f64,
    /// Effective f32 SIMD lanes per SHAVE core (4 of 8 issue slots sustain
    /// element-wise streams once load/store overhead is charged).
    pub shave_lanes: usize,
    /// Software-managed scratchpad ("persistent state storage"), bytes.
    pub scratchpad_bytes: u64,
    /// Nominal DMA bandwidth, GB/s.
    pub dma_bw_gbps: f64,
    /// Global LPDDR5X capacity, bytes (bounds the KV cache in `state`).
    pub dram_bytes: u64,
    /// Page size of the paged session-memory pool (`crate::memory`), bytes.
    pub state_page_bytes: u64,
    /// Fraction of global memory reserved for persistent session state;
    /// the rest holds weights, activations, and the runtime.
    pub state_pool_frac: f64,

    // ---- Microarchitectural overheads (effective-ceiling drivers) -----
    /// Systolic array fill latency per tile stream, cycles.
    pub dpu_fill_cycles: u64,
    /// Systolic array drain latency per tile stream, cycles.
    pub dpu_drain_cycles: u64,
    /// DSP descriptor-issue overhead charged per DPU primitive, ns.
    pub dpu_issue_ns: f64,
    /// FP16 throughput relative to INT8 (paper benchmarks at 16-bit).
    pub fp16_rate: f64,
    /// SHAVE op dispatch overhead, ns.
    pub shave_issue_ns: f64,
    /// Cycles per element for transcendental ops (exp in softmax).
    pub shave_exp_cycles: f64,
    /// Cycles per element for simple elementwise ops (mul/add/scale).
    pub shave_simple_cycles: f64,
    /// Row length a SHAVE core reduces in one pass; longer softmax rows
    /// need hierarchical merge passes with scratchpad re-traversals (this
    /// is what turns Retentive SHAVE-bound past N = 1024, Table II).
    pub shave_reduce_span: usize,
    /// DMA descriptor setup per transfer, ns.
    pub dma_setup_ns: f64,
    /// Extra penalty when the destination buffer is freshly allocated
    /// (the §V "allocation/deallocation of large buffers" overhead).
    pub dma_alloc_ns: f64,
    /// Host CPU memcpy bandwidth for the §V concat-offload ablation, GB/s.
    pub cpu_memcpy_gbps: f64,
    /// Host CPU op issue overhead, ns.
    pub cpu_issue_ns: f64,
}

impl Default for NpuConfig {
    fn default() -> Self {
        Self {
            pe_array: 128,
            dpu_clock_ghz: 0.305,
            shave_cores: 8,
            shave_clock_ghz: 1.4,
            shave_lanes: 4,
            scratchpad_bytes: 4 * 1024 * 1024,
            dma_bw_gbps: 64.0,
            dram_bytes: 32 * 1024 * 1024 * 1024,
            state_page_bytes: 64 * 1024,
            state_pool_frac: 0.5,
            dpu_fill_cycles: 128,
            dpu_drain_cycles: 128,
            dpu_issue_ns: 5_000.0,
            fp16_rate: 0.5,
            shave_issue_ns: 1_000.0,
            shave_exp_cycles: 12.0,
            shave_simple_cycles: 2.0,
            shave_reduce_span: 512,
            dma_setup_ns: 1_500.0,
            dma_alloc_ns: 20_000.0,
            cpu_memcpy_gbps: 8.0,
            cpu_issue_ns: 1_000.0,
        }
    }
}

impl NpuConfig {
    /// Nominal INT8 compute peak, GOP/s (Table I: ~10 TOPS).
    pub fn peak_int8_gops(&self) -> f64 {
        (self.pe_array * self.pe_array) as f64 * 2.0 * self.dpu_clock_ghz
    }

    /// Nominal FP16 compute peak, GOP/s.
    pub fn peak_fp16_gops(&self) -> f64 {
        self.peak_int8_gops() * self.fp16_rate
    }

    /// Nominal DMA bandwidth, bytes/ns.
    pub fn dma_bytes_per_ns(&self) -> f64 {
        self.dma_bw_gbps // GB/s == bytes/ns
    }

    /// Aggregate SHAVE element rate for simple ops, elements/ns.
    pub fn shave_simple_elems_per_ns(&self) -> f64 {
        (self.shave_cores * self.shave_lanes) as f64 * self.shave_clock_ghz
            / self.shave_simple_cycles
    }

    /// Aggregate SHAVE element rate for exp-class ops, elements/ns.
    pub fn shave_exp_elems_per_ns(&self) -> f64 {
        (self.shave_cores * self.shave_lanes) as f64 * self.shave_clock_ghz
            / self.shave_exp_cycles
    }

    /// DPU cycle time in ns.
    pub fn dpu_cycle_ns(&self) -> f64 {
        1.0 / self.dpu_clock_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table1() {
        let hw = NpuConfig::default();
        // 10 TOPS @ INT8 within 2%.
        let peak = hw.peak_int8_gops();
        assert!((peak - 10_000.0).abs() / 10_000.0 < 0.02, "peak={peak}");
        assert_eq!(hw.scratchpad_bytes, 4 * 1024 * 1024);
        assert_eq!(hw.shave_cores, 8);
        assert_eq!(hw.dma_bw_gbps, 64.0);
    }

    #[test]
    fn state_pool_is_a_strict_dram_fraction() {
        let hw = NpuConfig::default();
        assert!(hw.state_pool_frac > 0.0 && hw.state_pool_frac < 1.0);
        assert!(hw.state_page_bytes > 0);
        assert_eq!(hw.dram_bytes % hw.state_page_bytes, 0, "pages tile DRAM evenly");
    }

    #[test]
    fn fp16_is_half_int8() {
        let hw = NpuConfig::default();
        assert!((hw.peak_fp16_gops() - hw.peak_int8_gops() / 2.0).abs() < 1e-9);
    }

    #[test]
    fn shave_rates_positive_and_ordered() {
        let hw = NpuConfig::default();
        assert!(hw.shave_exp_elems_per_ns() < hw.shave_simple_elems_per_ns());
        assert!(hw.shave_exp_elems_per_ns() > 0.0);
    }
}
