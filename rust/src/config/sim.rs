//! Simulator knobs that are policy (not hardware): tiling, buffering, and
//! the §V ablation switches.

/// Policy configuration for lowering + simulation.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Matmul tile edge; matches the PE array (128).
    pub tile: usize,
    /// Double-buffer DMA streams (prefetch next tile while computing).
    pub double_buffer: bool,
    /// Chunk size for chunkwise lowerings (linear/retentive); §V finds the
    /// 4 MB scratchpad optimum at 2048-token prefill chunks and we default
    /// the *operator* chunk to one tile row.
    pub chunk: usize,
    /// §V ablation: offload tensor-concat traffic to the host CPU instead
    /// of the NPU DMA engine (paper: −32 % Fourier latency).
    pub offload_concat_to_cpu: bool,
    /// Precision in bytes per element (paper benchmarks 16-bit ⇒ 2).
    pub elem_bytes: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            tile: 128,
            double_buffer: true,
            chunk: 128,
            offload_concat_to_cpu: false,
            elem_bytes: 2,
        }
    }
}

impl SimConfig {
    pub fn with_offload(mut self, on: bool) -> Self {
        self.offload_concat_to_cpu = on;
        self
    }

    pub fn with_double_buffer(mut self, on: bool) -> Self {
        self.double_buffer = on;
        self
    }

    pub fn with_chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setup() {
        let c = SimConfig::default();
        assert_eq!(c.tile, 128);
        assert_eq!(c.elem_bytes, 2, "paper benchmarks at 16-bit precision");
        assert!(!c.offload_concat_to_cpu);
    }

    #[test]
    fn builders_compose() {
        let c = SimConfig::default().with_offload(true).with_chunk(256);
        assert!(c.offload_concat_to_cpu);
        assert_eq!(c.chunk, 256);
        assert!(c.double_buffer);
    }
}
