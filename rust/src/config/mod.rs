//! Configuration: hardware spec (paper Table I), simulator knobs, and
//! workload descriptions.

pub mod hw;
pub mod parse;
pub mod sim;
pub mod workload;

pub use hw::NpuConfig;
pub use sim::SimConfig;
pub use workload::{OperatorKind, WorkloadSpec};
