//! Key=value config parsing for hardware what-if studies.
//!
//! `npuperf ... --hw-config FILE` (or `--hw key=value` pairs) overrides
//! [`NpuConfig`] fields so the §V co-design questions — "what if the
//! scratchpad were 8 MB?", "what if DMA setup were halved?" — become one
//! command-line flag instead of a recompile. Lines are `key = value`,
//! `#` comments allowed.

use anyhow::{anyhow, bail, Result};

use super::hw::NpuConfig;

/// Apply one `key=value` override to a config.
pub fn apply(hw: &mut NpuConfig, key: &str, value: &str) -> Result<()> {
    let f = || -> Result<f64> {
        value.trim().parse::<f64>().map_err(|e| anyhow!("bad value for {key}: {e}"))
    };
    let u = || -> Result<u64> {
        let v = value.trim();
        // Accept unit suffixes for byte quantities: k/m/g (binary).
        let (num, mult) = match v.to_ascii_lowercase() {
            ref s if s.ends_with('g') => (&v[..v.len() - 1], 1u64 << 30),
            ref s if s.ends_with('m') => (&v[..v.len() - 1], 1u64 << 20),
            ref s if s.ends_with('k') => (&v[..v.len() - 1], 1u64 << 10),
            _ => (v, 1),
        };
        Ok(num
            .trim()
            .parse::<u64>()
            .map_err(|e| anyhow!("bad value for {key}: {e}"))?
            * mult)
    };
    match key.trim() {
        "pe_array" => hw.pe_array = u()? as usize,
        "dpu_clock_ghz" => hw.dpu_clock_ghz = f()?,
        "shave_cores" => hw.shave_cores = u()? as usize,
        "shave_clock_ghz" => hw.shave_clock_ghz = f()?,
        "shave_lanes" => hw.shave_lanes = u()? as usize,
        "scratchpad_bytes" => hw.scratchpad_bytes = u()?,
        "dma_bw_gbps" => hw.dma_bw_gbps = f()?,
        "dram_bytes" => hw.dram_bytes = u()?,
        "state_page_bytes" => {
            let v = u()?;
            if v == 0 {
                bail!("state_page_bytes must be positive");
            }
            hw.state_page_bytes = v;
        }
        "state_pool_frac" => {
            let v = f()?;
            if !(v > 0.0 && v <= 1.0) {
                bail!("state_pool_frac must be in (0, 1], got {v}");
            }
            hw.state_pool_frac = v;
        }
        "dpu_fill_cycles" => hw.dpu_fill_cycles = u()?,
        "dpu_drain_cycles" => hw.dpu_drain_cycles = u()?,
        "dpu_issue_ns" => hw.dpu_issue_ns = f()?,
        "fp16_rate" => hw.fp16_rate = f()?,
        "shave_issue_ns" => hw.shave_issue_ns = f()?,
        "shave_exp_cycles" => hw.shave_exp_cycles = f()?,
        "shave_simple_cycles" => hw.shave_simple_cycles = f()?,
        "shave_reduce_span" => hw.shave_reduce_span = u()? as usize,
        "dma_setup_ns" => hw.dma_setup_ns = f()?,
        "dma_alloc_ns" => hw.dma_alloc_ns = f()?,
        "cpu_memcpy_gbps" => hw.cpu_memcpy_gbps = f()?,
        "cpu_issue_ns" => hw.cpu_issue_ns = f()?,
        other => bail!("unknown hw config key {other:?}"),
    }
    Ok(())
}

/// Parse a whole config file of `key = value` lines over the defaults.
pub fn from_file(path: &str) -> Result<NpuConfig> {
    let text = std::fs::read_to_string(path)?;
    let mut hw = NpuConfig::default();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        apply(&mut hw, k, v).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
    }
    Ok(hw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_overrides_fields() {
        let mut hw = NpuConfig::default();
        apply(&mut hw, "dma_bw_gbps", "128").unwrap();
        apply(&mut hw, "scratchpad_bytes", "8m").unwrap();
        apply(&mut hw, "shave_cores", "16").unwrap();
        assert_eq!(hw.dma_bw_gbps, 128.0);
        assert_eq!(hw.scratchpad_bytes, 8 << 20);
        assert_eq!(hw.shave_cores, 16);
    }

    #[test]
    fn unit_suffixes() {
        let mut hw = NpuConfig::default();
        apply(&mut hw, "scratchpad_bytes", "512k").unwrap();
        assert_eq!(hw.scratchpad_bytes, 512 << 10);
        apply(&mut hw, "dram_bytes", "16g").unwrap();
        assert_eq!(hw.dram_bytes, 16 << 30);
    }

    #[test]
    fn session_memory_keys() {
        let mut hw = NpuConfig::default();
        apply(&mut hw, "state_page_bytes", "128k").unwrap();
        apply(&mut hw, "state_pool_frac", "0.25").unwrap();
        assert_eq!(hw.state_page_bytes, 128 << 10);
        assert_eq!(hw.state_pool_frac, 0.25);
    }

    #[test]
    fn degenerate_session_memory_values_rejected() {
        let mut hw = NpuConfig::default();
        assert!(apply(&mut hw, "state_page_bytes", "0").is_err(), "0 page would div-by-zero");
        assert!(apply(&mut hw, "state_pool_frac", "1.5").is_err());
        assert!(apply(&mut hw, "state_pool_frac", "-0.1").is_err());
        assert!(apply(&mut hw, "state_pool_frac", "0").is_err(), "a zero pool serves nothing");
        assert_eq!(hw, NpuConfig::default(), "rejected overrides leave hw untouched");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut hw = NpuConfig::default();
        assert!(apply(&mut hw, "warp_count", "32").is_err());
    }

    #[test]
    fn file_roundtrip_with_comments() {
        let dir = std::env::temp_dir().join(format!("npuperf-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("hw.cfg");
        std::fs::write(&p, "# bigger NPU\nscratchpad_bytes = 8m\ndma_bw_gbps = 128 # fast\n\n")
            .unwrap();
        let hw = from_file(p.to_str().unwrap()).unwrap();
        assert_eq!(hw.scratchpad_bytes, 8 << 20);
        assert_eq!(hw.dma_bw_gbps, 128.0);
        // Unspecified fields keep defaults.
        assert_eq!(hw.shave_cores, 8);
    }

    #[test]
    fn malformed_line_errors_with_lineno() {
        let dir = std::env::temp_dir().join(format!("npuperf-cfg2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.cfg");
        std::fs::write(&p, "scratchpad_bytes 4m\n").unwrap();
        let err = from_file(p.to_str().unwrap()).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }
}
