//! Metrics registry: counters, gauges, and log-bucketed histograms.
//!
//! The registry is the single store behind every serving metric — the
//! coordinator's human snapshot, the Prometheus exposition, and the JSON
//! dump all render from the same [`MetricsRegistry`], so they cannot
//! disagree (asserted by the obs conformance section of the testkit).
//!
//! Histograms use power-of-two log buckets ([`Histogram`]): bounded
//! memory regardless of sample count, replacing the full-sample vectors
//! the coordinator metrics used to keep per operator. The price is
//! quantile resolution — a reported quantile is exact on `count`, `sum`,
//! `min`, and `max`, and within one bucket (a factor of 2) on
//! interpolated quantiles; see the property tests.
//!
//! Series are keyed by metric name plus a sorted label list
//! ([`SeriesId`]) and stored in `BTreeMap`s, so every export iterates in
//! one deterministic order — a pinned-seed serve run produces a
//! byte-identical exposition, which is what lets CI keep a golden
//! `.prom` fixture.

use std::collections::BTreeMap;

/// Number of histogram buckets: bucket 0 holds `v <= 1`, bucket `i`
/// holds `(2^(i-1), 2^i]`, bucket 64 catches everything above `2^63`.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// Fixed-size log-bucketed histogram (power-of-two bucket bounds).
///
/// Values are nonnegative `f64`s (the serving stack records nanoseconds
/// and byte counts); negative, NaN, and sub-1 values land in bucket 0.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: [0; HISTOGRAM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    /// Bucket index for a value: 0 for `v <= 1` (and NaN / negatives),
    /// otherwise the unique `i` with `2^(i-1) < ceil(v) <= 2^i`.
    pub fn bucket_index(v: f64) -> usize {
        if !(v > 1.0) {
            return 0;
        }
        let c = v.ceil() as u64; // saturating cast
        if c <= 1 {
            0
        } else {
            64 - (c - 1).leading_zeros() as usize
        }
    }

    /// Upper bound of bucket `i` (inclusive).
    pub fn upper_bound(i: usize) -> f64 {
        if i >= 64 {
            u64::MAX as f64
        } else {
            (1u64 << i) as f64
        }
    }

    /// Lower bound of bucket `i` (exclusive, except bucket 0 which
    /// starts at 0).
    pub fn lower_bound(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            Self::upper_bound(i - 1)
        }
    }

    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Raw bucket counts (sum equals [`Histogram::count`]).
    pub fn buckets(&self) -> &[u64; HISTOGRAM_BUCKETS] {
        &self.buckets
    }

    /// Interpolated quantile, `q` in percent (`50.0` = median).
    ///
    /// Exact when all samples are equal (the result clamps to
    /// `[min, max]`); otherwise within the power-of-two bucket holding
    /// the target rank. Monotone in `q` by construction.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 100.0);
        let target = q / 100.0 * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= target {
                let lo = Self::lower_bound(i);
                let hi = Self::upper_bound(i);
                let frac = (target - cum as f64).max(0.0) / c as f64;
                return (lo + (hi - lo) * frac).clamp(self.min, self.max);
            }
            cum = next;
        }
        self.max
    }
}

/// One time series: a metric name plus its sorted label list. `Ord` over
/// both gives the registry's deterministic export order.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesId {
    pub name: &'static str,
    /// Sorted `(key, value)` pairs; empty for unlabeled series.
    pub labels: Vec<(&'static str, String)>,
}

impl SeriesId {
    pub fn new(name: &'static str, labels: &[(&'static str, &str)]) -> Self {
        let mut labels: Vec<(&'static str, String)> =
            labels.iter().map(|(k, v)| (*k, v.to_string())).collect();
        labels.sort();
        Self { name, labels }
    }

    /// Prometheus-style label block: `{k="v",..}`, empty when unlabeled.
    pub fn label_block(&self) -> String {
        render_labels(&self.labels)
    }
}

pub(crate) fn render_labels(labels: &[(&'static str, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{{{}}}", inner.join(","))
}

/// Deterministically ordered store of counters, gauges and histograms.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<SeriesId, u64>,
    gauges: BTreeMap<SeriesId, f64>,
    histograms: BTreeMap<SeriesId, Histogram>,
    help: BTreeMap<&'static str, &'static str>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a `# HELP` line to a metric name (exported verbatim).
    pub fn describe(&mut self, name: &'static str, help: &'static str) {
        self.help.insert(name, help);
    }

    pub fn help(&self, name: &str) -> Option<&'static str> {
        self.help.get(name).copied()
    }

    pub fn inc(&mut self, name: &'static str, labels: &[(&'static str, &str)], delta: u64) {
        *self.counters.entry(SeriesId::new(name, labels)).or_insert(0) += delta;
    }

    /// Set a counter to an absolute cumulative value — for mirroring a
    /// source that already keeps the running total (e.g.
    /// [`crate::memory::MemStats`]), so there is exactly one counting
    /// site.
    pub fn set_counter(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: u64) {
        self.counters.insert(SeriesId::new(name, labels), v);
    }

    pub fn set_gauge(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: f64) {
        self.gauges.insert(SeriesId::new(name, labels), v);
    }

    pub fn observe(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: f64) {
        self.histograms.entry(SeriesId::new(name, labels)).or_default().record(v);
    }

    pub fn counter(&self, name: &'static str, labels: &[(&'static str, &str)]) -> u64 {
        self.counters.get(&SeriesId::new(name, labels)).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &'static str, labels: &[(&'static str, &str)]) -> Option<f64> {
        self.gauges.get(&SeriesId::new(name, labels)).copied()
    }

    pub fn histogram(
        &self,
        name: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Option<&Histogram> {
        self.histograms.get(&SeriesId::new(name, labels))
    }

    /// Sum every counter series of `name` whose labels include all of
    /// `filter` (empty filter = all series of that name).
    pub fn sum_counters(&self, name: &str, filter: &[(&str, &str)]) -> u64 {
        self.counters
            .iter()
            .filter(|(id, _)| id.name == name && matches_filter(&id.labels, filter))
            .map(|(_, v)| *v)
            .sum()
    }

    /// Total count across every histogram series of `name` matching
    /// `filter`.
    pub fn sum_histogram_counts(&self, name: &str, filter: &[(&str, &str)]) -> u64 {
        self.histograms
            .iter()
            .filter(|(id, _)| id.name == name && matches_filter(&id.labels, filter))
            .map(|(_, h)| h.count())
            .sum()
    }

    /// Distinct values of label `key` across every histogram series of
    /// `name`, in deterministic (sorted) order.
    pub fn histogram_label_values(&self, name: &str, key: &str) -> Vec<String> {
        let mut out: Vec<String> = self
            .histograms
            .keys()
            .filter(|id| id.name == name)
            .flat_map(|id| {
                id.labels.iter().filter(|(k, _)| *k == key).map(|(_, v)| v.clone())
            })
            .collect();
        out.sort();
        out.dedup();
        out
    }

    pub fn counters(&self) -> impl Iterator<Item = (&SeriesId, u64)> {
        self.counters.iter().map(|(id, v)| (id, *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&SeriesId, f64)> {
        self.gauges.iter().map(|(id, v)| (id, *v))
    }

    pub fn histograms(&self) -> impl Iterator<Item = (&SeriesId, &Histogram)> {
        self.histograms.iter()
    }
}

fn matches_filter(labels: &[(&'static str, String)], filter: &[(&str, &str)]) -> bool {
    filter.iter().all(|(fk, fv)| labels.iter().any(|(k, v)| k == fk && v == fv))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall, Rng};
    use crate::util::stats::Summary;

    #[test]
    fn bucket_bounds_cover_the_line() {
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(1.0), 0);
        assert_eq!(Histogram::bucket_index(1.5), 1);
        assert_eq!(Histogram::bucket_index(2.0), 1);
        assert_eq!(Histogram::bucket_index(2.1), 2);
        assert_eq!(Histogram::bucket_index(1024.0), 10);
        assert_eq!(Histogram::bucket_index(1025.0), 11);
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(-3.0), 0);
        assert_eq!(Histogram::bucket_index(1e30), 64);
    }

    #[test]
    fn property_bucket_bounds_are_monotone() {
        for i in 1..HISTOGRAM_BUCKETS {
            assert!(Histogram::upper_bound(i) > Histogram::upper_bound(i - 1));
            assert_eq!(Histogram::lower_bound(i), Histogram::upper_bound(i - 1));
        }
    }

    #[test]
    fn property_values_land_inside_their_bucket() {
        forall(
            "histogram bucket containment",
            200,
            |rng: &mut Rng| (rng.below(1u64 << 40) as f64) * 1e-3,
            |&v| {
                let i = Histogram::bucket_index(v);
                let (lo, hi) = (Histogram::lower_bound(i), Histogram::upper_bound(i));
                // ceil(v) is what gets bucketed, so containment is on the
                // rounded-up value.
                let c = v.max(1.0).ceil();
                if (i == 0 || c > lo) && c <= hi {
                    Ok(())
                } else {
                    Err(format!("{v} -> bucket {i} ({lo}, {hi}]"))
                }
            },
        );
    }

    #[test]
    fn property_count_conservation() {
        forall(
            "histogram count conservation",
            50,
            |rng: &mut Rng| {
                (0..rng.range(1, 200)).map(|_| rng.below(1u64 << 30) as f64).collect::<Vec<_>>()
            },
            |vals| {
                let mut h = Histogram::new();
                for &v in vals {
                    h.record(v);
                }
                let bucket_total: u64 = h.buckets().iter().sum();
                if bucket_total == h.count() && h.count() == vals.len() as u64 {
                    Ok(())
                } else {
                    Err(format!("buckets sum {bucket_total} != count {}", h.count()))
                }
            },
        );
    }

    #[test]
    fn property_quantiles_are_ordered() {
        forall(
            "histogram quantile ordering",
            50,
            |rng: &mut Rng| {
                (0..rng.range(1, 300)).map(|_| rng.below(1u64 << 45) as f64).collect::<Vec<_>>()
            },
            |vals| {
                let mut h = Histogram::new();
                for &v in vals {
                    h.record(v);
                }
                let (p50, p95, p99) = (h.quantile(50.0), h.quantile(95.0), h.quantile(99.0));
                if p50 <= p95 && p95 <= p99 && p99 <= h.max() {
                    Ok(())
                } else {
                    Err(format!("p50={p50} p95={p95} p99={p99} max={}", h.max()))
                }
            },
        );
    }

    #[test]
    fn property_quantile_tracks_exact_full_sample_path() {
        // Cross-check against the old full-sample `Summary` path the
        // histogram replaced: count-weighted moments must agree exactly
        // (same additions in the same order), and a quantile must land
        // within a factor of 2 of the target-rank order statistic —
        // that sample's log bucket brackets it by construction.
        forall(
            "histogram vs exact quantiles",
            40,
            |rng: &mut Rng| {
                (0..rng.range(5, 400)).map(|_| rng.below(1u64 << 40) as f64).collect::<Vec<_>>()
            },
            |vals| {
                let mut h = Histogram::new();
                let mut s = Summary::new();
                for &v in vals {
                    h.record(v);
                    s.push(v);
                }
                if h.mean() != s.mean() || h.min() != s.min() || h.max() != s.max() {
                    return Err(format!(
                        "exact moments diverge: mean {} vs {}, min {} vs {}, max {} vs {}",
                        h.mean(),
                        s.mean(),
                        h.min(),
                        s.min(),
                        h.max(),
                        s.max()
                    ));
                }
                let mut sorted = vals.clone();
                sorted.sort_by(f64::total_cmp);
                for q in [50.0, 95.0, 99.0] {
                    let k =
                        ((q / 100.0 * vals.len() as f64).ceil() as usize).clamp(1, vals.len()) - 1;
                    let (x, approx) = (sorted[k], h.quantile(q));
                    if approx < x / 2.0 - 1.0 || approx > 2.0 * x + 2.0 {
                        return Err(format!("q{q}: histogram {approx} vs rank sample {x}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn identical_samples_make_quantiles_exact() {
        let mut h = Histogram::new();
        for _ in 0..10 {
            h.record(777.0);
        }
        // min == max == 777 and quantiles clamp to [min, max].
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.quantile(q), 777.0);
        }
        assert_eq!(h.mean(), 777.0);
        assert_eq!(h.min(), 777.0);
        assert_eq!(h.max(), 777.0);
    }

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn registry_counters_and_labels() {
        let mut r = MetricsRegistry::new();
        r.inc("req_total", &[("operator", "causal")], 2);
        r.inc("req_total", &[("operator", "linear")], 1);
        r.inc("req_total", &[("operator", "causal")], 1);
        assert_eq!(r.counter("req_total", &[("operator", "causal")]), 3);
        assert_eq!(r.sum_counters("req_total", &[]), 4);
        assert_eq!(r.sum_counters("req_total", &[("operator", "linear")]), 1);
        assert_eq!(r.counter("req_total", &[("operator", "fourier")]), 0);
    }

    #[test]
    fn registry_label_order_is_canonical() {
        let a = SeriesId::new("m", &[("b", "2"), ("a", "1")]);
        let b = SeriesId::new("m", &[("a", "1"), ("b", "2")]);
        assert_eq!(a, b);
        assert_eq!(a.label_block(), "{a=\"1\",b=\"2\"}");
    }

    #[test]
    fn registry_histograms_observe() {
        let mut r = MetricsRegistry::new();
        r.observe("lat", &[("operator", "causal")], 100.0);
        r.observe("lat", &[("operator", "causal")], 300.0);
        let h = r.histogram("lat", &[("operator", "causal")]).unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 400.0);
        assert_eq!(r.sum_histogram_counts("lat", &[]), 2);
        assert_eq!(r.histogram_label_values("lat", "operator"), vec!["causal".to_string()]);
    }

    #[test]
    fn set_counter_mirrors_absolute_totals() {
        let mut r = MetricsRegistry::new();
        r.set_counter("evictions_total", &[], 7);
        r.set_counter("evictions_total", &[], 9);
        assert_eq!(r.counter("evictions_total", &[]), 9, "absolute, not additive");
    }
}
