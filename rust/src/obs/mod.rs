//! Observability subsystem: per-request tracing, a bounded-memory
//! metrics registry, and export sinks.
//!
//! Three layers, documented in `docs/OBSERVABILITY.md`:
//!
//! - [`trace`] — the span model. Each served request gets a
//!   [`trace::RequestTrace`]: lifecycle stages stamped on the injected
//!   [`crate::coordinator::Clock`] plus the per-engine spans of the NPU
//!   simulation nested under the request.
//! - [`metrics`] — [`metrics::MetricsRegistry`], the single store of
//!   counters, gauges, and power-of-two log-bucketed
//!   [`metrics::Histogram`]s, labeled by operator /
//!   [`crate::ops::BoundClass`] / backend.
//! - [`export`] — sinks over both: a merged Chrome/Perfetto timeline
//!   ([`export::chrome`]), Prometheus text exposition
//!   ([`export::prometheus`]), JSON snapshot ([`export::json`]), a JSONL
//!   event log ([`export::jsonl`]), and the validators behind
//!   `npuperf obs`.

pub mod export;
pub mod metrics;
pub mod trace;

pub use export::{chrome, json, jsonl, lint_prometheus, prometheus, validate_json, PromLint};
pub use metrics::{Histogram, MetricsRegistry, SeriesId, HISTOGRAM_BUCKETS};
pub use trace::{engine_spans, EngineSpan, RequestTrace, Stage, Tracer};
