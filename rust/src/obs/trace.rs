//! Per-request span model for the serving stack.
//!
//! Every request the coordinator dispatches gets a [`RequestTrace`]: a
//! flat list of lifecycle [`Stage`] spans (queued → lower → admission →
//! backend → respond, all stamped on the injected
//! [`crate::coordinator::Clock`]) plus the per-engine [`EngineSpan`]s of
//! the NPU simulation, rebased onto the request's timeline at the moment
//! its backend stage began. [`crate::obs::export::chrome`] renders a
//! collection of these as one merged Perfetto-loadable timeline — the
//! multi-request generalization of the single-op
//! [`crate::npu::trace_dump`].
//!
//! One deliberate dilation: the backend stage's extent is the
//! *simulated* span (model time), not the wall time the simulator took
//! to run, so the nested engine spans tile their parent exactly and the
//! timeline shows where the modeled NPU spent its nanoseconds. Under a
//! frozen `ManualClock` every other stage has zero width and the
//! timeline is exactly assertable.

use std::collections::HashMap;

use crate::npu::engine::{engine_index, ps_to_ns, SimTrace};
use crate::ops::{Engine, OpGraph, PrimOp};

/// Human label for a lowered primitive (shared with
/// [`crate::npu::trace_dump`]).
pub fn prim_label(p: &PrimOp) -> String {
    match p {
        PrimOp::MatMul { m, n, k } => format!("matmul {m}x{n}x{k}"),
        PrimOp::EltWise { kind, elems } => format!("eltwise {kind:?} {elems}"),
        PrimOp::Softmax { rows, cols } => format!("softmax {rows}x{cols}"),
        PrimOp::Transfer { bytes, dir, fresh_alloc } => {
            format!("dma {dir:?} {bytes}B{}", if *fresh_alloc { " +alloc" } else { "" })
        }
        PrimOp::Concat { bytes } => format!("concat {bytes}B"),
        PrimOp::HostOp { bytes } => format!("host {bytes}B"),
    }
}

/// One lifecycle stage of a request, on the serve-loop clock (ns).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Stage {
    pub name: &'static str,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl Stage {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// One simulated primitive on one NPU engine, absolute ns on the
/// request's timeline (already rebased by the tracer).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineSpan {
    pub engine: Engine,
    pub name: String,
    pub start_ns: f64,
    pub dur_ns: f64,
    /// Node id in the lowered graph.
    pub node: usize,
    /// Dependency count (fan-in) of the node.
    pub deps: usize,
}

/// Extract per-engine spans from a simulation trace, starting at 0 ns;
/// the tracer rebases them onto the request timeline.
pub fn engine_spans(graph: &OpGraph, trace: &SimTrace) -> Vec<EngineSpan> {
    graph
        .nodes
        .iter()
        .map(|node| {
            let t = trace.timings[node.id];
            EngineSpan {
                engine: node.prim.engine(),
                name: prim_label(&node.prim),
                start_ns: ps_to_ns(t.start_ps),
                dur_ns: ps_to_ns(t.end_ps.saturating_sub(t.start_ps)),
                node: node.id,
                deps: node.deps.len(),
            }
        })
        .collect()
}

/// Full span tree of one request.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTrace {
    pub trace_id: u64,
    pub session: u64,
    /// Workload label, e.g. `causal N=1024`.
    pub label: String,
    /// Registry operator that served it (set at lowering; `None` when
    /// shed before lowering or served by a precompiled artifact).
    pub operator: Option<&'static str>,
    /// Fleet device that executed it, e.g. `d0` (set at placement;
    /// `None` on traces captured before dispatch).
    pub device: Option<&'static str>,
    /// `served`, `shed`, or `error`.
    pub outcome: &'static str,
    pub stages: Vec<Stage>,
    pub engine_spans: Vec<EngineSpan>,
}

impl RequestTrace {
    /// Earliest stage start (ns); `u64::MAX` when empty.
    pub fn start_ns(&self) -> u64 {
        self.stages.iter().map(|s| s.start_ns).min().unwrap_or(u64::MAX)
    }
}

/// Collects request traces on the serving thread. Every method is a
/// no-op when disabled, so the untraced serve path pays one branch; the
/// completed-trace buffer is capacity-bounded (overflow is counted, not
/// stored).
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    capacity: usize,
    active: HashMap<u64, RequestTrace>,
    done: Vec<RequestTrace>,
    dropped: u64,
}

impl Tracer {
    pub fn new(enabled: bool, capacity: usize) -> Self {
        Self { enabled, capacity, active: HashMap::new(), done: Vec::new(), dropped: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Traces dropped because the completed buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Open a trace at request intake.
    pub fn begin(&mut self, trace_id: u64, session: u64, label: String) {
        if !self.enabled {
            return;
        }
        self.active.insert(
            trace_id,
            RequestTrace {
                trace_id,
                session,
                label,
                operator: None,
                device: None,
                outcome: "open",
                stages: Vec::new(),
                engine_spans: Vec::new(),
            },
        );
    }

    /// Record one lifecycle stage on an open trace.
    pub fn stage(&mut self, trace_id: u64, name: &'static str, start_ns: u64, end_ns: u64) {
        if let Some(t) = self.active.get_mut(&trace_id) {
            t.stages.push(Stage { name, start_ns, end_ns: end_ns.max(start_ns) });
        }
    }

    pub fn set_operator(&mut self, trace_id: u64, operator: &'static str) {
        if let Some(t) = self.active.get_mut(&trace_id) {
            t.operator = Some(operator);
        }
    }

    /// Stamp the fleet device the request was placed on.
    pub fn set_device(&mut self, trace_id: u64, device: &'static str) {
        if let Some(t) = self.active.get_mut(&trace_id) {
            t.device = Some(device);
        }
    }

    /// Attach simulated engine spans, rebased so the simulation's t=0
    /// lands at `base_ns` on the request timeline.
    pub fn attach_engine_spans(&mut self, trace_id: u64, base_ns: u64, spans: &[EngineSpan]) {
        if let Some(t) = self.active.get_mut(&trace_id) {
            t.engine_spans.extend(spans.iter().map(|s| EngineSpan {
                start_ns: s.start_ns + base_ns as f64,
                name: s.name.clone(),
                ..*s
            }));
        }
    }

    /// Close a trace with its outcome and move it to the completed
    /// buffer (or count it dropped when over capacity).
    pub fn finish(&mut self, trace_id: u64, outcome: &'static str) {
        let Some(mut t) = self.active.remove(&trace_id) else {
            return;
        };
        t.outcome = outcome;
        if self.done.len() >= self.capacity {
            self.dropped += 1;
        } else {
            self.done.push(t);
        }
    }

    /// Completed traces, in completion order.
    pub fn snapshot(&self) -> Vec<RequestTrace> {
        self.done.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
    use crate::npu::engine::simulate;
    use crate::ops;

    fn lowered(op: OperatorKind, n: usize) -> (OpGraph, SimTrace) {
        let (hw, sim) = (NpuConfig::default(), SimConfig::default());
        let g = ops::lower(&WorkloadSpec::new(op, n), &hw, &sim);
        let t = simulate(&g, &hw, &sim);
        (g, t)
    }

    #[test]
    fn engine_spans_cover_every_node() {
        let (g, t) = lowered(OperatorKind::Linear, 256);
        let spans = engine_spans(&g, &t);
        assert_eq!(spans.len(), g.len());
        for s in &spans {
            assert!(s.dur_ns >= 0.0);
            assert!(s.start_ns >= 0.0);
        }
        // Spans reflect the simulated schedule, ps -> ns.
        let makespan = ps_to_ns(t.span_ps);
        assert!(spans.iter().all(|s| s.start_ns + s.dur_ns <= makespan + 1e-6));
    }

    #[test]
    fn tracer_records_a_full_lifecycle() {
        let mut tr = Tracer::new(true, 16);
        tr.begin(7, 3, "causal N=128".into());
        tr.stage(7, "queued", 100, 200);
        tr.set_operator(7, "causal");
        tr.set_device(7, "d0");
        let (g, t) = lowered(OperatorKind::Causal, 128);
        let spans = engine_spans(&g, &t);
        tr.attach_engine_spans(7, 200, &spans);
        tr.stage(7, "respond", 200, 210);
        tr.finish(7, "served");
        let done = tr.snapshot();
        assert_eq!(done.len(), 1);
        let rt = &done[0];
        assert_eq!(rt.trace_id, 7);
        assert_eq!(rt.operator, Some("causal"));
        assert_eq!(rt.device, Some("d0"));
        assert_eq!(rt.outcome, "served");
        assert_eq!(rt.stages.len(), 2);
        assert_eq!(rt.engine_spans.len(), spans.len());
        // Rebased onto the request timeline.
        assert!(rt.engine_spans.iter().all(|s| s.start_ns >= 200.0));
        assert_eq!(rt.start_ns(), 100);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut tr = Tracer::new(false, 16);
        tr.begin(1, 1, "x".into());
        tr.stage(1, "queued", 0, 1);
        tr.finish(1, "served");
        assert!(tr.snapshot().is_empty());
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn capacity_bounds_completed_traces() {
        let mut tr = Tracer::new(true, 2);
        for id in 0..5 {
            tr.begin(id, 0, "x".into());
            tr.finish(id, "served");
        }
        assert_eq!(tr.snapshot().len(), 2);
        assert_eq!(tr.dropped(), 3);
    }

    #[test]
    fn backwards_stage_is_clamped() {
        let mut tr = Tracer::new(true, 4);
        tr.begin(1, 0, "x".into());
        tr.stage(1, "weird", 50, 10);
        tr.finish(1, "served");
        let done = tr.snapshot();
        assert_eq!(done[0].stages[0].dur_ns(), 0);
    }

    #[test]
    fn engine_index_agrees_with_trace_dump_tids() {
        // The chrome export puts engine tracks at tid 1 + engine_index;
        // pin the mapping the fixtures rely on.
        assert_eq!(engine_index(Engine::Dpu), 0);
        assert_eq!(engine_index(Engine::Shave), 1);
        assert_eq!(engine_index(Engine::Dma), 2);
        assert_eq!(engine_index(Engine::Cpu), 3);
    }
}
