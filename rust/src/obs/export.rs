//! Observability sinks: Chrome/Perfetto trace assembly, Prometheus text
//! exposition, JSON metric snapshots, and a JSONL event log — plus the
//! validators `npuperf obs` and CI run over the emitted artifacts.
//!
//! All emitters are hand-rolled (serde is not in the offline crate set)
//! behind one shared [`ChromeTrace`] builder that owns the comma/escape
//! discipline and sorts events by timestamp, so every producer —
//! [`crate::npu::trace_dump`]'s single-op dump and the coordinator's
//! merged multi-request timeline alike — emits valid JSON with monotone
//! timestamps by construction.

use std::fmt::Write as _;

use super::metrics::{Histogram, MetricsRegistry};
use super::trace::RequestTrace;
use crate::npu::engine::engine_index;
use crate::ops::Engine;

/// Escape a string for inclusion inside a JSON string literal.
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

struct ChromeEvent {
    ts_us: f64,
    pid: u64,
    tid: u32,
    rendered: String,
}

/// Builder for Chrome Trace Event Format JSON (the `[...]` array form
/// both `chrome://tracing` and Perfetto load).
///
/// Metadata records come first, then every `"X"` span sorted by
/// `(ts, pid, tid)` — so timestamps are monotone in the emitted order
/// and the array never carries a trailing comma, even when empty.
#[derive(Default)]
pub struct ChromeTrace {
    meta: Vec<String>,
    events: Vec<ChromeEvent>,
}

impl ChromeTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Name the process `pid` (one per request in merged timelines).
    pub fn process_name(&mut self, pid: u64, name: &str) {
        self.meta.push(format!(
            r#"  {{"name":"process_name","ph":"M","pid":{pid},"args":{{"name":"{}"}}}}"#,
            escape_json(name)
        ));
    }

    /// Name the thread `(pid, tid)` (request track or engine track).
    pub fn thread_name(&mut self, pid: u64, tid: u32, name: &str) {
        self.meta.push(format!(
            r#"  {{"name":"thread_name","ph":"M","pid":{pid},"tid":{tid},"args":{{"name":"{}"}}}}"#,
            escape_json(name)
        ));
    }

    /// Add one complete ("X") span; `args` is a pre-rendered JSON object
    /// (empty string = no args field).
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &mut self,
        pid: u64,
        tid: u32,
        name: &str,
        cat: &str,
        ts_us: f64,
        dur_us: f64,
        args: &str,
    ) {
        let mut rendered = format!(
            r#"  {{"name":"{}","cat":"{}","ph":"X","pid":{pid},"tid":{tid},"ts":{ts_us:.3},"dur":{dur_us:.3}"#,
            escape_json(name),
            escape_json(cat),
        );
        if !args.is_empty() {
            let _ = write!(rendered, r#","args":{args}"#);
        }
        rendered.push('}');
        self.events.push(ChromeEvent { ts_us, pid, tid, rendered });
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty() && self.events.is_empty()
    }

    /// Render the full JSON array.
    pub fn render(mut self) -> String {
        self.events.sort_by(|a, b| {
            a.ts_us.total_cmp(&b.ts_us).then_with(|| (a.pid, a.tid).cmp(&(b.pid, b.tid)))
        });
        let lines: Vec<String> =
            self.meta.into_iter().chain(self.events.into_iter().map(|e| e.rendered)).collect();
        format!("[\n{}\n]\n", lines.join(",\n"))
    }
}

fn ns_to_us(ns: f64) -> f64 {
    ns / 1e3
}

/// First pid of the per-device summary processes in [`chrome`] — far
/// above any `trace_id + 1` request pid, so the two ranges never
/// collide.
pub const DEVICE_PID_BASE: u64 = 1_000_000;

/// Merge completed request traces into one Perfetto-loadable timeline.
///
/// Layout: one process per request (`pid = trace_id + 1`); tid 0 is the
/// request lifecycle track, tids 1–4 are the DPU/SHAVE/DMA/CPU engine
/// tracks (`1 + engine_index`), so the simulated engine spans nest under
/// their request. Requests stamped with a fleet device additionally get
/// one summary span on that device's process track (pids from
/// [`DEVICE_PID_BASE`], one per distinct device label in sorted order),
/// so per-device occupancy reads directly off the timeline. All
/// timestamps are rebased so the earliest stage in the collection lands
/// at t=0.
pub fn chrome(traces: &[RequestTrace]) -> String {
    let t0 = traces.iter().map(|t| t.start_ns()).min().unwrap_or(0);
    let t0 = if t0 == u64::MAX { 0 } else { t0 };
    let mut out = ChromeTrace::new();
    let mut ordered: Vec<&RequestTrace> = traces.iter().collect();
    ordered.sort_by_key(|t| t.trace_id);
    let mut devices: Vec<&'static str> = ordered.iter().filter_map(|t| t.device).collect();
    devices.sort();
    devices.dedup();
    for (i, dev) in devices.into_iter().enumerate() {
        let pid = DEVICE_PID_BASE + i as u64;
        out.process_name(pid, &format!("device {dev}"));
        out.thread_name(pid, 0, "requests");
        for tr in ordered.iter().filter(|t| t.device == Some(dev)) {
            let start = tr.start_ns();
            if start == u64::MAX {
                continue;
            }
            let end = tr.stages.iter().map(|s| s.end_ns).max().unwrap_or(start);
            out.span(
                pid,
                0,
                &format!("req {} {}", tr.trace_id, tr.label),
                "request",
                ns_to_us(start.saturating_sub(t0) as f64),
                ns_to_us(end.saturating_sub(start) as f64),
                &format!(
                    r#"{{"session":{},"outcome":"{}"}}"#,
                    tr.session,
                    escape_json(tr.outcome)
                ),
            );
        }
    }
    for tr in ordered {
        let pid = tr.trace_id + 1;
        out.process_name(
            pid,
            &format!(
                "req {} {} session={} [{}]{}{}",
                tr.trace_id,
                tr.label,
                tr.session,
                tr.outcome,
                tr.operator.map(|o| format!(" op={o}")).unwrap_or_default(),
                tr.device.map(|d| format!(" dev={d}")).unwrap_or_default()
            ),
        );
        out.thread_name(pid, 0, "request");
        for s in &tr.stages {
            out.span(
                pid,
                0,
                s.name,
                "stage",
                ns_to_us(s.start_ns.saturating_sub(t0) as f64),
                ns_to_us(s.dur_ns() as f64),
                "",
            );
        }
        let mut seen = [false; 4];
        for es in &tr.engine_spans {
            seen[engine_index(es.engine)] = true;
        }
        for e in Engine::ALL {
            if seen[engine_index(e)] {
                out.thread_name(pid, 1 + engine_index(e) as u32, e.name());
            }
        }
        for es in &tr.engine_spans {
            out.span(
                pid,
                1 + engine_index(es.engine) as u32,
                &es.name,
                es.engine.name(),
                ns_to_us(es.start_ns - t0 as f64),
                ns_to_us(es.dur_ns),
                &format!(r#"{{"node":{},"deps":{}}}"#, es.node, es.deps),
            );
        }
    }
    out.render()
}

/// JSONL event log: one line per request header, stage, and engine span.
pub fn jsonl(traces: &[RequestTrace]) -> String {
    let mut out = String::new();
    let mut ordered: Vec<&RequestTrace> = traces.iter().collect();
    ordered.sort_by_key(|t| t.trace_id);
    for tr in ordered {
        let _ = writeln!(
            out,
            r#"{{"event":"request","trace_id":{},"session":{},"label":"{}","operator":{},"device":{},"outcome":"{}"}}"#,
            tr.trace_id,
            tr.session,
            escape_json(&tr.label),
            tr.operator.map(|o| format!("\"{}\"", escape_json(o))).unwrap_or_else(|| "null".into()),
            tr.device.map(|d| format!("\"{}\"", escape_json(d))).unwrap_or_else(|| "null".into()),
            escape_json(tr.outcome),
        );
        for s in &tr.stages {
            let _ = writeln!(
                out,
                r#"{{"event":"stage","trace_id":{},"name":"{}","start_ns":{},"dur_ns":{}}}"#,
                tr.trace_id,
                escape_json(s.name),
                s.start_ns,
                s.dur_ns(),
            );
        }
        for es in &tr.engine_spans {
            let _ = writeln!(
                out,
                r#"{{"event":"engine","trace_id":{},"engine":"{}","name":"{}","start_ns":{:.3},"dur_ns":{:.3},"node":{}}}"#,
                tr.trace_id,
                es.engine.name(),
                escape_json(&es.name),
                es.start_ns,
                es.dur_ns,
                es.node,
            );
        }
    }
    out
}

/// Prometheus text exposition of the whole registry: counters, gauges,
/// then histograms (`_bucket`/`_sum`/`_count` with power-of-two `le`
/// bounds), each preceded by its `# HELP`/`# TYPE` block. Deterministic:
/// the registry iterates in `BTreeMap` order.
pub fn prometheus(reg: &MetricsRegistry) -> String {
    let mut out = String::new();
    let header =
        |out: &mut String, described: &mut Option<&'static str>, name: &'static str, kind: &str| {
            if *described != Some(name) {
                if let Some(help) = reg.help(name) {
                    let _ = writeln!(out, "# HELP {name} {help}");
                }
                let _ = writeln!(out, "# TYPE {name} {kind}");
                *described = Some(name);
            }
        };
    let mut described: Option<&'static str> = None;
    for (id, v) in reg.counters() {
        header(&mut out, &mut described, id.name, "counter");
        let _ = writeln!(out, "{}{} {v}", id.name, id.label_block());
    }
    described = None;
    for (id, v) in reg.gauges() {
        header(&mut out, &mut described, id.name, "gauge");
        let _ = writeln!(out, "{}{} {v}", id.name, id.label_block());
    }
    described = None;
    for (id, h) in reg.histograms() {
        header(&mut out, &mut described, id.name, "histogram");
        let base = &id.labels;
        let hi = h.buckets().iter().rposition(|&c| c > 0).unwrap_or(0);
        let mut cum = 0u64;
        for (i, &c) in h.buckets().iter().enumerate().take(hi.min(63) + 1) {
            cum += c;
            let mut labels = base.clone();
            labels.push(("le", format!("{}", Histogram::upper_bound(i))));
            labels.sort();
            let _ = writeln!(
                out,
                "{}_bucket{} {cum}",
                id.name,
                super::metrics::render_labels(&labels)
            );
        }
        let mut labels = base.clone();
        labels.push(("le", "+Inf".to_string()));
        labels.sort();
        let _ = writeln!(
            out,
            "{}_bucket{} {}",
            id.name,
            super::metrics::render_labels(&labels),
            h.count()
        );
        let _ = writeln!(out, "{}_sum{} {}", id.name, id.label_block(), h.sum());
        let _ = writeln!(out, "{}_count{} {}", id.name, id.label_block(), h.count());
    }
    out
}

/// JSON snapshot of the registry: counters/gauges as maps keyed by
/// `name{labels}`, histograms with their summary statistics.
pub fn json(reg: &MetricsRegistry) -> String {
    let mut out = String::from("{\n  \"counters\": {");
    let counters: Vec<String> = reg
        .counters()
        .map(|(id, v)| {
            format!("\n    \"{}{}\": {v}", escape_json(id.name), escape_json(&id.label_block()))
        })
        .collect();
    out += &counters.join(",");
    out += "\n  },\n  \"gauges\": {";
    let gauges: Vec<String> = reg
        .gauges()
        .map(|(id, v)| {
            format!("\n    \"{}{}\": {v}", escape_json(id.name), escape_json(&id.label_block()))
        })
        .collect();
    out += &gauges.join(",");
    out += "\n  },\n  \"histograms\": {";
    let hists: Vec<String> = reg
        .histograms()
        .map(|(id, h)| {
            format!(
                "\n    \"{}{}\": {{\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\"p50\":{},\"p95\":{},\"p99\":{}}}",
                escape_json(id.name),
                escape_json(&id.label_block()),
                h.count(),
                h.sum(),
                h.min(),
                h.max(),
                h.quantile(50.0),
                h.quantile(95.0),
                h.quantile(99.0),
            )
        })
        .collect();
    out += &hists.join(",");
    out += "\n  }\n}\n";
    out
}

/// Minimal JSON well-formedness check (recursive descent, no serde).
/// Returns `Err` with a byte offset on the first syntax error.
pub fn validate_json(text: &str) -> Result<(), String> {
    let b = text.as_bytes();
    let mut pos = 0usize;
    skip_ws(b, &mut pos);
    parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing content at byte {pos}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while matches!(b.get(*pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<(), String> {
    match b.get(*pos) {
        None => Err(format!("unexpected end of input at byte {pos}")),
        Some(b'{') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                skip_ws(b, pos);
                parse_value(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(());
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                parse_value(b, pos)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(());
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'"') => parse_string(b, pos),
        Some(b't') => expect_word(b, pos, "true"),
        Some(b'f') => expect_word(b, pos, "false"),
        Some(b'n') => expect_word(b, pos, "null"),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(b, pos),
        Some(c) => Err(format!("unexpected byte {:?} at {pos}", *c as char)),
    }
}

fn expect(b: &[u8], pos: &mut usize, want: u8) -> Result<(), String> {
    if b.get(*pos) == Some(&want) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at byte {pos}", want as char))
    }
}

fn expect_word(b: &[u8], pos: &mut usize, word: &str) -> Result<(), String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(())
    } else {
        Err(format!("expected `{word}` at byte {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<(), String> {
    expect(b, pos, b'"')?;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            b'\\' => {
                *pos += 2; // escape pair (\uXXXX hex digits pass the scan below)
            }
            _ => *pos += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while let Some(c) = b.get(*pos) {
        if !(c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            break;
        }
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    text.parse::<f64>().map_err(|e| format!("bad number `{text}` at byte {start}: {e}"))?;
    Ok(())
}

/// Summary of a linted Prometheus exposition.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PromLint {
    pub samples: usize,
    pub histograms: usize,
    pub help_lines: usize,
}

/// Lint Prometheus text exposition format: every line must be a
/// comment/blank or `name{labels} value`; `_bucket` runs must be
/// cumulative with a final `+Inf` equal to the series' `_count`.
pub fn lint_prometheus(text: &str) -> Result<PromLint, String> {
    let mut lint = PromLint::default();
    let mut bucket_run: Option<(String, u64)> = None; // (series key, last cum)
    let mut inf_count: Option<(String, u64)> = None;
    for (lineno, line) in text.lines().enumerate() {
        let n = lineno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if !(rest.starts_with("HELP ") || rest.starts_with("TYPE ") || rest.is_empty()) {
                return Err(format!("line {n}: comment is neither HELP nor TYPE"));
            }
            lint.help_lines += 1;
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {n}: expected `name{{labels}} value`"))?;
        let value: f64 =
            value.parse().map_err(|e| format!("line {n}: bad sample value: {e}"))?;
        let name = series.split('{').next().unwrap_or(series);
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || name.starts_with(|c: char| c.is_ascii_digit())
        {
            return Err(format!("line {n}: invalid metric name `{name}`"));
        }
        if series.contains('{') && !series.ends_with('}') {
            return Err(format!("line {n}: unterminated label block"));
        }
        lint.samples += 1;
        if let Some(base) = name.strip_suffix("_bucket") {
            let le = series
                .split("le=\"")
                .nth(1)
                .and_then(|s| s.split('"').next())
                .ok_or_else(|| format!("line {n}: bucket without le label"))?;
            let key = bucket_series_key(base, series);
            match &mut bucket_run {
                Some((k, last)) if *k == key => {
                    if value < *last as f64 {
                        return Err(format!("line {n}: bucket counts not cumulative"));
                    }
                    *last = value as u64;
                }
                _ => bucket_run = Some((key.clone(), value as u64)),
            }
            if le == "+Inf" {
                lint.histograms += 1;
                inf_count = Some((base.to_string(), value as u64));
                bucket_run = None;
            }
        } else if let Some(base) = name.strip_suffix("_count") {
            if let Some((inf_base, inf)) = &inf_count {
                if inf_base == base && value as u64 != *inf {
                    return Err(format!(
                        "line {n}: {base}_count {value} != +Inf bucket {inf}"
                    ));
                }
            }
            inf_count = None;
        }
    }
    Ok(lint)
}

/// Series identity for bucket-monotonicity: base name + labels minus le.
fn bucket_series_key(base: &str, series: &str) -> String {
    let labels = series.split('{').nth(1).unwrap_or("").trim_end_matches('}');
    let kept: Vec<&str> =
        labels.split(',').filter(|kv| !kv.starts_with("le=")).collect();
    format!("{base}{{{}}}", kept.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{EngineSpan, Stage};

    fn sample_trace() -> RequestTrace {
        RequestTrace {
            trace_id: 3,
            session: 7,
            label: "causal N=128".into(),
            operator: Some("causal"),
            device: Some("d0"),
            outcome: "served",
            stages: vec![
                Stage { name: "queued", start_ns: 1000, end_ns: 2000 },
                Stage { name: "respond", start_ns: 2500, end_ns: 2600 },
            ],
            engine_spans: vec![EngineSpan {
                engine: Engine::Dpu,
                name: "matmul 8x8x8".into(),
                start_ns: 2000.0,
                dur_ns: 300.0,
                node: 0,
                deps: 0,
            }],
        }
    }

    #[test]
    fn chrome_merges_and_validates() {
        let json = chrome(&[sample_trace()]);
        validate_json(&json).unwrap();
        assert!(json.contains(r#""process_name""#));
        assert!(json.contains(r#""name":"request""#));
        assert!(json.contains(r#""name":"DPU""#));
        assert!(json.contains(r#""cat":"stage""#));
        // The serving device gets its own summary process track.
        assert!(json.contains(r#""name":"device d0""#), "{json}");
        assert!(json.contains(r#""cat":"request""#), "{json}");
        // Rebased to the earliest stage: queued starts at ts 0.
        assert!(json.contains(r#""ts":0.000"#), "{json}");
    }

    #[test]
    fn chrome_timestamps_are_monotone() {
        let json = chrome(&[sample_trace()]);
        let mut last = f64::NEG_INFINITY;
        for part in json.split(r#""ts":"#).skip(1) {
            let ts: f64 = part.split(',').next().unwrap().parse().unwrap();
            assert!(ts >= last, "timestamps must be sorted: {ts} after {last}");
            last = ts;
        }
        assert!(last > f64::NEG_INFINITY, "at least one event");
    }

    #[test]
    fn empty_trace_set_is_valid_json() {
        let json = chrome(&[]);
        validate_json(&json).unwrap();
        let empty = ChromeTrace::new();
        assert!(empty.is_empty());
        validate_json(&empty.render()).unwrap();
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let text = jsonl(&[sample_trace()]);
        assert_eq!(text.lines().count(), 4, "{text}");
        assert!(text.contains(r#""device":"d0""#), "{text}");
        for line in text.lines() {
            validate_json(line).unwrap();
        }
    }

    #[test]
    fn prometheus_round_trips_the_registry() {
        let mut reg = MetricsRegistry::new();
        reg.describe("req_total", "requests served");
        reg.inc("req_total", &[("operator", "causal")], 3);
        reg.set_gauge("pool_pages", &[], 42.0);
        reg.observe("latency_ns", &[("operator", "causal")], 100.0);
        reg.observe("latency_ns", &[("operator", "causal")], 5000.0);
        let text = prometheus(&reg);
        let lint = lint_prometheus(&text).unwrap();
        assert_eq!(lint.histograms, 1);
        assert!(text.contains("# HELP req_total requests served"), "{text}");
        assert!(text.contains("# TYPE req_total counter"), "{text}");
        assert!(text.contains(r#"req_total{operator="causal"} 3"#), "{text}");
        assert!(text.contains("pool_pages 42"), "{text}");
        assert!(text.contains(r#"latency_ns_bucket{le="+Inf",operator="causal"} 2"#), "{text}");
        assert!(text.contains(r#"latency_ns_count{operator="causal"} 2"#), "{text}");
        assert!(text.contains(r#"latency_ns_sum{operator="causal"} 5100"#), "{text}");
    }

    #[test]
    fn lint_rejects_malformed_expositions() {
        assert!(lint_prometheus("no_value_here\n").is_err());
        assert!(lint_prometheus("name bogus\n").is_err());
        assert!(lint_prometheus("1badname 3\n").is_err());
        assert!(lint_prometheus("# FOO not help\n").is_err());
        let shrinking = "h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\nh_bucket{le=\"+Inf\"} 3\n";
        assert!(lint_prometheus(shrinking).is_err(), "non-cumulative buckets");
        let mismatched = "h_bucket{le=\"+Inf\"} 3\nh_count 4\n";
        assert!(lint_prometheus(mismatched).is_err(), "+Inf != count");
    }

    #[test]
    fn json_snapshot_is_valid() {
        let mut reg = MetricsRegistry::new();
        reg.inc("a_total", &[("k", "v")], 1);
        reg.set_gauge("g", &[], 2.5);
        reg.observe("h_ns", &[], 10.0);
        let text = json(&reg);
        validate_json(&text).unwrap();
        assert!(text.contains(r#""a_total{k=\"v\"}""#), "{text}");
        assert!(text.contains(r#""p50""#), "{text}");
        let empty = json(&MetricsRegistry::new());
        validate_json(&empty).unwrap();
    }

    #[test]
    fn validate_json_catches_breakage() {
        validate_json(r#"{"a":[1,2,{"b":null}],"c":"x\"y"}"#).unwrap();
        assert!(validate_json("[1,2,]").is_err(), "trailing comma");
        assert!(validate_json("{\"a\":}").is_err());
        assert!(validate_json("[1,2] extra").is_err());
        assert!(validate_json("").is_err());
        assert!(validate_json("[\"unterminated]").is_err());
    }
}
