//! Model-level deployment planner: from operator microbenchmarks to a
//! whole LLM on the NPU — the question the paper's §I actually motivates
//! ("can this 100K-token workload run on-device?").
//!
//! A model is L transformer layers × H heads of a causal operator plus an
//! MLP. Per-layer cost = H single-head operator graphs (simulated once,
//! heads are identical) + the MLP matmuls + projections; the planner
//! composes prefill latency, sustained decode tokens/s, persistent-state
//! footprint and energy, and renders a feasibility verdict against the
//! Table-I memory budget.

use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use crate::coordinator::state::footprint_for;
use crate::npu;
use crate::ops::{self, decode, GraphBuilder, PrimOp};

use super::energy::EnergyModel;

/// A transformer model description.
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub layers: usize,
    pub heads: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub d_state: usize,
    pub op: OperatorKind,
}

impl ModelSpec {
    /// A ~100M-parameter reference config (the scale of the repo's E2E).
    pub fn reference(op: OperatorKind) -> Self {
        Self { layers: 12, heads: 12, d_model: 768, d_ff: 3072, d_state: 16, op }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.heads
    }

    /// Parameter count (attention + MLP + embeddings excluded).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff as u64;
        self.layers as u64 * (4 * d * d + 2 * d * ff)
    }
}

/// Deployment plan for (model, context).
#[derive(Clone, Debug)]
pub struct DeployPlan {
    pub spec: ModelSpec,
    pub n: usize,
    /// Full prefill latency, ms.
    pub prefill_ms: f64,
    /// Sustained decode rate at this retained context, tokens/s.
    pub decode_tps: f64,
    /// Persistent inference state (KV cache or recurrent state), bytes.
    pub state_bytes: u64,
    /// Weights footprint at 16-bit, bytes.
    pub weight_bytes: u64,
    /// Prefill energy, J.
    pub prefill_j: f64,
    /// Fits the global memory budget?
    pub fits_memory: bool,
}

/// MLP + projection cost for one layer over `rows` tokens (DPU matmuls +
/// gelu on SHAVE), as a standalone graph.
fn mlp_graph(rows: usize, d_model: usize, d_ff: usize) -> ops::OpGraph {
    let mut b = GraphBuilder::new(format!("mlp r={rows}"));
    // QKV + output projections.
    let p1 = b.push_simple(PrimOp::MatMul { m: rows, n: 4 * d_model, k: d_model }, vec![]);
    let up = b.push_simple(PrimOp::MatMul { m: rows, n: d_ff, k: d_model }, vec![p1]);
    let act = b.push_simple(
        PrimOp::EltWise { kind: ops::EltKind::Exp, elems: rows * d_ff },
        vec![up],
    );
    let down = b.push_simple(PrimOp::MatMul { m: rows, n: d_model, k: d_ff }, vec![act]);
    let _ln = b.push_simple(
        PrimOp::EltWise { kind: ops::EltKind::Simple, elems: 4 * rows * d_model },
        vec![down],
    );
    b.finish()
}

/// Build the plan by composing simulated pieces.
pub fn plan(spec: &ModelSpec, n: usize, hw: &NpuConfig, sim: &SimConfig) -> DeployPlan {
    let w = WorkloadSpec::new(spec.op, n)
        .with_d_head(spec.d_head())
        .with_d_state(spec.d_state);

    // Prefill: per layer = H identical head graphs (serial on one NPU) +
    // the MLP block.
    let head = npu::run(&ops::lower(&w, hw, sim), hw, sim);
    let mlp = npu::run(&mlp_graph(n, spec.d_model, spec.d_ff), hw, sim);
    let layer_ns = head.span_ns * spec.heads as f64 + mlp.span_ns;
    let prefill_ns = layer_ns * spec.layers as f64;

    // Decode: one step per layer = H head steps + MLP over a single row.
    let head_step = npu::run(&decode::lower_step(&w, hw, sim), hw, sim);
    let mlp_step = npu::run(&mlp_graph(1, spec.d_model, spec.d_ff), hw, sim);
    let step_ns =
        (head_step.span_ns * spec.heads as f64 + mlp_step.span_ns) * spec.layers as f64;

    // Persistent state per Fig 1 — the registry's state-footprint growth
    // curve (the same number the session-memory pool charges at serving
    // time), summed over layers & heads. State is priced at the pool's
    // fixed convention (fp16 KV, f32 recurrent accumulators) regardless
    // of `sim.elem_bytes`: the retained cache does not requantize with
    // the compute precision under test.
    let per_head_state = footprint_for(spec.op, n, spec.d_head(), spec.d_state);
    let state_bytes = per_head_state * (spec.heads * spec.layers) as u64;
    let weight_bytes = spec.params() * sim.elem_bytes;

    let energy = EnergyModel::default();
    let prefill_j = (energy.evaluate(&head).total_j() * spec.heads as f64
        + energy.evaluate(&mlp).total_j())
        * spec.layers as f64;

    DeployPlan {
        spec: *spec,
        n,
        prefill_ms: prefill_ns / 1e6,
        decode_tps: 1e9 / step_ns,
        state_bytes,
        weight_bytes,
        prefill_j,
        fits_memory: state_bytes + weight_bytes <= hw.dram_bytes,
    }
}

/// Human-readable feasibility report across operators at one context.
pub fn feasibility_report(n: usize, hw: &NpuConfig, sim: &SimConfig) -> String {
    let mut out = format!(
        "Deployment plan: 12x768 reference model (~{}M params) at N={n}\n\
         {:<12} {:>12} {:>12} {:>12} {:>12} {:>10}\n",
        ModelSpec::reference(OperatorKind::Causal).params() / 1_000_000,
        "operator",
        "prefill ms",
        "decode t/s",
        "state",
        "energy J",
        "fits?"
    );
    for op in OperatorKind::ALL {
        let p = plan(&ModelSpec::reference(op), n, hw, sim);
        out += &format!(
            "{:<12} {:>12.1} {:>12.0} {:>12} {:>12.2} {:>10}\n",
            op.paper_name(),
            p.prefill_ms,
            p.decode_tps,
            crate::util::fmt::bytes(p.state_bytes),
            p.prefill_j,
            if p.fits_memory { "yes" } else { "NO" }
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> (NpuConfig, SimConfig) {
        (NpuConfig::default(), SimConfig::default())
    }

    #[test]
    fn reference_model_is_about_100m_params() {
        let m = ModelSpec::reference(OperatorKind::Causal);
        assert!((80..130).contains(&(m.params() / 1_000_000)), "{}", m.params());
        assert_eq!(m.d_head(), 64);
    }

    #[test]
    fn kv_state_grows_recurrent_does_not() {
        let (hw, sim) = cfg();
        let kv4 = plan(&ModelSpec::reference(OperatorKind::Causal), 4096, &hw, &sim);
        let kv16 = plan(&ModelSpec::reference(OperatorKind::Causal), 16_384, &hw, &sim);
        assert_eq!(kv16.state_bytes, 4 * kv4.state_bytes);
        let ssm4 = plan(&ModelSpec::reference(OperatorKind::Linear), 4096, &hw, &sim);
        let ssm16 = plan(&ModelSpec::reference(OperatorKind::Linear), 16_384, &hw, &sim);
        assert_eq!(ssm4.state_bytes, ssm16.state_bytes);
    }

    #[test]
    fn paper_intro_claim_kv_cache_exceeds_scratchpad_30x() {
        // §I: "at just 16K tokens the KV cache consumes over 768 MB — more
        // than 30x the capacity of leading NPUs". Our 12-layer reference is
        // smaller than Llama, but the per-scratchpad ratio is the claim.
        let (hw, sim) = cfg();
        let p = plan(&ModelSpec::reference(OperatorKind::Causal), 16_384, &hw, &sim);
        assert!(
            p.state_bytes > 30 * hw.scratchpad_bytes,
            "KV {} vs scratchpad {}",
            p.state_bytes,
            hw.scratchpad_bytes
        );
    }

    #[test]
    fn structured_operator_decodes_faster_at_long_context() {
        let (hw, sim) = cfg();
        let causal = plan(&ModelSpec::reference(OperatorKind::Causal), 16_384, &hw, &sim);
        let toe = plan(&ModelSpec::reference(OperatorKind::Toeplitz), 16_384, &hw, &sim);
        assert!(toe.decode_tps > 5.0 * causal.decode_tps);
        assert!(toe.prefill_ms < causal.prefill_ms);
    }

    #[test]
    fn report_renders_all_operators() {
        let (hw, sim) = cfg();
        let r = feasibility_report(2048, &hw, &sim);
        for op in OperatorKind::ALL {
            assert!(r.contains(op.paper_name()));
        }
    }

    #[test]
    fn prefill_energy_positive_and_bounded() {
        let (hw, sim) = cfg();
        let p = plan(&ModelSpec::reference(OperatorKind::Linear), 4096, &hw, &sim);
        assert!(p.prefill_j > 0.0);
        // Energy must be consistent with power envelope x time.
        assert!(p.prefill_j < 40.0 * p.prefill_ms / 1e3);
    }
}
