//! Roofline model with effective ceilings (paper §IV, Table VII, Fig 7).

use crate::config::WorkloadSpec;
use crate::npu::ExecReport;
use crate::ops::registry::{self, CausalOperator};

use super::calibrate::Ceilings;

/// The roofline: attainable GOP/s as a function of operational intensity.
#[derive(Clone, Copy, Debug)]
pub struct Roofline {
    pub ceilings: Ceilings,
}

/// One operator placed on the roofline.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    pub name: String,
    /// Operational intensity, ops/byte (x-axis).
    pub intensity: f64,
    /// Measured (simulated) performance, GOP/s (y-axis).
    pub measured_gops: f64,
    /// Roofline bound at this intensity, GOP/s.
    pub bound_gops: f64,
}

impl RooflinePoint {
    /// Fraction of the attainable roof actually achieved (§IV-D).
    pub fn roof_fraction(&self) -> f64 {
        if self.bound_gops == 0.0 {
            0.0
        } else {
            self.measured_gops / self.bound_gops
        }
    }

    /// Memory-bound ⇔ the intensity sits left of the inflection.
    pub fn memory_bound(&self, roofline: &Roofline) -> bool {
        self.intensity < roofline.ceilings.i_crit()
    }
}

impl Roofline {
    pub fn new(ceilings: Ceilings) -> Self {
        Self { ceilings }
    }

    /// Attainable performance at `intensity` under the effective roofs:
    /// min(π_eff, β_eff · I).
    pub fn bound_gops(&self, intensity: f64) -> f64 {
        (self.ceilings.beta_eff_gbps * intensity).min(self.ceilings.pi_eff_gops)
    }

    /// Place one simulated operator run on the roofline, resolving the
    /// workload's kind through the operator registry (canonical kernel).
    /// Intensity is the *analytical* ops/byte
    /// ([`CausalOperator::profile`] — the paper's Table VII convention);
    /// measured GOP/s is algorithmic ops over simulated time.
    pub fn place(&self, spec: &WorkloadSpec, report: &ExecReport, elem_bytes: u64) -> RooflinePoint {
        self.place_op(registry::global().for_kind(spec.op), spec, report, elem_bytes)
    }

    /// Place a specific registry operator (e.g. a variant like
    /// `retentive-chunked` whose profile differs from its kind's canonical
    /// kernel) on the roofline.
    pub fn place_op(
        &self,
        op: &dyn CausalOperator,
        spec: &WorkloadSpec,
        report: &ExecReport,
        elem_bytes: u64,
    ) -> RooflinePoint {
        let prof = op.profile(spec, elem_bytes);
        let intensity = prof.intensity();
        let measured = prof.ops as f64 / report.span_ns;
        RooflinePoint {
            name: op.paper_name().to_string(),
            intensity,
            measured_gops: measured,
            bound_gops: self.bound_gops(intensity),
        }
    }

    /// ASCII roofline plot (Fig 7): log-log axes, ceiling lines + points.
    pub fn ascii_plot(&self, points: &[RooflinePoint], width: usize, height: usize) -> String {
        let x_min: f64 = 1.0;
        let x_max: f64 = 1000.0;
        let y_min: f64 = 0.1;
        let y_max: f64 = self.ceilings.pi_nominal_gops * 2.0;
        let xpos = |v: f64| -> usize {
            let f = ((v.max(x_min).ln() - x_min.ln()) / (x_max.ln() - x_min.ln())).clamp(0.0, 1.0);
            (f * (width - 1) as f64).round() as usize
        };
        let ypos = |v: f64| -> usize {
            let f = ((v.max(y_min).ln() - y_min.ln()) / (y_max.ln() - y_min.ln())).clamp(0.0, 1.0);
            height - 1 - (f * (height - 1) as f64).round() as usize
        };
        let mut grid = vec![vec![' '; width]; height];
        // Effective roof.
        for px in 0..width {
            let i = (x_min.ln() + (x_max.ln() - x_min.ln()) * px as f64 / (width - 1) as f64).exp();
            let y = ypos(self.bound_gops(i));
            grid[y][px] = '-';
        }
        // Nominal compute peak for reference.
        let ynom = ypos(self.ceilings.pi_nominal_gops);
        for px in 0..width {
            if grid[ynom][px] == ' ' {
                grid[ynom][px] = '.';
            }
        }
        for (idx, p) in points.iter().enumerate() {
            let x = xpos(p.intensity);
            let y = ypos(p.measured_gops);
            grid[y][x] = char::from(b'A' + (idx as u8 % 26));
        }
        let mut out = String::new();
        out += &format!(
            "GOP/s (log) | roofline: pi_eff={:.0} GOP/s, beta_eff={:.2} GB/s, I_crit={:.0}\n",
            self.ceilings.pi_eff_gops,
            self.ceilings.beta_eff_gbps,
            self.ceilings.i_crit()
        );
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out += &format!("+{}\n", "-".repeat(width));
        out += " intensity 1 .. 1000 ops/byte (log)\n";
        for (idx, p) in points.iter().enumerate() {
            out += &format!(
                " {} = {} (I={:.1}, {:.1} GOP/s, {:.1}% of roof)\n",
                char::from(b'A' + (idx as u8 % 26)),
                p.name,
                p.intensity,
                p.measured_gops,
                100.0 * p.roof_fraction()
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, OperatorKind, SimConfig};
    use crate::model::calibrate::calibrate;
    use crate::{npu, ops};

    fn roofline() -> Roofline {
        Roofline::new(calibrate(&NpuConfig::default(), &SimConfig::default()))
    }

    #[test]
    fn bound_is_min_of_two_roofs() {
        let r = roofline();
        let low_i = r.bound_gops(1.0);
        assert!((low_i - r.ceilings.beta_eff_gbps).abs() < 1e-9);
        let high_i = r.bound_gops(10_000.0);
        assert!((high_i - r.ceilings.pi_eff_gops).abs() < 1e-9);
    }

    #[test]
    fn bound_monotone_in_intensity() {
        let r = roofline();
        let mut prev = 0.0;
        for i in [0.5, 1.0, 10.0, 100.0, 156.0, 500.0] {
            let b = r.bound_gops(i);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn all_operators_land_under_the_nominal_roof() {
        // Physical soundness: no simulated run may beat the *nominal*
        // roofline at its achieved (simulated-traffic) intensity. The
        // effective ceilings are pessimistic micro-pattern ceilings, not
        // hard caps — fused operators legitimately exceed them (our fused
        // retentive beats the paper's streaming kernel, see EXPERIMENTS).
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = roofline();
        for op in OperatorKind::ALL {
            let spec = crate::config::WorkloadSpec::new(op, 4096);
            let g = ops::lower(&spec, &hw, &sim);
            let rep = npu::run(&g, &hw, &sim);
            let achieved = rep.achieved_gops();
            let nominal_bound = (r.ceilings.beta_nominal_gbps * rep.intensity())
                .min(r.ceilings.pi_nominal_gops);
            assert!(
                achieved <= nominal_bound,
                "{op}: achieved {achieved:.1} GOP/s beats nominal bound {nominal_bound:.1}"
            );
        }
    }

    #[test]
    fn memory_patterns_not_flop_counts_dominate() {
        // §IV-E's closing claim: the spilling quadratic operator achieves a
        // small fraction of its effective roof despite the highest
        // intensity.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = roofline();
        let spec = crate::config::WorkloadSpec::new(OperatorKind::Causal, 4096);
        let g = ops::lower(&spec, &hw, &sim);
        let rep = npu::run(&g, &hw, &sim);
        let p = r.place(&spec, &rep, sim.elem_bytes);
        assert!(p.intensity > 50.0, "causal is intense: {:.1}", p.intensity);
        assert!(
            p.roof_fraction() < 0.5,
            "yet achieves a fraction of roof: {:.2}",
            p.roof_fraction()
        );
    }

    #[test]
    fn quadratic_ops_are_compute_side_linear_memory_side() {
        // Table VII: Causal I=61 vs Linear I=16 — both left of I_crit but
        // causal is ~4x more intense.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = roofline();
        let place = |op| {
            let spec = crate::config::WorkloadSpec::new(op, 4096);
            let g = ops::lower(&spec, &hw, &sim);
            let rep = npu::run(&g, &hw, &sim);
            r.place(&spec, &rep, sim.elem_bytes)
        };
        let causal = place(OperatorKind::Causal);
        let linear = place(OperatorKind::Linear);
        assert!(causal.intensity > 2.0 * linear.intensity);
    }

    #[test]
    fn fourier_has_worst_roof_fraction() {
        // §IV-D: Fourier 0.7 % of roof — catastrophically underutilized.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = roofline();
        let frac = |op| {
            let spec = crate::config::WorkloadSpec::new(op, 4096);
            let g = ops::lower(&spec, &hw, &sim);
            let rep = npu::run(&g, &hw, &sim);
            r.place(&spec, &rep, sim.elem_bytes).roof_fraction()
        };
        let fourier = frac(OperatorKind::Fourier);
        for op in [OperatorKind::Causal, OperatorKind::Toeplitz, OperatorKind::Linear] {
            assert!(fourier < frac(op), "fourier must be worst");
        }
    }

    #[test]
    fn variant_placement_uses_its_own_profile() {
        // A registry variant (retentive-chunked) must land on the roofline
        // with its own analytical profile, not its kind's quadratic one.
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let r = roofline();
        let reg = crate::ops::registry::global();
        let chunked = reg.get("retentive-chunked").unwrap();
        let spec = crate::config::WorkloadSpec::new(OperatorKind::Retentive, 4096);
        let rep = npu::run(&chunked.lower(&spec, &hw, &sim), &hw, &sim);
        let via_variant = r.place_op(chunked, &spec, &rep, sim.elem_bytes);
        let via_kind = r.place(&spec, &rep, sim.elem_bytes);
        assert_eq!(via_variant.name, "Ret-Chunked");
        assert_eq!(via_kind.name, "Retentive");
        assert!(
            (via_variant.intensity - via_kind.intensity).abs() > 1.0,
            "chunked profile ({}) must differ from the quadratic kernel's ({})",
            via_variant.intensity,
            via_kind.intensity
        );
    }

    #[test]
    fn ascii_plot_renders() {
        let r = roofline();
        let pts = vec![RooflinePoint {
            name: "Test".into(),
            intensity: 61.0,
            measured_gops: 21.4,
            bound_gops: r.bound_gops(61.0),
        }];
        let plot = r.ascii_plot(&pts, 60, 16);
        assert!(plot.contains('A'));
        assert!(plot.contains("I_crit"));
    }
}
