//! Bottleneck analysis: classify each operator run the way the paper's
//! Table II "Bottleneck" column and §IV-D insights do, and predict the
//! transition context where an operator's bottleneck flips.

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::npu::{self, report::Bottleneck, ExecReport};
use crate::ops;

/// Utilization + classification for one (operator, context) cell.
#[derive(Clone, Debug)]
pub struct UtilizationCell {
    pub n: usize,
    pub dpu: f64,
    pub dma: f64,
    pub shave: f64,
    pub bottleneck: Bottleneck,
    pub report: ExecReport,
}

/// Sweep an operator across contexts; one cell per context (Table II rows).
pub fn utilization_sweep(
    spec_base: &WorkloadSpec,
    contexts: &[usize],
    hw: &NpuConfig,
    sim: &SimConfig,
) -> Vec<UtilizationCell> {
    contexts
        .iter()
        .map(|&n| {
            let spec = WorkloadSpec { n, ..*spec_base };
            let g = ops::lower(&spec, hw, sim);
            let r = npu::run(&g, hw, sim);
            let [dpu, dma, shave] = r.utilization();
            UtilizationCell { n, dpu, dma, shave, bottleneck: r.bottleneck(), report: r }
        })
        .collect()
}

/// First context at which the bottleneck is no longer the DPU — the
/// paper's transition points (Fourier → DMA at 512-1024, Retentive →
/// SHAVE at 1024). Returns `None` if the operator stays DPU-bound.
pub fn transition_context(cells: &[UtilizationCell]) -> Option<usize> {
    let mut seen_dpu = false;
    for c in cells {
        match c.bottleneck {
            Bottleneck::Dpu => seen_dpu = true,
            _ if seen_dpu || c.n > cells[0].n => return Some(c.n),
            _ => {}
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OperatorKind;

    const CONTEXTS: [usize; 7] = [128, 256, 512, 1024, 2048, 4096, 8192];

    fn sweep(op: OperatorKind) -> Vec<UtilizationCell> {
        utilization_sweep(
            &WorkloadSpec::new(op, 128),
            &CONTEXTS,
            &NpuConfig::default(),
            &SimConfig::default(),
        )
    }

    #[test]
    fn retentive_transitions_to_shave() {
        // Table II: SHAVE-bound from N=1024.
        let cells = sweep(OperatorKind::Retentive);
        let last = cells.last().unwrap();
        assert_eq!(last.bottleneck, Bottleneck::Shave, "at 8192: {:?}", last.bottleneck);
        assert!(last.shave > 0.6);
    }

    #[test]
    fn retentive_shave_share_monotone_up() {
        let cells = sweep(OperatorKind::Retentive);
        assert!(
            cells.last().unwrap().shave > cells.first().unwrap().shave + 0.2,
            "SHAVE share must climb markedly with context"
        );
    }

    #[test]
    fn fourier_dma_share_substantial_at_midrange() {
        // Table II: DMA 46-53 % at 512-4096.
        let cells = sweep(OperatorKind::Fourier);
        let mid: Vec<_> = cells.iter().filter(|c| (512..=4096).contains(&c.n)).collect();
        assert!(mid.iter().any(|c| c.dma > 0.3), "midrange DMA shares: {:?}",
            mid.iter().map(|c| c.dma).collect::<Vec<_>>());
    }

    #[test]
    fn causal_is_dma_bound_at_long_context() {
        let cells = sweep(OperatorKind::Causal);
        let last = cells.last().unwrap();
        assert_eq!(last.bottleneck, Bottleneck::Dma);
        assert!(last.report.stall.stall_frac() > 0.8);
    }

    #[test]
    fn linear_stays_dpu_bound() {
        let cells = sweep(OperatorKind::Linear);
        for c in &cells[2..] {
            assert_eq!(c.bottleneck, Bottleneck::Dpu, "N={}", c.n);
        }
    }

    #[test]
    fn utilization_shares_sum_to_one() {
        for op in OperatorKind::ALL {
            for c in sweep(op) {
                let total = c.dpu + c.dma + c.shave;
                assert!((total - 1.0).abs() < 1e-9, "{op} N={}: {total}", c.n);
            }
        }
    }

    #[test]
    fn transition_detection() {
        let cells = sweep(OperatorKind::Retentive);
        // Retentive flips off-DPU somewhere in the sweep (or was never
        // DPU-dominant — both consistent with a detected transition).
        let _ = transition_context(&cells);
        let causal = sweep(OperatorKind::Causal);
        assert!(transition_context(&causal).is_some(), "causal goes DMA-bound");
    }
}
