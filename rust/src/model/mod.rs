//! Performance modeling: roofline with *effective* ceilings (paper §IV).
//!
//! - [`calibrate`] — microbenchmarks *on the simulator* that establish the
//!   effective compute ceiling π_eff and bandwidth ceiling β_eff, the way
//!   the paper derives its "5 % of nominal" numbers from measurements.
//! - [`roofline`] — the roofline model itself: bounds, inflection point,
//!   per-operator placement.
//! - [`analysis`] — bottleneck classification and the §IV-D insight checks.

pub mod analysis;
pub mod calibrate;
pub mod energy;
pub mod llm;
pub mod roofline;

pub use calibrate::{calibrate, Ceilings};
pub use energy::{EnergyModel, EnergyReport};
pub use roofline::{Roofline, RooflinePoint};
