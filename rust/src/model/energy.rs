//! Energy model — the edge constraint behind the whole paper (§I: "strict
//! power budgets", Table I: 10 TOPS @ 35 W).
//!
//! Per-engine energy intensities are derived from the 35 W envelope split
//! across the engines at full utilization, plus DRAM access energy at
//! LPDDR5X-class pJ/byte. Energy per inference = Σ busy-time × engine
//! power + DMA bytes × byte energy + idle leakage over the span. The
//! interesting output is **J/inference and inferences/J per operator** —
//! on a battery, Toeplitz vs Causal is not a 190× latency gap but also a
//! ~100× energy gap.

use crate::npu::ExecReport;

/// Engine power split of the 35 W envelope (active power, W).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub dpu_w: f64,
    pub shave_w: f64,
    pub dma_w: f64,
    /// Idle/leakage floor while the operator runs, W.
    pub idle_w: f64,
    /// DRAM access energy, pJ/byte (LPDDR5X-class, ~12 pJ/bit).
    pub dram_pj_per_byte: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // 35 W TDP: systolic array dominates, vector cores next, DMA small;
        // ~4 W idle floor for the always-on fabric.
        Self { dpu_w: 20.0, shave_w: 7.0, dma_w: 4.0, idle_w: 4.0, dram_pj_per_byte: 100.0 }
    }
}

/// Energy breakdown of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct EnergyReport {
    pub dpu_j: f64,
    pub shave_j: f64,
    pub dma_j: f64,
    pub dram_j: f64,
    pub idle_j: f64,
}

impl EnergyReport {
    pub fn total_j(&self) -> f64 {
        self.dpu_j + self.shave_j + self.dma_j + self.dram_j + self.idle_j
    }

    /// Millijoules per operator invocation.
    pub fn total_mj(&self) -> f64 {
        self.total_j() * 1e3
    }

    /// Energy efficiency: logical ops per joule needs the report's ops.
    pub fn gops_per_joule(&self, logical_ops: u64) -> f64 {
        logical_ops as f64 / 1e9 / self.total_j()
    }
}

impl EnergyModel {
    /// Evaluate the model on a simulated run.
    pub fn evaluate(&self, report: &ExecReport) -> EnergyReport {
        let s = 1e-9; // ns -> s
        EnergyReport {
            dpu_j: report.busy_ns[0] * s * self.dpu_w,
            shave_j: report.busy_ns[1] * s * self.shave_w,
            dma_j: report.busy_ns[2] * s * self.dma_w,
            dram_j: report.dma_bytes as f64 * self.dram_pj_per_byte * 1e-12,
            idle_j: report.span_ns * s * self.idle_w,
        }
    }

    /// Average power over the run (must stay under the 35 W envelope).
    pub fn average_power_w(&self, report: &ExecReport) -> f64 {
        let e = self.evaluate(report);
        e.total_j() / (report.span_ns * 1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
    use crate::{npu, ops};

    fn run(op: OperatorKind, n: usize) -> ExecReport {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let g = ops::lower(&WorkloadSpec::new(op, n), &hw, &sim);
        npu::run(&g, &hw, &sim)
    }

    #[test]
    fn average_power_within_envelope() {
        let m = EnergyModel::default();
        for op in OperatorKind::ALL {
            let p = m.average_power_w(&run(op, 4096));
            assert!(
                (3.0..36.0).contains(&p),
                "{op}: avg power {p:.1} W outside [idle, TDP]"
            );
        }
    }

    #[test]
    fn structured_operators_are_energy_proportional() {
        // Toeplitz at 8192 must cost orders of magnitude less energy than
        // causal — latency × power both favor it.
        let m = EnergyModel::default();
        let causal = m.evaluate(&run(OperatorKind::Causal, 8192)).total_j();
        let toe = m.evaluate(&run(OperatorKind::Toeplitz, 8192)).total_j();
        assert!(causal / toe > 40.0, "causal {causal:.4} J vs toeplitz {toe:.6} J");
    }

    #[test]
    fn dram_energy_visible_for_spilling_operator() {
        let m = EnergyModel::default();
        let e = m.evaluate(&run(OperatorKind::Causal, 8192));
        assert!(e.dram_j > 0.02 * e.total_j(), "spill traffic must show up in energy");
    }

    #[test]
    fn efficiency_metric_orders_operators() {
        let m = EnergyModel::default();
        let eff = |op| {
            let r = run(op, 4096);
            m.evaluate(&r).gops_per_joule(r.logical_ops)
        };
        assert!(eff(OperatorKind::Toeplitz) > eff(OperatorKind::Causal));
    }

    #[test]
    fn hw_envelope_is_consistent_with_table1() {
        let hw = NpuConfig::default();
        let m = EnergyModel::default();
        // peak compute power ~= dpu+shave+dma+idle == 35 W envelope
        let tdp = m.dpu_w + m.shave_w + m.dma_w + m.idle_w;
        assert!((tdp - 35.0).abs() < 1.0);
        // and the headline efficiency: ~10 TOPS / 35 W ≈ 0.29 TOPS/W INT8.
        let tops_per_w = hw.peak_int8_gops() / 1000.0 / tdp;
        assert!((0.2..0.4).contains(&tops_per_w));
    }
}
