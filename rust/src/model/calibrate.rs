//! Effective-ceiling calibration (paper §IV-A).
//!
//! The paper's roofline does not use nominal peaks: microbenchmarks on the
//! real NPU showed "architectural overheads limit achievable performance to
//! just 5 % of nominal". We reproduce the methodology: run two
//! microbenchmarks *on the simulator* —
//!
//! 1. **streamed matmul** — a pipeline of 128³ tile matmuls whose operands
//!    stream through DMA staging buffers (the realistic operator inner
//!    loop) → π_eff;
//! 2. **tile-buffer DMA stream** — a sequence of freshly allocated
//!    tile-buffer transfers (the §V alloc/dealloc pattern) → β_eff;
//!
//! and derive the compute/memory inflection I_crit = π_eff / β_eff.

use crate::config::{NpuConfig, SimConfig};
use crate::npu;
use crate::ops::{BufferAccess, GraphBuilder, PrimOp, TransferDir};

/// Calibrated effective ceilings.
#[derive(Clone, Copy, Debug)]
pub struct Ceilings {
    /// Effective compute ceiling, GOP/s (paper: ~500).
    pub pi_eff_gops: f64,
    /// Effective DMA bandwidth ceiling, GB/s (paper: ~3.2).
    pub beta_eff_gbps: f64,
    /// Nominal FP16 compute peak, GOP/s.
    pub pi_nominal_gops: f64,
    /// Nominal DMA bandwidth, GB/s.
    pub beta_nominal_gbps: f64,
}

impl Ceilings {
    /// Compute/memory inflection point, ops/byte (paper: ~156).
    pub fn i_crit(&self) -> f64 {
        self.pi_eff_gops / self.beta_eff_gbps
    }

    /// Fraction of nominal compute the effective ceiling reaches.
    pub fn compute_derate(&self) -> f64 {
        self.pi_eff_gops / self.pi_nominal_gops
    }

    /// Fraction of nominal bandwidth the effective ceiling reaches.
    pub fn bandwidth_derate(&self) -> f64 {
        self.beta_eff_gbps / self.beta_nominal_gbps
    }
}

/// Microbenchmark 1: tile-streamed matmul pipeline (64 tiles, operands
/// double-buffered through recycled DMA staging rings — the best-case
/// operator inner loop a hand-tuned kernel achieves).
fn streamed_matmul_gops(hw: &NpuConfig, sim: &SimConfig) -> f64 {
    let t = sim.tile;
    let tile_bytes = (t * t) as u64 * sim.elem_bytes;
    let mut b = GraphBuilder::new("calib-matmul");
    let buf = b.buffer();
    let tiles = 64;
    for _ in 0..tiles {
        // Prefetched operand tiles: pulls are independent of prior matmuls
        // (double buffering), buffers recycled (no allocation penalty).
        let t_a = b.push(
            PrimOp::Transfer { bytes: tile_bytes, dir: TransferDir::Pull, fresh_alloc: false },
            vec![],
            vec![],
            vec![BufferAccess::new(buf, tile_bytes, false)],
        );
        let t_b = b.push(
            PrimOp::Transfer { bytes: tile_bytes, dir: TransferDir::Pull, fresh_alloc: false },
            vec![],
            vec![],
            vec![BufferAccess::new(buf, tile_bytes, false)],
        );
        b.push_simple(PrimOp::MatMul { m: t, n: t, k: t }, vec![t_a, t_b]);
    }
    let g = b.finish();
    let r = npu::run(&g, hw, sim);
    g.logical_ops as f64 / r.span_ns
}

/// Microbenchmark 2: fresh tile-buffer DMA stream (64 × 64 KiB transfers).
fn dma_stream_gbps(hw: &NpuConfig, sim: &SimConfig) -> f64 {
    let bytes_per = 64 * 1024u64;
    let mut b = GraphBuilder::new("calib-dma");
    let mut prev = None;
    let n = 64;
    for _ in 0..n {
        let deps = prev.map(|p| vec![p]).unwrap_or_default();
        prev = Some(b.push_simple(
            PrimOp::Transfer { bytes: bytes_per, dir: TransferDir::Pull, fresh_alloc: true },
            deps,
        ));
    }
    let g = b.finish();
    let r = npu::run(&g, hw, sim);
    (n as u64 * bytes_per) as f64 / r.span_ns // bytes/ns == GB/s
}

/// Run both microbenchmarks and assemble the ceilings.
pub fn calibrate(hw: &NpuConfig, sim: &SimConfig) -> Ceilings {
    Ceilings {
        pi_eff_gops: streamed_matmul_gops(hw, sim),
        beta_eff_gbps: dma_stream_gbps(hw, sim),
        pi_nominal_gops: hw.peak_fp16_gops(),
        beta_nominal_gbps: hw.dma_bw_gbps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ceilings() -> Ceilings {
        calibrate(&NpuConfig::default(), &SimConfig::default())
    }

    #[test]
    fn pi_eff_lands_near_paper_500() {
        let c = ceilings();
        assert!(
            (250.0..900.0).contains(&c.pi_eff_gops),
            "pi_eff {:.0} GOP/s (paper: 500)",
            c.pi_eff_gops
        );
    }

    #[test]
    fn beta_eff_lands_near_paper_3_2() {
        let c = ceilings();
        assert!(
            (1.5..6.0).contains(&c.beta_eff_gbps),
            "beta_eff {:.2} GB/s (paper: 3.2)",
            c.beta_eff_gbps
        );
    }

    #[test]
    fn effective_is_small_fraction_of_nominal() {
        // §IV-A: ~5 % of nominal on both axes.
        let c = ceilings();
        assert!(c.compute_derate() < 0.25, "derate {:.3}", c.compute_derate());
        assert!(c.bandwidth_derate() < 0.12, "derate {:.3}", c.bandwidth_derate());
    }

    #[test]
    fn i_crit_is_order_100() {
        // Paper: ~156 ops/byte.
        let c = ceilings();
        assert!(
            (50.0..400.0).contains(&c.i_crit()),
            "I_crit {:.0} (paper: 156)",
            c.i_crit()
        );
    }

    #[test]
    fn calibration_is_deterministic() {
        let a = ceilings();
        let b = ceilings();
        assert_eq!(a.pi_eff_gops, b.pi_eff_gops);
        assert_eq!(a.beta_eff_gbps, b.beta_eff_gbps);
    }
}
