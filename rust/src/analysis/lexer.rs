//! Line/column-accurate token scanner for Rust source.
//!
//! This is *not* a parser: `npuperf lint`'s rules are token patterns
//! (`.unwrap` followed by `(`, a string literal in a call's first
//! argument slot, ...), so all the lexer has to get exactly right is the
//! part regexes cannot — comments, the full string-literal zoo (raw,
//! byte, hashed), char-vs-lifetime disambiguation, and nested block
//! comments — so a rule never fires on text the compiler would never
//! execute. Dependency-free by design: the vendored offline build has no
//! syn/proc-macro2 to lean on, and the lint must run everywhere the
//! build does.

/// Token classification. Comments are kept as tokens (pragmas live in
/// them); rule patterns run over the non-comment subsequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`foo`, `fn`, `r#type`).
    Ident,
    /// Any string literal (`"…"`, `r#"…"#`, `b"…"`); `text` is the
    /// *content* with quotes and prefixes stripped, escapes left as-is.
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Numeric literal (`42`, `0x1F`, `1.5e3`, `4096usize`).
    Num,
    /// Single punctuation character (`.`, `[`, `!`, …).
    Punct,
    /// `// …` to end of line; `text` includes the slashes.
    LineComment,
    /// `/* … */`, nesting respected; `text` includes the delimiters.
    BlockComment,
}

/// One token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

impl Token {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

struct Scanner {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    col: u32,
}

impl Scanner {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek_at(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: malformed input (unterminated string,
/// stray byte) degrades to best-effort tokens rather than an error, so a
/// half-edited file still lints instead of crashing the pass.
pub fn lex(src: &str) -> Vec<Token> {
    let mut s = Scanner { chars: src.chars().collect(), pos: 0, line: 1, col: 1 };
    let mut out = Vec::new();
    while let Some(c) = s.peek() {
        let (line, col) = (s.line, s.col);
        if c.is_whitespace() {
            s.bump();
            continue;
        }
        if c == '/' && s.peek_at(1) == Some('/') {
            let mut text = String::new();
            while let Some(c) = s.peek() {
                if c == '\n' {
                    break;
                }
                text.push(c);
                s.bump();
            }
            out.push(Token { kind: TokKind::LineComment, text, line, col });
            continue;
        }
        if c == '/' && s.peek_at(1) == Some('*') {
            let mut text = String::new();
            let mut depth = 0usize;
            while let Some(c) = s.peek() {
                if c == '/' && s.peek_at(1) == Some('*') {
                    depth += 1;
                    text.push('/');
                    text.push('*');
                    s.bump();
                    s.bump();
                } else if c == '*' && s.peek_at(1) == Some('/') {
                    depth -= 1;
                    text.push('*');
                    text.push('/');
                    s.bump();
                    s.bump();
                    if depth == 0 {
                        break;
                    }
                } else {
                    text.push(c);
                    s.bump();
                }
            }
            out.push(Token { kind: TokKind::BlockComment, text, line, col });
            continue;
        }
        // String-literal prefixes must win over plain ident scanning.
        if let Some(tok) = try_string_or_char(&mut s, line, col) {
            out.push(tok);
            continue;
        }
        if is_ident_start(c) {
            let mut text = String::new();
            while let Some(c) = s.peek() {
                if !is_ident_continue(c) {
                    break;
                }
                text.push(c);
                s.bump();
            }
            out.push(Token { kind: TokKind::Ident, text, line, col });
            continue;
        }
        if c.is_ascii_digit() {
            let mut text = String::new();
            while let Some(c) = s.peek() {
                if is_ident_continue(c) {
                    text.push(c);
                    s.bump();
                } else if c == '.' && s.peek_at(1).is_some_and(|d| d.is_ascii_digit()) {
                    // `1.5` continues the number; `1..n` does not.
                    text.push(c);
                    s.bump();
                } else {
                    break;
                }
            }
            out.push(Token { kind: TokKind::Num, text, line, col });
            continue;
        }
        s.bump();
        out.push(Token { kind: TokKind::Punct, text: c.to_string(), line, col });
    }
    out
}

/// Scan a string/char/lifetime form if one starts at the cursor:
/// `"…"`, `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `b'…'`, `'…'`, `'life`,
/// and raw identifiers `r#name`. Returns `None` when the cursor is on
/// something else (plain ident, number, punct).
fn try_string_or_char(s: &mut Scanner, line: u32, col: u32) -> Option<Token> {
    match s.peek()? {
        '\'' => Some(char_or_lifetime(s, line, col)),
        '"' => {
            s.bump();
            Some(quoted_string(s, line, col))
        }
        'r' | 'b' => {
            // Work out whether this `r`/`b` heads a literal before
            // committing — otherwise it is an ordinary identifier start.
            let (prefix_len, hashes, quote) = match (s.peek()?, s.peek_at(1)) {
                ('b', Some('\'')) => (1, 0, '\''),
                ('b', Some('"')) => (1, 0, '"'),
                ('b', Some('r')) => {
                    let h = count_hashes(s, 2);
                    match s.peek_at(2 + h) {
                        Some('"') => (2, h, '"'),
                        _ => return None,
                    }
                }
                ('r', Some('"')) => (1, 0, '"'),
                ('r', Some('#')) => {
                    let h = count_hashes(s, 1);
                    match s.peek_at(1 + h) {
                        Some('"') => (1, h, '"'),
                        // `r#name`: raw identifier, lex as Ident.
                        Some(c) if is_ident_start(c) => {
                            let mut text = String::new();
                            s.bump(); // r
                            s.bump(); // #
                            while let Some(c) = s.peek() {
                                if !is_ident_continue(c) {
                                    break;
                                }
                                text.push(c);
                                s.bump();
                            }
                            return Some(Token { kind: TokKind::Ident, text, line, col });
                        }
                        _ => return None,
                    }
                }
                _ => return None,
            };
            for _ in 0..prefix_len + hashes {
                s.bump();
            }
            s.bump(); // opening quote
            if quote == '\'' {
                return Some(char_body(s, line, col));
            }
            Some(if hashes > 0 || is_raw_prefix(s, prefix_len) {
                raw_string(s, hashes, line, col)
            } else {
                quoted_string(s, line, col)
            })
        }
        _ => None,
    }
}

/// `true` when the literal we just committed to was `r`-prefixed (no
/// escape processing); byte strings `b"…"` still process escapes.
fn is_raw_prefix(s: &Scanner, prefix_len: usize) -> bool {
    // The prefix sits immediately before the just-consumed quote.
    let quote_pos = s.pos - 1;
    (1..=prefix_len).any(|back| s.chars.get(quote_pos.wrapping_sub(back)) == Some(&'r'))
}

fn count_hashes(s: &Scanner, from: usize) -> usize {
    let mut h = 0;
    while s.peek_at(from + h) == Some('#') {
        h += 1;
    }
    h
}

/// Body of a non-raw string; the opening `"` is already consumed.
fn quoted_string(s: &mut Scanner, line: u32, col: u32) -> Token {
    let mut text = String::new();
    while let Some(c) = s.bump() {
        match c {
            '"' => break,
            '\\' => {
                text.push(c);
                if let Some(e) = s.bump() {
                    text.push(e);
                }
            }
            _ => text.push(c),
        }
    }
    Token { kind: TokKind::Str, text, line, col }
}

/// Body of a raw string; the opening `"` is consumed, `hashes` is the
/// number of `#` required after the closing quote.
fn raw_string(s: &mut Scanner, hashes: usize, line: u32, col: u32) -> Token {
    let mut text = String::new();
    while let Some(c) = s.bump() {
        if c == '"' {
            let mut seen = 0;
            while seen < hashes && s.peek() == Some('#') {
                seen += 1;
                s.bump();
            }
            if seen == hashes {
                break;
            }
            text.push('"');
            for _ in 0..seen {
                text.push('#');
            }
            continue;
        }
        text.push(c);
    }
    Token { kind: TokKind::Str, text, line, col }
}

/// Disambiguate `'a'` (char) from `'a` (lifetime) at a `'` cursor.
fn char_or_lifetime(s: &mut Scanner, line: u32, col: u32) -> Token {
    // A lifetime is `'` + ident NOT followed by a closing `'`.
    if s.peek_at(1).is_some_and(is_ident_start) && s.peek_at(2) != Some('\'') {
        s.bump(); // '
        let mut text = String::from("'");
        while let Some(c) = s.peek() {
            if !is_ident_continue(c) {
                break;
            }
            text.push(c);
            s.bump();
        }
        return Token { kind: TokKind::Lifetime, text, line, col };
    }
    s.bump(); // '
    char_body(s, line, col)
}

/// Char-literal body; the opening `'` is consumed.
fn char_body(s: &mut Scanner, line: u32, col: u32) -> Token {
    let mut text = String::new();
    match s.bump() {
        Some('\\') => {
            text.push('\\');
            if let Some(e) = s.bump() {
                text.push(e);
            }
            // `\u{…}` and friends: scan to the closing quote.
            while let Some(c) = s.bump() {
                if c == '\'' {
                    break;
                }
                text.push(c);
            }
        }
        Some(c) => {
            text.push(c);
            s.bump(); // closing '
        }
        None => {}
    }
    Token { kind: TokKind::Char, text, line, col }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_and_positions() {
        let toks = lex("foo.bar()\n  baz");
        assert_eq!(toks.len(), 6);
        assert!(toks[0].is(TokKind::Ident, "foo"));
        assert!(toks[1].is(TokKind::Punct, "."));
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[5].line, toks[5].col), (2, 3));
        assert!(toks[5].is(TokKind::Ident, "baz"));
    }

    #[test]
    fn comments_are_tokens_not_code() {
        let toks = kinds("a // unwrap()\n/* panic! /* nested */ */ b");
        assert_eq!(toks[0], (TokKind::Ident, "a".to_string()));
        assert_eq!(toks[1].0, TokKind::LineComment);
        assert!(toks[1].1.contains("unwrap"));
        assert_eq!(toks[2].0, TokKind::BlockComment);
        assert!(toks[2].1.contains("nested"));
        assert_eq!(toks[3], (TokKind::Ident, "b".to_string()));
    }

    #[test]
    fn string_zoo() {
        let toks = kinds(r####""plain" r"raw" r#"one"# b"bytes" br#"both"# "esc\"aped""####);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(strs, vec!["plain", "raw", "one", "bytes", "both", "esc\\\"aped"]);
    }

    #[test]
    fn chars_vs_lifetimes() {
        let toks = kinds("'a' 'x 'static b'\\n' '\\u{1F600}'");
        assert_eq!(toks[0], (TokKind::Char, "a".to_string()));
        assert_eq!(toks[1], (TokKind::Lifetime, "'x".to_string()));
        assert_eq!(toks[2], (TokKind::Lifetime, "'static".to_string()));
        assert_eq!(toks[3].0, TokKind::Char);
        assert_eq!(toks[4].0, TokKind::Char);
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let toks = kinds("1..n 1.5e3 0x1F 4096usize");
        assert_eq!(toks[0], (TokKind::Num, "1".to_string()));
        assert_eq!(toks[1], (TokKind::Punct, ".".to_string()));
        assert_eq!(toks[2], (TokKind::Punct, ".".to_string()));
        assert_eq!(toks[3], (TokKind::Ident, "n".to_string()));
        assert_eq!(toks[4], (TokKind::Num, "1.5e3".to_string()));
        assert_eq!(toks[5], (TokKind::Num, "0x1F".to_string()));
        assert_eq!(toks[6], (TokKind::Num, "4096usize".to_string()));
    }

    #[test]
    fn raw_identifier() {
        let toks = kinds("r#type r#fn");
        assert_eq!(toks[0], (TokKind::Ident, "type".to_string()));
        assert_eq!(toks[1], (TokKind::Ident, "fn".to_string()));
    }

    #[test]
    fn string_spanning_metric_name_is_one_token() {
        let toks = kinds(r#"reg.inc("some_metric_total", &[])"#);
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Str && t == "some_metric_total"));
    }
}
