//! Intra-crate call graph over the [`super::parser`] ASTs.
//!
//! Resolution is name-based and deliberately over-approximate: a call
//! edge is added for every plausible callee, so reachability never
//! misses a real chain at the cost of occasional fan-out through
//! same-named methods (`.place(…)` links to every `place` method with a
//! `self` receiver). The rules that consume the graph treat it
//! accordingly — panic-reachability findings on over-approximate chains
//! are waivable with a reason, and resolution that fails entirely just
//! drops the edge.
//!
//! Only non-test functions from `rust/src/` participate: test fns,
//! benches, and examples have their own entry points and are not part
//! of the serve path.

use std::collections::{BTreeMap, BTreeSet};

use super::parser::{file_module, FileAst, FnDef};

/// The graph: `fns[id] = (file_path, fn)` with `edges[id]` the sorted,
/// deduplicated callee ids.
pub struct CallGraph<'a> {
    pub fns: Vec<(&'a str, &'a FnDef)>,
    pub edges: Vec<Vec<usize>>,
}

impl<'a> CallGraph<'a> {
    /// Build from per-file ASTs (callers must pass them in a
    /// deterministic order — the fn ids follow it).
    pub fn build(asts: &'a [FileAst]) -> Self {
        let mut fns: Vec<(&str, &FnDef)> = Vec::new();
        let mut free: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut methods: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for ast in asts {
            if file_module(&ast.path).is_none() {
                continue;
            }
            for fd in &ast.fns {
                if fd.is_test {
                    continue;
                }
                let fid = fns.len();
                fns.push((ast.path.as_str(), fd));
                if fd.impl_type.is_some() {
                    methods.entry(fd.name.as_str()).or_default().push(fid);
                } else {
                    free.entry(fd.name.as_str()).or_default().push(fid);
                }
            }
        }
        let mut g = CallGraph { fns, edges: Vec::new() };
        g.edges = (0..g.fns.len()).map(|fid| g.resolve(fid, &free, &methods)).collect();
        g
    }

    fn resolve(
        &self,
        fid: usize,
        free: &BTreeMap<&str, Vec<usize>>,
        methods: &BTreeMap<&str, Vec<usize>>,
    ) -> Vec<usize> {
        let (path, fd) = self.fns[fid];
        let mut out: Vec<usize> = Vec::new();
        for call in &fd.calls {
            let segs: Vec<&str> = call
                .path
                .iter()
                .map(String::as_str)
                .filter(|s| !matches!(*s, "crate" | "self" | "super"))
                .collect();
            let Some((&name, quals)) = segs.split_last() else {
                continue;
            };
            if quals.is_empty() {
                // Bare call: a free fn in the same file wins; otherwise
                // only a crate-unique name resolves.
                let cands = free.get(name).map(Vec::as_slice).unwrap_or(&[]);
                let same_file: Vec<usize> =
                    cands.iter().copied().filter(|&i| self.fns[i].0 == path).collect();
                if !same_file.is_empty() {
                    out.extend(same_file);
                } else if cands.len() == 1 {
                    out.extend_from_slice(cands);
                }
                continue;
            }
            let mut qlast = quals[quals.len() - 1];
            if qlast == "Self" {
                if let Some(ty) = &fd.impl_type {
                    qlast = ty;
                }
            }
            if qlast.chars().next().is_some_and(|c| c.is_uppercase()) {
                // `Type::name(…)` — methods and associated fns of Type.
                out.extend(
                    methods
                        .get(name)
                        .map(Vec::as_slice)
                        .unwrap_or(&[])
                        .iter()
                        .copied()
                        .filter(|&i| self.fns[i].1.impl_type.as_deref() == Some(qlast)),
                );
                continue;
            }
            // `module::name(…)` — free fns whose module path contains
            // every qualifier segment (subset match survives re-exports).
            out.extend(
                free.get(name).map(Vec::as_slice).unwrap_or(&[]).iter().copied().filter(|&i| {
                    quals.iter().all(|q| self.fns[i].1.module.iter().any(|m| m == q))
                }),
            );
        }
        for m in &fd.methods {
            // `.name(…)` — only methods with a `self` receiver, so an
            // associated fn sharing a name with a std method (e.g.
            // `SourceFile::parse` vs `.parse::<u64>()`) gains no edge.
            let cands: Vec<usize> = methods
                .get(m.name.as_str())
                .map(Vec::as_slice)
                .unwrap_or(&[])
                .iter()
                .copied()
                .filter(|&i| self.fns[i].1.has_self)
                .collect();
            if m.recv_root.as_deref() == Some("self") {
                if let Some(ty) = &fd.impl_type {
                    let own: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&i| self.fns[i].1.impl_type.as_deref() == Some(ty.as_str()))
                        .collect();
                    if !own.is_empty() {
                        out.extend(own);
                        continue;
                    }
                }
            }
            out.extend(cands);
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// BFS from `starts`; returns `reached fn id → parent id` (entry
    /// points map to `None`). Deterministic: starts and neighbor lists
    /// are visited in sorted order, so parent chains are stable.
    pub fn reachable_from(&self, starts: &[usize]) -> BTreeMap<usize, Option<usize>> {
        let mut parent: BTreeMap<usize, Option<usize>> = BTreeMap::new();
        let mut queue: Vec<usize> = Vec::new();
        let mut sorted_starts: Vec<usize> = starts.to_vec();
        sorted_starts.sort_unstable();
        for s in sorted_starts {
            if let std::collections::btree_map::Entry::Vacant(e) = parent.entry(s) {
                e.insert(None);
                queue.push(s);
            }
        }
        let mut qi = 0;
        while qi < queue.len() {
            let cur = queue[qi];
            qi += 1;
            for &nb in &self.edges[cur] {
                if let std::collections::btree_map::Entry::Vacant(e) = parent.entry(nb) {
                    e.insert(Some(cur));
                    queue.push(nb);
                }
            }
        }
        parent
    }

    /// Transitive callers of `targets` (targets included).
    pub fn callers_closure(&self, targets: &BTreeSet<usize>) -> BTreeSet<usize> {
        let mut rev: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (fid, nbs) in self.edges.iter().enumerate() {
            for &nb in nbs {
                rev.entry(nb).or_default().push(fid);
            }
        }
        let mut seen = targets.clone();
        let mut queue: Vec<usize> = targets.iter().copied().collect();
        let mut qi = 0;
        while qi < queue.len() {
            let cur = queue[qi];
            qi += 1;
            for &nb in rev.get(&cur).map(Vec::as_slice).unwrap_or(&[]) {
                if seen.insert(nb) {
                    queue.push(nb);
                }
            }
        }
        seen
    }

    /// Shortest discovered call chain to `fid`, root first, as
    /// fully-qualified names.
    pub fn chain(&self, parent: &BTreeMap<usize, Option<usize>>, fid: usize) -> Vec<String> {
        let mut names = Vec::new();
        let mut cur = Some(fid);
        while let Some(c) = cur {
            names.push(self.fns[c].1.qualified());
            cur = parent.get(&c).copied().flatten();
        }
        names.reverse();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parser::parse_file;
    use crate::analysis::source::SourceFile;

    fn asts(srcs: &[(&str, &str)]) -> Vec<FileAst> {
        srcs.iter().map(|(p, s)| parse_file(&SourceFile::parse(p, s))).collect()
    }

    fn find(g: &CallGraph, name: &str) -> usize {
        g.fns.iter().position(|(_, fd)| fd.name == name).expect(name)
    }

    #[test]
    fn transitive_chain_crosses_files_and_impls() {
        let a = asts(&[
            (
                "rust/src/coordinator/dispatch.rs",
                "pub struct D;\nimpl D { pub fn dispatch(&self) { crate::ops::lower_all(); } }\n",
            ),
            (
                "rust/src/ops/mod.rs",
                "pub fn lower_all() { helper(); }\nfn helper() { boom(); }\n",
            ),
            ("rust/src/ops/causal.rs", "pub fn boom() { panic!(\"x\"); }\n"),
        ]);
        let g = CallGraph::build(&a);
        let entry = find(&g, "dispatch");
        let target = find(&g, "boom");
        let parent = g.reachable_from(&[entry]);
        assert!(parent.contains_key(&target));
        let chain = g.chain(&parent, target);
        assert_eq!(
            chain,
            vec![
                "coordinator::dispatch::D::dispatch".to_string(),
                "ops::lower_all".to_string(),
                "ops::helper".to_string(),
                "ops::causal::boom".to_string(),
            ],
            "every frame of the chain is named"
        );
    }

    #[test]
    fn dot_calls_do_not_resolve_to_associated_fns() {
        let a = asts(&[
            (
                "rust/src/a.rs",
                "pub struct S;\nimpl S { pub fn parse(path: &str) { bad(); } }\nfn bad() {}\n",
            ),
            ("rust/src/b.rs", "pub fn go(s: &str) { s.parse(); }\n"),
        ]);
        let g = CallGraph::build(&a);
        let go = find(&g, "go");
        assert!(
            g.edges[go].is_empty(),
            "`.parse()` must not link to the associated fn S::parse"
        );
        let qual = asts(&[
            (
                "rust/src/a.rs",
                "pub struct S;\nimpl S { pub fn parse(path: &str) { } }\n",
            ),
            ("rust/src/b.rs", "pub fn go() { S::parse(\"x\"); }\n"),
        ]);
        let g2 = CallGraph::build(&qual);
        let go2 = find(&g2, "go");
        assert_eq!(g2.edges[go2].len(), 1, "qualified Type::assoc does resolve");
    }

    #[test]
    fn self_calls_prefer_the_enclosing_impl() {
        let a = asts(&[(
            "rust/src/a.rs",
            "pub struct A; pub struct B;\n\
             impl A { pub fn go(&self) { self.step(); } fn step(&self) {} }\n\
             impl B { fn step(&self) {} }\n",
        )]);
        let g = CallGraph::build(&a);
        let go = find(&g, "go");
        assert_eq!(g.edges[go].len(), 1);
        let callee = g.edges[go][0];
        assert_eq!(g.fns[callee].1.impl_type.as_deref(), Some("A"));
    }

    #[test]
    fn test_fns_and_non_src_files_are_excluded() {
        let a = asts(&[
            (
                "rust/src/a.rs",
                "#[cfg(test)]\nmod tests { fn t() {} }\npub fn live() {}\n",
            ),
            ("rust/benches/b.rs", "fn bench_body() { live(); }\n"),
        ]);
        let g = CallGraph::build(&a);
        assert_eq!(g.fns.len(), 1);
        assert_eq!(g.fns[0].1.name, "live");
    }

    #[test]
    fn callers_closure_walks_reverse_edges() {
        let a = asts(&[(
            "rust/src/a.rs",
            "pub fn top() { mid(); }\nfn mid() { emit(); }\nfn emit() {}\nfn unrelated() {}\n",
        )]);
        let g = CallGraph::build(&a);
        let emit = find(&g, "emit");
        let closure = g.callers_closure(&BTreeSet::from([emit]));
        assert_eq!(closure.len(), 3);
        assert!(!closure.contains(&find(&g, "unrelated")));
    }
}
