//! The five `npuperf lint` rules, as token patterns over
//! [`SourceFile`]s. Each rule documents its scope precisely; all of them
//! respect `lint:allow` pragmas (see [`super::source`]) except the
//! `pragma` meta-rule, which reports waiver misuse itself.
//!
//! Scope conventions:
//!
//! - rules 1–4 are about *shipping* code: they skip `#[cfg(test)]` /
//!   `#[test]` regions and whole files under `rust/tests/`;
//! - rule 5 (`golden-fixture-hygiene`) is about *test* code and scans
//!   everything, test regions included, except the blessed
//!   `testkit/golden.rs` implementation.

use std::collections::BTreeMap;

use super::lexer::TokKind;
use super::report::Finding;
use super::source::SourceFile;

pub const NO_WALL_CLOCK: &str = "no-wall-clock";
pub const NO_PANIC: &str = "no-panic-serve-path";
pub const METRIC_NAMES: &str = "metric-names-single-source";
pub const LABEL_SETS: &str = "label-set-consistency";
pub const GOLDEN_HYGIENE: &str = "golden-fixture-hygiene";
/// Meta-rule for malformed `lint:allow` pragmas (not waivable).
pub const PRAGMA: &str = "pragma";

/// Rules a `lint:allow` pragma may name.
pub const RULE_NAMES: [&str; 5] =
    [NO_WALL_CLOCK, NO_PANIC, METRIC_NAMES, LABEL_SETS, GOLDEN_HYGIENE];

// Spelled in halves so the lint's own source does not trip the rules it
// implements (rule 3 flags string literals with the metric prefix; rule
// 5 flags strings naming the golden directory).
const METRIC_PREFIX: &str = concat!("npu", "perf_");
const GOLDEN_DIR_FRAGMENT: &str = concat!("tests/", "golden");

/// The file allowed to read host time.
const CLOCK_FILE: &str = "coordinator/clock.rs";
/// The file defining `metrics::names` (the single metric-name source).
const NAMES_FILE: &str = "coordinator/metrics.rs";
/// The blessed golden-fixture implementation.
const GOLDEN_IMPL_FILE: &str = "testkit/golden.rs";

/// Identifiers that read the host clock.
const WALL_IDENTS: [&str; 3] = ["Instant", "SystemTime", "UNIX_EPOCH"];

/// Serve-path modules rule 2 protects.
const SERVE_PATH_FILES: [&str; 4] = [
    "coordinator/server.rs",
    "coordinator/dispatch.rs",
    "coordinator/batcher.rs",
    "coordinator/state.rs",
];

/// `MetricsRegistry` record methods whose first argument is a metric
/// name and second a label array.
const RECORD_METHODS: [&str; 4] = ["inc", "observe", "set_gauge", "set_counter"];

/// Keywords that rule out the `ident[` indexing pattern (e.g.
/// `for x in [a, b]` is an array literal, not an index).
const KEYWORDS: [&str; 24] = [
    "as", "box", "break", "const", "continue", "dyn", "else", "enum", "fn", "for", "if", "impl",
    "in", "let", "loop", "match", "mod", "move", "mut", "ref", "return", "static", "while",
    "where",
];

/// Metric names declared in `metrics::names`: const ident → value, plus
/// the declaration site of each value for doc-sync diagnostics.
#[derive(Debug, Default)]
pub struct NamesIndex {
    pub consts: BTreeMap<String, String>,
    pub entries: Vec<(String, u32)>,
    pub file: Option<String>,
}

/// Run every rule over `files`; `observability_doc` (the text of
/// `docs/OBSERVABILITY.md`) enables the cross-artifact half of rule 3.
pub fn run_all(files: &[SourceFile], observability_doc: Option<&str>) -> Vec<Finding> {
    let mut findings = Vec::new();
    let names = extract_metric_names(files);
    for f in files {
        pragma_misuse(f, &mut findings);
        no_wall_clock(f, &mut findings);
        no_panic_serve_path(f, &mut findings);
        metric_name_literals(f, &mut findings);
        golden_hygiene(f, &mut findings);
    }
    label_set_consistency(files, &names, &mut findings);
    if let Some(doc) = observability_doc {
        doc_sync(&names, doc, &mut findings);
    }
    findings
}

fn emit(
    findings: &mut Vec<Finding>,
    f: &SourceFile,
    rule: &'static str,
    line: u32,
    col: u32,
    message: String,
) {
    let allowed = f.allow(rule, line).map(str::to_string);
    findings.push(Finding { rule, file: f.path.clone(), line, col, message, allowed });
}

/// Meta-rule: malformed pragmas are findings, never waivable.
fn pragma_misuse(f: &SourceFile, findings: &mut Vec<Finding>) {
    for bp in &f.bad_pragmas {
        findings.push(Finding {
            rule: PRAGMA,
            file: f.path.clone(),
            line: bp.line,
            col: bp.col,
            message: bp.message.clone(),
            allowed: None,
        });
    }
}

/// Rule 1: host-time reads are confined to `coordinator::clock`.
fn no_wall_clock(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.is_test_file || f.path.ends_with(CLOCK_FILE) {
        return;
    }
    for &ti in &f.code {
        let t = &f.tokens[ti];
        if t.kind == TokKind::Ident
            && WALL_IDENTS.contains(&t.text.as_str())
            && !f.in_test_region(t.line)
        {
            emit(
                findings,
                f,
                NO_WALL_CLOCK,
                t.line,
                t.col,
                format!(
                    "`{}` reads host time; inject `coordinator::Clock` instead \
                     (only {CLOCK_FILE} may touch std::time)",
                    t.text
                ),
            );
        }
    }
}

fn on_serve_path(path: &str) -> bool {
    SERVE_PATH_FILES.iter().any(|s| path.ends_with(s))
        || path.contains("src/memory/")
        || path.contains("src/obs/")
}

/// Rule 2: no panicking constructs on the serve path.
fn no_panic_serve_path(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.is_test_file || !on_serve_path(&f.path) {
        return;
    }
    let tok = |ci: usize| &f.tokens[f.code[ci]];
    for ci in 0..f.code.len() {
        let t = tok(ci);
        if f.in_test_region(t.line) {
            continue;
        }
        // `.unwrap()` / `.expect(` — method position only, so a free
        // function named `expect` does not trip it.
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && ci > 0
            && tok(ci - 1).is(TokKind::Punct, ".")
            && ci + 1 < f.code.len()
            && tok(ci + 1).is(TokKind::Punct, "(")
        {
            emit(
                findings,
                f,
                NO_PANIC,
                t.line,
                t.col,
                format!(".{}() can panic on the serve path; return an error instead", t.text),
            );
            continue;
        }
        if t.kind == TokKind::Ident
            && t.text == "panic"
            && ci + 1 < f.code.len()
            && tok(ci + 1).is(TokKind::Punct, "!")
        {
            emit(
                findings,
                f,
                NO_PANIC,
                t.line,
                t.col,
                "panic! on the serve path; return an error instead".to_string(),
            );
            continue;
        }
        // `expr[index]` with a variable index: `xs[i]`, `map[&key]`,
        // `b[*pos]`. Conservative: the indexed expression must end in an
        // identifier, `)`, or `]`, and the index must be a lone
        // (possibly `&`/`*`-prefixed) identifier.
        if t.is(TokKind::Punct, "[") && ci > 0 {
            let prev = tok(ci - 1);
            let indexes_expr = (prev.kind == TokKind::Ident
                && !KEYWORDS.contains(&prev.text.as_str()))
                || prev.is(TokKind::Punct, ")")
                || prev.is(TokKind::Punct, "]");
            if !indexes_expr {
                continue;
            }
            let mut j = ci + 1;
            if j < f.code.len()
                && (tok(j).is(TokKind::Punct, "&") || tok(j).is(TokKind::Punct, "*"))
            {
                j += 1;
            }
            if j + 1 < f.code.len()
                && tok(j).kind == TokKind::Ident
                && !KEYWORDS.contains(&tok(j).text.as_str())
                && tok(j + 1).is(TokKind::Punct, "]")
            {
                emit(
                    findings,
                    f,
                    NO_PANIC,
                    t.line,
                    t.col,
                    format!(
                        "indexing `[{}]` can panic on the serve path; use .get()",
                        tok(j).text
                    ),
                );
            }
        }
    }
}

/// Rule 3 (definition half): metric-name string literals may only appear
/// in `metrics::names` — everywhere else, use the constant.
fn metric_name_literals(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.is_test_file || f.path.ends_with(NAMES_FILE) {
        return;
    }
    for &ti in &f.code {
        let t = &f.tokens[ti];
        if t.kind == TokKind::Str
            && t.text.starts_with(METRIC_PREFIX)
            && !f.in_test_region(t.line)
        {
            emit(
                findings,
                f,
                METRIC_NAMES,
                t.line,
                t.col,
                format!(
                    "metric name literal \"{}\" outside metrics::names; use the constant",
                    t.text
                ),
            );
        }
    }
}

/// Rule 5: nothing outside `testkit::golden` names the golden fixture
/// directory — tests must go through the bless/compare helpers.
fn golden_hygiene(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.path.ends_with(GOLDEN_IMPL_FILE) {
        return;
    }
    for &ti in &f.code {
        let t = &f.tokens[ti];
        if t.kind == TokKind::Str && t.text.contains(GOLDEN_DIR_FRAGMENT) {
            emit(
                findings,
                f,
                GOLDEN_HYGIENE,
                t.line,
                t.col,
                format!(
                    "path \"{}\" names the golden fixture directory; route fixture I/O \
                     through testkit::golden",
                    t.text
                ),
            );
        }
    }
}

/// Find `pub mod names { … }` in the names file and index its consts.
pub fn extract_metric_names(files: &[SourceFile]) -> NamesIndex {
    let mut idx = NamesIndex::default();
    let Some(f) = files.iter().find(|f| f.path.ends_with(NAMES_FILE)) else {
        return idx;
    };
    idx.file = Some(f.path.clone());
    let tok = |ci: usize| &f.tokens[f.code[ci]];
    // Locate `mod names {`.
    let mut start = None;
    for ci in 0..f.code.len().saturating_sub(2) {
        if tok(ci).is(TokKind::Ident, "mod")
            && tok(ci + 1).is(TokKind::Ident, "names")
            && tok(ci + 2).is(TokKind::Punct, "{")
        {
            start = Some(ci + 2);
            break;
        }
    }
    let Some(open) = start else {
        return idx;
    };
    let mut depth = 0usize;
    let mut ci = open;
    while ci < f.code.len() {
        let t = tok(ci);
        if t.is(TokKind::Punct, "{") {
            depth += 1;
        } else if t.is(TokKind::Punct, "}") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if t.is(TokKind::Ident, "const") && ci + 1 < f.code.len() {
            let name = tok(ci + 1).text.clone();
            // Scan to the `=` then take the string value.
            let mut j = ci + 2;
            while j < f.code.len() && !tok(j).is(TokKind::Punct, "=") {
                j += 1;
            }
            if j + 1 < f.code.len() && tok(j + 1).kind == TokKind::Str {
                let value = tok(j + 1).text.clone();
                idx.entries.push((value.clone(), tok(j + 1).line));
                idx.consts.insert(name, value);
            }
        }
        ci += 1;
    }
    idx
}

/// One record call site: where, and with which sorted label keys.
struct LabelSite {
    file: String,
    line: u32,
    col: u32,
    keys: Vec<String>,
    allowed: Option<String>,
}

/// Rule 4: every record call site of a metric uses the same label keys.
///
/// Only *literal* `&[("key", …), …]` label arrays participate; sites
/// passing a label slice through a variable are skipped (the lint is
/// token-level, not data-flow). Empty `&[]` label sets are exempt — the
/// fleet-aggregate convention records the same name both per-device and
/// unlabeled.
fn label_set_consistency(files: &[SourceFile], names: &NamesIndex, findings: &mut Vec<Finding>) {
    let mut first_site: BTreeMap<String, LabelSite> = BTreeMap::new();
    for f in files {
        if f.is_test_file {
            continue;
        }
        let tok = |ci: usize| &f.tokens[f.code[ci]];
        for ci in 0..f.code.len() {
            let t = tok(ci);
            if !(t.kind == TokKind::Ident
                && RECORD_METHODS.contains(&t.text.as_str())
                && ci > 0
                && tok(ci - 1).is(TokKind::Punct, ".")
                && ci + 1 < f.code.len()
                && tok(ci + 1).is(TokKind::Punct, "("))
            {
                continue;
            }
            if f.in_test_region(t.line) {
                continue;
            }
            let Some((args, _close)) = split_args(f, ci + 1) else {
                continue;
            };
            if args.len() < 2 {
                continue;
            }
            let Some(name) = resolve_name(f, &args[0], names) else {
                continue;
            };
            let Some(keys) = literal_label_keys(f, &args[1]) else {
                continue;
            };
            if keys.is_empty() {
                continue;
            }
            let site = LabelSite {
                file: f.path.clone(),
                line: t.line,
                col: t.col,
                keys,
                allowed: f.allow(LABEL_SETS, t.line).map(str::to_string),
            };
            match first_site.get(&name) {
                None => {
                    first_site.insert(name, site);
                }
                Some(prev) if prev.keys == site.keys => {}
                Some(prev) => {
                    let allowed = site.allowed.clone().or_else(|| prev.allowed.clone());
                    findings.push(Finding {
                        rule: LABEL_SETS,
                        file: site.file,
                        line: site.line,
                        col: site.col,
                        message: format!(
                            "metric `{name}` recorded with label keys [{}] here but [{}] at \
                             {}:{}",
                            site.keys.join(", "),
                            prev.keys.join(", "),
                            prev.file,
                            prev.line
                        ),
                        allowed,
                    });
                }
            }
        }
    }
}

/// Split the argument tokens of a call whose `(` sits at code index
/// `open`. Returns per-argument spans of code indices and the index of
/// the matching `)`.
fn split_args(f: &SourceFile, open: usize) -> Option<(Vec<Vec<usize>>, usize)> {
    let tok = |ci: usize| &f.tokens[f.code[ci]];
    let mut depth = 0usize;
    let mut args: Vec<Vec<usize>> = vec![Vec::new()];
    let mut ci = open;
    while ci < f.code.len() {
        let t = tok(ci);
        let open_delim = t.kind == TokKind::Punct && matches!(t.text.as_str(), "(" | "[" | "{");
        let close_delim = t.kind == TokKind::Punct && matches!(t.text.as_str(), ")" | "]" | "}");
        if open_delim {
            depth += 1;
            if depth > 1 {
                args.last_mut()?.push(ci);
            }
        } else if close_delim {
            depth = depth.checked_sub(1)?;
            if depth == 0 {
                if args.last().is_some_and(Vec::is_empty) {
                    args.pop();
                }
                return Some((args, ci));
            }
            args.last_mut()?.push(ci);
        } else if depth == 1 && t.is(TokKind::Punct, ",") {
            args.push(Vec::new());
        } else {
            args.last_mut()?.push(ci);
        }
        ci += 1;
    }
    None
}

/// Resolve a call's first argument to a metric name: either a string
/// literal with the metric prefix, or a `names::CONST` path looked up
/// in the extracted index.
fn resolve_name(f: &SourceFile, arg: &[usize], names: &NamesIndex) -> Option<String> {
    let toks: Vec<_> = arg.iter().map(|&ci| &f.tokens[f.code[ci]]).collect();
    if let Some(t) = toks.iter().find(|t| t.kind == TokKind::Str) {
        if t.text.starts_with(METRIC_PREFIX) {
            return Some(t.text.clone());
        }
        return None;
    }
    for w in 0..toks.len() {
        if toks[w].is(TokKind::Ident, "names")
            && toks.get(w + 1).is_some_and(|t| t.is(TokKind::Punct, ":"))
            && toks.get(w + 2).is_some_and(|t| t.is(TokKind::Punct, ":"))
        {
            if let Some(c) = toks.get(w + 3).filter(|t| t.kind == TokKind::Ident) {
                return Some(
                    names
                        .consts
                        .get(&c.text)
                        .cloned()
                        .unwrap_or_else(|| format!("names::{}", c.text)),
                );
            }
        }
    }
    None
}

/// Extract sorted label keys from a *literal* `&[("key", …), …]` second
/// argument; `None` when the labels are not a literal array.
fn literal_label_keys(f: &SourceFile, arg: &[usize]) -> Option<Vec<String>> {
    let toks: Vec<_> = arg.iter().map(|&ci| &f.tokens[f.code[ci]]).collect();
    let mut i = 0;
    while i < toks.len() && toks[i].is(TokKind::Punct, "&") {
        i += 1;
    }
    if !toks.get(i)?.is(TokKind::Punct, "[") {
        return None;
    }
    let mut keys = Vec::new();
    let mut j = i + 1;
    while j < toks.len() {
        if toks[j].is(TokKind::Punct, "]") {
            keys.sort();
            return Some(keys);
        }
        if toks[j].is(TokKind::Punct, "(") {
            if let Some(t) = toks.get(j + 1) {
                if t.kind == TokKind::Str {
                    keys.push(t.text.clone());
                }
            }
        }
        j += 1;
    }
    None
}

/// Rule 3 (doc half): every declared metric name appears in
/// `docs/OBSERVABILITY.md`.
fn doc_sync(names: &NamesIndex, doc: &str, findings: &mut Vec<Finding>) {
    let Some(file) = &names.file else {
        return;
    };
    for (value, line) in &names.entries {
        if !doc.contains(value.as_str()) {
            findings.push(Finding {
                rule: METRIC_NAMES,
                file: file.clone(),
                line: *line,
                col: 1,
                message: format!("metric `{value}` is not documented in docs/OBSERVABILITY.md"),
                allowed: None,
            });
        }
    }
}
