//! The `npuperf lint` rules. Rules 1–5 are token patterns over
//! [`SourceFile`]s; rules 6–8 are semantic, consuming the
//! [`super::parser`] AST and [`super::callgraph`]. Each rule documents
//! its scope precisely; all of them respect `lint:allow` pragmas (see
//! [`super::source`]) except the `pragma` meta-rule, which reports
//! waiver misuse itself.
//!
//! Scope conventions:
//!
//! - rules 1–4 and 6–8 are about *shipping* code: they skip
//!   `#[cfg(test)]` / `#[test]` regions and whole files under
//!   `rust/tests/`;
//! - rule 5 (`golden-fixture-hygiene`) is about *test* code and scans
//!   everything, test regions included, except the blessed
//!   `testkit/golden.rs` implementation;
//! - `rust/benches/` and `examples/` are scanned by every applicable
//!   rule except `no-wall-clock` — they measure host time by design.

use std::collections::{BTreeMap, BTreeSet};

use super::callgraph::CallGraph;
use super::lexer::TokKind;
use super::parser::{parse_file, FileAst};
use super::report::Finding;
use super::source::SourceFile;

pub const NO_WALL_CLOCK: &str = "no-wall-clock";
pub const NO_PANIC: &str = "no-panic-serve-path";
pub const METRIC_NAMES: &str = "metric-names-single-source";
pub const LABEL_SETS: &str = "label-set-consistency";
pub const GOLDEN_HYGIENE: &str = "golden-fixture-hygiene";
pub const PANIC_REACH: &str = "panic-reachability";
pub const UNIT_CONSISTENCY: &str = "unit-consistency";
pub const NONDET_ITER: &str = "nondet-iteration";
/// Meta-rule for malformed `lint:allow` pragmas (not waivable).
pub const PRAGMA: &str = "pragma";

/// Rules a `lint:allow` pragma may name.
pub const RULE_NAMES: [&str; 8] = [
    NO_WALL_CLOCK,
    NO_PANIC,
    METRIC_NAMES,
    LABEL_SETS,
    GOLDEN_HYGIENE,
    PANIC_REACH,
    UNIT_CONSISTENCY,
    NONDET_ITER,
];

// Spelled in halves so the lint's own source does not trip the rules it
// implements (rule 3 flags string literals with the metric prefix; rule
// 5 flags strings naming the golden directory).
const METRIC_PREFIX: &str = concat!("npu", "perf_");
const GOLDEN_DIR_FRAGMENT: &str = concat!("tests/", "golden");

/// The file allowed to read host time.
const CLOCK_FILE: &str = "coordinator/clock.rs";
/// The file defining `metrics::names` (the single metric-name source).
const NAMES_FILE: &str = "coordinator/metrics.rs";
/// The blessed golden-fixture implementation.
const GOLDEN_IMPL_FILE: &str = "testkit/golden.rs";

/// Identifiers that read the host clock.
const WALL_IDENTS: [&str; 3] = ["Instant", "SystemTime", "UNIX_EPOCH"];

/// Serve-path modules rule 2 protects.
const SERVE_PATH_FILES: [&str; 4] = [
    "coordinator/server.rs",
    "coordinator/dispatch.rs",
    "coordinator/batcher.rs",
    "coordinator/state.rs",
];

/// `MetricsRegistry` record methods whose first argument is a metric
/// name and second a label array.
const RECORD_METHODS: [&str; 4] = ["inc", "observe", "set_gauge", "set_counter"];

/// Keywords that rule out the `ident[` indexing pattern (e.g.
/// `for x in [a, b]` is an array literal, not an index).
const KEYWORDS: [&str; 24] = [
    "as", "box", "break", "const", "continue", "dyn", "else", "enum", "fn", "for", "if", "impl",
    "in", "let", "loop", "match", "mod", "move", "mut", "ref", "return", "static", "while",
    "where",
];

/// Metric names declared in `metrics::names`: const ident → value, plus
/// the declaration site of each value for doc-sync diagnostics.
#[derive(Debug, Default)]
pub struct NamesIndex {
    pub consts: BTreeMap<String, String>,
    pub entries: Vec<(String, u32)>,
    pub file: Option<String>,
}

/// Run every rule over `files`; `observability_doc` (the text of
/// `docs/OBSERVABILITY.md`) enables the cross-artifact half of rule 3.
pub fn run_all(files: &[SourceFile], observability_doc: Option<&str>) -> Vec<Finding> {
    let mut findings = Vec::new();
    let names = extract_metric_names(files);
    for f in files {
        pragma_misuse(f, &mut findings);
        no_wall_clock(f, &mut findings);
        no_panic_serve_path(f, &mut findings);
        metric_name_literals(f, &mut findings);
        golden_hygiene(f, &mut findings);
    }
    label_set_consistency(files, &names, &mut findings);
    if let Some(doc) = observability_doc {
        doc_sync(&names, doc, &mut findings);
    }
    run_semantic(files, &mut findings);
    findings
}

fn emit(
    findings: &mut Vec<Finding>,
    f: &SourceFile,
    rule: &'static str,
    line: u32,
    col: u32,
    message: String,
) {
    let allowed = f.allow(rule, line).map(str::to_string);
    findings.push(Finding { rule, file: f.path.clone(), line, col, message, allowed });
}

/// Meta-rule: malformed pragmas are findings, never waivable.
fn pragma_misuse(f: &SourceFile, findings: &mut Vec<Finding>) {
    for bp in &f.bad_pragmas {
        findings.push(Finding {
            rule: PRAGMA,
            file: f.path.clone(),
            line: bp.line,
            col: bp.col,
            message: bp.message.clone(),
            allowed: None,
        });
    }
}

/// Rule 1: host-time reads are confined to `coordinator::clock`.
/// Benches and examples are exempt — they measure host time by design.
fn no_wall_clock(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.is_test_file
        || f.path.ends_with(CLOCK_FILE)
        || f.path.starts_with("rust/benches/")
        || f.path.starts_with("examples/")
    {
        return;
    }
    for &ti in &f.code {
        let t = &f.tokens[ti];
        if t.kind == TokKind::Ident
            && WALL_IDENTS.contains(&t.text.as_str())
            && !f.in_test_region(t.line)
        {
            emit(
                findings,
                f,
                NO_WALL_CLOCK,
                t.line,
                t.col,
                format!(
                    "`{}` reads host time; inject `coordinator::Clock` instead \
                     (only {CLOCK_FILE} may touch std::time)",
                    t.text
                ),
            );
        }
    }
}

fn on_serve_path(path: &str) -> bool {
    SERVE_PATH_FILES.iter().any(|s| path.ends_with(s))
        || path.contains("src/memory/")
        || path.contains("src/obs/")
}

/// Rule 2: no panicking constructs on the serve path.
fn no_panic_serve_path(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.is_test_file || !on_serve_path(&f.path) {
        return;
    }
    let tok = |ci: usize| &f.tokens[f.code[ci]];
    for ci in 0..f.code.len() {
        let t = tok(ci);
        if f.in_test_region(t.line) {
            continue;
        }
        // `.unwrap()` / `.expect(` — method position only, so a free
        // function named `expect` does not trip it.
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && ci > 0
            && tok(ci - 1).is(TokKind::Punct, ".")
            && ci + 1 < f.code.len()
            && tok(ci + 1).is(TokKind::Punct, "(")
        {
            emit(
                findings,
                f,
                NO_PANIC,
                t.line,
                t.col,
                format!(".{}() can panic on the serve path; return an error instead", t.text),
            );
            continue;
        }
        if t.kind == TokKind::Ident
            && t.text == "panic"
            && ci + 1 < f.code.len()
            && tok(ci + 1).is(TokKind::Punct, "!")
        {
            emit(
                findings,
                f,
                NO_PANIC,
                t.line,
                t.col,
                "panic! on the serve path; return an error instead".to_string(),
            );
            continue;
        }
        // `expr[index]` with a variable index: `xs[i]`, `map[&key]`,
        // `b[*pos]`. Conservative: the indexed expression must end in an
        // identifier, `)`, or `]`, and the index must be a lone
        // (possibly `&`/`*`-prefixed) identifier.
        if t.is(TokKind::Punct, "[") && ci > 0 {
            let prev = tok(ci - 1);
            let indexes_expr = (prev.kind == TokKind::Ident
                && !KEYWORDS.contains(&prev.text.as_str()))
                || prev.is(TokKind::Punct, ")")
                || prev.is(TokKind::Punct, "]");
            if !indexes_expr {
                continue;
            }
            let mut j = ci + 1;
            if j < f.code.len()
                && (tok(j).is(TokKind::Punct, "&") || tok(j).is(TokKind::Punct, "*"))
            {
                j += 1;
            }
            if j + 1 < f.code.len()
                && tok(j).kind == TokKind::Ident
                && !KEYWORDS.contains(&tok(j).text.as_str())
                && tok(j + 1).is(TokKind::Punct, "]")
            {
                emit(
                    findings,
                    f,
                    NO_PANIC,
                    t.line,
                    t.col,
                    format!(
                        "indexing `[{}]` can panic on the serve path; use .get()",
                        tok(j).text
                    ),
                );
            }
        }
    }
}

/// Rule 3 (definition half): metric-name string literals may only appear
/// in `metrics::names` — everywhere else, use the constant.
fn metric_name_literals(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.is_test_file || f.path.ends_with(NAMES_FILE) {
        return;
    }
    for &ti in &f.code {
        let t = &f.tokens[ti];
        if t.kind == TokKind::Str
            && t.text.starts_with(METRIC_PREFIX)
            && !f.in_test_region(t.line)
        {
            emit(
                findings,
                f,
                METRIC_NAMES,
                t.line,
                t.col,
                format!(
                    "metric name literal \"{}\" outside metrics::names; use the constant",
                    t.text
                ),
            );
        }
    }
}

/// Rule 5: nothing outside `testkit::golden` names the golden fixture
/// directory — tests must go through the bless/compare helpers.
fn golden_hygiene(f: &SourceFile, findings: &mut Vec<Finding>) {
    if f.path.ends_with(GOLDEN_IMPL_FILE) {
        return;
    }
    for &ti in &f.code {
        let t = &f.tokens[ti];
        if t.kind == TokKind::Str && t.text.contains(GOLDEN_DIR_FRAGMENT) {
            emit(
                findings,
                f,
                GOLDEN_HYGIENE,
                t.line,
                t.col,
                format!(
                    "path \"{}\" names the golden fixture directory; route fixture I/O \
                     through testkit::golden",
                    t.text
                ),
            );
        }
    }
}

/// Find `pub mod names { … }` in the names file and index its consts.
pub fn extract_metric_names(files: &[SourceFile]) -> NamesIndex {
    let mut idx = NamesIndex::default();
    let Some(f) = files.iter().find(|f| f.path.ends_with(NAMES_FILE)) else {
        return idx;
    };
    idx.file = Some(f.path.clone());
    let tok = |ci: usize| &f.tokens[f.code[ci]];
    // Locate `mod names {`.
    let mut start = None;
    for ci in 0..f.code.len().saturating_sub(2) {
        if tok(ci).is(TokKind::Ident, "mod")
            && tok(ci + 1).is(TokKind::Ident, "names")
            && tok(ci + 2).is(TokKind::Punct, "{")
        {
            start = Some(ci + 2);
            break;
        }
    }
    let Some(open) = start else {
        return idx;
    };
    let mut depth = 0usize;
    let mut ci = open;
    while ci < f.code.len() {
        let t = tok(ci);
        if t.is(TokKind::Punct, "{") {
            depth += 1;
        } else if t.is(TokKind::Punct, "}") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if t.is(TokKind::Ident, "const") && ci + 1 < f.code.len() {
            let name = tok(ci + 1).text.clone();
            // Scan to the `=` then take the string value.
            let mut j = ci + 2;
            while j < f.code.len() && !tok(j).is(TokKind::Punct, "=") {
                j += 1;
            }
            if j + 1 < f.code.len() && tok(j + 1).kind == TokKind::Str {
                let value = tok(j + 1).text.clone();
                idx.entries.push((value.clone(), tok(j + 1).line));
                idx.consts.insert(name, value);
            }
        }
        ci += 1;
    }
    idx
}

/// One record call site: where, and with which sorted label keys.
struct LabelSite {
    file: String,
    line: u32,
    col: u32,
    keys: Vec<String>,
    allowed: Option<String>,
}

/// Rule 4: every record call site of a metric uses the same label keys.
///
/// Only *literal* `&[("key", …), …]` label arrays participate; sites
/// passing a label slice through a variable are skipped (the lint is
/// token-level, not data-flow). Empty `&[]` label sets are exempt — the
/// fleet-aggregate convention records the same name both per-device and
/// unlabeled.
fn label_set_consistency(files: &[SourceFile], names: &NamesIndex, findings: &mut Vec<Finding>) {
    let mut first_site: BTreeMap<String, LabelSite> = BTreeMap::new();
    for f in files {
        if f.is_test_file {
            continue;
        }
        let tok = |ci: usize| &f.tokens[f.code[ci]];
        for ci in 0..f.code.len() {
            let t = tok(ci);
            if !(t.kind == TokKind::Ident
                && RECORD_METHODS.contains(&t.text.as_str())
                && ci > 0
                && tok(ci - 1).is(TokKind::Punct, ".")
                && ci + 1 < f.code.len()
                && tok(ci + 1).is(TokKind::Punct, "("))
            {
                continue;
            }
            if f.in_test_region(t.line) {
                continue;
            }
            let Some((args, _close)) = split_args(f, ci + 1) else {
                continue;
            };
            if args.len() < 2 {
                continue;
            }
            let Some(name) = resolve_name(f, &args[0], names) else {
                continue;
            };
            let Some(keys) = literal_label_keys(f, &args[1]) else {
                continue;
            };
            if keys.is_empty() {
                continue;
            }
            let site = LabelSite {
                file: f.path.clone(),
                line: t.line,
                col: t.col,
                keys,
                allowed: f.allow(LABEL_SETS, t.line).map(str::to_string),
            };
            match first_site.get(&name) {
                None => {
                    first_site.insert(name, site);
                }
                Some(prev) if prev.keys == site.keys => {}
                Some(prev) => {
                    let allowed = site.allowed.clone().or_else(|| prev.allowed.clone());
                    findings.push(Finding {
                        rule: LABEL_SETS,
                        file: site.file,
                        line: site.line,
                        col: site.col,
                        message: format!(
                            "metric `{name}` recorded with label keys [{}] here but [{}] at \
                             {}:{}",
                            site.keys.join(", "),
                            prev.keys.join(", "),
                            prev.file,
                            prev.line
                        ),
                        allowed,
                    });
                }
            }
        }
    }
}

/// Split the argument tokens of a call whose `(` sits at code index
/// `open`. Returns per-argument spans of code indices and the index of
/// the matching `)`.
fn split_args(f: &SourceFile, open: usize) -> Option<(Vec<Vec<usize>>, usize)> {
    let tok = |ci: usize| &f.tokens[f.code[ci]];
    let mut depth = 0usize;
    let mut args: Vec<Vec<usize>> = vec![Vec::new()];
    let mut ci = open;
    while ci < f.code.len() {
        let t = tok(ci);
        let open_delim = t.kind == TokKind::Punct && matches!(t.text.as_str(), "(" | "[" | "{");
        let close_delim = t.kind == TokKind::Punct && matches!(t.text.as_str(), ")" | "]" | "}");
        if open_delim {
            depth += 1;
            if depth > 1 {
                args.last_mut()?.push(ci);
            }
        } else if close_delim {
            depth = depth.checked_sub(1)?;
            if depth == 0 {
                if args.last().is_some_and(Vec::is_empty) {
                    args.pop();
                }
                return Some((args, ci));
            }
            args.last_mut()?.push(ci);
        } else if depth == 1 && t.is(TokKind::Punct, ",") {
            args.push(Vec::new());
        } else {
            args.last_mut()?.push(ci);
        }
        ci += 1;
    }
    None
}

/// Resolve a call's first argument to a metric name: either a string
/// literal with the metric prefix, or a `names::CONST` path looked up
/// in the extracted index.
fn resolve_name(f: &SourceFile, arg: &[usize], names: &NamesIndex) -> Option<String> {
    let toks: Vec<_> = arg.iter().map(|&ci| &f.tokens[f.code[ci]]).collect();
    if let Some(t) = toks.iter().find(|t| t.kind == TokKind::Str) {
        if t.text.starts_with(METRIC_PREFIX) {
            return Some(t.text.clone());
        }
        return None;
    }
    for w in 0..toks.len() {
        if toks[w].is(TokKind::Ident, "names")
            && toks.get(w + 1).is_some_and(|t| t.is(TokKind::Punct, ":"))
            && toks.get(w + 2).is_some_and(|t| t.is(TokKind::Punct, ":"))
        {
            if let Some(c) = toks.get(w + 3).filter(|t| t.kind == TokKind::Ident) {
                return Some(
                    names
                        .consts
                        .get(&c.text)
                        .cloned()
                        .unwrap_or_else(|| format!("names::{}", c.text)),
                );
            }
        }
    }
    None
}

/// Extract sorted label keys from a *literal* `&[("key", …), …]` second
/// argument; `None` when the labels are not a literal array.
fn literal_label_keys(f: &SourceFile, arg: &[usize]) -> Option<Vec<String>> {
    let toks: Vec<_> = arg.iter().map(|&ci| &f.tokens[f.code[ci]]).collect();
    let mut i = 0;
    while i < toks.len() && toks[i].is(TokKind::Punct, "&") {
        i += 1;
    }
    if !toks.get(i)?.is(TokKind::Punct, "[") {
        return None;
    }
    let mut keys = Vec::new();
    let mut j = i + 1;
    while j < toks.len() {
        if toks[j].is(TokKind::Punct, "]") {
            keys.sort();
            return Some(keys);
        }
        if toks[j].is(TokKind::Punct, "(") {
            if let Some(t) = toks.get(j + 1) {
                if t.kind == TokKind::Str {
                    keys.push(t.text.clone());
                }
            }
        }
        j += 1;
    }
    None
}

// ---------------------------------------------------------------------------
// Semantic rules (6–8): parser + call-graph backed.
// ---------------------------------------------------------------------------

/// Files whose non-test fns are panic-reachability entry points.
const ENTRY_FILES: [&str; 3] =
    ["coordinator/server.rs", "coordinator/dispatch.rs", "coordinator/batcher.rs"];

/// Files/dirs whose fns emit external artifacts (exporters, reports,
/// golden fixtures) — the nondet-iteration rule protects everything
/// that reaches or is reached by them.
const EMIT_FILES_SUFFIX: [&str; 4] =
    ["coordinator/metrics.rs", "testkit/golden.rs", "npu/report.rs", "npu/trace_dump.rs"];
const EMIT_DIRS: [&str; 2] = ["src/obs/", "src/report/"];

/// Identifier suffix → unit, per the repo's naming convention.
const UNIT_SUFFIXES: [(&str, &str); 8] = [
    ("_ns", "ns"),
    ("_ms", "ms"),
    ("_us", "us"),
    ("_bytes", "bytes"),
    ("_gbps", "gbps"),
    ("_gops", "gops"),
    ("_frac", "frac"),
    ("_ops", "ops"),
];
/// Bare identifiers that *are* a unit-bearing quantity.
const UNIT_WORDS: [(&str, &str); 6] = [
    ("ns", "ns"),
    ("ms", "ms"),
    ("bytes", "bytes"),
    ("gbps", "gbps"),
    ("gops", "gops"),
    ("frac", "frac"),
];

const ITER_METHODS: [&str; 7] =
    ["iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain"];
const SORT_METHODS: [&str; 6] =
    ["sort", "sort_by", "sort_by_key", "sort_unstable", "sort_unstable_by", "sort_unstable_by_key"];
const HASH_TYPES: [&str; 2] = ["HashMap", "HashSet"];

fn unit_of(term: Option<&str>) -> Option<&'static str> {
    let t = term?;
    for (suf, u) in UNIT_SUFFIXES {
        if t.len() > suf.len() && t.ends_with(suf) {
            return Some(u);
        }
    }
    UNIT_WORDS.iter().find(|(w, _)| *w == t).map(|&(_, u)| u)
}

/// Run the three semantic rules. Parses every file, builds the call
/// graph over `rust/src/`, and appends findings in deterministic order.
pub fn run_semantic(files: &[SourceFile], findings: &mut Vec<Finding>) {
    let mut asts: Vec<FileAst> = files.iter().map(parse_file).collect();
    asts.sort_by(|a, b| a.path.cmp(&b.path));
    let ast_by_path: BTreeMap<&str, &FileAst> =
        asts.iter().map(|a| (a.path.as_str(), a)).collect();
    let file_by_path: BTreeMap<&str, &SourceFile> =
        files.iter().map(|f| (f.path.as_str(), f)).collect();
    let cg = CallGraph::build(&asts);

    // --- panic-reachability -------------------------------------------------
    let entries: Vec<usize> = (0..cg.fns.len())
        .filter(|&fid| ENTRY_FILES.iter().any(|s| cg.fns[fid].0.ends_with(s)))
        .collect();
    let parent = cg.reachable_from(&entries);
    for (&fid, _) in &parent {
        let (path, fd) = cg.fns[fid];
        if on_serve_path(path) {
            continue; // the token-level rule 2 already covers these files
        }
        let Some(f) = file_by_path.get(path) else { continue };
        for p in &fd.panics {
            emit(
                findings,
                f,
                PANIC_REACH,
                p.line,
                p.col,
                format!(
                    "{} can panic and is reachable from the serve path: {}",
                    p.what,
                    cg.chain(&parent, fid).join(" -> ")
                ),
            );
        }
    }

    // --- unit-consistency ---------------------------------------------------
    for f in files {
        if f.is_test_file {
            continue;
        }
        let Some(ast) = ast_by_path.get(f.path.as_str()) else { continue };
        for fd in &ast.fns {
            if fd.is_test {
                continue;
            }
            for b in &fd.binaries {
                let (lu, ru) = (unit_of(b.lhs.as_deref()), unit_of(b.rhs.as_deref()));
                let (Some(lu), Some(ru)) = (lu, ru) else { continue };
                if lu == ru || b.lhs_mul || b.rhs_mul {
                    continue; // same unit, or a derived-unit mul/div context
                }
                emit(
                    findings,
                    f,
                    UNIT_CONSISTENCY,
                    b.line,
                    b.col,
                    format!(
                        "`{}` ({lu}) {} `{}` ({ru}) mixes units",
                        b.lhs.as_deref().unwrap_or(""),
                        b.op,
                        b.rhs.as_deref().unwrap_or("")
                    ),
                );
            }
        }
    }

    // --- nondet-iteration ---------------------------------------------------
    // Hash-typed struct fields, per struct name.
    let mut hashy_fields: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for ast in &asts {
        for (sname, fname, ty) in &ast.fields {
            if ty.iter().any(|t| HASH_TYPES.contains(&t.as_str())) {
                hashy_fields.entry(sname).or_default().insert(fname);
            }
        }
    }
    // Emission scope: fns in exporter/report/golden files, their
    // transitive callers, and everything they call.
    let emit_fids: BTreeSet<usize> = (0..cg.fns.len())
        .filter(|&fid| {
            let p = cg.fns[fid].0;
            EMIT_FILES_SUFFIX.iter().any(|s| p.ends_with(s)) || EMIT_DIRS.iter().any(|d| p.contains(d))
        })
        .collect();
    let mut scope = cg.callers_closure(&emit_fids);
    let emit_list: Vec<usize> = emit_fids.iter().copied().collect();
    scope.extend(cg.reachable_from(&emit_list).keys().copied());
    let fid_of: BTreeMap<(&str, u32), usize> =
        (0..cg.fns.len()).map(|fid| ((cg.fns[fid].0, cg.fns[fid].1.line), fid)).collect();
    for f in files {
        if f.is_test_file {
            continue;
        }
        let Some(ast) = ast_by_path.get(f.path.as_str()) else { continue };
        for fd in &ast.fns {
            if fd.is_test {
                continue;
            }
            let in_scope = fid_of
                .get(&(f.path.as_str(), fd.line))
                .is_some_and(|fid| scope.contains(fid));
            if !in_scope {
                continue;
            }
            // Hash-typed locals: params and lets whose type (or the
            // head of whose initializer) names a hash container.
            let mut local_hashy: BTreeSet<&str> = BTreeSet::new();
            for (name, ty) in &fd.params {
                if ty.iter().any(|t| HASH_TYPES.contains(&t.as_str())) {
                    local_hashy.insert(name);
                }
            }
            for l in &fd.lets {
                if l.ty.iter().any(|t| HASH_TYPES.contains(&t.as_str()))
                    || l.init.iter().take(2).any(|t| HASH_TYPES.contains(&t.as_str()))
                {
                    local_hashy.insert(&l.name);
                }
            }
            let own_fields = fd
                .impl_type
                .as_deref()
                .and_then(|ty| hashy_fields.get(ty))
                .cloned()
                .unwrap_or_default();
            let is_hashy = |root: Option<&str>, last: Option<&str>| match root {
                Some("self") => {
                    last.is_some_and(|l| l != "self" && own_fields.contains(l))
                }
                Some(r) => last == Some(r) && local_hashy.contains(r),
                None => false,
            };
            let mut sites: Vec<(u32, u32, String)> = Vec::new();
            for m in &fd.methods {
                if ITER_METHODS.contains(&m.name.as_str())
                    && is_hashy(m.recv_root.as_deref(), m.recv_last.as_deref())
                {
                    let over = m.recv_last.as_deref().or(m.recv_root.as_deref()).unwrap_or("");
                    sites.push((m.line, m.col, format!(".{}() over `{over}`", m.name)));
                }
            }
            for fo in &fd.fors {
                let hot = local_hashy.contains(fo.root.as_str())
                    || (fo.root == "self"
                        && fo.idents.len() > 1
                        && own_fields.contains(fo.idents[1].as_str()));
                let what = if fo.root == "self" && fo.idents.len() > 1 {
                    fo.idents[1].as_str()
                } else {
                    fo.root.as_str()
                };
                if hot && !sites.iter().any(|(l, c, _)| (*l, *c) == (fo.line, fo.col)) {
                    sites.push((fo.line, fo.col, format!("for-loop over `{what}`")));
                }
            }
            if sites.is_empty() {
                continue;
            }
            // Escapes: an explicit sort, or a BTree collection mention,
            // at or below the site line within the same fn.
            let sorted_after: Vec<u32> = fd
                .methods
                .iter()
                .filter(|m| SORT_METHODS.contains(&m.name.as_str()))
                .map(|m| m.line)
                .collect();
            sites.sort();
            sites.dedup();
            for (line, col, what) in sites {
                if sorted_after.iter().any(|&sl| sl >= line)
                    || fd.btree_mentions.iter().any(|&ml| ml >= line)
                {
                    continue;
                }
                emit(
                    findings,
                    f,
                    NONDET_ITER,
                    line,
                    col,
                    format!(
                        "{what} iterates a hash container on an emission path ({}); \
                         order is nondeterministic",
                        fd.qualified()
                    ),
                );
            }
        }
    }
}

/// Rule 3 (doc half): every declared metric name appears in
/// `docs/OBSERVABILITY.md`.
fn doc_sync(names: &NamesIndex, doc: &str, findings: &mut Vec<Finding>) {
    let Some(file) = &names.file else {
        return;
    };
    for (value, line) in &names.entries {
        if !doc.contains(value.as_str()) {
            findings.push(Finding {
                rule: METRIC_NAMES,
                file: file.clone(),
                line: *line,
                col: 1,
                message: format!("metric `{value}` is not documented in docs/OBSERVABILITY.md"),
                allowed: None,
            });
        }
    }
}
