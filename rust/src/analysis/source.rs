//! Per-file analysis model: the token stream plus the two pieces of
//! structure every rule needs — which lines are test code, and which
//! findings the author has explicitly waived with a reasoned pragma.
//!
//! Pragma grammar (see `docs/LINTS.md`):
//!
//! ```text
//! // lint:allow(<rule-name>, "<non-empty reason>")
//! // lint:allow-file(<rule-name>, "<non-empty reason>")
//! ```
//!
//! A `lint:allow` pragma waives findings of `<rule-name>` on its own
//! line and the line immediately below it. `lint:allow-file` waives the
//! rule for the whole file — every finding is still reported,
//! individually carrying the reason, so file-level waivers stay visible
//! debt. The reason is mandatory in both forms: a waiver without a
//! recorded justification is itself reported (rule name `pragma`).

use super::lexer::{lex, TokKind, Token};
use super::rules::RULE_NAMES;

/// A parsed, well-formed `lint:allow` pragma.
#[derive(Clone, Debug)]
pub struct Pragma {
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

/// A malformed pragma — reported as a finding so waivers cannot rot.
#[derive(Clone, Debug)]
pub struct BadPragma {
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// One lexed source file with the derived structure rules run over.
#[derive(Debug)]
pub struct SourceFile {
    /// Repo-relative path with `/` separators (`rust/src/obs/export.rs`).
    pub path: String,
    /// Full token stream, comments included.
    pub tokens: Vec<Token>,
    /// Indices into `tokens` of non-comment tokens (what rules scan).
    pub code: Vec<usize>,
    /// Inclusive line ranges covered by `#[test]` / `#[cfg(test)]` items.
    pub test_regions: Vec<(u32, u32)>,
    pub pragmas: Vec<Pragma>,
    /// `lint:allow-file` pragmas — whole-file waivers (line is where the
    /// pragma sits, kept for diagnostics only).
    pub file_pragmas: Vec<Pragma>,
    pub bad_pragmas: Vec<BadPragma>,
    /// Whole-file test code (anything under `rust/tests/`).
    pub is_test_file: bool,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let path = path.replace('\\', "/");
        let tokens = lex(src);
        let code: Vec<usize> = tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
            .map(|(i, _)| i)
            .collect();
        let test_regions = find_test_regions(&tokens, &code);
        let (pragmas, file_pragmas, bad_pragmas) = parse_pragmas(&tokens);
        let is_test_file = path.starts_with("rust/tests/") || path.contains("/tests/");
        SourceFile {
            path,
            tokens,
            code,
            test_regions,
            pragmas,
            file_pragmas,
            bad_pragmas,
            is_test_file,
        }
    }

    /// Is `line` inside test-only code (a `#[cfg(test)] mod` body, a
    /// `#[test]` fn, or a whole test file)?
    pub fn in_test_region(&self, line: u32) -> bool {
        self.is_test_file || self.test_regions.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// The waiver reason if a `lint:allow(rule, …)` pragma covers `line`
    /// (same line or the line directly above), or a `lint:allow-file`
    /// pragma covers the whole file.
    pub fn allow(&self, rule: &str, line: u32) -> Option<&str> {
        self.pragmas
            .iter()
            .find(|p| p.rule == rule && (p.line == line || p.line + 1 == line))
            .or_else(|| self.file_pragmas.iter().find(|p| p.rule == rule))
            .map(|p| p.reason.as_str())
    }
}

/// Locate `#[…test…]`-attributed items and return their line spans.
///
/// The walk is structural, not syntactic: an outer attribute group whose
/// bracket contents mention the identifier `test` (`#[test]`,
/// `#[cfg(test)]`, `#[tokio::test]`) marks the next item; the item's
/// span runs to the `}` matching its first `{`, or to a top-level `;`
/// for bodiless items.
fn find_test_regions(tokens: &[Token], code: &[usize]) -> Vec<(u32, u32)> {
    let tok = |ci: usize| -> &Token { &tokens[code[ci]] };
    let mut regions = Vec::new();
    let mut i = 0;
    while i < code.len() {
        if !tok(i).is(TokKind::Punct, "#") {
            i += 1;
            continue;
        }
        // `#![…]` inner attributes decorate the enclosing scope, not a
        // following item — skip them.
        let mut j = i + 1;
        if j < code.len() && tok(j).is(TokKind::Punct, "!") {
            i = j + 1;
            continue;
        }
        if j >= code.len() || !tok(j).is(TokKind::Punct, "[") {
            i += 1;
            continue;
        }
        let start_line = tok(i).line;
        // Scan the attribute group, noting whether it mentions `test`
        // (`#[cfg(not(test))]` guards *non*-test code — not a region).
        let mut depth = 0usize;
        let mut mentions_test = false;
        let mut negated = false;
        while j < code.len() {
            let t = tok(j);
            if t.is(TokKind::Punct, "[") {
                depth += 1;
            } else if t.is(TokKind::Punct, "]") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t.kind == TokKind::Ident && t.text == "test" {
                mentions_test = true;
            } else if t.kind == TokKind::Ident && t.text == "not" {
                negated = true;
            }
            j += 1;
        }
        if !mentions_test || negated {
            i = j + 1;
            continue;
        }
        // Skip any further attribute groups on the same item.
        let mut k = j + 1;
        while k + 1 < code.len()
            && tok(k).is(TokKind::Punct, "#")
            && tok(k + 1).is(TokKind::Punct, "[")
        {
            let mut depth = 0usize;
            k += 1;
            while k < code.len() {
                let t = tok(k);
                if t.is(TokKind::Punct, "[") {
                    depth += 1;
                } else if t.is(TokKind::Punct, "]") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            k += 1;
        }
        // Find the item body: first `{`, brace-matched to its `}`; a
        // bodiless item ends at the first top-level `;`.
        let mut end_line = start_line;
        let mut braces = 0usize;
        let mut found_body = false;
        while k < code.len() {
            let t = tok(k);
            if t.is(TokKind::Punct, "{") {
                braces += 1;
                found_body = true;
            } else if t.is(TokKind::Punct, "}") {
                braces = braces.saturating_sub(1);
                if found_body && braces == 0 {
                    end_line = t.line;
                    break;
                }
            } else if t.is(TokKind::Punct, ";") && !found_body {
                end_line = t.line;
                break;
            }
            k += 1;
        }
        if k >= code.len() {
            end_line = tokens.last().map(|t| t.line).unwrap_or(start_line);
        }
        regions.push((start_line, end_line));
        i = k + 1;
    }
    regions
}

/// Extract `lint:allow` / `lint:allow-file` pragmas from line comments;
/// anything that looks like a pragma but does not parse becomes a
/// [`BadPragma`].
fn parse_pragmas(tokens: &[Token]) -> (Vec<Pragma>, Vec<Pragma>, Vec<BadPragma>) {
    let mut good = Vec::new();
    let mut file_good = Vec::new();
    let mut bad = Vec::new();
    for t in tokens {
        if t.kind != TokKind::LineComment {
            continue;
        }
        let body = t.text.trim_start_matches('/').trim();
        // `-file` must be peeled first: both forms share the prefix.
        let (rest, file_scoped) = match body.strip_prefix("lint:allow-file") {
            Some(r) => (r, true),
            None => match body.strip_prefix("lint:allow") {
                Some(r) => (r, false),
                None => continue,
            },
        };
        let form = if file_scoped { "lint:allow-file" } else { "lint:allow" };
        let mut fail = |message: String| {
            bad.push(BadPragma { line: t.line, col: t.col, message });
        };
        let Some(inner) = rest.trim().strip_prefix('(').and_then(|r| r.strip_suffix(')')) else {
            fail(format!("malformed pragma: expected {form}(rule, \"reason\")"));
            continue;
        };
        let Some((rule, reason)) = inner.split_once(',') else {
            fail(format!(
                "pragma for `{}` is missing its reason: {form}(rule, \"reason\")",
                inner.trim()
            ));
            continue;
        };
        let rule = rule.trim().to_string();
        let reason = reason.trim().trim_matches('"').trim().to_string();
        if !RULE_NAMES.contains(&rule.as_str()) {
            fail(format!("pragma names unknown rule `{rule}`"));
            continue;
        }
        if reason.is_empty() {
            fail(format!("pragma for `{rule}` has an empty reason — justify the waiver"));
            continue;
        }
        let p = Pragma { line: t.line, rule, reason };
        if file_scoped {
            file_good.push(p);
        } else {
            good.push(p);
        }
    }
    (good, file_good, bad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mod_region_covers_its_body() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let f = SourceFile::parse("rust/src/a.rs", src);
        assert_eq!(f.test_regions, vec![(2, 5)]);
        assert!(!f.in_test_region(1));
        assert!(f.in_test_region(4));
        assert!(!f.in_test_region(6));
    }

    #[test]
    fn test_fn_with_stacked_attrs() {
        let src = "#[test]\n#[ignore]\nfn t() {\n  boom();\n}\n";
        let f = SourceFile::parse("rust/src/a.rs", src);
        assert_eq!(f.test_regions, vec![(1, 5)]);
    }

    #[test]
    fn non_test_attrs_do_not_open_regions() {
        let src = "#[derive(Debug)]\nstruct S;\n#[inline]\nfn f() {}\n";
        let f = SourceFile::parse("rust/src/a.rs", src);
        assert!(f.test_regions.is_empty());
    }

    #[test]
    fn pragma_roundtrip_and_misuse() {
        let src = "// lint:allow(no-wall-clock, \"bench measures host time\")\nuse std::time::Instant;\n// lint:allow(no-panic-serve-path)\n// lint:allow(bogus-rule, \"x\")\n";
        let f = SourceFile::parse("rust/src/a.rs", src);
        assert_eq!(f.pragmas.len(), 1);
        assert_eq!(f.allow("no-wall-clock", 2), Some("bench measures host time"));
        assert_eq!(f.allow("no-wall-clock", 3), None);
        assert_eq!(f.bad_pragmas.len(), 2);
        assert!(f.bad_pragmas[0].message.contains("missing its reason"));
        assert!(f.bad_pragmas[1].message.contains("unknown rule"));
    }

    #[test]
    fn file_pragma_covers_every_line_with_its_reason() {
        let src = "// lint:allow-file(panic-reachability, \"dense indices by construction\")\n\
                   fn a() { x.unwrap(); }\n\nfn b() { y.unwrap(); }\n";
        let f = SourceFile::parse("rust/src/a.rs", src);
        assert_eq!(f.file_pragmas.len(), 1);
        assert!(f.pragmas.is_empty());
        assert_eq!(f.allow("panic-reachability", 2), Some("dense indices by construction"));
        assert_eq!(f.allow("panic-reachability", 4), Some("dense indices by construction"));
        assert_eq!(f.allow("no-wall-clock", 2), None, "only the named rule is waived");
        // Malformed file pragmas are findings like line pragmas.
        let g = SourceFile::parse("rust/src/b.rs", "// lint:allow-file(panic-reachability)\n");
        assert_eq!(g.bad_pragmas.len(), 1);
        assert!(g.bad_pragmas[0].message.contains("lint:allow-file"));
    }

    #[test]
    fn files_under_tests_are_whole_file_test_regions() {
        let f = SourceFile::parse("rust/tests/lint.rs", "fn x() { y.unwrap(); }");
        assert!(f.in_test_region(1));
    }
}
