//! Recursive-descent parser over the [`super::lexer`] token stream,
//! producing the lightweight AST the semantic rules need: the item tree
//! (modules, impls, fns, struct fields) and, per function body, a flat
//! event list — calls with their `::` paths, method calls with their
//! receiver chain, panic sites, binary expressions with operand terms,
//! `for` loops, and `let` bindings.
//!
//! This is deliberately *not* full Rust. Everything the rules do with
//! the AST degrades safely when the parser under-approximates: an
//! unparsed expression yields no events, which means no finding — never
//! a spurious one. The hard lexical cases (nested generics vs shift,
//! raw strings, char-vs-lifetime, `cfg(not(test))`) are already settled
//! by the lexer and region tracker; this layer only adds structure.

use super::lexer::{TokKind, Token};
use super::source::SourceFile;

/// A function (free fn or impl/trait method) with its body events.
#[derive(Debug, Default)]
pub struct FnDef {
    /// Module path within the crate (`["coordinator", "batcher"]`),
    /// derived from the file path plus any nested `mod` items.
    pub module: Vec<String>,
    /// `Some("Fleet")` for methods defined in `impl Fleet { … }` (or a
    /// trait impl / trait definition body).
    pub impl_type: Option<String>,
    pub name: String,
    /// Line of the `fn` keyword — unique per file, used as an id.
    pub line: u32,
    /// Inside a `#[test]` / `#[cfg(test)]` region or a test file.
    pub is_test: bool,
    /// Whether the first parameter is (a reference to) `self`.
    pub has_self: bool,
    /// Parameter `name` → identifiers appearing in its type.
    pub params: Vec<(String, Vec<String>)>,
    pub calls: Vec<CallSite>,
    pub methods: Vec<MethodSite>,
    pub panics: Vec<PanicSite>,
    pub binaries: Vec<BinarySite>,
    pub fors: Vec<ForSite>,
    pub lets: Vec<LetSite>,
    /// `BTreeMap` / `BTreeSet` identifier sightings (sortedness escapes
    /// for the nondet-iteration rule).
    pub btree_mentions: Vec<u32>,
}

impl FnDef {
    /// Fully qualified display name: `coordinator::device::Fleet::place`.
    pub fn qualified(&self) -> String {
        let mut segs: Vec<&str> = self.module.iter().map(String::as_str).collect();
        if let Some(ty) = &self.impl_type {
            segs.push(ty);
        }
        segs.push(&self.name);
        segs.join("::")
    }
}

/// `foo(…)` / `a::b::foo(…)` — path call.
#[derive(Debug)]
pub struct CallSite {
    pub path: Vec<String>,
    pub line: u32,
    pub col: u32,
}

/// `recv.foo(…)` — method call. `recv_root` is the leftmost term of the
/// receiver chain (`self` in `self.pending.values()`), `recv_last` the
/// segment directly before the method (`pending`).
#[derive(Debug)]
pub struct MethodSite {
    pub name: String,
    pub recv_root: Option<String>,
    pub recv_last: Option<String>,
    pub line: u32,
    pub col: u32,
}

/// A construct that can panic: `.unwrap()`, `.expect(…)`, `panic!`, or
/// variable indexing (same conservative pattern as the token rule).
#[derive(Debug)]
pub struct PanicSite {
    /// Human-readable site description (`".unwrap()"`, "indexing `[i]`").
    pub what: String,
    pub line: u32,
    pub col: u32,
}

/// `lhs OP rhs` for the unit-bearing operators (`+ - < > <= >= == != +=
/// -=`). Terms are the last identifier of each operand's path/call, or
/// `None` when the operand is not a simple term; `*_mul` marks operands
/// adjacent to `*` or `/` (derived-unit context the unit rule skips).
#[derive(Debug)]
pub struct BinarySite {
    pub op: &'static str,
    pub lhs: Option<String>,
    pub lhs_mul: bool,
    pub rhs: Option<String>,
    pub rhs_mul: bool,
    pub line: u32,
    pub col: u32,
}

/// `for pat in expr { … }`: the iterated expression's leading term and
/// every identifier appearing in it.
#[derive(Debug)]
pub struct ForSite {
    pub root: String,
    pub idents: Vec<String>,
    pub line: u32,
    pub col: u32,
}

/// `let [mut] name [: Ty] = init;` — identifiers of the type annotation
/// and the head of the initializer (enough to spot hash containers).
#[derive(Debug)]
pub struct LetSite {
    pub name: String,
    pub ty: Vec<String>,
    pub init: Vec<String>,
}

/// One parsed file: its functions plus struct fields (for field-type
/// lookups keyed by struct name).
#[derive(Debug, Default)]
pub struct FileAst {
    pub path: String,
    pub fns: Vec<FnDef>,
    /// `(struct_name, field_name, type_identifiers)`.
    pub fields: Vec<(String, String, Vec<String>)>,
}

/// Module path a file contributes to the crate tree, or `None` for
/// files outside `rust/src/` (tests, benches, examples — excluded from
/// the call graph).
pub fn file_module(path: &str) -> Option<Vec<String>> {
    let rel = path.strip_prefix("rust/src/")?;
    let mut parts: Vec<String> = rel.split('/').map(str::to_string).collect();
    let last = parts.pop()?;
    match last.as_str() {
        "mod.rs" => {}
        "lib.rs" => parts.clear(),
        "main.rs" => parts.push("main".to_string()),
        _ => parts.push(last.trim_end_matches(".rs").to_string()),
    }
    Some(parts)
}

/// Parse one source file into its [`FileAst`].
pub fn parse_file(f: &SourceFile) -> FileAst {
    let toks: Vec<&Token> = f.code.iter().map(|&ci| &f.tokens[ci]).collect();
    let mut ast = FileAst { path: f.path.clone(), ..Default::default() };
    let module = file_module(&f.path).unwrap_or_default();
    let mut p = Parser { f, toks, ast: &mut ast };
    let end = p.toks.len();
    p.items(0, end, &module, None);
    ast
}

/// Keywords that cannot start a call path or indexed expression.
const KEYWORDS: [&str; 24] = [
    "as", "box", "break", "const", "continue", "dyn", "else", "enum", "fn", "for", "if", "impl",
    "in", "let", "loop", "match", "mod", "move", "mut", "ref", "return", "static", "while",
    "where",
];

/// Primitive numeric types (cast targets the term extractor sees
/// through: in `bytes as f64 / gbps` the term is `bytes`, not `f64`).
const PRIMITIVES: [&str; 14] = [
    "f32", "f64", "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128",
    "isize",
];

struct Parser<'a> {
    f: &'a SourceFile,
    toks: Vec<&'a Token>,
    ast: &'a mut FileAst,
}

impl<'a> Parser<'a> {
    fn is(&self, i: usize, kind: TokKind, text: &str) -> bool {
        self.toks.get(i).is_some_and(|t| t.kind == kind && t.text == text)
    }

    fn ident(&self, i: usize) -> Option<&str> {
        self.toks.get(i).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
    }

    /// Two tokens printed with nothing between them (`<` `=` forming
    /// `<=`, but not the `<` and `=` of `a < b = …` on one line).
    fn adjacent(&self, i: usize, j: usize) -> bool {
        match (self.toks.get(i), self.toks.get(j)) {
            (Some(a), Some(b)) => {
                a.line == b.line && b.col == a.col + (a.text.chars().count().max(1) as u32)
            }
            _ => false,
        }
    }

    /// `i` at an opening delimiter; index just past its match.
    fn skip_balanced(&self, mut i: usize, open: &str, close: &str) -> usize {
        let mut depth = 0usize;
        while i < self.toks.len() {
            let t = self.toks[i];
            if t.is(TokKind::Punct, open) {
                depth += 1;
            } else if t.is(TokKind::Punct, close) {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            i += 1;
        }
        self.toks.len()
    }

    /// `i` at `<`; skip a generics group, stepping over `->` arrows so
    /// `Fn(A) -> B` bounds do not unbalance the angles.
    fn skip_angles(&self, mut i: usize) -> usize {
        let mut depth = 0usize;
        while i < self.toks.len() {
            let t = self.toks[i];
            if t.is(TokKind::Punct, "-") && self.is(i + 1, TokKind::Punct, ">")
                && self.adjacent(i, i + 1)
            {
                i += 2;
                continue;
            }
            if t.is(TokKind::Punct, "<") {
                depth += 1;
            } else if t.is(TokKind::Punct, ">") {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            i += 1;
        }
        self.toks.len()
    }

    fn items(&mut self, mut i: usize, end: usize, module: &[String], impl_type: Option<&str>) {
        while i < end {
            let t = self.toks[i];
            if t.is(TokKind::Punct, "#") {
                let mut j = i + 1;
                if self.is(j, TokKind::Punct, "!") {
                    j += 1;
                }
                i = if self.is(j, TokKind::Punct, "[") {
                    self.skip_balanced(j, "[", "]")
                } else {
                    i + 1
                };
                continue;
            }
            if t.kind != TokKind::Ident {
                i += 1;
                continue;
            }
            match t.text.as_str() {
                "pub" if self.is(i + 1, TokKind::Punct, "(") => {
                    i = self.skip_balanced(i + 1, "(", ")");
                }
                "pub" | "unsafe" | "async" | "extern" | "default" => i += 1,
                "const" if self.ident(i + 1) == Some("fn") => i += 1,
                "mod" if self.ident(i + 1).is_some() => {
                    let name = self.toks[i + 1].text.clone();
                    if self.is(i + 2, TokKind::Punct, "{") {
                        let close = self.skip_balanced(i + 2, "{", "}");
                        let mut nested = module.to_vec();
                        nested.push(name);
                        self.items(i + 3, close.saturating_sub(1), &nested, None);
                        i = close;
                    } else {
                        i += 3;
                    }
                }
                "fn" if self.ident(i + 1).is_some() => {
                    i = self.function(i, end, module, impl_type);
                }
                "impl" | "trait" => {
                    i = self.impl_or_trait(i, end, module);
                }
                "struct" if self.ident(i + 1).is_some() => {
                    let sname = self.toks[i + 1].text.clone();
                    let mut j = i + 2;
                    if self.is(j, TokKind::Punct, "<") {
                        j = self.skip_angles(j);
                    }
                    if self.is(j, TokKind::Punct, "{") {
                        let close = self.skip_balanced(j, "{", "}");
                        self.struct_fields(&sname, j + 1, close.saturating_sub(1));
                        i = close;
                    } else if self.is(j, TokKind::Punct, "(") {
                        i = self.skip_balanced(j, "(", ")");
                    } else {
                        i = j;
                    }
                }
                "enum" | "union" => {
                    while i < end && !self.is(i, TokKind::Punct, "{") {
                        i += 1;
                    }
                    if i < end {
                        i = self.skip_balanced(i, "{", "}");
                    }
                }
                "use" | "type" | "static" | "const" => {
                    let mut depth = 0i32;
                    while i < end {
                        let tt = self.toks[i];
                        if tt.kind == TokKind::Punct {
                            match tt.text.as_str() {
                                "(" | "[" | "{" => depth += 1,
                                ")" | "]" | "}" => depth -= 1,
                                ";" if depth == 0 => {
                                    i += 1;
                                    break;
                                }
                                _ => {}
                            }
                        }
                        i += 1;
                    }
                }
                "macro_rules" => {
                    while i < end && !self.is(i, TokKind::Punct, "{") {
                        i += 1;
                    }
                    if i < end {
                        i = self.skip_balanced(i, "{", "}");
                    }
                }
                _ => i += 1,
            }
        }
    }

    /// `i` at `impl` or `trait`; parse the header, recurse on the body.
    fn impl_or_trait(&mut self, i: usize, end: usize, module: &[String]) -> usize {
        let is_trait = self.toks[i].text == "trait";
        let mut j = i + 1;
        let trait_name =
            if is_trait { self.ident(j).map(str::to_string) } else { None };
        if is_trait && trait_name.is_some() {
            j += 1;
        }
        if self.is(j, TokKind::Punct, "<") {
            j = self.skip_angles(j);
        }
        // Walk the header: for `impl Trait for Type`, the type name is
        // the last path identifier after `for`.
        let mut tyname: Option<String> = None;
        while j < end {
            let t = self.toks[j];
            if t.is(TokKind::Punct, "{") {
                break;
            }
            if t.kind == TokKind::Ident && t.text == "where" {
                while j < end && !self.is(j, TokKind::Punct, "{") {
                    j += 1;
                }
                break;
            }
            if t.kind == TokKind::Ident && t.text == "for" {
                tyname = None;
                j += 1;
                continue;
            }
            if t.kind == TokKind::Ident {
                tyname = Some(t.text.clone());
                j += 1;
                if self.is(j, TokKind::Punct, "<") {
                    j = self.skip_angles(j);
                }
                continue;
            }
            j += 1;
        }
        let tyname = if is_trait { trait_name } else { tyname };
        if self.is(j, TokKind::Punct, "{") {
            let close = self.skip_balanced(j, "{", "}");
            let ty = tyname.clone();
            self.items(j + 1, close.saturating_sub(1), module, ty.as_deref());
            close
        } else {
            j + 1
        }
    }

    fn struct_fields(&mut self, sname: &str, mut i: usize, end: usize) {
        let mut depth = 0i32;
        while i < end {
            let t = self.toks[i];
            if t.kind == TokKind::Punct && matches!(t.text.as_str(), "(" | "[" | "{") {
                depth += 1;
            } else if t.kind == TokKind::Punct && matches!(t.text.as_str(), ")" | "]" | "}") {
                depth -= 1;
            } else if depth == 0
                && t.kind == TokKind::Ident
                && t.text != "pub"
                && t.text != "crate"
                && self.is(i + 1, TokKind::Punct, ":")
                && !self.is(i + 2, TokKind::Punct, ":")
            {
                let mut j = i + 2;
                let mut d2 = 0i32;
                let mut ty = Vec::new();
                while j < end {
                    let tj = self.toks[j];
                    if tj.kind == TokKind::Punct {
                        match tj.text.as_str() {
                            "(" | "[" | "{" | "<" => d2 += 1,
                            ")" | "]" | "}" | ">" => d2 -= 1,
                            "," if d2 <= 0 => break,
                            _ => {}
                        }
                    } else if tj.kind == TokKind::Ident {
                        ty.push(tj.text.clone());
                    }
                    j += 1;
                }
                self.ast.fields.push((sname.to_string(), t.text.clone(), ty));
                i = j;
                continue;
            }
            i += 1;
        }
    }

    /// `i` at `fn`; parse signature + body, return the index past it.
    fn function(
        &mut self,
        i: usize,
        end: usize,
        module: &[String],
        impl_type: Option<&str>,
    ) -> usize {
        let name = self.toks[i + 1].text.clone();
        let line = self.toks[i].line;
        let mut fd = FnDef {
            module: module.to_vec(),
            impl_type: impl_type.map(str::to_string),
            name,
            line,
            is_test: self.f.is_test_file || self.f.in_test_region(line),
            ..Default::default()
        };
        let mut j = i + 2;
        if self.is(j, TokKind::Punct, "<") {
            j = self.skip_angles(j);
        }
        if !self.is(j, TokKind::Punct, "(") {
            return j;
        }
        let close_paren = self.skip_balanced(j, "(", ")");
        self.params(&mut fd, j + 1, close_paren.saturating_sub(1));
        j = close_paren;
        // Return type / where clause: scan to the body `{` or a `;`.
        let mut depth = 0i32;
        while j < end {
            let t = self.toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "<" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "-" if self.is(j + 1, TokKind::Punct, ">") && self.adjacent(j, j + 1) => {
                        j += 2;
                        continue;
                    }
                    ">" => depth -= 1,
                    "{" if depth <= 0 => break,
                    ";" if depth <= 0 => {
                        self.ast.fns.push(fd);
                        return j + 1;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        if j >= end {
            self.ast.fns.push(fd);
            return j;
        }
        let body_close = self.skip_balanced(j, "{", "}");
        self.body(&mut fd, j + 1, body_close.saturating_sub(1));
        self.ast.fns.push(fd);
        body_close
    }

    fn params(&mut self, fd: &mut FnDef, mut i: usize, end: usize) {
        let mut depth = 0i32;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new()];
        while i < end {
            let t = self.toks[i];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" | "<" => depth += 1,
                    ")" | "]" | "}" | ">" => depth -= 1,
                    "," if depth <= 0 => {
                        groups.push(Vec::new());
                        i += 1;
                        continue;
                    }
                    _ => {}
                }
            }
            if let Some(g) = groups.last_mut() {
                g.push(i);
            }
            i += 1;
        }
        if let Some(first) = groups.first() {
            fd.has_self = first.iter().any(|&k| self.ident(k) == Some("self"));
        }
        for g in &groups {
            // find the top-level `:` (not `::`); name = last ident before
            let mut d = 0i32;
            let mut colon = None;
            for (w, &k) in g.iter().enumerate() {
                let t = self.toks[k];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" | "<" => d += 1,
                        ")" | "]" | "}" | ">" => d -= 1,
                        ":" if d == 0 => {
                            if g.get(w + 1).is_some_and(|&k2| self.is(k2, TokKind::Punct, ":")) {
                                continue;
                            }
                            colon = Some(w);
                            break;
                        }
                        _ => {}
                    }
                }
            }
            let Some(cw) = colon else { continue };
            let name = g[..cw]
                .iter()
                .rev()
                .filter_map(|&k| self.ident(k))
                .find(|s| *s != "mut" && *s != "ref");
            let ty: Vec<String> = g[cw + 1..]
                .iter()
                .filter_map(|&k| self.ident(k))
                .map(str::to_string)
                .collect();
            if let Some(name) = name {
                fd.params.push((name.to_string(), ty));
            }
        }
    }

    fn body(&mut self, fd: &mut FnDef, lo: usize, hi: usize) {
        let mut i = lo;
        while i < hi {
            let t = self.toks[i];
            if t.kind == TokKind::Ident {
                if t.text == "BTreeMap" || t.text == "BTreeSet" {
                    fd.btree_mentions.push(t.line);
                }
                let prev_dot = i > lo && self.is(i - 1, TokKind::Punct, ".");
                let next_paren = i + 1 < hi && self.is(i + 1, TokKind::Punct, "(");
                if (t.text == "unwrap" || t.text == "expect") && prev_dot && next_paren {
                    fd.panics.push(PanicSite {
                        what: format!(".{}()", t.text),
                        line: t.line,
                        col: t.col,
                    });
                    i += 1;
                    continue;
                }
                if t.text == "panic" && i + 1 < hi && self.is(i + 1, TokKind::Punct, "!") {
                    fd.panics.push(PanicSite {
                        what: "panic!".to_string(),
                        line: t.line,
                        col: t.col,
                    });
                    i += 1;
                    continue;
                }
                if next_paren && !KEYWORDS.contains(&t.text.as_str()) {
                    if prev_dot {
                        let (root, last) = self.receiver(lo, i - 1);
                        fd.methods.push(MethodSite {
                            name: t.text.clone(),
                            recv_root: root,
                            recv_last: last,
                            line: t.line,
                            col: t.col,
                        });
                    } else {
                        let mut path = vec![t.text.clone()];
                        let mut j = i;
                        while j >= lo + 3
                            && self.is(j - 1, TokKind::Punct, ":")
                            && self.is(j - 2, TokKind::Punct, ":")
                            && self.ident(j - 3).is_some()
                        {
                            path.insert(0, self.toks[j - 3].text.clone());
                            j -= 3;
                        }
                        fd.calls.push(CallSite { path, line: t.line, col: t.col });
                    }
                    i += 1;
                    continue;
                }
                if t.text == "for" && !self.is(i + 1, TokKind::Punct, "<") {
                    self.for_loop(fd, i + 1, hi);
                    i += 1;
                    continue;
                }
                if t.text == "let" {
                    self.let_binding(fd, i + 1, hi);
                    i += 1;
                    continue;
                }
                i += 1;
                continue;
            }
            if t.is(TokKind::Punct, "[") && i > lo {
                let prev = self.toks[i - 1];
                let indexes_expr = (prev.kind == TokKind::Ident
                    && !KEYWORDS.contains(&prev.text.as_str()))
                    || prev.is(TokKind::Punct, ")")
                    || prev.is(TokKind::Punct, "]");
                if indexes_expr {
                    let mut j = i + 1;
                    if j < hi
                        && (self.is(j, TokKind::Punct, "&") || self.is(j, TokKind::Punct, "*"))
                    {
                        j += 1;
                    }
                    if j + 1 < hi
                        && self.ident(j).is_some_and(|s| !KEYWORDS.contains(&s))
                        && self.is(j + 1, TokKind::Punct, "]")
                    {
                        fd.panics.push(PanicSite {
                            what: format!("indexing `[{}]`", self.toks[j].text),
                            line: t.line,
                            col: t.col,
                        });
                    }
                }
                i += 1;
                continue;
            }
            if t.kind == TokKind::Punct && matches!(t.text.as_str(), "+" | "-" | "<" | ">" | "=" | "!")
            {
                let (op, width) = self.merge_op(i, hi);
                if let Some(op) = op {
                    let (lhs, lhs_mul) = self.backward_term(lo, i.wrapping_sub(1), i > lo);
                    let (rhs, rhs_mul) = self.forward_term(i + width, hi);
                    fd.binaries.push(BinarySite {
                        op,
                        lhs,
                        lhs_mul,
                        rhs,
                        rhs_mul,
                        line: t.line,
                        col: t.col,
                    });
                }
                i += width;
                continue;
            }
            i += 1;
        }
    }

    /// Merge adjacent punct pairs into compound operators. Returns the
    /// unit-bearing operator (if any) and the token width consumed.
    fn merge_op(&self, i: usize, hi: usize) -> (Option<&'static str>, usize) {
        let c1 = self.toks[i].text.as_str();
        let c2 = if i + 1 < hi
            && self.toks[i + 1].kind == TokKind::Punct
            && self.adjacent(i, i + 1)
        {
            Some(self.toks[i + 1].text.as_str())
        } else {
            None
        };
        if let Some(c2) = c2 {
            let two = [
                ("-", ">", None),
                ("=", ">", None),
                ("<", "<", None),
                (">", ">", None),
                ("<", "=", Some("<=")),
                (">", "=", Some(">=")),
                ("=", "=", Some("==")),
                ("!", "=", Some("!=")),
                ("+", "=", Some("+=")),
                ("-", "=", Some("-=")),
            ];
            for (a, b, op) in two {
                if c1 == a && c2 == b {
                    return (op, 2);
                }
            }
        }
        match c1 {
            "+" => (Some("+"), 1),
            "-" => (Some("-"), 1),
            "<" => (Some("<"), 1),
            ">" => (Some(">"), 1),
            _ => (None, 1),
        }
    }

    /// Receiver chain of a method call; `dot` is the index of the `.`
    /// before the method name.
    fn receiver(&self, lo: usize, dot: usize) -> (Option<String>, Option<String>) {
        let mut chain: Vec<String> = Vec::new();
        let mut j = dot as isize - 1;
        let lo = lo as isize;
        while j >= lo {
            let t = self.toks[j as usize];
            if t.is(TokKind::Punct, "?") {
                j -= 1;
                continue;
            }
            if t.is(TokKind::Punct, ")") || t.is(TokKind::Punct, "]") {
                let (open, close) = if t.text == ")" { ("(", ")") } else { ("[", "]") };
                let opener = self.match_back(lo as usize, j as usize, close, open);
                j = opener as isize - 1;
                if j >= lo && self.toks[j as usize].kind == TokKind::Ident {
                    chain.push(self.toks[j as usize].text.clone());
                    j -= 1;
                } else {
                    break;
                }
            } else if t.kind == TokKind::Ident {
                chain.push(t.text.clone());
                j -= 1;
            } else {
                break;
            }
            if j >= lo && self.toks[j as usize].is(TokKind::Punct, ".") {
                j -= 1;
                continue;
            }
            if j - 1 >= lo
                && self.toks[j as usize].is(TokKind::Punct, ":")
                && self.toks[(j - 1) as usize].is(TokKind::Punct, ":")
            {
                j -= 2;
                continue;
            }
            break;
        }
        let root = chain.last().cloned();
        let last = chain.first().cloned();
        (root, last)
    }

    /// Backward-match `close` at index `j` to its `open`.
    fn match_back(&self, lo: usize, j: usize, close: &str, open: &str) -> usize {
        let mut depth = 0i32;
        let mut k = j as isize;
        while k >= lo as isize {
            let t = self.toks[k as usize];
            if t.is(TokKind::Punct, close) {
                depth += 1;
            } else if t.is(TokKind::Punct, open) {
                depth -= 1;
                if depth == 0 {
                    return k as usize;
                }
            }
            k -= 1;
        }
        lo
    }

    /// The operand term ending at index `j` (exclusive-end form handled
    /// by the caller passing `valid`). Returns `(last_ident, mul_adj)`.
    fn backward_term(&self, lo: usize, j: usize, valid: bool) -> (Option<String>, bool) {
        if !valid || j < lo || j >= self.toks.len() {
            return (None, false);
        }
        let t = self.toks[j];
        let (mut term, mut start) = if t.is(TokKind::Punct, ")") {
            let opener = self.match_back(lo, j, ")", "(");
            if opener == lo && !self.toks[lo].is(TokKind::Punct, "(") {
                return (None, false);
            }
            if opener == 0 {
                return (None, false);
            }
            let k = opener - 1;
            if k < lo {
                return (None, false);
            }
            let tk = self.toks[k];
            if tk.kind == TokKind::Ident && !KEYWORDS.contains(&tk.text.as_str()) {
                (tk.text.clone(), k)
            } else {
                return (None, false);
            }
        } else if t.kind == TokKind::Ident && !KEYWORDS.contains(&t.text.as_str()) {
            if t.kind == TokKind::Ident && PRIMITIVES.contains(&t.text.as_str()) {
                // `x as f64`: see through the cast to the real term.
                if j >= lo + 2 && self.ident(j - 1) == Some("as") {
                    return self.backward_term(lo, j - 2, true);
                }
            }
            (t.text.clone(), j)
        } else {
            return (None, false);
        };
        // Absorb the leading `.`/`::` path so mul-adjacency looks at the
        // token before the whole chain.
        loop {
            if start >= lo + 2
                && self.toks[start - 1].is(TokKind::Punct, ".")
                && self.toks[start - 2].kind == TokKind::Ident
            {
                start -= 2;
            } else if start >= lo + 3
                && self.toks[start - 1].is(TokKind::Punct, ":")
                && self.toks[start - 2].is(TokKind::Punct, ":")
                && self.toks[start - 3].kind == TokKind::Ident
            {
                start -= 3;
            } else {
                break;
            }
        }
        if term.is_empty() {
            term.clear();
        }
        let mul = start > lo
            && self.toks[start - 1].kind == TokKind::Punct
            && matches!(self.toks[start - 1].text.as_str(), "*" | "/");
        (Some(term), mul)
    }

    /// The operand term starting at index `i`.
    fn forward_term(&self, mut i: usize, hi: usize) -> (Option<String>, bool) {
        while i < hi
            && self.toks[i].kind == TokKind::Punct
            && matches!(self.toks[i].text.as_str(), "&" | "*" | "-")
        {
            i += 1;
        }
        if i >= hi {
            return (None, false);
        }
        let t = self.toks[i];
        if t.is(TokKind::Punct, "(") {
            return (None, false); // parenthesized group, not a simple term
        }
        if t.kind != TokKind::Ident || KEYWORDS.contains(&t.text.as_str()) {
            return (None, false);
        }
        let mut term = t.text.clone();
        let mut j = i + 1;
        loop {
            if j < hi && self.toks[j].is(TokKind::Punct, ".") && self.ident(j + 1).is_some() {
                term = self.toks[j + 1].text.clone();
                j += 2;
            } else if j + 2 < hi
                && self.toks[j].is(TokKind::Punct, ":")
                && self.toks[j + 1].is(TokKind::Punct, ":")
                && self.ident(j + 2).is_some()
            {
                term = self.toks[j + 2].text.clone();
                j += 3;
            } else if j < hi && self.toks[j].is(TokKind::Punct, "(") {
                j = self.skip_balanced(j, "(", ")").min(hi);
                break;
            } else {
                break;
            }
        }
        // `term as f64 / other`: the cast does not end the mul context.
        while j < hi && self.ident(j) == Some("as") {
            j += 1;
            while j < hi && self.toks[j].kind == TokKind::Ident {
                j += 1;
                if j + 1 < hi
                    && self.toks[j].is(TokKind::Punct, ":")
                    && self.toks[j + 1].is(TokKind::Punct, ":")
                {
                    j += 2;
                } else {
                    break;
                }
            }
        }
        let mul = j < hi
            && self.toks[j].kind == TokKind::Punct
            && matches!(self.toks[j].text.as_str(), "*" | "/");
        (Some(term), mul)
    }

    fn for_loop(&mut self, fd: &mut FnDef, mut i: usize, hi: usize) {
        // pattern until `in` (bail on `{` — malformed / not a loop)
        while i < hi && self.ident(i) != Some("in") {
            if self.is(i, TokKind::Punct, "{") {
                return;
            }
            i += 1;
        }
        i += 1;
        let mut depth = 0i32;
        let mut idents = Vec::new();
        let mut root: Option<(String, u32, u32)> = None;
        while i < hi {
            let t = self.toks[i];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth <= 0 => break,
                    _ => {}
                }
            } else if t.kind == TokKind::Ident {
                if root.is_none() && t.text != "mut" && t.text != "ref" {
                    root = Some((t.text.clone(), t.line, t.col));
                }
                idents.push(t.text.clone());
            }
            i += 1;
        }
        if let Some((root, line, col)) = root {
            fd.fors.push(ForSite { root, idents, line, col });
        }
    }

    fn let_binding(&mut self, fd: &mut FnDef, mut i: usize, hi: usize) {
        if self.ident(i) == Some("mut") {
            i += 1;
        }
        let Some(name) = self.ident(i).map(str::to_string) else {
            return;
        };
        i += 1;
        let mut ty = Vec::new();
        let mut init = Vec::new();
        if self.is(i, TokKind::Punct, ":") {
            i += 1;
            let mut depth = 0i32;
            while i < hi {
                let t = self.toks[i];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" | "<" => depth += 1,
                        ")" | "]" | "}" | ">" => depth -= 1,
                        "=" | ";" if depth <= 0 => break,
                        _ => {}
                    }
                } else if t.kind == TokKind::Ident {
                    ty.push(t.text.clone());
                }
                i += 1;
            }
        }
        if self.is(i, TokKind::Punct, "=") {
            i += 1;
            let mut depth = 0i32;
            let mut steps = 0;
            while i < hi && steps < 200 {
                let t = self.toks[i];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth -= 1,
                        ";" if depth <= 0 => break,
                        _ => {}
                    }
                } else if t.kind == TokKind::Ident {
                    init.push(t.text.clone());
                }
                i += 1;
                steps += 1;
            }
        }
        fd.lets.push(LetSite { name, ty, init });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(path: &str, src: &str) -> FileAst {
        parse_file(&SourceFile::parse(path, src))
    }

    #[test]
    fn fn_signatures_and_module_paths() {
        let ast = parse(
            "rust/src/coordinator/batcher.rs",
            "pub fn free(a: u64, spec: WorkloadSpec) -> u64 { a }\n\
             impl Batcher { fn queued(&self) -> usize { self.pending.len() } }\n",
        );
        assert_eq!(ast.fns.len(), 2);
        assert_eq!(ast.fns[0].qualified(), "coordinator::batcher::free");
        assert_eq!(ast.fns[0].params.len(), 2);
        assert!(!ast.fns[0].has_self);
        assert_eq!(ast.fns[1].qualified(), "coordinator::batcher::Batcher::queued");
        assert!(ast.fns[1].has_self);
    }

    #[test]
    fn nested_generics_do_not_eat_the_fn_body() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f(m: BTreeMap<String, Vec<Vec<u8>>>) -> Option<Vec<u8>> { g(); None }\n",
        );
        assert_eq!(ast.fns.len(), 1);
        assert_eq!(ast.fns[0].calls.len(), 1);
        assert_eq!(ast.fns[0].calls[0].path, vec!["g".to_string()]);
    }

    #[test]
    fn raw_strings_and_idents_stay_out_of_the_way() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f() { let r#type = r#\"fn fake() { panic!() }\"#; use_it(r#type); }\n",
        );
        assert_eq!(ast.fns.len(), 1);
        assert!(ast.fns[0].panics.is_empty(), "panic inside a raw string is data");
        assert_eq!(ast.fns[0].calls.len(), 1);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f<'a>(x: &'a str) -> &'a str { let c = 'x'; helper(x, c) }\n",
        );
        assert_eq!(ast.fns.len(), 1);
        assert_eq!(ast.fns[0].calls.len(), 1);
        assert_eq!(ast.fns[0].params[0].0, "x");
    }

    #[test]
    fn cfg_not_test_fns_are_live_code() {
        let ast = parse(
            "rust/src/model/x.rs",
            "#[cfg(not(test))]\nfn live() { x.unwrap(); }\n\
             #[cfg(test)]\nmod tests { fn t() { y.unwrap(); } }\n",
        );
        let live: Vec<_> = ast.fns.iter().filter(|f| !f.is_test).collect();
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].name, "live");
        assert_eq!(live[0].panics.len(), 1);
        let test: Vec<_> = ast.fns.iter().filter(|f| f.is_test).collect();
        assert_eq!(test.len(), 1);
    }

    #[test]
    fn method_receivers_root_and_last() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f(&self) { self.pending.values(); jobs.iter(); self.a.b.c.keys(); }\n",
        );
        let m = &ast.fns[0].methods;
        assert_eq!(m[0].name, "values");
        assert_eq!(m[0].recv_root.as_deref(), Some("self"));
        assert_eq!(m[0].recv_last.as_deref(), Some("pending"));
        assert_eq!(m[1].recv_root.as_deref(), Some("jobs"));
        assert_eq!(m[1].recv_last.as_deref(), Some("jobs"));
        assert_eq!(m[2].recv_last.as_deref(), Some("c"));
    }

    #[test]
    fn binary_terms_see_through_casts_and_respect_mul_context() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f() { let x = setup_ns + bytes as f64 / beta_gbps; let y = busy_ns + state_bytes; }\n",
        );
        let b = &ast.fns[0].binaries;
        let plus: Vec<_> = b.iter().filter(|s| s.op == "+").collect();
        assert_eq!(plus.len(), 2);
        assert_eq!(plus[0].rhs.as_deref(), Some("bytes"));
        assert!(plus[0].rhs_mul, "cast-then-divide keeps the mul context");
        assert_eq!(plus[1].lhs.as_deref(), Some("busy_ns"));
        assert_eq!(plus[1].rhs.as_deref(), Some("state_bytes"));
        assert!(!plus[1].rhs_mul);
    }

    #[test]
    fn shift_and_arrow_are_not_comparisons() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f() -> u64 { let a_ns = 1u64 << 3; map(|x| -> u64 { x }); a_ns }\n",
        );
        assert!(ast.fns[0].binaries.iter().all(|b| b.op != "<" && b.op != ">"));
    }

    #[test]
    fn struct_fields_record_type_idents() {
        let ast = parse(
            "rust/src/model/x.rs",
            "pub struct S { pub jobs: HashMap<u64, Job>, names: Vec<String> }\n",
        );
        assert_eq!(ast.fields.len(), 2);
        assert_eq!(ast.fields[0].1, "jobs");
        assert!(ast.fields[0].2.contains(&"HashMap".to_string()));
        assert!(!ast.fields[1].2.contains(&"HashMap".to_string()));
    }

    #[test]
    fn for_loops_capture_the_iterated_expression() {
        let ast = parse(
            "rust/src/model/x.rs",
            "fn f(&self) { for (k, v) in self.index.iter() { use_it(k, v); } }\n",
        );
        let fo = &ast.fns[0].fors;
        assert_eq!(fo.len(), 1);
        assert_eq!(fo[0].root, "self");
        assert!(fo[0].idents.contains(&"index".to_string()));
    }
}
