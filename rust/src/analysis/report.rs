//! Diagnostic model and renderers for `npuperf lint`.
//!
//! Two outputs from one finding list: a compiler-style human rendering
//! for terminals, and a JSONL report (one object per finding, in the
//! style of the `obs` event log) for CI artifacts and tooling. Findings
//! waived by a reasoned `lint:allow` pragma stay in the report —
//! `allowed` carries the recorded reason — but do not fail the run.

use crate::obs::export::escape_json;

/// One diagnostic from one rule at one source position.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule name (`no-wall-clock`, …) or `pragma` for waiver misuse.
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
    /// `Some(reason)` when a `lint:allow` pragma waived this finding.
    pub allowed: Option<String>,
}

/// The full result of one lint pass.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl LintReport {
    /// Deterministic order: by file, then position, then rule.
    pub fn sort(&mut self) {
        self.findings
            .sort_by(|a, b| (&a.file, a.line, a.col, a.rule).cmp(&(&b.file, b.line, b.col, b.rule)));
    }

    /// Findings that actually fail the run (not pragma-waived).
    pub fn active(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.allowed.is_none())
    }

    pub fn is_clean(&self) -> bool {
        self.active().next().is_none()
    }

    /// Compiler-style terminal rendering.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in self.active() {
            out += &format!("{}:{}:{}: [{}] {}\n", f.file, f.line, f.col, f.rule, f.message);
        }
        let waived = self.findings.len() - self.active().count();
        let active = self.active().count();
        if active == 0 {
            out += &format!(
                "lint: clean — {} files scanned, {waived} finding(s) waived by pragma\n",
                self.files_scanned
            );
        } else {
            out += &format!(
                "lint: {active} finding(s) in {} files scanned ({waived} waived by pragma)\n",
                self.files_scanned
            );
        }
        out
    }

    /// One JSON object per finding (waived ones included, with their
    /// reason), each line independently parseable.
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let allowed = match &f.allowed {
                Some(r) => format!("\"{}\"", escape_json(r)),
                None => "null".to_string(),
            };
            out += &format!(
                "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"col\":{},\"message\":\"{}\",\"allowed\":{}}}\n",
                escape_json(f.rule),
                escape_json(&f.file),
                f.line,
                f.col,
                escape_json(&f.message),
                allowed
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(file: &str, line: u32, allowed: Option<&str>) -> Finding {
        Finding {
            rule: "no-wall-clock",
            file: file.to_string(),
            line,
            col: 1,
            message: "host time read".to_string(),
            allowed: allowed.map(str::to_string),
        }
    }

    #[test]
    fn waived_findings_do_not_fail_but_are_reported() {
        let mut rep = LintReport {
            findings: vec![finding("b.rs", 2, Some("bench")), finding("a.rs", 9, None)],
            files_scanned: 2,
        };
        rep.sort();
        assert!(!rep.is_clean());
        assert_eq!(rep.findings[0].file, "a.rs", "sorted by file");
        let human = rep.render_human();
        assert!(human.contains("a.rs:9:1: [no-wall-clock]"));
        assert!(!human.contains("b.rs:2"), "waived finding is not an error line");
        assert!(human.contains("1 waived"));
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let rep = LintReport {
            findings: vec![finding("a.rs", 1, None), finding("b \"q\".rs", 2, Some("why"))],
            files_scanned: 2,
        };
        let jsonl = rep.render_jsonl();
        assert_eq!(jsonl.lines().count(), 2);
        for line in jsonl.lines() {
            crate::obs::validate_json(line).expect(line);
        }
        assert!(jsonl.contains("\"allowed\":\"why\""));
    }
}
