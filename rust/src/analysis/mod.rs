//! `npuperf lint`: project-specific static analysis for the serving
//! stack's three non-negotiables — determinism (no stray wall-clock
//! reads), panic-freedom on the serve path, and metric/doc consistency.
//!
//! The repo's conformance story (seeded replays, golden expositions,
//! differential checks — see `docs/TESTING.md`) is *dynamic*: it proves
//! the code that ran was deterministic. This subsystem is the static
//! half: a dependency-free token-level scanner ([`lexer`]), a
//! lightweight recursive-descent parser ([`parser`]) feeding a
//! call-graph builder ([`callgraph`]), and a rule engine ([`rules`])
//! that keep the properties from regressing before anything runs.
//! Eight rules, catalogued with rationale and the `lint:allow` pragma
//! grammar in `docs/LINTS.md`:
//!
//! 1. `no-wall-clock` — host time is read only in `coordinator::clock`;
//! 2. `no-panic-serve-path` — no `unwrap`/`expect`/`panic!`/indexing in
//!    the serve-path modules;
//! 3. `metric-names-single-source` — metric names live in
//!    `metrics::names` and every one is documented;
//! 4. `label-set-consistency` — one metric, one label-key set;
//! 5. `golden-fixture-hygiene` — golden-dir I/O goes through
//!    `testkit::golden`;
//! 6. `panic-reachability` — no panic site transitively reachable from
//!    the serve entry points, with the full call chain reported;
//! 7. `unit-consistency` — no arithmetic mixing `_ns`/`_bytes`/`_gbps`/…
//!    quantities (multiply/divide derives units and is exempt);
//! 8. `nondet-iteration` — no hash-container iteration on paths that
//!    feed exporters, reports, or golden fixtures.
//!
//! Findings render human-readable, as JSONL, and as SARIF 2.1.0
//! ([`sarif`]); the checked-in `lint-baseline.json` ratchet
//! ([`baseline`]) only ever shrinks. The pass self-hosts: `npuperf
//! lint` exits 0 on this repo at HEAD, and `selftest`'s
//! `lint-conformance` / `semantic-lint-conformance` sections prove each
//! rule still fires on embedded known-bad fixtures.

pub mod baseline;
pub mod callgraph;
pub mod lexer;
pub mod parser;
pub mod report;
pub mod rules;
pub mod sarif;
pub mod source;

use std::path::{Path, PathBuf};

use anyhow::Context;

pub use report::{Finding, LintReport};
pub use source::SourceFile;

/// A configured lint pass: feed it sources, run, get a [`LintReport`].
#[derive(Debug, Default)]
pub struct Analyzer {
    files: Vec<SourceFile>,
    observability_doc: Option<String>,
}

impl Analyzer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one source file. `path` should be repo-relative with `/`
    /// separators — rule scopes key off it (`rust/tests/` marks test
    /// files, `coordinator/clock.rs` is the blessed clock module, …).
    pub fn add_source(&mut self, path: &str, src: &str) {
        self.files.push(SourceFile::parse(path, src));
    }

    /// Provide `docs/OBSERVABILITY.md` so rule 3 can cross-check that
    /// every declared metric name is documented.
    pub fn set_observability_doc(&mut self, text: &str) {
        self.observability_doc = Some(text.to_string());
    }

    /// Run every rule and return the sorted report.
    pub fn run(mut self) -> LintReport {
        self.files.sort_by(|a, b| a.path.cmp(&b.path));
        let mut rep = LintReport {
            findings: rules::run_all(&self.files, self.observability_doc.as_deref()),
            files_scanned: self.files.len(),
        };
        rep.sort();
        rep
    }
}

/// Lint the repository rooted at `root`: every `.rs` under `rust/src`,
/// `rust/tests`, `rust/benches`, and `examples` (golden fixtures and
/// lint fixtures excluded), with `docs/OBSERVABILITY.md` wired in for
/// the doc-sync check.
pub fn lint_repo(root: &Path) -> anyhow::Result<LintReport> {
    let src_root = root.join("rust").join("src");
    if !src_root.is_dir() {
        anyhow::bail!(
            "{} has no rust/src directory — pass the repo root: npuperf lint <repo-root>",
            root.display()
        );
    }
    let mut paths = Vec::new();
    collect_rs(&src_root, &mut paths)?;
    for extra in
        [root.join("rust").join("tests"), root.join("rust").join("benches"), root.join("examples")]
    {
        if extra.is_dir() {
            collect_rs(&extra, &mut paths)?;
        }
    }
    paths.sort();
    let mut analyzer = Analyzer::new();
    for p in &paths {
        let rel = p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/");
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {rel}"))?;
        analyzer.add_source(&rel, &text);
    }
    if let Ok(doc) = std::fs::read_to_string(root.join("docs").join("OBSERVABILITY.md")) {
        analyzer.set_observability_doc(&doc);
    }
    Ok(analyzer.run())
}

/// Recursively collect `.rs` files, skipping data directories: golden
/// fixtures (not Rust) and the lint's own known-bad fixture corpus.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("listing {}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "golden" || name == "lint_fixtures" {
                continue;
            }
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run one embedded fixture through a fresh [`Analyzer`] under a
/// synthetic path (paths drive rule scoping).
fn lint_fixture(path: &str, src: &str) -> LintReport {
    let mut a = Analyzer::new();
    a.add_source(path, src);
    a.run()
}

/// The `lint-conformance` selftest section: prove every rule fires on
/// its known-bad fixture, stays quiet on the known-good twin, and that
/// the pragma waiver round-trips (reason recorded, missing reason
/// rejected). The fixtures are embedded at compile time, so the check
/// is independent of the working directory.
pub fn selftest_section() -> Result<String, String> {
    // (rule, bad fixture path+src, good fixture path+src). Synthetic
    // paths place each fixture in the scope its rule watches.
    let cases: [(&'static str, (&str, &str), (&str, &str)); 5] = [
        (
            rules::NO_WALL_CLOCK,
            (
                "rust/src/report/fixture.rs",
                include_str!("../../tests/lint_fixtures/no_wall_clock_bad.rs"),
            ),
            (
                "rust/src/report/fixture.rs",
                include_str!("../../tests/lint_fixtures/no_wall_clock_good.rs"),
            ),
        ),
        (
            rules::NO_PANIC,
            (
                "rust/src/coordinator/server.rs",
                include_str!("../../tests/lint_fixtures/no_panic_bad.rs"),
            ),
            (
                "rust/src/coordinator/server.rs",
                include_str!("../../tests/lint_fixtures/no_panic_good.rs"),
            ),
        ),
        (
            rules::METRIC_NAMES,
            (
                "rust/src/obs/fixture.rs",
                include_str!("../../tests/lint_fixtures/metric_names_bad.rs"),
            ),
            (
                "rust/src/obs/fixture.rs",
                include_str!("../../tests/lint_fixtures/metric_names_good.rs"),
            ),
        ),
        (
            rules::LABEL_SETS,
            (
                "rust/src/coordinator/fixture.rs",
                include_str!("../../tests/lint_fixtures/label_set_bad.rs"),
            ),
            (
                "rust/src/coordinator/fixture.rs",
                include_str!("../../tests/lint_fixtures/label_set_good.rs"),
            ),
        ),
        (
            rules::GOLDEN_HYGIENE,
            (
                "rust/tests/fixture.rs",
                include_str!("../../tests/lint_fixtures/golden_hygiene_bad.rs"),
            ),
            (
                "rust/tests/fixture.rs",
                include_str!("../../tests/lint_fixtures/golden_hygiene_good.rs"),
            ),
        ),
    ];
    for (rule, (bad_path, bad_src), (good_path, good_src)) in cases {
        let bad = lint_fixture(bad_path, bad_src);
        if !bad.active().any(|f| f.rule == rule) {
            return Err(format!("rule {rule} did not fire on its known-bad fixture"));
        }
        let good = lint_fixture(good_path, good_src);
        if good.findings.iter().any(|f| f.rule == rule) {
            return Err(format!("rule {rule} fired on its known-good fixture"));
        }
    }

    let waived = lint_fixture(
        "rust/src/memory/fixture.rs",
        include_str!("../../tests/lint_fixtures/pragma_roundtrip.rs"),
    );
    if !waived.is_clean() {
        return Err(format!(
            "reasoned pragma did not waive its finding: {}",
            waived.render_human()
        ));
    }
    let recorded = waived.findings.iter().any(|f| {
        f.rule == rules::NO_PANIC
            && f.allowed.as_deref().is_some_and(|r| r.contains("reasoned waiver"))
    });
    if !recorded {
        return Err("waived finding lost its pragma reason".to_string());
    }

    let bare = lint_fixture(
        "rust/src/memory/fixture.rs",
        include_str!("../../tests/lint_fixtures/pragma_missing_reason.rs"),
    );
    let pragma_reported = bare.active().any(|f| f.rule == rules::PRAGMA);
    let finding_active = bare.active().any(|f| f.rule == rules::NO_PANIC);
    if !pragma_reported || !finding_active {
        return Err(format!(
            "reason-less pragma must be reported and must not waive (got: {})",
            bare.render_human()
        ));
    }

    Ok(format!(
        "{} rules fire on bad fixtures and stay quiet on good ones; pragma waiver round-trips",
        cases.len()
    ))
}

/// The `semantic-lint-conformance` selftest section: the parser-backed
/// rules against compile-time-embedded fixtures. Proves the transitive
/// panic chain names every frame, the unit rule respects derived-unit
/// contexts, and the nondet rule distinguishes hash from BTree
/// iteration.
pub fn semantic_selftest_section() -> Result<String, String> {
    let entry = include_str!("../../tests/lint_fixtures/panic_reach_entry.rs");
    let run_pair = |callee_src: &str| -> LintReport {
        let mut a = Analyzer::new();
        a.add_source("rust/src/coordinator/dispatch.rs", entry);
        a.add_source("rust/src/ops/fixture.rs", callee_src);
        a.run()
    };
    let bad = run_pair(include_str!("../../tests/lint_fixtures/panic_reach_bad.rs"));
    let Some(finding) = bad.active().find(|f| f.rule == rules::PANIC_REACH) else {
        return Err("panic-reachability did not fire on the planted transitive panic".to_string());
    };
    for frame in [
        "coordinator::dispatch::Dispatcher::dispatch",
        "ops::fixture::lower_stage",
        "ops::fixture::plan_tail",
    ] {
        if !finding.message.contains(frame) {
            return Err(format!(
                "panic-reachability chain is missing frame `{frame}`: {}",
                finding.message
            ));
        }
    }
    let good = run_pair(include_str!("../../tests/lint_fixtures/panic_reach_good.rs"));
    if good.findings.iter().any(|f| f.rule == rules::PANIC_REACH) {
        return Err("panic-reachability fired on the panic-free twin".to_string());
    }

    let pairs: [(&str, &str, &str, &str); 2] = [
        (
            rules::UNIT_CONSISTENCY,
            "rust/src/npu/fixture.rs",
            include_str!("../../tests/lint_fixtures/unit_mix_bad.rs"),
            include_str!("../../tests/lint_fixtures/unit_mix_good.rs"),
        ),
        (
            rules::NONDET_ITER,
            "rust/src/obs/fixture.rs",
            include_str!("../../tests/lint_fixtures/nondet_iter_bad.rs"),
            include_str!("../../tests/lint_fixtures/nondet_iter_good.rs"),
        ),
    ];
    for (rule, path, bad_src, good_src) in pairs {
        let bad = lint_fixture(path, bad_src);
        if !bad.active().any(|f| f.rule == rule) {
            return Err(format!("rule {rule} did not fire on its known-bad fixture"));
        }
        let good = lint_fixture(path, good_src);
        if good.findings.iter().any(|f| f.rule == rule) {
            return Err(format!("rule {rule} fired on its known-good fixture"));
        }
    }

    Ok("3 semantic rules fire on bad fixtures and stay quiet on good ones; \
        panic chain names every frame"
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selftest_section_passes() {
        selftest_section().expect("lint conformance");
    }

    #[test]
    fn semantic_selftest_section_passes() {
        semantic_selftest_section().expect("semantic lint conformance");
    }

    #[test]
    fn analyzer_report_is_sorted_and_jsonl_valid() {
        let mut a = Analyzer::new();
        a.add_source(
            "rust/src/memory/z.rs",
            "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        a.add_source(
            "rust/src/memory/a.rs",
            "pub fn g(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        let rep = a.run();
        assert_eq!(rep.files_scanned, 2);
        assert!(!rep.is_clean());
        assert!(rep.findings[0].file < rep.findings[1].file);
        for line in rep.render_jsonl().lines() {
            crate::obs::validate_json(line).expect(line);
        }
    }
}
