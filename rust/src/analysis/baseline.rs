//! The lint ratchet: a checked-in baseline (`lint-baseline.json`) of
//! active finding counts per (rule, file). A lint run compared against
//! the baseline fails on any *growth* — a new finding, or more findings
//! of a rule in a file than recorded — while *shrinkage* passes and is
//! reported so the baseline can be tightened (`--update-baseline`).
//! Waived findings never enter the baseline; they are already
//! individually justified in source.
//!
//! The file format is deliberately tiny (`{"entries":[{"rule":…,
//! "file":…,"count":…}]}`), rendered deterministically and parsed with
//! a purpose-built scanner — no serde, same as every other artifact in
//! this crate.

use std::collections::BTreeMap;

use crate::obs::export::escape_json;

use super::report::LintReport;

/// Active finding counts keyed by (rule, file).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Baseline {
    pub entries: BTreeMap<(String, String), usize>,
}

/// Outcome of a ratchet comparison.
#[derive(Clone, Debug, Default)]
pub struct RatchetOutcome {
    /// (rule, file, baseline count, current count) where current grew.
    pub regressions: Vec<(String, String, usize, usize)>,
    /// Same shape where current shrank — the baseline can be tightened.
    pub improvements: Vec<(String, String, usize, usize)>,
}

impl RatchetOutcome {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }

    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for (rule, file, was, now) in &self.regressions {
            out += &format!(
                "ratchet: [{rule}] {file}: {was} -> {now} active finding(s) — \
                 new findings fail the ratchet\n"
            );
        }
        for (rule, file, was, now) in &self.improvements {
            out += &format!(
                "ratchet: [{rule}] {file}: {was} -> {now} — shrank; tighten the \
                 baseline with --update-baseline\n"
            );
        }
        out
    }
}

impl Baseline {
    /// Count the *active* (non-waived) findings of a report.
    pub fn from_report(report: &LintReport) -> Baseline {
        let mut entries: BTreeMap<(String, String), usize> = BTreeMap::new();
        for f in report.active() {
            *entries.entry((f.rule.to_string(), f.file.clone())).or_insert(0) += 1;
        }
        Baseline { entries }
    }

    /// Deterministic JSON rendering (entries sorted by rule then file).
    pub fn render(&self) -> String {
        let mut out = String::from("{\"entries\":[");
        for (i, ((rule, file), count)) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out += &format!(
                "\n  {{\"rule\":\"{}\",\"file\":\"{}\",\"count\":{count}}}",
                escape_json(rule),
                escape_json(file)
            );
        }
        if !self.entries.is_empty() {
            out.push('\n');
        }
        out += "]}\n";
        out
    }

    /// Parse the baseline format. Strict about what it accepts: every
    /// entry object must carry string `rule`/`file` and numeric `count`.
    pub fn parse(text: &str) -> Result<Baseline, String> {
        crate::obs::validate_json(text.trim()).map_err(|e| format!("baseline is not valid JSON: {e}"))?;
        let mut entries: BTreeMap<(String, String), usize> = BTreeMap::new();
        let body = text
            .split_once("\"entries\"")
            .ok_or_else(|| "baseline has no \"entries\" key".to_string())?
            .1;
        let mut rest = body;
        while let Some(obj_start) = rest.find('{') {
            let obj_end = rest[obj_start..]
                .find('}')
                .map(|e| obj_start + e)
                .ok_or_else(|| "unterminated entry object".to_string())?;
            let obj = &rest[obj_start..=obj_end];
            let rule = extract_string(obj, "rule")?;
            let file = extract_string(obj, "file")?;
            let count = extract_number(obj, "count")?;
            if entries.insert((rule.clone(), file.clone()), count).is_some() {
                return Err(format!("duplicate baseline entry for [{rule}] {file}"));
            }
            rest = &rest[obj_end + 1..];
        }
        Ok(Baseline { entries })
    }

    /// Ratchet comparison: `self` is the recorded baseline, `current`
    /// the fresh run.
    pub fn check(&self, current: &Baseline) -> RatchetOutcome {
        let mut out = RatchetOutcome::default();
        let keys: std::collections::BTreeSet<&(String, String)> =
            self.entries.keys().chain(current.entries.keys()).collect();
        for key in keys {
            let was = self.entries.get(key).copied().unwrap_or(0);
            let now = current.entries.get(key).copied().unwrap_or(0);
            let row = (key.0.clone(), key.1.clone(), was, now);
            if now > was {
                out.regressions.push(row);
            } else if now < was {
                out.improvements.push(row);
            }
        }
        out
    }
}

/// `"key":"value"` — unescapes the two escapes our renderer produces.
fn extract_string(obj: &str, key: &str) -> Result<String, String> {
    let pat = format!("\"{key}\":\"");
    let start = obj.find(&pat).ok_or_else(|| format!("entry missing \"{key}\""))? + pat.len();
    let mut out = String::new();
    let mut chars = obj[start..].chars();
    loop {
        match chars.next() {
            Some('\\') => match chars.next() {
                Some(c) => out.push(c),
                None => return Err(format!("unterminated string for \"{key}\"")),
            },
            Some('"') => return Ok(out),
            Some(c) => out.push(c),
            None => return Err(format!("unterminated string for \"{key}\"")),
        }
    }
}

fn extract_number(obj: &str, key: &str) -> Result<usize, String> {
    let pat = format!("\"{key}\":");
    let start = obj.find(&pat).ok_or_else(|| format!("entry missing \"{key}\""))? + pat.len();
    let digits: String =
        obj[start..].chars().skip_while(|c| c.is_whitespace()).take_while(char::is_ascii_digit).collect();
    digits.parse().map_err(|_| format!("\"{key}\" is not a number"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::report::Finding;

    fn finding(rule: &'static str, file: &str, allowed: Option<&str>) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line: 1,
            col: 1,
            message: "m".to_string(),
            allowed: allowed.map(str::to_string),
        }
    }

    #[test]
    fn roundtrip_and_waived_exclusion() {
        let rep = LintReport {
            findings: vec![
                finding("no-wall-clock", "a.rs", None),
                finding("no-wall-clock", "a.rs", None),
                finding("unit-consistency", "b.rs", None),
                finding("panic-reachability", "c.rs", Some("waived")),
            ],
            files_scanned: 3,
        };
        let b = Baseline::from_report(&rep);
        assert_eq!(b.entries.len(), 2, "waived findings stay out of the baseline");
        let parsed = Baseline::parse(&b.render()).expect("roundtrip");
        assert_eq!(parsed, b);
        let empty = Baseline::parse(&Baseline::default().render()).expect("empty roundtrip");
        assert!(empty.entries.is_empty());
    }

    #[test]
    fn growth_fails_shrinkage_passes() {
        let mut old = Baseline::default();
        old.entries.insert(("no-wall-clock".into(), "a.rs".into()), 2);
        old.entries.insert(("unit-consistency".into(), "b.rs".into()), 1);
        // Shrink a.rs, clear b.rs entirely: passes, two improvements.
        let mut smaller = Baseline::default();
        smaller.entries.insert(("no-wall-clock".into(), "a.rs".into()), 1);
        let out = old.check(&smaller);
        assert!(out.passed());
        assert_eq!(out.improvements.len(), 2);
        assert!(out.render_human().contains("--update-baseline"));
        // Grow a.rs and add a new file: fails with two regressions.
        let mut bigger = old.clone();
        bigger.entries.insert(("no-wall-clock".into(), "a.rs".into()), 3);
        bigger.entries.insert(("nondet-iteration".into(), "c.rs".into()), 1);
        let out = old.check(&bigger);
        assert!(!out.passed());
        assert_eq!(out.regressions.len(), 2);
        assert!(out.render_human().contains("[nondet-iteration] c.rs: 0 -> 1"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Baseline::parse("not json").is_err());
        assert!(Baseline::parse("{\"nope\":[]}").is_err());
        assert!(Baseline::parse(
            "{\"entries\":[{\"rule\":\"r\",\"file\":\"f\",\"count\":1},\
             {\"rule\":\"r\",\"file\":\"f\",\"count\":2}]}"
        )
        .is_err(), "duplicate keys rejected");
    }
}
