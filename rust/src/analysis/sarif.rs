//! SARIF 2.1.0 rendering of a [`LintReport`] (`npuperf lint --sarif-out
//! F`), hand-serialized like every other JSON artifact in this crate.
//!
//! Shape: one `run`, the tool driver listing every rule, one `result`
//! per finding. Waived findings are emitted with `level: "note"` and an
//! in-source `suppression` carrying the pragma reason, so SARIF viewers
//! show waivers as suppressed rather than dropping them — same
//! visible-debt contract as the JSONL report.

use crate::obs::export::escape_json;

use super::report::LintReport;
use super::rules::{PRAGMA, RULE_NAMES};

const SARIF_VERSION: &str = "2.1.0";
const SARIF_SCHEMA: &str = "https://json.schemastore.org/sarif-2.1.0.json";

/// Render the full report as a single SARIF 2.1.0 document.
pub fn render_sarif(report: &LintReport) -> String {
    let mut rules = String::new();
    for (i, rule) in RULE_NAMES.iter().chain(std::iter::once(&PRAGMA)).enumerate() {
        if i > 0 {
            rules.push(',');
        }
        rules += &format!("{{\"id\":\"{}\"}}", escape_json(rule));
    }
    let mut results = String::new();
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            results.push(',');
        }
        let level = if f.allowed.is_some() { "note" } else { "error" };
        let suppressions = match &f.allowed {
            Some(reason) => format!(
                ",\"suppressions\":[{{\"kind\":\"inSource\",\"justification\":\"{}\"}}]",
                escape_json(reason)
            ),
            None => String::new(),
        };
        results += &format!(
            "{{\"ruleId\":\"{}\",\"level\":\"{level}\",\"message\":{{\"text\":\"{}\"}},\
             \"locations\":[{{\"physicalLocation\":{{\"artifactLocation\":{{\"uri\":\"{}\"}},\
             \"region\":{{\"startLine\":{},\"startColumn\":{}}}}}}}]{suppressions}}}",
            escape_json(f.rule),
            escape_json(&f.message),
            escape_json(&f.file),
            f.line,
            f.col
        );
    }
    format!(
        "{{\"$schema\":\"{SARIF_SCHEMA}\",\"version\":\"{SARIF_VERSION}\",\
         \"runs\":[{{\"tool\":{{\"driver\":{{\"name\":\"npuperf-lint\",\
         \"rules\":[{rules}]}}}},\"results\":[{results}]}}]}}\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::report::Finding;

    fn report() -> LintReport {
        LintReport {
            findings: vec![
                Finding {
                    rule: "no-wall-clock",
                    file: "rust/src/a.rs".to_string(),
                    line: 3,
                    col: 7,
                    message: "reads host \"time\"".to_string(),
                    allowed: None,
                },
                Finding {
                    rule: "panic-reachability",
                    file: "rust/src/b.rs".to_string(),
                    line: 9,
                    col: 1,
                    message: "chain".to_string(),
                    allowed: Some("dense indices".to_string()),
                },
            ],
            files_scanned: 2,
        }
    }

    #[test]
    fn sarif_is_valid_json_with_the_required_shape() {
        let doc = render_sarif(&report());
        crate::obs::validate_json(doc.trim()).expect("SARIF must be valid JSON");
        assert!(doc.contains("\"version\":\"2.1.0\""));
        assert!(doc.contains("sarif-2.1.0.json"));
        assert!(doc.contains("\"name\":\"npuperf-lint\""));
        assert!(doc.contains("\"ruleId\":\"no-wall-clock\""));
        assert!(doc.contains("\"startLine\":3"));
        assert!(doc.contains("\"startColumn\":7"));
        assert!(doc.contains("reads host \\\"time\\\""), "messages are escaped");
    }

    #[test]
    fn waived_findings_become_suppressed_notes() {
        let doc = render_sarif(&report());
        assert!(doc.contains("\"level\":\"error\""));
        assert!(doc.contains("\"level\":\"note\""));
        assert!(doc.contains("\"suppressions\":[{\"kind\":\"inSource\",\"justification\":\"dense indices\"}]"));
        let active_count = doc.matches("\"level\":\"error\"").count();
        assert_eq!(active_count, 1);
    }

    #[test]
    fn every_rule_is_declared_on_the_driver() {
        let doc = render_sarif(&LintReport::default());
        for rule in RULE_NAMES {
            assert!(doc.contains(&format!("{{\"id\":\"{rule}\"}}")), "{rule} missing");
        }
        assert!(doc.contains("{\"id\":\"pragma\"}"));
    }
}
