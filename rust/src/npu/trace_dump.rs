//! Chrome-trace export of a single simulation (`chrome://tracing` /
//! Perfetto).
//!
//! Emits the Trace Event Format (JSON array of complete "X" events), one
//! track per NPU engine, so a simulated operator's schedule can be
//! inspected visually: `npuperf trace <op> <N> --out trace.json`.
//!
//! Built on the shared [`crate::obs::export::ChromeTrace`] emitter — the
//! same machinery the coordinator uses for merged multi-request
//! timelines ([`crate::obs::export::chrome`]) — so comma discipline,
//! escaping, and timestamp ordering are correct by construction (the
//! hand-rolled predecessor emitted a trailing comma for empty graphs).

use crate::obs::export::ChromeTrace;
use crate::obs::trace::prim_label;
use crate::ops::{Engine, OpGraph};

use super::engine::{engine_index, SimTrace};

/// Render the trace as Chrome Trace Event JSON (timestamps in µs), one
/// thread per engine on a single process.
pub fn to_chrome_trace(graph: &OpGraph, trace: &SimTrace) -> String {
    let mut out = ChromeTrace::new();
    // Thread-name metadata per engine (exactly one record each).
    for e in Engine::ALL {
        out.thread_name(1, engine_index(e) as u32, e.name());
    }
    for node in &graph.nodes {
        let t = trace.timings[node.id];
        out.span(
            1,
            engine_index(node.prim.engine()) as u32,
            &prim_label(&node.prim),
            node.prim.engine().name(),
            t.start_ps as f64 / 1e6,
            (t.end_ps - t.start_ps) as f64 / 1e6,
            &format!(r#"{{"node":{},"deps":{}}}"#, node.id, node.deps.len()),
        );
    }
    out.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
    use crate::npu::engine::simulate;
    use crate::obs::validate_json;
    use crate::ops;

    fn render(op: OperatorKind, n: usize) -> (OpGraph, SimTrace, String) {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let g = ops::lower(&WorkloadSpec::new(op, n), &hw, &sim);
        let trace = simulate(&g, &hw, &sim);
        let json = to_chrome_trace(&g, &trace);
        (g, trace, json)
    }

    #[test]
    fn trace_is_valid_json_shape() {
        let (g, _, json) = render(OperatorKind::Linear, 256);
        assert!(json.starts_with("[\n"));
        assert!(json.trim_end().ends_with(']'));
        // One X event per node + 4 metadata events.
        assert_eq!(json.matches(r#""ph":"X""#).count(), g.len());
        assert_eq!(json.matches(r#""ph":"M""#).count(), 4);
        assert!(json.contains(r#""name":"SHAVE""#));
        // Balanced braces (cheap well-formedness check without serde).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        validate_json(&json).expect("parses as JSON");
    }

    #[test]
    fn durations_match_sim() {
        let (_, trace, json) = render(OperatorKind::Toeplitz, 256);
        let t0 = trace.timings[0];
        let dur_us = (t0.end_ps - t0.start_ps) as f64 / 1e6;
        assert!(json.contains(&format!(r#""dur":{dur_us:.3}"#)));
    }

    #[test]
    fn timestamps_are_monotone() {
        let (_, _, json) = render(OperatorKind::Causal, 512);
        let mut last = f64::NEG_INFINITY;
        for part in json.split(r#""ts":"#).skip(1) {
            let ts: f64 = part.split(',').next().unwrap().parse().unwrap();
            assert!(ts >= last, "events sorted by ts: {ts} after {last}");
            last = ts;
        }
    }

    #[test]
    fn empty_graph_is_still_valid_json() {
        let g = OpGraph { nodes: Vec::new(), logical_ops: 0, label: "empty".into() };
        let trace = SimTrace::default();
        let json = to_chrome_trace(&g, &trace);
        validate_json(&json).expect("no trailing comma on empty graphs");
        assert_eq!(json.matches(r#""ph":"M""#).count(), 4);
        assert_eq!(json.matches(r#""ph":"X""#).count(), 0);
    }
}
