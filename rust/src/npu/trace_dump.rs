//! Chrome-trace export of a simulation (`chrome://tracing` / Perfetto).
//!
//! Emits the Trace Event Format (JSON array of complete "X" events), one
//! track per NPU engine, so a simulated operator's schedule can be
//! inspected visually: `npuperf trace <op> <N> --out trace.json`.

use std::fmt::Write as _;

use crate::ops::{Engine, OpGraph, PrimOp};

use super::engine::SimTrace;

fn prim_name(p: &PrimOp) -> String {
    match p {
        PrimOp::MatMul { m, n, k } => format!("matmul {m}x{n}x{k}"),
        PrimOp::EltWise { kind, elems } => format!("eltwise {kind:?} {elems}"),
        PrimOp::Softmax { rows, cols } => format!("softmax {rows}x{cols}"),
        PrimOp::Transfer { bytes, dir, fresh_alloc } => {
            format!("dma {dir:?} {bytes}B{}", if *fresh_alloc { " +alloc" } else { "" })
        }
        PrimOp::Concat { bytes } => format!("concat {bytes}B"),
        PrimOp::HostOp { bytes } => format!("host {bytes}B"),
    }
}

fn tid(e: Engine) -> u32 {
    match e {
        Engine::Dpu => 0,
        Engine::Shave => 1,
        Engine::Dma => 2,
        Engine::Cpu => 3,
    }
}

/// Render the trace as Chrome Trace Event JSON (timestamps in µs).
pub fn to_chrome_trace(graph: &OpGraph, trace: &SimTrace) -> String {
    let mut out = String::from("[\n");
    // Thread-name metadata per engine.
    for e in Engine::ALL {
        let _ = writeln!(
            out,
            r#"  {{"name":"thread_name","ph":"M","pid":1,"tid":{},"args":{{"name":"{}"}}}},"#,
            tid(e),
            e.name()
        );
    }
    let mut first = true;
    for node in &graph.nodes {
        let t = trace.timings[node.id];
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let _ = write!(
            out,
            r#"  {{"name":"{}","cat":"{}","ph":"X","pid":1,"tid":{},"ts":{:.3},"dur":{:.3},"args":{{"node":{},"deps":{}}}}}"#,
            prim_name(&node.prim),
            node.prim.engine().name(),
            tid(node.prim.engine()),
            t.start_ps as f64 / 1e6,
            (t.end_ps - t.start_ps) as f64 / 1e6,
            node.id,
            node.deps.len(),
        );
    }
    out.push_str("\n]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
    use crate::npu::engine::simulate;
    use crate::ops;

    #[test]
    fn trace_is_valid_json_shape() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let spec = WorkloadSpec::new(OperatorKind::Linear, 256);
        let g = ops::lower(&spec, &hw, &sim);
        let trace = simulate(&g, &hw, &sim);
        let json = to_chrome_trace(&g, &trace);
        assert!(json.starts_with("[\n"));
        assert!(json.trim_end().ends_with(']'));
        // One X event per node + 4 metadata events.
        assert_eq!(json.matches(r#""ph":"X""#).count(), g.len());
        assert_eq!(json.matches(r#""ph":"M""#).count(), 4);
        assert!(json.contains(r#""name":"SHAVE""#));
        // Balanced braces (cheap well-formedness check without serde).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn durations_match_sim() {
        let hw = NpuConfig::default();
        let sim = SimConfig::default();
        let spec = WorkloadSpec::new(OperatorKind::Toeplitz, 256);
        let g = ops::lower(&spec, &hw, &sim);
        let trace = simulate(&g, &hw, &sim);
        let json = to_chrome_trace(&g, &trace);
        let t0 = trace.timings[0];
        let dur_us = (t0.end_ps - t0.start_ps) as f64 / 1e6;
        assert!(json.contains(&format!(r#""dur":{dur_us:.3}"#)));
    }
}
