//! Derived metrics for one simulated operator run — the quantities the
//! paper's tables report.

use crate::ops::{Engine, OpGraph};

use super::cache::CacheStats;
use super::engine::{engine_index, ps_to_ns, SimTrace};
use super::pipeline::StallStats;

/// Which engine bounds the run (paper Table II's "Bottleneck" column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bottleneck {
    Dpu,
    Dma,
    Shave,
    /// Two engines within 10 % of each other (paper's "DMA / DPU" rows).
    Mixed(Engine, Engine),
}

impl std::fmt::Display for Bottleneck {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Bottleneck::Dpu => write!(f, "DPU"),
            Bottleneck::Dma => write!(f, "DMA"),
            Bottleneck::Shave => write!(f, "SHAVE"),
            Bottleneck::Mixed(a, b) => write!(f, "{} / {}", a.name(), b.name()),
        }
    }
}

/// Full per-run report.
#[derive(Clone, Debug)]
pub struct ExecReport {
    pub label: String,
    /// End-to-end latency, ns.
    pub span_ns: f64,
    /// Busy time per engine [DPU, SHAVE, DMA, CPU], ns.
    pub busy_ns: [f64; 4],
    /// Primitive counts per engine.
    pub prim_count: [u64; 4],
    /// Logical ops executed (numerator of achieved GOP/s).
    pub logical_ops: u64,
    /// Bytes moved by the DMA engine.
    pub dma_bytes: u64,
    pub cache: CacheStats,
    pub stall: StallStats,
}

impl ExecReport {
    pub fn from_trace(graph: &OpGraph, trace: &SimTrace) -> Self {
        ExecReport {
            label: graph.label.clone(),
            span_ns: ps_to_ns(trace.span_ps),
            busy_ns: [
                ps_to_ns(trace.busy_ps[0]),
                ps_to_ns(trace.busy_ps[1]),
                ps_to_ns(trace.busy_ps[2]),
                ps_to_ns(trace.busy_ps[3]),
            ],
            prim_count: trace.count,
            logical_ops: graph.logical_ops,
            dma_bytes: graph.dma_bytes(),
            cache: CacheStats::from_trace(graph, trace),
            stall: StallStats::from_trace(trace),
        }
    }

    pub fn latency_ms(&self) -> f64 {
        self.span_ns / 1e6
    }

    /// Throughput in operator invocations per second (paper Table IV).
    pub fn throughput_ops_s(&self) -> f64 {
        if self.span_ns == 0.0 {
            0.0
        } else {
            1e9 / self.span_ns
        }
    }

    /// Achieved GOP/s (ops per ns == GOP/s), paper Table VII "Measured".
    pub fn achieved_gops(&self) -> f64 {
        if self.span_ns == 0.0 {
            0.0
        } else {
            self.logical_ops as f64 / self.span_ns
        }
    }

    fn busy(&self, e: Engine) -> f64 {
        self.busy_ns[engine_index(e)]
    }

    /// Utilization breakdown over the three NPU engines, normalized to sum
    /// to 1 (paper Table II rows sum to 100 %). CPU (ablation only) is
    /// excluded, matching the NPU profiler's view.
    pub fn utilization(&self) -> [f64; 3] {
        let d = self.busy(Engine::Dpu);
        let s = self.busy(Engine::Shave);
        let m = self.busy(Engine::Dma);
        let total = d + s + m;
        if total == 0.0 {
            [0.0; 3]
        } else {
            [d / total, m / total, s / total] // [DPU, DMA, SHAVE] paper order
        }
    }

    /// Bottleneck classification: largest busy share; two engines within
    /// 10 % relative are reported as mixed (Table II's "DMA / DPU").
    pub fn bottleneck(&self) -> Bottleneck {
        let [dpu, dma, shave] = self.utilization();
        let mut ranked = [
            (dpu, Engine::Dpu),
            (dma, Engine::Dma),
            (shave, Engine::Shave),
        ];
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let (top, second) = (ranked[0], ranked[1]);
        if top.0 > 0.0 && (top.0 - second.0) / top.0 < 0.10 {
            return Bottleneck::Mixed(second.1, top.1);
        }
        match top.1 {
            Engine::Dpu => Bottleneck::Dpu,
            Engine::Dma => Bottleneck::Dma,
            Engine::Shave => Bottleneck::Shave,
            Engine::Cpu => unreachable!("CPU excluded from NPU utilization"),
        }
    }

    /// Compute utilization vs the FP16 nominal peak (paper Table VIII).
    pub fn compute_utilization(&self, peak_gops: f64) -> f64 {
        if peak_gops == 0.0 {
            0.0
        } else {
            self.achieved_gops() / peak_gops
        }
    }

    /// One stable line of the quantities the conformance suite pins in
    /// golden fixtures: label, exact simulated span, DMA traffic and
    /// logical op count. Everything here is deterministic simulator
    /// output, so byte-exact fixture diffs are meaningful.
    pub fn conformance_line(&self) -> String {
        format!(
            "{} span_ns={:.3} dma_bytes={} logical_ops={}",
            self.label, self.span_ns, self.dma_bytes, self.logical_ops
        )
    }

    /// Achieved operational intensity, ops/byte (roofline x-coordinate).
    pub fn intensity(&self) -> f64 {
        if self.dma_bytes == 0 {
            0.0
        } else {
            self.logical_ops as f64 / self.dma_bytes as f64
        }
    }

    /// Achieved GOP/s as a fraction of the roofline ceiling at this run's
    /// operational intensity: `min(π_eff, β_eff · I)` with the calibrated
    /// effective ceilings (paper §IV). 1.0 means the run sits on the
    /// roofline; 0.0 when the ceiling degenerates (no traffic, no ops).
    pub fn roofline_utilization(&self, pi_eff_gops: f64, beta_eff_gbps: f64) -> f64 {
        let roof = pi_eff_gops.min(beta_eff_gbps * self.intensity());
        if roof <= 0.0 {
            0.0
        } else {
            self.achieved_gops() / roof
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, SimConfig};
    use crate::npu::engine::simulate;
    use crate::ops::{EltKind, GraphBuilder, PrimOp, TransferDir};

    fn report_for(build: impl FnOnce(&mut GraphBuilder)) -> ExecReport {
        let mut b = GraphBuilder::new("t");
        build(&mut b);
        let g = b.finish();
        let trace = simulate(&g, &NpuConfig::default(), &SimConfig::default());
        ExecReport::from_trace(&g, &trace)
    }

    #[test]
    fn utilization_sums_to_one() {
        let r = report_for(|b| {
            let t = b.push_simple(
                PrimOp::Transfer { bytes: 1 << 16, dir: TransferDir::Pull, fresh_alloc: true },
                vec![],
            );
            let m = b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![t]);
            b.push_simple(PrimOp::Softmax { rows: 128, cols: 128 }, vec![m]);
        });
        let u = r.utilization();
        assert!((u.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(u.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn bottleneck_is_dominant_engine() {
        let r = report_for(|b| {
            b.push_simple(PrimOp::MatMul { m: 1024, n: 1024, k: 1024 }, vec![]);
            b.push_simple(
                PrimOp::Transfer { bytes: 1024, dir: TransferDir::Pull, fresh_alloc: false },
                vec![],
            );
        });
        assert_eq!(r.bottleneck(), Bottleneck::Dpu);
    }

    #[test]
    fn mixed_bottleneck_when_close() {
        // Craft near-equal DPU and DMA busy times.
        let r = report_for(|b| {
            b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
            // One fresh 32 KiB transfer ≈ matmul tile time at defaults.
            b.push_simple(
                PrimOp::Transfer {
                    bytes: 120 * 1024,
                    dir: TransferDir::Pull,
                    fresh_alloc: false,
                },
                vec![],
            );
        });
        // Either mixed or single: just ensure classification is stable and
        // names the heavier engine.
        let _ = r.bottleneck();
    }

    #[test]
    fn throughput_is_inverse_latency() {
        let r = report_for(|b| {
            b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
        });
        let want = 1e3 / r.latency_ms();
        assert!((r.throughput_ops_s() - want).abs() / want < 1e-9);
    }

    #[test]
    fn achieved_gops_uses_logical_ops() {
        let r = report_for(|b| {
            b.push_simple(PrimOp::MatMul { m: 256, n: 256, k: 256 }, vec![]);
        });
        let want = (2u64 * 256 * 256 * 256) as f64 / r.span_ns;
        assert!((r.achieved_gops() - want).abs() < 1e-9);
        assert!(r.compute_utilization(NpuConfig::default().peak_fp16_gops()) < 1.0);
    }

    #[test]
    fn roofline_utilization_is_bounded_by_the_ceiling() {
        let r = report_for(|b| {
            let t = b.push_simple(
                PrimOp::Transfer { bytes: 1 << 20, dir: TransferDir::Pull, fresh_alloc: true },
                vec![],
            );
            b.push_simple(PrimOp::MatMul { m: 256, n: 256, k: 256 }, vec![t]);
        });
        // Against a generous ceiling the run sits below the roofline; the
        // ratio scales inversely with the compute ceiling while the
        // bandwidth leg is not binding.
        let u = r.roofline_utilization(1e4, 1e4);
        assert!(u > 0.0 && u <= 1.0, "below a generous roofline: {u}");
        let tighter = r.roofline_utilization(5e3, 1e4);
        assert!(tighter >= u, "halving the compute ceiling cannot lower the ratio");
        // Degenerate ceilings report zero instead of dividing by zero.
        assert_eq!(r.roofline_utilization(0.0, 0.0), 0.0);
    }

    #[test]
    fn conformance_line_is_stable_and_complete() {
        let r = report_for(|b| {
            b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
        });
        let line = r.conformance_line();
        assert!(line.starts_with("t span_ns="), "{line}");
        assert!(line.contains(&format!("dma_bytes={}", r.dma_bytes)), "{line}");
        assert!(line.contains(&format!("logical_ops={}", r.logical_ops)), "{line}");
        assert_eq!(line, r.conformance_line(), "same report, same line");
    }

    #[test]
    fn eltwise_only_graph_is_shave_bound() {
        let r = report_for(|b| {
            b.push_simple(PrimOp::EltWise { kind: EltKind::Exp, elems: 1 << 20 }, vec![]);
        });
        assert_eq!(r.bottleneck(), Bottleneck::Shave);
    }
}
