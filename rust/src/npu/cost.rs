//! Per-primitive cost model: how many nanoseconds each engine takes.
//!
//! Costs are built from Table I rates plus the microarchitectural overheads
//! in [`NpuConfig`] (descriptor issue, systolic fill/drain, DMA setup and
//! buffer-allocation penalties). The paper's *effective* ceilings (§IV-A,
//! ~5 % of nominal) are not inputs — they emerge from these overheads and
//! are measured by `model::calibrate`.

use crate::config::{NpuConfig, SimConfig};
use crate::ops::{EltKind, PrimOp, TransferDir};

/// Cost model bound to a hardware + policy configuration.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub hw: NpuConfig,
    pub sim: SimConfig,
}

impl CostModel {
    pub fn new(hw: &NpuConfig, sim: &SimConfig) -> Self {
        Self { hw: hw.clone(), sim: sim.clone() }
    }

    /// Duration of one primitive, in ns.
    pub fn duration_ns(&self, prim: &PrimOp) -> f64 {
        match *prim {
            PrimOp::MatMul { m, n, k } => self.matmul_ns(m, n, k),
            PrimOp::EltWise { kind, elems } => self.eltwise_ns(kind, elems),
            PrimOp::Softmax { rows, cols } => self.softmax_ns(rows, cols),
            PrimOp::Transfer { bytes, dir, fresh_alloc } => {
                self.transfer_ns(bytes, dir, fresh_alloc)
            }
            PrimOp::Concat { bytes } => self.concat_ns(bytes),
            PrimOp::HostOp { bytes } => self.host_ns(bytes),
        }
    }

    /// Systolic matmul: per-primitive issue + per-tile fill/stream/drain.
    ///
    /// A 128×128 output tile streams `k_tile` reduction steps through the
    /// array (one column per cycle) after a fill ramp, then drains. FP16
    /// halves the streaming rate (two passes per MAC column).
    pub fn matmul_ns(&self, m: usize, n: usize, k: usize) -> f64 {
        let t = self.sim.tile;
        let tiles_m = m.div_ceil(t);
        let tiles_n = n.div_ceil(t);
        let tiles_k = k.div_ceil(t);
        let _ = tiles_k; // k streams contiguously through each (m,n) tile
        let cycle = self.hw.dpu_cycle_ns();
        // Per (m,n) tile: fill ramp + k reduction steps (FP16 = two passes
        // per column) + drain.
        let fill_drain =
            (self.hw.dpu_fill_cycles + self.hw.dpu_drain_cycles) as f64 * cycle;
        let per_tile = fill_drain + (k as f64 / self.hw.fp16_rate) * cycle;
        self.hw.dpu_issue_ns + (tiles_m * tiles_n) as f64 * per_tile
    }

    /// Element-wise op on SHAVE: dispatch + elems / class rate.
    pub fn eltwise_ns(&self, kind: EltKind, elems: usize) -> f64 {
        let rate = match kind {
            EltKind::Simple => self.hw.shave_simple_elems_per_ns(),
            EltKind::Exp => self.hw.shave_exp_elems_per_ns(),
        };
        self.hw.shave_issue_ns + elems as f64 / rate
    }

    /// Row softmax: max + sub/exp + sum + div ⇒ 3 simple passes + 1 exp
    /// pass, plus hierarchical merge passes when rows exceed the SHAVE
    /// reduce span (cross-tile max/sum merges re-traverse the scratchpad —
    /// the mechanism behind Retentive's SHAVE-bound regime, Table II).
    pub fn softmax_ns(&self, rows: usize, cols: usize) -> f64 {
        let elems = (rows * cols) as f64;
        let segments = cols.div_ceil(self.hw.shave_reduce_span).max(1);
        // log2-depth merge tree; each level is 2 simple re-passes.
        let merge_levels = (usize::BITS - (segments - 1).leading_zeros()) as f64;
        self.hw.shave_issue_ns
            + (3.0 + 2.0 * merge_levels) * elems / self.hw.shave_simple_elems_per_ns()
            + elems / self.hw.shave_exp_elems_per_ns()
    }

    /// DMA transfer: descriptor setup + optional allocation penalty + wire
    /// time at nominal bandwidth. The asymmetric alloc penalty is the §V
    /// "frequent allocation/deallocation of large buffers" overhead.
    pub fn transfer_ns(&self, bytes: u64, _dir: TransferDir, fresh_alloc: bool) -> f64 {
        let alloc = if fresh_alloc { self.hw.dma_alloc_ns } else { 0.0 };
        self.hw.dma_setup_ns + alloc + bytes as f64 / self.hw.dma_bytes_per_ns()
    }

    /// DMA concat: gather-read + write through the engine (2× wire traffic)
    /// into a freshly allocated contiguous buffer.
    pub fn concat_ns(&self, bytes: u64) -> f64 {
        self.hw.dma_setup_ns
            + self.hw.dma_alloc_ns
            + 2.0 * bytes as f64 / self.hw.dma_bytes_per_ns()
    }

    /// Host-CPU byte-moving op (§V offload ablation).
    pub fn host_ns(&self, bytes: u64) -> f64 {
        self.hw.cpu_issue_ns + bytes as f64 / self.hw.cpu_memcpy_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::new(&NpuConfig::default(), &SimConfig::default())
    }

    #[test]
    fn matmul_single_tile_cost_breakdown() {
        let c = cm();
        let cycle = c.hw.dpu_cycle_ns();
        let want = c.hw.dpu_issue_ns + (256.0 + 128.0 / 0.5) * cycle;
        assert!((c.matmul_ns(128, 128, 128) - want).abs() < 1e-6);
    }

    #[test]
    fn matmul_scales_with_tiles() {
        let c = cm();
        let one = c.matmul_ns(128, 128, 128) - c.hw.dpu_issue_ns;
        let four = c.matmul_ns(256, 256, 128) - c.hw.dpu_issue_ns;
        assert!((four / one - 4.0).abs() < 1e-9);
    }

    #[test]
    fn matmul_partial_k_cheaper() {
        let c = cm();
        assert!(c.matmul_ns(128, 128, 64) < c.matmul_ns(128, 128, 128));
    }

    #[test]
    fn effective_compute_is_single_digit_pct_of_nominal() {
        // The §IV-A claim: per-tile overheads push achievable matmul
        // throughput to a few % of the 10 TOPS nominal.
        let c = cm();
        let ops = 2.0 * 128.0 * 128.0 * 128.0;
        let gops = ops / c.matmul_ns(128, 128, 128); // ops/ns == GOP/s
        let frac = gops / c.hw.peak_fp16_gops();
        assert!(
            (0.05..0.60).contains(&frac),
            "streamed-tile efficiency {frac:.3} out of plausible band"
        );
    }

    #[test]
    fn transfer_alloc_penalty_dominates_small_tiles() {
        let c = cm();
        let fresh = c.transfer_ns(64 * 1024, TransferDir::Pull, true);
        let reused = c.transfer_ns(64 * 1024, TransferDir::Pull, false);
        assert!(fresh > reused + c.hw.dma_alloc_ns * 0.99);
        // Effective bandwidth for fresh 64 KiB tile-buffer transfers lands
        // near the paper's beta_eff = 3.2 GB/s (§IV-A), an order of
        // magnitude under the 64 GB/s nominal.
        let eff_gbps = 64.0 * 1024.0 / fresh;
        assert!((1.5..6.0).contains(&eff_gbps), "eff bw {eff_gbps:.2} GB/s");
    }

    #[test]
    fn softmax_long_rows_pay_merge_passes() {
        let c = cm();
        let short = c.softmax_ns(128, 512);
        let long = c.softmax_ns(128, 8192);
        // 16x the elements but strictly more than 16x the time: the
        // hierarchical reduce re-passes kick in past the reduce span.
        assert!(long > 16.0 * (short - c.hw.shave_issue_ns));
    }

    #[test]
    fn softmax_has_exp_pass() {
        let c = cm();
        let sm = c.softmax_ns(128, 128) - c.hw.shave_issue_ns;
        let simple_only = 4.0 * (128.0 * 128.0) / c.hw.shave_simple_elems_per_ns();
        assert!(sm > simple_only, "softmax must charge the exp pass");
    }

    #[test]
    fn concat_charges_double_traffic() {
        let c = cm();
        let t = c.concat_ns(1 << 20);
        let wire = 2.0 * (1u64 << 20) as f64 / c.hw.dma_bytes_per_ns();
        assert!(t >= wire);
    }

    #[test]
    fn host_op_slower_than_dma_wire() {
        let c = cm();
        assert!(c.host_ns(1 << 20) > (1u64 << 20) as f64 / c.hw.dma_bytes_per_ns());
    }
}
