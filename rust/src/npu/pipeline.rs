//! Pipeline-stall analysis (paper Tables V & VIII).
//!
//! The NPU's execution pipeline has pull (DMA in), compute (DPU/SHAVE) and
//! push (DMA out) stages. The paper's profiler reports the fraction of
//! active pipeline slots in which a compute engine sat stalled waiting for
//! the pull stage; we reproduce that as
//!
//! ```text
//! stall% = wait_compute / (wait_compute + busy_compute)
//! ```
//!
//! where `wait` accumulates every idle gap on the DPU/SHAVE engines whose
//! next primitive existed but whose operands had not yet been produced
//! (by DMA *or* by the other compute engine — data is data).

// lint:allow-file(panic-reachability, "engine ids index fixed-size per-engine arrays sized from the Engine enum; in bounds by construction")

use crate::ops::Engine;

use super::engine::{engine_index, SimTrace};

/// Stall metrics for one simulated run.
#[derive(Clone, Copy, Debug, Default)]
pub struct StallStats {
    pub busy_compute_ps: u64,
    pub wait_compute_ps: u64,
}

impl StallStats {
    pub fn from_trace(trace: &SimTrace) -> Self {
        let dpu = engine_index(Engine::Dpu);
        let shave = engine_index(Engine::Shave);
        StallStats {
            busy_compute_ps: trace.busy_ps[dpu] + trace.busy_ps[shave],
            wait_compute_ps: trace.stall_ps[dpu] + trace.stall_ps[shave],
        }
    }

    /// Stall fraction in [0, 1].
    pub fn stall_frac(&self) -> f64 {
        let total = self.busy_compute_ps + self.wait_compute_ps;
        if total == 0 {
            0.0
        } else {
            self.wait_compute_ps as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, SimConfig};
    use crate::npu::engine::simulate;
    use crate::ops::{GraphBuilder, PrimOp, TransferDir};

    #[test]
    fn dma_starved_compute_shows_high_stall() {
        // Each matmul waits on a slow fresh-alloc pull: stall dominates.
        let mut b = GraphBuilder::new("starved");
        let mut prev_mm = None;
        for _ in 0..8 {
            let deps = prev_mm.map(|p| vec![p]).unwrap_or_default();
            let t = b.push_simple(
                PrimOp::Transfer {
                    bytes: 32 * 1024,
                    dir: TransferDir::Pull,
                    fresh_alloc: true,
                },
                deps,
            );
            prev_mm =
                Some(b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 64 }, vec![t]));
        }
        let g = b.finish();
        let trace = simulate(&g, &NpuConfig::default(), &SimConfig::default());
        let stats = StallStats::from_trace(&trace);
        assert!(
            stats.stall_frac() > 0.5,
            "serialized pull->compute chain must stall: {}",
            stats.stall_frac()
        );
    }

    #[test]
    fn pure_compute_chain_has_no_stall() {
        let mut b = GraphBuilder::new("compute");
        let mut prev = None;
        for _ in 0..5 {
            let deps = prev.map(|p| vec![p]).unwrap_or_default();
            prev = Some(b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, deps));
        }
        let g = b.finish();
        let trace = simulate(&g, &NpuConfig::default(), &SimConfig::default());
        let stats = StallStats::from_trace(&trace);
        assert_eq!(stats.wait_compute_ps, 0);
        assert_eq!(stats.stall_frac(), 0.0);
    }

    #[test]
    fn empty_trace_zero() {
        assert_eq!(StallStats::from_trace(&SimTrace::default()).stall_frac(), 0.0);
    }
}
