//! Cache-efficiency and state-reuse instrumentation (paper Table V).
//!
//! - **Cache efficiency** = scratchpad hits / total operand accesses, at
//!   tile granularity, as tagged by the lowering's scratchpad allocator.
//!   Quadratic attention's spilled score matrix produces a long miss tail
//!   (7.7 % for Full Causal at N = 8192); structured operators keep their
//!   working set resident (84-88 %).
//! - **Reuse latency** = size-weighted mean time between a buffer's first
//!   write and its last read: how long produced bytes sit before being
//!   consumed. Phase-separated quadratic attention parks 128 MB of scores
//!   for ~half the run; streaming operators re-consume within ~1-2 ms.

// lint:allow-file(panic-reachability, "per-buffer bookkeeping is indexed by buffer ids the lowering allocated; dense by construction")

use crate::ops::OpGraph;

use super::engine::{ps_to_ns, SimTrace};

/// Aggregated cache metrics for one simulated operator run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Size-weighted mean produce→last-consume distance, ns.
    pub reuse_ns: f64,
}

impl CacheStats {
    /// Hit fraction in [0, 1]; 0 when there were no accesses.
    pub fn efficiency(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Derive the stats from a lowered graph + its simulation trace.
    pub fn from_trace(graph: &OpGraph, trace: &SimTrace) -> Self {
        let mut hits = 0u64;
        let mut misses = 0u64;
        // Per buffer: (first_write_end_ps, last_read_end_ps, bytes).
        let mut first_write: Vec<Option<u64>> = Vec::new();
        let mut last_read: Vec<Option<u64>> = Vec::new();
        let mut buf_bytes: Vec<u64> = Vec::new();
        let ensure = |v: &mut Vec<Option<u64>>, w: &mut Vec<u64>, id: usize| {
            if v.len() <= id {
                v.resize(id + 1, None);
                w.resize(id + 1, 0);
            }
        };

        for node in &graph.nodes {
            let t = trace.timings[node.id];
            for acc in &node.reads {
                if acc.hit {
                    hits += acc.count as u64;
                } else {
                    misses += acc.count as u64;
                }
                ensure(&mut last_read, &mut buf_bytes, acc.buffer);
                let slot = &mut last_read[acc.buffer];
                *slot = Some(slot.map_or(t.end_ps, |p| p.max(t.end_ps)));
                buf_bytes[acc.buffer] =
                    buf_bytes[acc.buffer].max(acc.bytes * acc.count as u64);
            }
            for acc in &node.writes {
                ensure(&mut first_write, &mut buf_bytes, acc.buffer);
                let slot = &mut first_write[acc.buffer];
                if slot.is_none() {
                    *slot = Some(t.end_ps);
                }
                buf_bytes[acc.buffer] =
                    buf_bytes[acc.buffer].max(acc.bytes * acc.count as u64);
            }
        }

        let n = first_write.len().max(last_read.len());
        first_write.resize(n, None);
        last_read.resize(n, None);
        buf_bytes.resize(n, 0);
        let mut weighted = 0.0f64;
        let mut weight = 0.0f64;
        for id in 0..n {
            if let (Some(w), Some(r)) = (first_write[id], last_read[id]) {
                if r > w {
                    let bytes = buf_bytes[id] as f64;
                    weighted += ps_to_ns(r - w) * bytes;
                    weight += bytes;
                }
            }
        }
        let reuse_ns = if weight > 0.0 { weighted / weight } else { 0.0 };
        CacheStats { hits, misses, reuse_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NpuConfig, SimConfig};
    use crate::npu::engine::simulate;
    use crate::ops::{BufferAccess, EltKind, GraphBuilder, PrimOp, TransferDir};

    fn acc(buffer: usize, bytes: u64, hit: bool) -> BufferAccess {
        BufferAccess::new(buffer, bytes, hit)
    }

    #[test]
    fn efficiency_counts_tagged_accesses() {
        let mut b = GraphBuilder::new("c");
        let buf = b.buffer();
        let w = b.push(
            PrimOp::Transfer { bytes: 64, dir: TransferDir::Pull, fresh_alloc: true },
            vec![],
            vec![],
            vec![acc(buf, 64, false)],
        );
        b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: 16 },
            vec![w],
            vec![acc(buf, 64, true), acc(buf, 64, true), acc(buf, 64, false)],
            vec![],
        );
        let g = b.finish();
        let trace = simulate(&g, &NpuConfig::default(), &SimConfig::default());
        let stats = CacheStats::from_trace(&g, &trace);
        assert_eq!(stats.hits, 2);
        assert_eq!(stats.misses, 1);
        assert!((stats.efficiency() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reuse_measures_write_to_last_read_gap() {
        let mut b = GraphBuilder::new("r");
        let buf = b.buffer();
        let w = b.push(
            PrimOp::Transfer { bytes: 1 << 20, dir: TransferDir::Push, fresh_alloc: true },
            vec![],
            vec![],
            vec![acc(buf, 1 << 20, false)],
        );
        // A long unrelated op delays the read.
        let delay = b.push_simple(PrimOp::MatMul { m: 512, n: 512, k: 512 }, vec![w]);
        b.push(
            PrimOp::EltWise { kind: EltKind::Simple, elems: 4 },
            vec![delay],
            vec![acc(buf, 1 << 20, false)],
            vec![],
        );
        let g = b.finish();
        let trace = simulate(&g, &NpuConfig::default(), &SimConfig::default());
        let stats = CacheStats::from_trace(&g, &trace);
        let gap_ns =
            ps_to_ns(trace.timings[2].end_ps - trace.timings[0].end_ps);
        assert!((stats.reuse_ns - gap_ns).abs() < 1.0);
        assert!(stats.reuse_ns > 0.0);
    }

    #[test]
    fn empty_graph_zeroes() {
        let g = GraphBuilder::new("e").finish();
        let trace = SimTrace::default();
        let stats = CacheStats::from_trace(&g, &trace);
        assert_eq!(stats, CacheStats::default());
        assert_eq!(stats.efficiency(), 0.0);
    }
}
