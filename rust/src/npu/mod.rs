//! Cycle-approximate, event-driven NPU simulator.
//!
//! This is the substrate that replaces the paper's physical Intel-AI-PC NPU
//! (DESIGN.md §2): a [`cost`] model for each engine (DPU systolic array,
//! SHAVE vector cores, DMA, host CPU), an event-driven [`engine`] that
//! executes a lowered [`crate::ops::OpGraph`] with per-engine serialization
//! and dependency tracking, a [`scratchpad`] allocator used at lowering
//! time, and [`cache`]/[`pipeline`] instrumentation that reproduces the
//! vendor profiler's counters (utilization %, pipeline stalls, cache
//! efficiency, state-reuse latency).
//!
//! The simulator is operator-agnostic: it executes whatever DAG the
//! [operator registry](crate::ops::registry) lowered. [`run`] takes a
//! pre-lowered graph; [`run_workload`] is the registry-dispatched
//! convenience the report layer builds its tables/figures on (workload
//! spec in, full [`ExecReport`] out — no operator `match` anywhere on
//! the path). The coordinator's serve loop resolves the registry itself
//! instead, because it also needs the operator's name for response
//! attribution and a per-request error on unregistered kinds.

pub mod cache;
pub mod cost;
pub mod engine;
pub mod pipeline;
pub mod report;
pub mod scratchpad;
pub mod trace_dump;

pub use cost::CostModel;
pub use engine::{simulate, NodeTiming, SimTrace};
pub use report::ExecReport;
pub use scratchpad::Scratchpad;

use crate::config::{NpuConfig, SimConfig, WorkloadSpec};
use crate::ops::OpGraph;

/// Convenience: lower-level `simulate` + full report derivation.
pub fn run(graph: &OpGraph, hw: &NpuConfig, sim: &SimConfig) -> ExecReport {
    let trace = simulate(graph, hw, sim);
    ExecReport::from_trace(graph, &trace)
}

/// Registry-dispatched execution: resolve `spec.op` through the operator
/// registry, lower, simulate, and derive the report in one call.
pub fn run_workload(spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> ExecReport {
    run(&crate::ops::lower(spec, hw, sim), hw, sim)
}
