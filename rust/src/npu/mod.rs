//! Cycle-approximate, event-driven NPU simulator.
//!
//! This is the substrate that replaces the paper's physical Intel-AI-PC NPU
//! (DESIGN.md §2): a [`cost`] model for each engine (DPU systolic array,
//! SHAVE vector cores, DMA, host CPU), an event-driven [`engine`] that
//! executes a lowered [`crate::ops::OpGraph`] with per-engine serialization
//! and dependency tracking, a [`scratchpad`] allocator used at lowering
//! time, and [`cache`]/[`pipeline`] instrumentation that reproduces the
//! vendor profiler's counters (utilization %, pipeline stalls, cache
//! efficiency, state-reuse latency).

pub mod cache;
pub mod cost;
pub mod engine;
pub mod pipeline;
pub mod report;
pub mod scratchpad;
pub mod trace_dump;

pub use cost::CostModel;
pub use engine::{simulate, NodeTiming, SimTrace};
pub use report::ExecReport;
pub use scratchpad::Scratchpad;

use crate::config::{NpuConfig, SimConfig};
use crate::ops::OpGraph;

/// Convenience: lower-level `simulate` + full report derivation.
pub fn run(graph: &OpGraph, hw: &NpuConfig, sim: &SimConfig) -> ExecReport {
    let trace = simulate(graph, hw, sim);
    ExecReport::from_trace(graph, &trace)
}
