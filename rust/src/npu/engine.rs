//! Event-driven execution of an [`OpGraph`] over the NPU's engines.
//!
//! Each engine (DPU, SHAVE, DMA, CPU) executes one primitive at a time;
//! primitives become *ready* when all dependencies complete. Times are kept
//! in integer picoseconds for determinism. The scheduler is
//! earliest-ready-first with node-id tie-breaking — the static, in-order
//! dispatch a real NPU command list gives you.
//!
//! The engine is deliberately operator-blind: it consumes any [`OpGraph`]
//! produced by a [`crate::ops::CausalOperator`] lowering, so registering a
//! new operator (see [`crate::ops::registry`]) requires no simulator
//! changes — the per-primitive [`CostModel`] is the only hardware contract.

// lint:allow-file(panic-reachability, "simulator kernel: the scheduler addresses the op graph by dense node/engine indices it constructed itself in simulate(); every index is in bounds by construction")

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::{NpuConfig, SimConfig};
use crate::ops::{Engine, OpGraph};

use super::cost::CostModel;

/// Per-node schedule produced by the simulator (all times in ps).
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeTiming {
    /// All dependencies completed.
    pub ready_ps: u64,
    /// Engine began executing the primitive.
    pub start_ps: u64,
    /// Primitive completed.
    pub end_ps: u64,
}

/// Full simulation trace: node timings + per-engine aggregates (ps).
#[derive(Clone, Debug, Default)]
pub struct SimTrace {
    pub timings: Vec<NodeTiming>,
    /// Makespan of the graph.
    pub span_ps: u64,
    /// Busy time per engine, indexed by [`engine_index`].
    pub busy_ps: [u64; 4],
    /// Pull-stall time per engine: idle gaps where the engine's next
    /// primitive existed but its operands were not yet ready.
    pub stall_ps: [u64; 4],
    /// Number of primitives per engine.
    pub count: [u64; 4],
}

pub fn engine_index(e: Engine) -> usize {
    match e {
        Engine::Dpu => 0,
        Engine::Shave => 1,
        Engine::Dma => 2,
        Engine::Cpu => 3,
    }
}

fn to_ps(ns: f64) -> u64 {
    (ns * 1000.0).round() as u64
}

pub fn ps_to_ns(ps: u64) -> f64 {
    ps as f64 / 1000.0
}

/// Simulate `graph` on the configured hardware; panics on malformed DAGs
/// (builders always emit valid topological order — enforced by
/// `OpGraph::validate` in tests).
pub fn simulate(graph: &OpGraph, hw: &NpuConfig, sim: &SimConfig) -> SimTrace {
    let cost = CostModel::new(hw, sim);
    let n = graph.nodes.len();
    let mut indegree: Vec<u32> = vec![0; n];
    let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
    for node in &graph.nodes {
        indegree[node.id] = node.deps.len() as u32;
        for &d in &node.deps {
            dependents[d].push(node.id as u32);
        }
    }

    // Pre-compute durations once (ps).
    let durations: Vec<u64> =
        graph.nodes.iter().map(|nd| to_ps(cost.duration_ns(&nd.prim))).collect();

    let mut timings = vec![NodeTiming::default(); n];
    // Ready queues per engine: min-heap on (ready_ps, node_id).
    let mut ready: [BinaryHeap<Reverse<(u64, u32)>>; 4] = Default::default();
    // Completion events: min-heap on (end_ps, node_id).
    let mut running: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
    let mut engine_free: [u64; 4] = [0; 4];
    let mut engine_busy: [u64; 4] = [0; 4];
    let mut engine_stall: [u64; 4] = [0; 4];
    let mut engine_count: [u64; 4] = [0; 4];
    let mut engine_idle: [bool; 4] = [true; 4];

    for node in &graph.nodes {
        if node.deps.is_empty() {
            timings[node.id].ready_ps = 0;
            let e = engine_index(node.prim.engine());
            ready[e].push(Reverse((0, node.id as u32)));
        }
    }

    let mut now: u64 = 0;
    let mut span: u64 = 0;
    let mut done = 0usize;

    // Try to start work on every idle engine at time `now`.
    macro_rules! dispatch {
        () => {
            for e in 0..4 {
                if !engine_idle[e] {
                    continue;
                }
                if let Some(&Reverse((ready_ps, id))) = ready[e].peek() {
                    if ready_ps <= now {
                        ready[e].pop();
                        let id = id as usize;
                        // Pull stall: engine sat idle from max(free, ready-
                        // announce) waiting for this op's data.
                        let waited = now.saturating_sub(engine_free[e].max(ready_ps));
                        let gap = now.saturating_sub(engine_free[e]);
                        // Idle-waiting-on-data = the whole gap if data arrived
                        // after the engine freed, else zero.
                        let stall =
                            if ready_ps > engine_free[e] { gap } else { waited };
                        engine_stall[e] += stall;
                        let dur = durations[id];
                        timings[id].start_ps = now;
                        timings[id].end_ps = now + dur;
                        engine_busy[e] += dur;
                        engine_count[e] += 1;
                        engine_free[e] = now + dur;
                        engine_idle[e] = false;
                        running.push(Reverse((now + dur, id as u32)));
                    }
                }
            }
        };
    }

    dispatch!();
    while done < n {
        let Some(&Reverse((t, _))) = running.peek() else {
            // No running ops but not done: ready ops exist with ready_ps in
            // the future — advance to the earliest.
            let next = ready
                .iter()
                .filter_map(|q| q.peek().map(|&Reverse((r, _))| r))
                .min()
                .expect("deadlock: no running and no ready ops");
            now = next;
            dispatch!();
            continue;
        };
        now = t;
        // Complete everything ending at `now`.
        while let Some(&Reverse((t2, id))) = running.peek() {
            if t2 != now {
                break;
            }
            running.pop();
            let id = id as usize;
            done += 1;
            span = span.max(timings[id].end_ps);
            let e = engine_index(graph.nodes[id].prim.engine());
            engine_idle[e] = true;
            for &dep in &dependents[id] {
                let dep = dep as usize;
                indegree[dep] -= 1;
                if indegree[dep] == 0 {
                    let ready_at = graph.nodes[dep]
                        .deps
                        .iter()
                        .map(|&d| timings[d].end_ps)
                        .max()
                        .unwrap_or(0);
                    timings[dep].ready_ps = ready_at;
                    let eng = engine_index(graph.nodes[dep].prim.engine());
                    ready[eng].push(Reverse((ready_at, dep as u32)));
                }
            }
        }
        dispatch!();
    }

    SimTrace {
        timings,
        span_ps: span,
        busy_ps: engine_busy,
        stall_ps: engine_stall,
        count: engine_count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{EltKind, GraphBuilder, PrimOp, TransferDir};

    fn hw() -> NpuConfig {
        NpuConfig::default()
    }

    fn sim_cfg() -> SimConfig {
        SimConfig::default()
    }

    fn transfer(bytes: u64) -> PrimOp {
        PrimOp::Transfer { bytes, dir: TransferDir::Pull, fresh_alloc: false }
    }

    #[test]
    fn single_node_span_equals_duration() {
        let mut b = GraphBuilder::new("one");
        b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
        let g = b.finish();
        let trace = simulate(&g, &hw(), &sim_cfg());
        let cost = CostModel::new(&hw(), &sim_cfg());
        assert_eq!(trace.span_ps, to_ps(cost.matmul_ns(128, 128, 128)));
        assert_eq!(trace.busy_ps[0], trace.span_ps);
        assert_eq!(trace.count[0], 1);
    }

    #[test]
    fn chain_serializes_and_charges_stall() {
        // transfer -> matmul: DPU must wait for DMA; that wait is DPU stall.
        let mut b = GraphBuilder::new("chain");
        let t = b.push_simple(transfer(1 << 20), vec![]);
        b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![t]);
        let g = b.finish();
        let trace = simulate(&g, &hw(), &sim_cfg());
        let dma_end = trace.timings[0].end_ps;
        assert_eq!(trace.timings[1].start_ps, dma_end);
        assert_eq!(trace.stall_ps[0], dma_end, "DPU stalled for the whole pull");
        assert_eq!(trace.span_ps, trace.timings[1].end_ps);
    }

    #[test]
    fn independent_ops_on_different_engines_overlap() {
        let mut b = GraphBuilder::new("overlap");
        b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
        b.push_simple(PrimOp::EltWise { kind: EltKind::Simple, elems: 10_000 }, vec![]);
        b.push_simple(transfer(1 << 20), vec![]);
        let g = b.finish();
        let trace = simulate(&g, &hw(), &sim_cfg());
        let serial: u64 = trace.busy_ps.iter().sum();
        assert!(trace.span_ps < serial, "3 engines must overlap");
        assert_eq!(trace.span_ps, trace.busy_ps.iter().copied().max().unwrap());
    }

    #[test]
    fn same_engine_ops_serialize() {
        let mut b = GraphBuilder::new("serial");
        b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
        b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 128 }, vec![]);
        let g = b.finish();
        let trace = simulate(&g, &hw(), &sim_cfg());
        assert_eq!(trace.span_ps, trace.busy_ps[0]);
        assert_eq!(trace.timings[1].start_ps, trace.timings[0].end_ps);
        // Back-to-back on one engine: no pull stall.
        assert_eq!(trace.stall_ps[0], 0);
    }

    #[test]
    fn diamond_dependency_joins() {
        let mut b = GraphBuilder::new("diamond");
        let t = b.push_simple(transfer(1024), vec![]);
        let m1 = b.push_simple(PrimOp::MatMul { m: 128, n: 128, k: 64 }, vec![t]);
        let s1 = b.push_simple(
            PrimOp::EltWise { kind: EltKind::Simple, elems: 128 * 128 },
            vec![t],
        );
        b.push_simple(PrimOp::MatMul { m: 128, n: 64, k: 128 }, vec![m1, s1]);
        let g = b.finish();
        g.validate().unwrap();
        let trace = simulate(&g, &hw(), &sim_cfg());
        let join_start = trace.timings[3].start_ps;
        assert!(join_start >= trace.timings[1].end_ps);
        assert!(join_start >= trace.timings[2].end_ps);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut b = GraphBuilder::new("det");
        let mut prev = Vec::new();
        for i in 0..50 {
            let deps = if i >= 2 && i % 3 == 0 { vec![i - 2] } else { vec![] };
            prev.push(b.push_simple(
                PrimOp::EltWise { kind: EltKind::Simple, elems: 100 * (i + 1) },
                deps,
            ));
        }
        let g = b.finish();
        let a = simulate(&g, &hw(), &sim_cfg());
        let c = simulate(&g, &hw(), &sim_cfg());
        assert_eq!(a.span_ps, c.span_ps);
        for (x, y) in a.timings.iter().zip(&c.timings) {
            assert_eq!(x.start_ps, y.start_ps);
            assert_eq!(x.end_ps, y.end_ps);
        }
    }

    #[test]
    fn busy_never_exceeds_span_per_engine() {
        let mut b = GraphBuilder::new("cap");
        let mut last = None;
        for _ in 0..20 {
            let deps = last.map(|l| vec![l]).unwrap_or_default();
            last = Some(b.push_simple(transfer(64 * 1024), deps));
        }
        let g = b.finish();
        let trace = simulate(&g, &hw(), &sim_cfg());
        for e in 0..4 {
            assert!(trace.busy_ps[e] <= trace.span_ps);
        }
        assert_eq!(trace.busy_ps[2], trace.span_ps, "pure DMA chain");
    }
}
