//! Software-managed scratchpad allocator (paper Table I: 4 MB "persistent
//! state storage").
//!
//! Used at *lowering* time: operator lowerings ask for buffer residency;
//! what fits stays resident (subsequent accesses are cache hits), what does
//! not must stream through DMA (explicit `Transfer` nodes + cache misses).
//! An LRU pool supports tile-window reuse (Toeplitz's sliding K/V window).

use std::collections::HashMap;

use crate::ops::BufferId;

/// Allocation outcome for one buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Resident in scratchpad for its whole lifetime.
    Resident,
    /// Streams through DRAM: every touch beyond the working tile is a miss.
    Streamed,
}

/// Bump+LRU scratchpad model.
#[derive(Debug)]
pub struct Scratchpad {
    capacity: u64,
    used: u64,
    resident: HashMap<BufferId, u64>,
    /// LRU order for evictable (pool) buffers; most recent at the back.
    lru: Vec<BufferId>,
    /// Peak usage high-water mark (drives §V chunked-prefill analysis).
    peak: u64,
}

impl Scratchpad {
    pub fn new(capacity: u64) -> Self {
        Self { capacity, used: 0, resident: HashMap::new(), lru: Vec::new(), peak: 0 }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn is_resident(&self, id: BufferId) -> bool {
        self.resident.contains_key(&id)
    }

    /// Pin a buffer for its whole lifetime (no eviction). Returns
    /// `Streamed` without allocating when it cannot fit.
    pub fn pin(&mut self, id: BufferId, bytes: u64) -> Placement {
        if bytes <= self.free_bytes() {
            self.used += bytes;
            self.peak = self.peak.max(self.used);
            self.resident.insert(id, bytes);
            Placement::Resident
        } else {
            Placement::Streamed
        }
    }

    /// Allocate an evictable pool buffer, evicting LRU pool entries as
    /// needed. Returns the evicted ids (their next touch is a miss), or
    /// `Err(())` if the buffer can never fit (larger than what pinning
    /// left available plus all evictables).
    pub fn pool_alloc(&mut self, id: BufferId, bytes: u64) -> Result<Vec<BufferId>, ()> {
        let evictable: u64 =
            self.lru.iter().map(|b| self.resident.get(b).copied().unwrap_or(0)).sum();
        if bytes > self.free_bytes() + evictable {
            return Err(());
        }
        let mut evicted = Vec::new();
        while bytes > self.free_bytes() {
            let victim = self.lru.remove(0);
            if let Some(sz) = self.resident.remove(&victim) {
                self.used -= sz;
                evicted.push(victim);
            }
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.resident.insert(id, bytes);
        self.lru.push(id);
        Ok(evicted)
    }

    /// LRU touch: mark a pool buffer as recently used; returns true if the
    /// buffer was resident (a hit).
    pub fn touch(&mut self, id: BufferId) -> bool {
        if !self.resident.contains_key(&id) {
            return false;
        }
        if let Some(pos) = self.lru.iter().position(|&b| b == id) {
            let b = self.lru.remove(pos);
            self.lru.push(b);
        }
        true
    }

    /// Release a pinned or pooled buffer.
    pub fn free(&mut self, id: BufferId) {
        if let Some(sz) = self.resident.remove(&id) {
            self.used -= sz;
            if let Some(pos) = self.lru.iter().position(|&b| b == id) {
                self.lru.remove(pos);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_until_full_then_stream() {
        let mut sp = Scratchpad::new(100);
        assert_eq!(sp.pin(0, 60), Placement::Resident);
        assert_eq!(sp.pin(1, 60), Placement::Streamed);
        assert_eq!(sp.pin(2, 40), Placement::Resident);
        assert_eq!(sp.used(), 100);
        assert!(sp.is_resident(0));
        assert!(!sp.is_resident(1));
    }

    #[test]
    fn free_releases_space() {
        let mut sp = Scratchpad::new(100);
        sp.pin(0, 80);
        sp.free(0);
        assert_eq!(sp.used(), 0);
        assert_eq!(sp.pin(1, 80), Placement::Resident);
    }

    #[test]
    fn pool_evicts_lru_order() {
        let mut sp = Scratchpad::new(100);
        sp.pool_alloc(0, 40).unwrap();
        sp.pool_alloc(1, 40).unwrap();
        sp.touch(0); // 1 becomes LRU
        let evicted = sp.pool_alloc(2, 40).unwrap();
        assert_eq!(evicted, vec![1]);
        assert!(sp.is_resident(0) && sp.is_resident(2));
    }

    #[test]
    fn pool_alloc_too_big_errors() {
        let mut sp = Scratchpad::new(100);
        sp.pin(0, 50);
        assert!(sp.pool_alloc(1, 60).is_err());
    }

    #[test]
    fn pool_respects_pinned_space() {
        let mut sp = Scratchpad::new(100);
        sp.pin(0, 50);
        sp.pool_alloc(1, 30).unwrap();
        let evicted = sp.pool_alloc(2, 40).unwrap();
        assert_eq!(evicted, vec![1], "must evict pool, never pinned");
        assert!(sp.is_resident(0));
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut sp = Scratchpad::new(100);
        sp.pin(0, 70);
        sp.free(0);
        sp.pin(1, 30);
        assert_eq!(sp.peak(), 70);
    }

    #[test]
    fn touch_nonresident_is_miss() {
        let mut sp = Scratchpad::new(10);
        assert!(!sp.touch(99));
    }
}
