//! Golden snapshot tests for the report layer: pin the rendered output of
//! the paper-table and sweep/capacity reports under the default
//! `NpuConfig`/`SimConfig`, so any change to formatting *or* to the
//! underlying cost model shows up as a reviewable byte diff.
//!
//! Regeneration after an intentional change: `NPUPERF_BLESS=1 cargo test`
//! or `npuperf selftest --bless`, then commit the fixture
//! (rust/tests/golden/README.md).

use npuperf::config::NpuConfig;
use npuperf::memory::MemoryConfig;
use npuperf::ops::registry;
use npuperf::report::{sweep, tables};
use npuperf::testkit::golden::{self, Outcome};
use npuperf::testkit::invariants;

fn check(name: &str, actual: &str) {
    match golden::compare(name, actual, false) {
        Ok(_) => {}
        Err(e) => panic!("{e}"),
    }
}

#[test]
fn table1_matches_golden() {
    // Table 1 is rendered straight from the hardware description — no
    // simulation — so this pins the spec sheet and its formatting.
    check("table1.txt", &tables::table1(&NpuConfig::default()));
}

#[test]
fn sweep_report_matches_golden() {
    let text = sweep::sweep_report(
        &[512, 2048],
        &NpuConfig::default(),
        &npuperf::config::SimConfig::default(),
    );
    check("sweep_512_2048.txt", &text);
}

#[test]
fn capacity_report_matches_golden() {
    // from_hw (not calibrated) keeps the fixture independent of the
    // calibration microbenchmarks' exact β_eff digits.
    let mem = MemoryConfig::from_hw(&NpuConfig::default());
    let text = sweep::capacity_report_with(registry::global(), &[512, 8192], &mem);
    check("capacity_512_8192.txt", &text);
}

#[test]
fn footprint_fixture_is_checked_in_and_matches() {
    // Strict: this fixture ships with the repo (it is hand-computable
    // closed-form arithmetic), so `Blessed` here means a broken checkout,
    // not a first run.
    let table = invariants::footprint_table(registry::global());
    match golden::compare("footprints.txt", &table, false) {
        Ok(Outcome::Match) => {}
        Ok(Outcome::Blessed) => {
            panic!("footprints.txt was missing — it must be committed with the repo")
        }
        Err(e) => panic!("{e}"),
    }
}
