//! Integration: the L3 coordinator over both backends — batching, routing,
//! state management and metrics — including real PJRT execution when the
//! artifacts are present.

use npuperf::config::{OperatorKind, WorkloadSpec};
use npuperf::coordinator::{
    BackendKind, Coordinator, CoordinatorConfig, ManualClock, Request,
};
use npuperf::runtime::{Golden, Manifest};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[test]
fn simulation_only_coordinator_serves_full_grid() {
    let coord = Coordinator::new(CoordinatorConfig {
        max_wait_ns: 100_000,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let mut reqs = Vec::new();
    for (i, op) in OperatorKind::ALL.iter().enumerate() {
        for n in [512usize, 2048, 8192] {
            reqs.push(Request {
                spec: WorkloadSpec::new(*op, n),
                session: i as u64,
                inputs: None,
            });
        }
    }
    let responses = coord.submit_all(reqs).unwrap();
    assert_eq!(responses.len(), 15);
    assert!(responses.iter().all(|r| r.backend == BackendKind::Simulate));
    assert!(responses.iter().all(|r| r.sim_report.is_some()));
}

#[test]
fn hybrid_routing_uses_pjrt_for_compiled_contexts() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let coord = Coordinator::new(CoordinatorConfig {
        artifact_dir: Some(dir.clone()),
        max_wait_ns: 100_000,
        ..CoordinatorConfig::default()
    })
    .unwrap();

    // Real inputs from goldens so we can check output correctness too.
    let manifest = Manifest::load(&dir).unwrap();
    let golden = Golden::load(manifest.golden_path("causal_n128_d64")).unwrap();

    let short = coord
        .submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Causal, 128),
            session: 1,
            inputs: Some(golden.inputs.clone()),
        })
        .unwrap();
    assert_eq!(short.backend, BackendKind::Pjrt);
    let out = &short.outputs.as_ref().unwrap()[0];
    assert!(out.max_abs_diff(&golden.outputs[0]) < 2e-3, "served output == oracle");

    let long = coord
        .submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Causal, 8192),
            session: 1,
            inputs: None,
        })
        .unwrap();
    assert_eq!(long.backend, BackendKind::Simulate);
}

#[test]
fn concurrent_submitters_all_complete() {
    let coord = std::sync::Arc::new(
        Coordinator::new(CoordinatorConfig {
            max_wait_ns: 100_000,
            ..CoordinatorConfig::default()
        })
        .unwrap(),
    );
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let c = coord.clone();
        joins.push(std::thread::spawn(move || {
            let mut oks = 0;
            for i in 0..5 {
                let op = OperatorKind::ALL[(t as usize + i) % 5];
                let r = c
                    .submit(Request {
                        spec: WorkloadSpec::new(op, 1024),
                        session: t * 100 + i as u64,
                        inputs: None,
                    })
                    .unwrap();
                assert!(r.backend_ns > 0.0);
                oks += 1;
            }
            oks
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, 20);
}

#[test]
fn session_state_tracked_across_requests() {
    let coord = Coordinator::new(CoordinatorConfig {
        max_wait_ns: 100_000,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    for i in 0..4 {
        coord
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Causal, 2048),
                session: 7,
                inputs: None,
            })
            .unwrap();
        let _ = i;
    }
    let snap = coord.metrics_snapshot().unwrap();
    assert!(snap.contains("sessions=1"), "one logical session: {snap}");
    assert!(snap.contains("total=4"), "{snap}");
}

#[test]
fn injected_clock_makes_serving_metrics_deterministic() {
    // The serving thread reads time only through the injected clock, so a
    // frozen ManualClock yields exact uptime/throughput numbers — the
    // point of the injectable-clock refactor. max_batch=1 dispatches each
    // request on push, so nothing depends on the (frozen) batching window.
    let clock = ManualClock::new();
    let coord = Coordinator::new(CoordinatorConfig {
        max_batch: 1,
        max_wait_ns: 100_000,
        clock: Some(std::sync::Arc::new(clock.clone())),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    for i in 0..4 {
        coord
            .submit(Request {
                spec: WorkloadSpec::new(OperatorKind::Retentive, 1024),
                session: i,
                inputs: None,
            })
            .unwrap();
    }
    clock.advance_ns(8_000_000_000); // exactly 8 s on the fake clock
    let snap = coord.metrics_snapshot().unwrap();
    assert!(snap.contains("uptime_ms=8000.000"), "{snap}");
    assert!(snap.contains("rps=0.50"), "{snap}");
    // The per-operator table row: 4 served, and every latency column
    // (mean/p50/p95/p99/max) exactly zero — the clock never ticked while
    // a request was in flight.
    let row = snap
        .lines()
        .find(|l| l.starts_with("retentive"))
        .unwrap_or_else(|| panic!("missing retentive row: {snap}"));
    let cols: Vec<&str> = row.split_whitespace().collect();
    assert_eq!(cols[1], "4", "{row}");
    assert!(cols[2..].iter().all(|c| *c == "0.000"), "latency never ticked: {row}");
}

#[test]
fn queue_age_is_exact_under_a_manual_clock() {
    // A request that sits in an unfilled batch until the window expires
    // is charged an enqueue-to-dispatch age of *exactly* the injected
    // clock's movement: submit at t=0, advance by 5 ms (> the 2 ms
    // window), and the expiry dispatch stamps queue_ns = 5 ms. The
    // snapshot round trip is the FIFO barrier that guarantees the serve
    // loop stamped enqueued_ns before the clock moves.
    let clock = ManualClock::new();
    let coord = Coordinator::new(CoordinatorConfig {
        max_batch: 8, // never fills: expiry is the only dispatch path
        max_wait_ns: 2_000_000,
        clock: Some(std::sync::Arc::new(clock.clone())),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let pending = coord
        .submit_async(Request {
            spec: WorkloadSpec::new(OperatorKind::Toeplitz, 512),
            session: 3,
            inputs: None,
        })
        .unwrap();
    let _ = coord.metrics_snapshot().unwrap(); // barrier: Submit processed
    clock.advance_ns(5_000_000);
    let resp = pending.wait().unwrap();
    assert_eq!(resp.queue_ns, 5_000_000, "exact enqueue-to-dispatch age");
    // The queue histogram saw exactly that one sample; the exposition
    // carries the same number.
    let prom = coord.metrics_prometheus().unwrap();
    assert!(
        prom.contains(r#"npuperf_request_queue_ns_sum{operator="toeplitz"} 5000000"#),
        "{prom}"
    );
    assert!(
        prom.contains(r#"npuperf_request_queue_ns_count{operator="toeplitz"} 1"#),
        "{prom}"
    );
}

#[test]
fn simulated_latency_visible_in_response() {
    let coord = Coordinator::new(CoordinatorConfig {
        max_wait_ns: 100_000,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let fast = coord
        .submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Toeplitz, 8192),
            session: 1,
            inputs: None,
        })
        .unwrap();
    let slow = coord
        .submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Fourier, 8192),
            session: 2,
            inputs: None,
        })
        .unwrap();
    assert!(
        slow.backend_ns > 50.0 * fast.backend_ns,
        "fourier {} vs toeplitz {}",
        slow.backend_ns,
        fast.backend_ns
    );
}
