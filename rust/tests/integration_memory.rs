//! Integration: the paged session-memory subsystem end to end — footprint
//! growth per operator class, LRU-with-pinning eviction, spill/refill
//! pricing, capacity-aware serving under pool pressure, and the
//! `capacity` CLI report.

use npuperf::config::{NpuConfig, OperatorKind, WorkloadSpec};
use npuperf::coordinator::{Coordinator, CoordinatorConfig, Request};
use npuperf::memory::{MemoryConfig, SessionMemory, SpillModel};
use npuperf::ops::registry;

const PAGE: u64 = 64 * 1024;

fn pool_of(pages: u64) -> MemoryConfig {
    MemoryConfig::from_hw(&NpuConfig::default()).with_pool_bytes(pages * PAGE)
}

#[test]
fn footprint_growth_matches_operator_class() {
    let reg = registry::global();
    let fp = |name: &str, n: usize| {
        let op = reg.get(name).unwrap();
        op.state_footprint(&WorkloadSpec::new(op.kind(), n), n)
    };
    // Attention KV: O(N·d).
    assert_eq!(fp("causal", 8192), 4 * fp("causal", 2048));
    // Retention / SSM state: constant in context.
    for op in ["retentive", "retentive-chunked", "linear", "fourier"] {
        assert_eq!(fp(op, 2048), fp(op, 8192), "{op}");
    }
    // Banded ring buffer: grows to the band, then flat.
    assert!(fp("toeplitz", 64) < fp("toeplitz", 2048));
    assert_eq!(fp("toeplitz", 2048), fp("toeplitz", 8192));
}

#[test]
fn page_tables_grow_with_kv_and_stay_flat_for_state() {
    let mut m = SessionMemory::new(pool_of(1024));
    let reg = registry::global();
    let causal = reg.get("causal").unwrap();
    let linear = reg.get("linear").unwrap();
    m.open(1);
    m.open(2);
    let mut last = 0;
    for n in [1024usize, 2048, 4096] {
        let kv = m
            .admit(1, causal.state_footprint(&WorkloadSpec::new(OperatorKind::Causal, n), n))
            .unwrap();
        assert!(kv.pages > last, "KV page extent must grow with context");
        last = kv.pages;
        let ssm = m
            .admit(2, linear.state_footprint(&WorkloadSpec::new(OperatorKind::Linear, n), n))
            .unwrap();
        assert_eq!(ssm.pages, 1, "recurrent state pins one page at every context");
    }
}

#[test]
fn eviction_is_lru_with_pinning() {
    let mut m = SessionMemory::new(pool_of(9));
    for id in 1..=2u64 {
        m.open(id);
        m.admit(id, 4 * PAGE).unwrap();
    }
    m.pin(1); // 1 is LRU but pinned
    m.open(3);
    let adm = m.admit(3, 4 * PAGE).unwrap();
    assert_eq!(adm.evicted, vec![2], "pressure falls on the LRU *unpinned* session");
    assert!(m.is_resident(1));
    assert!(!m.is_resident(2));
}

#[test]
fn spill_and_refill_are_priced_by_the_dma_ceiling() {
    let cfg = pool_of(8);
    let price = SpillModel { beta_eff_gbps: cfg.beta_eff_gbps, setup_ns: cfg.spill_setup_ns };
    let mut m = SessionMemory::new(cfg);
    m.open(1);
    m.open(2);
    m.admit(1, 5 * PAGE).unwrap();
    let adm = m.admit(2, 5 * PAGE).unwrap(); // must spill session 1
    assert_eq!(adm.evicted, vec![1]);
    assert_eq!(adm.spill_ns, price.transfer_ns(5 * PAGE));
    let back = m.admit(1, 5 * PAGE).unwrap(); // refills 1, spilling 2
    assert_eq!(back.refill_ns, price.transfer_ns(5 * PAGE));
    assert_eq!(back.evicted, vec![2]);
    let stats = m.stats();
    assert_eq!(stats.evictions, 2);
    assert!(stats.spill_ns > 0.0 && stats.refill_ns > 0.0);
    assert_eq!(stats.spilled_bytes, 10 * PAGE);
}

#[test]
fn serve_loop_under_pressure_spills_instead_of_growing_unbounded() {
    // Pool of 32 pages; each causal N=2048 session needs 8 pages, so only
    // four sessions fit — a stream of 12 distinct sessions must still
    // complete, with the pressure surfacing as eviction/spill time.
    let coord = Coordinator::new(CoordinatorConfig {
        max_wait_ns: 100_000,
        state_budget_bytes: 32 * PAGE,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request {
            spec: WorkloadSpec::new(OperatorKind::Causal, 2048),
            session: i,
            inputs: None,
        })
        .collect();
    let responses = coord.submit_all(reqs).unwrap();
    assert_eq!(responses.len(), 12, "pressure must not drop requests");
    let spilled: f64 = responses.iter().map(|r| r.spill_ns).sum();
    assert!(spilled > 0.0, "pool pressure must surface as spill nanoseconds");
    let snap = coord.metrics_snapshot().unwrap();
    assert!(snap.contains("evictions="), "{snap}");
    assert!(!snap.contains("evictions=0"), "nonzero evictions expected:\n{snap}");
    assert!(snap.contains("shed=0"), "everything fit after eviction:\n{snap}");
}

#[test]
fn session_bookkeeping_is_bounded_by_gc() {
    // 12 distinct sessions stream through a pool that fits 4; with a
    // tracked-session cap of 6 the server forgets LRU spilled sessions
    // instead of remembering every session it ever saw.
    let coord = Coordinator::new(CoordinatorConfig {
        max_wait_ns: 100_000,
        state_budget_bytes: 32 * PAGE,
        max_tracked_sessions: 6,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request {
            spec: WorkloadSpec::new(OperatorKind::Causal, 2048),
            session: i,
            inputs: None,
        })
        .collect();
    coord.submit_all(reqs).unwrap();
    let snap = coord.metrics_snapshot().unwrap();
    assert!(snap.contains("sessions=6"), "tracked sessions capped at 6:\n{snap}");
}

#[test]
fn oversized_footprint_is_shed_with_an_error() {
    // One page of pool: a causal 2048-token session (512 KiB) can never
    // be paged in, so admission control sheds the request.
    let coord = Coordinator::new(CoordinatorConfig {
        max_wait_ns: 100_000,
        state_budget_bytes: PAGE,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let err = coord
        .submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Causal, 2048),
            session: 1,
            inputs: None,
        })
        .unwrap_err()
        .to_string();
    assert!(err.contains("shed"), "{err}");

    // A constant-state operator still fits the same pool.
    let ok = coord
        .submit(Request {
            spec: WorkloadSpec::new(OperatorKind::Linear, 2048),
            session: 2,
            inputs: None,
        })
        .unwrap();
    assert!(ok.backend_ns > 0.0);
}

#[test]
fn attention_capacity_collapses_while_constant_state_stays_flat() {
    let cfg = MemoryConfig::from_hw(&NpuConfig::default());
    let reg = registry::global();
    let cap = |name: &str, n: usize| {
        let op = reg.get(name).unwrap();
        cfg.max_sessions(op.state_footprint(&WorkloadSpec::new(op.kind(), n), n))
    };
    assert!(
        cap("causal", 512) >= 8 * cap("causal", 16384),
        "causal {} vs {}",
        cap("causal", 512),
        cap("causal", 16384)
    );
    for name in ["retentive", "linear", "fourier", "toeplitz"] {
        assert_eq!(cap(name, 512), cap(name, 16384), "{name} capacity must hold");
    }
}

#[test]
fn capacity_cli_smoke() {
    let args: Vec<String> =
        ["capacity", "--contexts", "512,8192"].iter().map(|s| s.to_string()).collect();
    let out = npuperf::cli::run(&args).unwrap();
    assert!(out.contains("Max sessions"), "{out}");
    assert!(out.contains("collapses with context"), "{out}");
    assert!(out.contains("flat"), "{out}");
    for name in ["Full Causal", "Retentive", "Toeplitz", "Linear", "Fourier", "Ret-Chunked"] {
        assert!(out.contains(name), "missing {name}:\n{out}");
    }
}
