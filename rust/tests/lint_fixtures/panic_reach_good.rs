//! Lint fixture (data, never compiled): the same call chain as
//! `panic_reach_bad.rs` with the tail made panic-free.

pub fn lower_stage() {
    plan_tail();
}

fn plan_tail() {
    let spills: Vec<u64> = Vec::new();
    if let Some(last) = spills.last() {
        let _ = last;
    }
}
