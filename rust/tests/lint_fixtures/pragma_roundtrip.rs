//! Waived finding: the pragma names the rule and carries its reason, so
//! the finding is recorded but does not fail the run.
pub fn lookup(xs: &[u64]) -> u64 {
    // lint:allow(no-panic-serve-path, "fixture: demonstrates a reasoned waiver")
    *xs.first().unwrap()
}
