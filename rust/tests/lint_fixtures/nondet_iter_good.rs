//! Lint fixture (data, never compiled): the same exporter over a
//! `BTreeMap` — iteration order is the key order, deterministic.

use std::collections::BTreeMap;

pub struct SeriesExporter {
    series: BTreeMap<String, u64>,
}

impl SeriesExporter {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, value) in &self.series {
            out.push_str(name);
            out.push_str(&value.to_string());
        }
        out
    }
}
