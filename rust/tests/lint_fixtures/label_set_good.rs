//! Known-good: the same sorted key set everywhere; the unlabeled
//! fleet-aggregate series is exempt by convention.
use crate::coordinator::metrics::names;
use crate::obs::MetricsRegistry;

pub fn feed(reg: &mut MetricsRegistry) {
    reg.inc(names::SERVED, &[("device", "d0"), ("operator", "causal")], 1);
    reg.inc(names::SERVED, &[("operator", "linear"), ("device", "d1")], 1);
    reg.inc(names::SERVED, &[], 2);
}
