//! Known-bad: one metric recorded with two different label-key sets.
use crate::coordinator::metrics::names;
use crate::obs::MetricsRegistry;

pub fn feed(reg: &mut MetricsRegistry) {
    reg.inc(names::SERVED, &[("operator", "causal")], 1);
    reg.inc(names::SERVED, &[("device", "d0")], 1);
}
