//! Known-good: time comes in through the injected Clock trait.
use crate::coordinator::Clock;

pub fn stamp(clock: &dyn Clock) -> u64 {
    clock.now_ns()
}
