//! Known-bad: a metric name spelled as a literal at the record site.
use crate::obs::MetricsRegistry;

pub fn feed(reg: &mut MetricsRegistry) {
    reg.inc("npuperf_widgets_total", &[("operator", "causal")], 1);
}
