//! Lint fixture (data, never compiled): iterating a `HashMap` field in
//! an exporter — `RandomState` order would leak into the rendered
//! output. Linted under the synthetic path `rust/src/obs/fixture.rs`.

use std::collections::HashMap;

pub struct SeriesExporter {
    series: HashMap<String, u64>,
}

impl SeriesExporter {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, value) in &self.series {
            out.push_str(name);
            out.push_str(&value.to_string());
        }
        out
    }
}
