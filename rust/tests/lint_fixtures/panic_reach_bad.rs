//! Lint fixture (data, never compiled): a panic site two call frames
//! below the serve entry in `panic_reach_entry.rs`. Linted under the
//! synthetic path `rust/src/ops/fixture.rs` — outside the token rule's
//! serve-path file list, so only call-graph reachability can flag it.

pub fn lower_stage() {
    plan_tail();
}

fn plan_tail() {
    let spills: Vec<u64> = Vec::new();
    let _last = spills.last().unwrap();
}
