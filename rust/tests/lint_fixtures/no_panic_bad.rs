//! Known-bad: four panic idioms on the serve path.
use std::collections::HashMap;

pub fn reply(xs: &[u64], i: usize, m: &HashMap<usize, u64>) -> u64 {
    let first = xs.first().unwrap();
    let second = m.get(&i).expect("missing");
    if *first > 3 {
        panic!("boom");
    }
    first + second + xs[i]
}
