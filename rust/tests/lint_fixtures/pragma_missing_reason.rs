//! Known-bad: a waiver without a justification — the pragma itself is
//! reported and the underlying finding stays active.
pub fn lookup(xs: &[u64]) -> u64 {
    // lint:allow(no-panic-serve-path)
    *xs.first().unwrap()
}
