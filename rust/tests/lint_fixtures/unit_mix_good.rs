//! Lint fixture (data, never compiled): dividing bytes by bandwidth
//! derives a time — multiply/divide contexts are exempt, including
//! through an `as` cast.

pub fn transfer_eta_ns(setup_ns: f64, state_bytes: u64, link_gbps: f64) -> f64 {
    setup_ns + state_bytes as f64 / link_gbps
}
