//! Lint fixture (data, never compiled): a serve-path entry point whose
//! dispatch transitively reaches a panic planted in another module.
//! Linted under the synthetic path `rust/src/coordinator/dispatch.rs`.

pub struct Dispatcher;

impl Dispatcher {
    pub fn dispatch(&self) {
        crate::ops::fixture::lower_stage();
    }
}
