//! Known-good: fallible lookups stay fallible on the serve path.
pub fn reply(xs: &[u64], i: usize) -> Option<u64> {
    let first = xs.first()?;
    let rest = xs.get(i)?;
    Some(first + rest)
}
