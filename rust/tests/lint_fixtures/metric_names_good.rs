//! Known-good: the name comes from the single source of truth.
use crate::coordinator::metrics::names;
use crate::obs::MetricsRegistry;

pub fn feed(reg: &mut MetricsRegistry) {
    reg.inc(names::SERVED, &[("operator", "causal")], 1);
}
