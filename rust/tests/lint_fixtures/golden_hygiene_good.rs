//! Known-good: fixture comparison through the blessed helper, which
//! owns the directory path and the bless workflow.
#[test]
fn compares_fixture_through_testkit() {
    let dir = crate::testkit::golden::default_dir();
    crate::testkit::golden::compare_in(&dir, "report.txt", "body", false).unwrap();
}
