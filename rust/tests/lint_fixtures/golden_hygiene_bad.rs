//! Known-bad: a test writing into the golden directory directly.
#[test]
fn writes_fixture_behind_the_harness_back() {
    std::fs::write("rust/tests/golden/sneaky.txt", b"data").unwrap();
}
