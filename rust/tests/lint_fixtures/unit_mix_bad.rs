//! Lint fixture (data, never compiled): adds nanoseconds to bytes —
//! the dimensional mix-up the unit-consistency rule exists to catch.

pub fn queue_eta(busy_until_ns: u64, state_bytes: u64) -> u64 {
    busy_until_ns + state_bytes
}
