//! Conformance suite: the testkit's deterministic checks as `cargo test`
//! targets — differential serve-vs-direct agreement, seeded invariant
//! workouts, replay determinism, selftest end-to-end, and the
//! harness-has-teeth proof (a perturbed cost constant must be detected).

use npuperf::config::{NpuConfig, SimConfig};
use npuperf::coordinator::{Coordinator, CoordinatorConfig, ManualClock};
use npuperf::testkit::{self, differential, invariants, workload, SelftestOptions};

const SEEDS: [u64; 3] = [1, 2, 3];

#[test]
fn differential_serve_vs_direct_is_clean() {
    let rep =
        differential::check(&NpuConfig::default(), &SimConfig::default(), &[256, 1024]).unwrap();
    assert!(rep.is_clean(), "{}", rep.render());
    assert!(rep.cases > 0);
}

#[test]
fn perturbed_cost_constant_is_detected() {
    // The teeth test: serve on the default config, lower directly on a
    // config whose DMA descriptor-setup cost was doubled. Every lowering
    // issues transfers, so the simulated spans must diverge — a harness
    // that stays green here would also miss a real cost-model regression.
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let mut bent = hw.clone();
    bent.dma_setup_ns *= 2.0;
    let rep = differential::check_against(&hw, &sim, &bent, &sim, &[512]).unwrap();
    assert!(!rep.is_clean(), "a doubled dma_setup_ns must be detected");
    assert!(
        rep.divergences.iter().any(|d| d.what.contains("cycle counts differ")),
        "{}",
        rep.render()
    );
}

#[test]
fn perturbed_sim_config_is_detected() {
    // Same teeth, different knob: disabling double buffering serializes
    // compute behind transfers, which must change simulated spans.
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let bent = sim.clone().with_double_buffer(false);
    let rep = differential::check_against(&hw, &sim, &hw, &bent, &[2048]).unwrap();
    assert!(!rep.is_clean(), "disabling double buffering must be detected");
}

#[test]
fn replay_same_seed_is_identical_across_coordinators() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    for seed in SEEDS {
        let reqs = workload::stream(&workload::StreamConfig::new(seed));
        let run = || {
            let coord =
                workload::deterministic_coordinator(&hw, &sim, 8 * 1024 * 1024).unwrap();
            workload::replay(&coord, &reqs)
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "seed {seed}: two fresh coordinators must agree exactly");
        assert_eq!(
            workload::signature(&a),
            workload::signature(&b),
            "seed {seed}: rendered signatures must agree too"
        );
    }
}

#[test]
fn replay_different_seeds_diverge() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let run = |seed: u64| {
        let coord = workload::deterministic_coordinator(&hw, &sim, 8 * 1024 * 1024).unwrap();
        workload::replay(&coord, &workload::stream(&workload::StreamConfig::new(seed)))
    };
    assert_ne!(run(1), run(2), "different seeds must produce different outcome streams");
}

#[test]
fn multi_device_replay_is_deterministic_across_seeds() {
    // The placement stage (session-affinity, then least-loaded by
    // busy_until_ns) is a pure function of the request stream under the
    // deterministic coordinator, so multi-device replays must agree
    // exactly — same outcomes, same rendered signature — across fresh
    // fleets, for every pinned seed.
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    for seed in SEEDS {
        let reqs = workload::stream(&workload::StreamConfig::new(seed));
        let run = || {
            let coord =
                workload::deterministic_fleet(&hw, &sim, 8 * 1024 * 1024, 4).unwrap();
            workload::replay(&coord, &reqs)
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "seed {seed}: two fresh 4-device fleets must agree exactly");
        assert_eq!(workload::signature(&a), workload::signature(&b), "seed {seed}");
    }
}

#[test]
fn fleet_parity_one_device_is_byte_identical_and_four_preserve_semantics() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    for seed in SEEDS {
        let rep = differential::fleet_parity(&hw, &sim, seed, 4).unwrap();
        assert!(rep.is_clean(), "seed {seed}: {}", rep.render());
    }
}

#[test]
fn four_devices_beat_one_on_aggregate_makespan() {
    // Acceptance: on a seeded multi-session stream, spreading sessions
    // over 4 model-time timelines must strictly shorten the fleet
    // makespan — the whole point of the execution layer.
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let makespan = |devices: usize| -> u64 {
        // Frozen clock: dispatch always happens at t=0, so busy_until_ns
        // is pure accumulated model time, not wall time.
        let coord = Coordinator::new(CoordinatorConfig {
            max_batch: 1,
            max_wait_ns: 100_000,
            state_budget_bytes: 64 * 1024 * 1024,
            devices,
            clock: Some(std::sync::Arc::new(ManualClock::new())),
            ..CoordinatorConfig::for_hw(hw.clone(), sim.clone())
        })
        .unwrap();
        let reqs = workload::stream(&workload::StreamConfig::new(1));
        for r in reqs {
            let _ = coord.submit(r);
        }
        let stats = coord.fleet().unwrap();
        assert_eq!(stats.len(), devices);
        stats.iter().map(|d| d.busy_until_ns).max().unwrap_or(0)
    };
    let (one, four) = (makespan(1), makespan(4));
    assert!(one > 0, "single device must have accumulated model time");
    assert!(
        four < one,
        "4-device makespan ({four} ns) must beat 1-device ({one} ns)"
    );
}

#[test]
fn memory_invariants_hold_across_seeds() {
    for seed in SEEDS {
        invariants::memory_workout(seed, 500).unwrap();
    }
}

#[test]
fn batcher_fairness_holds_across_seeds() {
    for seed in SEEDS {
        invariants::batcher_fairness(seed, 500).unwrap();
    }
}

#[test]
fn footprint_curves_keep_their_paper_shapes() {
    invariants::footprint_monotonicity(npuperf::ops::registry::global()).unwrap();
}

#[test]
fn selftest_end_to_end_blesses_then_matches() {
    let dir = std::env::temp_dir()
        .join(format!("npuperf-conformance-selftest-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = SelftestOptions {
        seeds: vec![1],
        contexts: vec![128, 256],
        bless: false,
        golden_dir: Some(dir.clone()),
    };
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let first = testkit::selftest(&hw, &sim, &opts);
    assert!(first.passed(), "{}", first.render());
    assert!(first.render().contains("blessed"), "{}", first.render());
    let second = testkit::selftest(&hw, &sim, &opts);
    assert!(second.passed(), "{}", second.render());
    assert!(
        second.render().contains("matches pinned fixture"),
        "{}",
        second.render()
    );
    let _ = std::fs::remove_dir_all(&dir);
}
