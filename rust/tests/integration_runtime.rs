//! Integration: the PJRT runtime loads AOT artifacts, executes them, and
//! reproduces the golden outputs computed by the JAX oracle at build time.
//! This is the cross-language numeric handshake of the three-layer stack.
//!
//! Requires `make artifacts` (skipped gracefully when absent).

use npuperf::runtime::{Golden, HloRuntime, Manifest, Tensor};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn manifest_lists_all_operator_artifacts() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for op in ["causal", "retentive", "toeplitz", "linear", "fourier"] {
        for n in [128, 256, 512] {
            let name = format!("{op}_n{n}_d64");
            assert!(m.get(&name).is_some(), "missing artifact {name}");
        }
    }
}

#[test]
fn every_operator_artifact_matches_golden() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    let platform = rt.platform().to_lowercase();
    assert!(platform == "cpu" || platform == "host", "platform {platform}");
    // N=128 for all five operators: full numeric validation.
    for op in ["causal", "retentive", "toeplitz", "linear", "fourier"] {
        let name = format!("{op}_n128_d64");
        let diff = rt.validate(&name).unwrap();
        assert!(diff < 2e-3, "{name}: max |Δ| = {diff}");
    }
}

#[test]
fn longer_context_artifact_matches_golden() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    let diff = rt.validate("causal_n512_d64").unwrap();
    assert!(diff < 2e-3, "causal_n512: max |Δ| = {diff}");
}

#[test]
fn block_artifact_matches_golden() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    let diff = rt.validate("block_causal_n128_dm256").unwrap();
    assert!(diff < 5e-3, "block: max |Δ| = {diff}");
}

#[test]
fn execute_reports_timing_and_shapes() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    let golden = Golden::load(dir.join("linear_n128_d64.golden.txt")).unwrap();
    let (outputs, exec_ns) = rt.execute("linear_n128_d64", &golden.inputs).unwrap();
    assert_eq!(outputs.len(), 1);
    assert_eq!(outputs[0].shape, vec![128, 64]);
    assert!(exec_ns > 0.0);
}

#[test]
fn execute_rejects_wrong_arity() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    let t = Tensor::new(vec![128, 64], vec![0.0; 128 * 64]).unwrap();
    assert!(rt.execute("causal_n128_d64", &[t]).is_err());
}

#[test]
fn decode_artifacts_match_goldens() {
    // One autoregressive step (attention over a 512-token KV cache, and
    // the recurrent linear state step) — the decode path of §II-A Eq. 3.
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    for name in ["decode_causal_n512_d64", "decode_linear_d64_r16"] {
        let diff = rt.validate(name).unwrap();
        assert!(diff < 1e-3, "{name}: max |Δ| = {diff}");
    }
    // The linear step returns (y, S', z') — three outputs.
    let golden = Golden::load(dir.join("decode_linear_d64_r16.golden.txt")).unwrap();
    let (outputs, _) = rt.execute("decode_linear_d64_r16", &golden.inputs).unwrap();
    assert_eq!(outputs.len(), 3);
    assert_eq!(outputs[1].shape, vec![16, 64], "updated state S'");
}

#[test]
fn failure_injection_corrupt_hlo_is_rejected() {
    // Copy a valid artifact set, corrupt one HLO file: loading must fail
    // with a parse error, not execute garbage.
    let dir = require_artifacts!();
    let tmp = std::env::temp_dir().join(format!("npuperf-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    for f in ["manifest.txt", "toeplitz_n128_d64.hlo.txt", "toeplitz_n128_d64.golden.txt"] {
        std::fs::copy(dir.join(f), tmp.join(f)).unwrap();
    }
    // Keep only the one artifact in the manifest.
    let manifest = std::fs::read_to_string(tmp.join("manifest.txt")).unwrap();
    let line = manifest.lines().find(|l| l.starts_with("toeplitz_n128_d64 ")).unwrap();
    std::fs::write(tmp.join("manifest.txt"), format!("{line}\n")).unwrap();
    // Corrupt the HLO body.
    std::fs::write(tmp.join("toeplitz_n128_d64.hlo.txt"), "HloModule broken {{{").unwrap();
    let mut rt = HloRuntime::new(&tmp).unwrap();
    let err = rt.execute(
        "toeplitz_n128_d64",
        &Golden::load(tmp.join("toeplitz_n128_d64.golden.txt")).unwrap().inputs,
    );
    assert!(err.is_err(), "corrupt HLO must not execute");
}

#[test]
fn failure_injection_unknown_artifact() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    assert!(rt.load("no_such_artifact").is_err());
    let t = Tensor::new(vec![1], vec![0.0]).unwrap();
    assert!(rt.execute("no_such_artifact", &[t]).is_err());
}

#[test]
fn failure_injection_wrong_shape_inputs() {
    let dir = require_artifacts!();
    let mut rt = HloRuntime::new(&dir).unwrap();
    // Right arity, wrong shapes: PJRT must reject, not crash.
    let bad = vec![Tensor::new(vec![64, 64], vec![0.0; 64 * 64]).unwrap(); 3];
    assert!(rt.execute("causal_n128_d64", &bad).is_err());
}

#[test]
fn executor_thread_roundtrip() {
    let dir = require_artifacts!();
    let exec = npuperf::runtime::executor::Executor::spawn(&dir).unwrap();
    let h = exec.handle();
    h.warmup("toeplitz_n128_d64").unwrap();
    let diff = h.validate("toeplitz_n128_d64").unwrap();
    assert!(diff < 2e-3, "via executor: {diff}");
    // Concurrent submissions from multiple threads through one handle.
    let golden = Golden::load(
        Manifest::load(&dir).unwrap().golden_path("toeplitz_n128_d64"),
    )
    .unwrap();
    let mut joins = Vec::new();
    for _ in 0..4 {
        let h = h.clone();
        let inputs = golden.inputs.clone();
        joins.push(std::thread::spawn(move || {
            h.execute("toeplitz_n128_d64", inputs).unwrap()
        }));
    }
    for j in joins {
        let out = j.join().unwrap();
        assert_eq!(out.outputs[0].shape, vec![128, 64]);
    }
}
