//! Integration: the observability layer end to end — single-op Chrome
//! trace golden, the CLI serve pipeline's exported artifacts, and the
//! conformance between the Prometheus exposition and the human snapshot
//! under an injected `ManualClock`.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::coordinator::{Coordinator, CoordinatorConfig, ManualClock, Request};
use npuperf::testkit::golden;
use npuperf::testkit::workload::{stream, StreamConfig};
use npuperf::{cli, npu, obs, ops};

/// Per-test scratch dir (tests run concurrently in one process).
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("npuperf-obs-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every `"ts":` value in a rendered Chrome trace, in emitted order.
fn timestamps(json: &str) -> Vec<f64> {
    json.match_indices("\"ts\":")
        .map(|(i, _)| {
            let rest = &json[i + 5..];
            let end = rest.find(',').unwrap();
            rest[..end].parse::<f64>().unwrap()
        })
        .collect()
}

fn run_cli(args: &[&str]) -> anyhow::Result<String> {
    cli::run(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
}

// Satellite: the single-op trace dump emits valid JSON (no trailing
// commas), one metadata record per engine, monotone timestamps — and its
// bytes are pinned by a golden fixture (the simulator is deterministic).
#[test]
fn trace_dump_chrome_trace_is_valid_and_golden() {
    let (hw, sim) = (NpuConfig::default(), SimConfig::default());
    let spec = WorkloadSpec::new(OperatorKind::Causal, 256);
    let g = ops::lower(&spec, &hw, &sim);
    let trace = npu::simulate(&g, &hw, &sim);
    let json = npu::trace_dump::to_chrome_trace(&g, &trace);

    obs::validate_json(&json).expect("trace dump must be well-formed JSON");
    assert!(!json.contains(",\n]"), "no trailing comma before the closing bracket");
    assert_eq!(
        json.matches(r#""name":"thread_name""#).count(),
        4,
        "one metadata record per engine (DPU/SHAVE/DMA/CPU):\n{json}"
    );
    let ts = timestamps(&json);
    assert_eq!(ts.len(), g.len(), "one X event per primitive");
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "timestamps monotone: {ts:?}");

    if let Err(diff) = golden::compare("trace_dump_causal_n256.json", &json, false) {
        panic!("{diff}");
    }
}

// Acceptance: the issue's exact CLI invocation produces a merged
// Perfetto-loadable timeline whose request spans nest the per-engine NPU
// spans, plus a lint-clean Prometheus exposition.
#[test]
fn serve_cli_exports_merged_timeline_and_metrics() {
    let dir = scratch("acceptance");
    let (trace_path, prom_path) = (dir.join("t.json"), dir.join("m.prom"));
    run_cli(&[
        "serve",
        "--requests",
        "32",
        "--seed",
        "1",
        "--trace-out",
        trace_path.to_str().unwrap(),
        "--metrics-out",
        prom_path.to_str().unwrap(),
    ])
    .unwrap();

    let trace = std::fs::read_to_string(&trace_path).unwrap();
    obs::validate_json(&trace).expect("merged timeline must be well-formed JSON");
    assert_eq!(
        trace.matches(r#""name":"process_name""#).count(),
        33,
        "one process per request plus the single device's summary track"
    );
    assert!(trace.contains(r#""name":"device d0""#), "device track present:\n{trace}");
    // Request lifecycle stages ride tid 0 of their request's process.
    for stage in ["queued", "admission", "respond"] {
        assert!(trace.contains(&format!(r#""name":"{stage}""#)), "missing {stage} stage");
    }
    let prom = std::fs::read_to_string(&prom_path).unwrap();
    let lint = obs::lint_prometheus(&prom).expect("exposition must lint");
    assert!(lint.samples > 0 && lint.histograms > 0, "{lint:?}");
    assert!(prom.contains("npuperf_requests_served_total"), "{prom}");
    // Engine nesting needs the simulate backend; with a compiled artifact
    // inventory present the short contexts route to PJRT instead, so only
    // assert it on the simulation-only deployment CI runs.
    if !std::path::Path::new("artifacts").is_dir() {
        assert!(trace.contains(r#""name":"npu-simulate""#), "backend stage present");
        assert!(
            trace.contains(r#""cat":"DPU""#) || trace.contains(r#""cat":"SHAVE""#),
            "per-engine spans nested in the merged timeline:\n{trace}"
        );
        assert!(trace.contains(r#""tid":1"#), "engine track beside the request track");
    }
}

// Acceptance: counters/histograms in the Prometheus exposition exactly
// match what `metrics_snapshot` renders, under a frozen ManualClock.
#[test]
fn prometheus_exposition_matches_snapshot_under_manual_clock() {
    let clock = ManualClock::new();
    let coord = Coordinator::new(CoordinatorConfig {
        max_batch: 1,
        max_wait_ns: 100_000,
        clock: Some(std::sync::Arc::new(clock.clone())),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    for (op, count) in [(OperatorKind::Toeplitz, 3u64), (OperatorKind::Fourier, 2)] {
        for i in 0..count {
            coord
                .submit(Request { spec: WorkloadSpec::new(op, 512), session: i, inputs: None })
                .unwrap();
        }
    }
    clock.advance_ns(1_000_000_000); // exactly 1 s

    let snap = coord.metrics_snapshot().unwrap();
    let prom = coord.metrics_prometheus().unwrap();
    let json = coord.metrics_json().unwrap();
    obs::lint_prometheus(&prom).expect("exposition must lint");
    obs::validate_json(&json).expect("JSON snapshot must parse");

    // Same counters, both renderings.
    for (op, served) in [("toeplitz", 3u64), ("fourier", 2)] {
        assert!(
            prom.contains(&format!(
                r#"npuperf_requests_served_total{{backend="simulate",device="d0",operator="{op}"}} {served}"#
            )),
            "{prom}"
        );
        let row = snap
            .lines()
            .find(|l| l.starts_with(op))
            .unwrap_or_else(|| panic!("missing {op} row: {snap}"));
        let cols: Vec<&str> = row.split_whitespace().collect();
        assert_eq!(cols[1], served.to_string(), "served column: {row}");
    }
    // Same clock, both renderings: frozen-clock latencies are exactly
    // zero in the table and land in the histogram's first bucket.
    assert!(snap.contains("total=5"), "{snap}");
    assert!(snap.contains("uptime_ms=1000.000"), "{snap}");
    assert!(snap.contains("rps=5.00"), "{snap}");
    assert!(prom.contains("npuperf_uptime_ns 1000000000"), "{prom}");
    assert!(prom.contains("npuperf_throughput_rps 5"), "{prom}");
    assert!(
        prom.contains(r#"npuperf_request_latency_ns_count{operator="toeplitz"} 3"#),
        "{prom}"
    );
    assert!(
        prom.contains(r#"npuperf_request_latency_ns_sum{operator="toeplitz"} 0"#),
        "{prom}"
    );
    assert!(
        prom.contains(r#"npuperf_request_latency_ns_bucket{le="1",operator="toeplitz"} 3"#),
        "all three zero-latency samples in the first bucket:\n{prom}"
    );
}

// CI golden guard: the deterministic serve pipeline's exposition for
// pinned seed 1 is byte-stable. Mirrors
// `npuperf serve --deterministic --requests 32 --seed 1` on a
// simulation-only deployment (constructed directly so a locally built
// artifact inventory cannot shift the fixture).
#[test]
fn deterministic_serve_metrics_match_golden() {
    let coord = Coordinator::new(CoordinatorConfig {
        max_batch: 1,
        max_wait_ns: 100_000,
        clock: Some(std::sync::Arc::new(ManualClock::new())),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    for r in stream(&StreamConfig { requests: 32, ..StreamConfig::new(1) }) {
        coord.submit(r).unwrap();
    }
    let prom = coord.metrics_prometheus().unwrap();
    obs::lint_prometheus(&prom).expect("exposition must lint");
    if let Err(diff) = golden::compare("serve_metrics_seed1.prom", &prom, false) {
        panic!("{diff}");
    }
}

// Same golden guard for the 4-device fleet: placement is deterministic
// under the frozen clock, so the device-labeled exposition is just as
// byte-stable as the single-device one.
#[test]
fn deterministic_serve_metrics_match_golden_devices4() {
    let coord = Coordinator::new(CoordinatorConfig {
        max_batch: 1,
        max_wait_ns: 100_000,
        devices: 4,
        clock: Some(std::sync::Arc::new(ManualClock::new())),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    for r in stream(&StreamConfig { requests: 32, ..StreamConfig::new(1) }) {
        coord.submit(r).unwrap();
    }
    let prom = coord.metrics_prometheus().unwrap();
    obs::lint_prometheus(&prom).expect("exposition must lint");
    assert!(prom.contains("npuperf_fleet_devices 4"), "{prom}");
    if let Err(diff) = golden::compare("serve_metrics_seed1_devices4.prom", &prom, false) {
        panic!("{diff}");
    }
}

// The JSONL event log from the same serve run parses line by line and
// carries all three event kinds.
#[test]
fn serve_cli_event_log_parses_per_line() {
    let dir = scratch("events");
    let events_path = dir.join("serve.events.jsonl");
    run_cli(&[
        "serve",
        "--requests",
        "6",
        "--seed",
        "2",
        "--deterministic",
        "--events-out",
        events_path.to_str().unwrap(),
    ])
    .unwrap();
    let log = std::fs::read_to_string(&events_path).unwrap();
    let mut kinds = std::collections::BTreeSet::new();
    for line in log.lines().filter(|l| !l.trim().is_empty()) {
        obs::validate_json(line).unwrap_or_else(|e| panic!("{e}: {line}"));
        let kind = line.split("\"event\":\"").nth(1).unwrap().split('"').next().unwrap();
        kinds.insert(kind.to_string());
    }
    assert!(kinds.contains("request") && kinds.contains("stage"), "{kinds:?}");
    // Engine events require the simulate backend (see the acceptance
    // test for the artifact-inventory caveat).
    if !std::path::Path::new("artifacts").is_dir() {
        assert!(kinds.contains("engine"), "{kinds:?}");
    }
}
