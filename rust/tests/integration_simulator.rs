//! Integration: the NPU simulator end-to-end — lowering → event-driven
//! execution → derived metrics — must reproduce the paper's qualitative
//! landscape across the whole operator × context grid.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::npu::{self, ExecReport};
use npuperf::ops;
use npuperf::util::check::{forall, Rng};

fn run(op: OperatorKind, n: usize) -> ExecReport {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let spec = WorkloadSpec::new(op, n);
    let g = ops::lower(&spec, &hw, &sim);
    g.validate().expect("valid DAG");
    npu::run(&g, &hw, &sim)
}

#[test]
fn quadratic_vs_subquadratic_scaling_separation() {
    // Table III/IV headline: quadratic operators blow up, structured ones
    // scale near-linearly. Check growth factors from 2048 to 8192 (4x N).
    let growth = |op| run(op, 8192).span_ns / run(op, 2048).span_ns;
    assert!(growth(OperatorKind::Causal) > 8.0, "causal ~quadratic");
    assert!(growth(OperatorKind::Fourier) > 8.0, "fourier ~quadratic");
    assert!(growth(OperatorKind::Toeplitz) < 6.0, "toeplitz ~linear");
    assert!(growth(OperatorKind::Linear) < 6.0, "linear ~linear");
}

#[test]
fn long_context_winner_order_matches_table4() {
    // Table IV at N=8192: Linear & Toeplitz >> Retentive > Fourier/Causal.
    let lat = |op| run(op, 8192).span_ns;
    let causal = lat(OperatorKind::Causal);
    let toeplitz = lat(OperatorKind::Toeplitz);
    let linear = lat(OperatorKind::Linear);
    let retentive = lat(OperatorKind::Retentive);
    let fourier = lat(OperatorKind::Fourier);
    assert!(toeplitz < linear, "toeplitz fastest (paper: 1.01 vs 3.16 ms)");
    assert!(linear < retentive);
    assert!(retentive < causal);
    assert!(causal < fourier, "fourier worst (paper: 347 vs 251 ms)");
    // And by a qualitative margin: >40x between structured and quadratic.
    assert!(causal / toeplitz > 40.0);
}

#[test]
fn causal_is_memory_bound_with_massive_stalls() {
    // Table V row 1: 96.7% stall, 7.7% cache efficiency, reuse ~120 ms.
    let r = run(OperatorKind::Causal, 8192);
    assert!(r.stall.stall_frac() > 0.8, "stall {}", r.stall.stall_frac());
    assert!(r.cache.efficiency() < 0.15, "cache {}", r.cache.efficiency());
    assert!(
        r.cache.reuse_ns > 0.3 * r.span_ns,
        "spilled scores sit for a large fraction of the run"
    );
}

#[test]
fn structured_operators_are_cache_friendly() {
    // Table V: Toeplitz 87.9%, Linear 83.8% vs Causal 7.7%.
    let toe = run(OperatorKind::Toeplitz, 4096);
    let lin = run(OperatorKind::Linear, 8192);
    let causal = run(OperatorKind::Causal, 8192);
    assert!(toe.cache.efficiency() > 0.7);
    assert!(lin.cache.efficiency() > 0.7);
    assert!(causal.cache.efficiency() < toe.cache.efficiency() / 5.0);
    // Reuse latencies: structured ops re-consume quickly.
    assert!(toe.cache.reuse_ns < causal.cache.reuse_ns / 20.0);
}

#[test]
fn bottleneck_transitions_match_table2() {
    // Retentive: SHAVE share grows monotonically-ish and dominates late.
    let shares: Vec<f64> = [128usize, 512, 2048, 8192]
        .iter()
        .map(|&n| run(OperatorKind::Retentive, n).utilization()[2])
        .collect();
    assert!(shares[3] > 0.6, "SHAVE-bound at 8192: {shares:?}");
    assert!(shares[3] > shares[0] + 0.2, "share must climb: {shares:?}");
    // Retentive never uses meaningful DMA (paper: 0.0% everywhere).
    for n in [512usize, 4096] {
        assert!(run(OperatorKind::Retentive, n).utilization()[1] < 0.08);
    }
    // Fourier: DPU-heavy with a substantial DMA share at long context.
    let f = run(OperatorKind::Fourier, 8192);
    let [dpu, dma, _] = f.utilization();
    assert!(dpu > 0.4 && dma > 0.2, "fourier DPU/DMA split: {dpu}/{dma}");
}

#[test]
fn throughput_reciprocal_consistency() {
    for op in OperatorKind::ALL {
        let r = run(op, 1024);
        let want = 1e9 / r.span_ns;
        assert!((r.throughput_ops_s() - want).abs() / want < 1e-9, "{op}");
    }
}

#[test]
fn simulation_is_deterministic_across_runs() {
    for op in OperatorKind::ALL {
        let a = run(op, 2048);
        let b = run(op, 2048);
        assert_eq!(a.span_ns, b.span_ns, "{op}");
        assert_eq!(a.cache.hits, b.cache.hits, "{op}");
        assert_eq!(a.busy_ns, b.busy_ns, "{op}");
    }
}

#[test]
fn property_all_metrics_well_formed_on_random_workloads() {
    forall(
        "well-formed reports",
        40,
        |rng: &mut Rng| {
            let ops = OperatorKind::ALL;
            let op = *rng.choose(&ops);
            // Mix power-of-two and awkward odd sizes (1, 7, 100, 129, ...).
            let n = if rng.bool() {
                128usize << rng.range(0, 5) // 128..4096
            } else {
                *rng.choose(&[1usize, 7, 32, 64, 100, 129, 200, 1000, 5000])
            };
            let d_state = *rng.choose(&[8usize, 16, 32, 64, 128]);
            (op, n, d_state)
        },
        |&(op, n, d_state)| {
            let hw = NpuConfig::default();
            let sim = SimConfig::default();
            let spec = WorkloadSpec::new(op, n).with_d_state(d_state);
            let g = ops::lower(&spec, &hw, &sim);
            g.validate()?;
            let r = npu::run(&g, &hw, &sim);
            if !(r.span_ns > 0.0) {
                return Err("zero span".into());
            }
            let [a, b, c] = r.utilization();
            if (a + b + c - 1.0).abs() > 1e-6 {
                return Err(format!("utilization sums to {}", a + b + c));
            }
            let s = r.stall.stall_frac();
            if !(0.0..=1.0).contains(&s) {
                return Err(format!("stall {s}"));
            }
            let e = r.cache.efficiency();
            if !(0.0..=1.0).contains(&e) {
                return Err(format!("cache eff {e}"));
            }
            for eng in 0..4 {
                if r.busy_ns[eng] > r.span_ns * (1.0 + 1e-9) {
                    return Err(format!("engine {eng} busy > span"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_latency_monotone_in_context() {
    forall(
        "monotone scaling",
        10,
        |rng: &mut Rng| *rng.choose(&OperatorKind::ALL),
        |&op| {
            let mut prev = 0.0;
            for n in [256usize, 512, 1024, 2048, 4096] {
                let s = run(op, n).span_ns;
                if s <= prev {
                    return Err(format!("{op} not monotone at N={n}"));
                }
                prev = s;
            }
            Ok(())
        },
    );
}
