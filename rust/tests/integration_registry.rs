//! Integration: the operator registry end-to-end — enumeration, dispatch
//! through the NPU engine, bottleneck classification against the paper's
//! taxonomy, and the "new operator = one trait impl + one registry line"
//! extension contract the architecture doc promises.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::npu;
use npuperf::ops::registry::{self, classify, BoundClass, CausalOperator, OperatorRegistry};
use npuperf::ops::{self, OpGraph};
use npuperf::report::sweep;

fn cfg() -> (NpuConfig, SimConfig) {
    (NpuConfig::default(), SimConfig::default())
}

#[test]
fn registry_enumerates_builtins_and_covers_every_kind() {
    let reg = registry::global();
    let names = reg.names();
    for want in ["causal", "retentive", "toeplitz", "linear", "fourier", "retentive-chunked"] {
        assert!(names.contains(&want), "missing {want} in {names:?}");
    }
    for kind in OperatorKind::ALL {
        assert_eq!(reg.for_kind(kind).kind(), kind);
    }
}

#[test]
fn every_registered_operator_dispatches_through_the_engine() {
    // The acceptance walk: enumerate -> lower -> simulate at two context
    // lengths, and get a well-formed report out of each cell.
    let (hw, sim) = cfg();
    for op in registry::global().iter() {
        for n in [512usize, 2048] {
            let spec = WorkloadSpec::new(op.kind(), n);
            let g = op.lower(&spec, &hw, &sim);
            g.validate().unwrap_or_else(|e| panic!("{} N={n}: {e}", op.name()));
            let r = npu::run(&g, &hw, &sim);
            assert!(r.span_ns > 0.0, "{} N={n}", op.name());
            let total: f64 = r.utilization().iter().sum();
            assert!((total - 1.0).abs() < 1e-6, "{} N={n}: {total}", op.name());
            let _ = classify(&r); // total over every cell
        }
    }
}

#[test]
fn pipeline_entry_points_agree_with_direct_registry_dispatch() {
    // ops::lower / npu::run_workload are the registry's front doors: they
    // must produce exactly the canonical entry's lowering.
    let (hw, sim) = cfg();
    for kind in OperatorKind::ALL {
        let spec = WorkloadSpec::new(kind, 1024);
        let via_entry = ops::lower(&spec, &hw, &sim);
        let via_registry = registry::global().for_kind(kind).lower(&spec, &hw, &sim);
        assert_eq!(via_entry.label, via_registry.label);
        assert_eq!(via_entry.len(), via_registry.len());
        let r = npu::run_workload(&spec, &hw, &sim);
        assert_eq!(r.span_ns, npu::run(&via_entry, &hw, &sim).span_ns);
    }
}

#[test]
fn classification_reproduces_the_paper_taxonomy() {
    // The paper's §IV landscape: the quadratic baseline thrashes memory,
    // retention hits the SHAVE vector wall, linear attention keeps the
    // systolic array as the limiter.
    let (hw, sim) = cfg();
    let class = |op, n| classify(&npu::run_workload(&WorkloadSpec::new(op, n), &hw, &sim));

    assert_eq!(
        class(OperatorKind::Causal, 8192),
        BoundClass::Memory,
        "spilling quadratic attention is memory-bound (Table V)"
    );
    assert_eq!(
        class(OperatorKind::Retentive, 8192),
        BoundClass::VectorCompute,
        "retentive decay is SHAVE-bound past N=1024 (Table II)"
    );
    for n in [4096usize, 8192] {
        assert_eq!(
            class(OperatorKind::Linear, n),
            BoundClass::Compute,
            "linear attention keeps the DPU as the limiter at N={n}"
        );
    }
    // Toeplitz keeps its working set resident: whatever dominates, it can
    // never classify as cache-thrashing memory-bound.
    assert_ne!(class(OperatorKind::Toeplitz, 4096), BoundClass::Memory);
    // Fourier's spectrum work is matmul+DMA, not vector-bound.
    assert_ne!(class(OperatorKind::Fourier, 2048), BoundClass::VectorCompute);
}

#[test]
fn decode_variants_dispatch_for_every_entry() {
    let (hw, sim) = cfg();
    for op in registry::global().iter() {
        let spec = WorkloadSpec::new(op.kind(), 1024);
        let g = op.lower_decode(&spec, &hw, &sim);
        g.validate().unwrap_or_else(|e| panic!("{}: {e}", op.name()));
        let r = npu::run(&g, &hw, &sim);
        assert!(r.span_ns > 0.0, "{} decode", op.name());
    }
}

#[test]
fn sweep_report_covers_the_full_registry() {
    let (hw, sim) = cfg();
    let text = sweep::sweep_report(&[128, 512, 2048], &hw, &sim);
    for op in registry::global().iter() {
        assert!(text.contains(op.paper_name()), "sweep missing {}", op.name());
    }
    assert!(text.contains("Classification"));
    assert!(text.contains("-bound"));
    assert!(text.contains("Long-context verdicts"));
}

// ---- the extension contract --------------------------------------------

/// The architecture doc's walkthrough operator: full causal attention
/// restricted to a fixed 256-token sliding window — implemented entirely
/// outside the pipeline by delegating to the Toeplitz lowering machinery.
struct SlidingWindow;

impl CausalOperator for SlidingWindow {
    fn name(&self) -> &'static str {
        "sliding-window"
    }
    fn paper_name(&self) -> &'static str {
        "SlidingWin"
    }
    fn kind(&self) -> OperatorKind {
        OperatorKind::Toeplitz
    }
    fn complexity(&self) -> &'static str {
        "O(N*W*d)"
    }
    fn lower(&self, spec: &WorkloadSpec, hw: &NpuConfig, sim: &SimConfig) -> OpGraph {
        // A 256-token window is a Toeplitz band at d_state = 32.
        let windowed = WorkloadSpec { d_state: 32, ..*spec };
        let mut g = ops::toeplitz::lower(&windowed, hw, sim);
        g.label = format!("sliding-window N={}", spec.n);
        g
    }
}

#[test]
fn new_operator_plugs_in_with_one_registry_line() {
    let (hw, sim) = cfg();
    let mut reg = OperatorRegistry::with_builtins();
    reg.register(Box::new(SlidingWindow)); // <- the one line

    // Enumerable...
    assert!(reg.names().contains(&"sliding-window"));
    // ...addressable by name...
    let op = reg.get("sliding-window").expect("registered");
    // ...and servable through the unchanged engine + report path.
    for n in [512usize, 2048] {
        let spec = WorkloadSpec::new(op.kind(), n);
        let g = op.lower(&spec, &hw, &sim);
        g.validate().unwrap();
        let r = npu::run(&g, &hw, &sim);
        assert!(r.span_ns > 0.0);
    }
    // The sweep report picks it up with zero report-layer changes.
    let text = sweep::sweep_report_with(&reg, &[512], &hw, &sim);
    assert!(text.contains("SlidingWin"), "{text}");

    // The canonical kind dispatch is untouched: Toeplitz still resolves to
    // the builtin registered first.
    assert_eq!(reg.for_kind(OperatorKind::Toeplitz).name(), "toeplitz");
}
