//! Integration: reproduction-quality gates for every paper table/figure.
//!
//! These tests encode the *shape* claims of the paper's evaluation — who
//! wins, by roughly what factor, where the transitions fall — against our
//! simulated values. They are the regression net under EXPERIMENTS.md.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::coordinator::chunking;
use npuperf::model::calibrate;
use npuperf::report::{figures, run_cell, tables};
use npuperf::{npu, ops};

fn cfg() -> (NpuConfig, SimConfig) {
    (NpuConfig::default(), SimConfig::default())
}

// ---- Table II ----------------------------------------------------------

#[test]
fn table2_fourier_transitions_and_retentive_goes_shave() {
    let (hw, sim) = cfg();
    // Fourier: meaningful DMA share (>= 20%) from 512 up (paper: 47-53%).
    for n in [512usize, 2048, 8192] {
        let [_, dma, _] = run_cell(OperatorKind::Fourier, n, &hw, &sim).utilization();
        assert!(dma > 0.2, "fourier N={n} dma={dma}");
    }
    // Retentive: SHAVE-bound regime from 1024 (paper: 65-76%).
    for n in [2048usize, 4096, 8192] {
        let [_, dma, shave] = run_cell(OperatorKind::Retentive, n, &hw, &sim).utilization();
        assert!(shave > 0.55, "retentive N={n} shave={shave}");
        assert!(dma < 0.05, "retentive DMA ~0 (paper: 0.0)");
    }
}

// ---- Table III ---------------------------------------------------------

#[test]
fn table3_latency_within_3x_of_paper_at_long_context() {
    let (hw, sim) = cfg();
    let paper = [
        (OperatorKind::Fourier, 347.79),
        (OperatorKind::Retentive, 85.41),
        (OperatorKind::Toeplitz, 1.01),
        (OperatorKind::Linear, 3.16),
    ];
    for (op, want) in paper {
        let got = run_cell(op, 8192, &hw, &sim).latency_ms();
        let ratio = got / want;
        assert!(
            (0.33..3.0).contains(&ratio),
            "{op} at 8192: ours {got:.2} ms vs paper {want:.2} ms (x{ratio:.2})"
        );
    }
}

// ---- Table IV ----------------------------------------------------------

#[test]
fn table4_causal_latency_and_throughput_shape() {
    let (hw, sim) = cfg();
    let r = run_cell(OperatorKind::Causal, 8192, &hw, &sim);
    // Paper: 251.41 ms, 4 ops/s.
    assert!((100.0..400.0).contains(&r.latency_ms()), "{}", r.latency_ms());
    assert!((2.5..10.0).contains(&r.throughput_ops_s()), "{}", r.throughput_ops_s());
}

// ---- Table V -----------------------------------------------------------

#[test]
fn table5_ordering_stall_and_cache() {
    let (hw, sim) = cfg();
    let causal = run_cell(OperatorKind::Causal, 8192, &hw, &sim);
    let linear = run_cell(OperatorKind::Linear, 8192, &hw, &sim);
    let toeplitz = run_cell(OperatorKind::Toeplitz, 4096, &hw, &sim);
    // Stall ordering: causal >> linear > toeplitz (paper 96.7/55.2/36.4).
    assert!(causal.stall.stall_frac() > linear.stall.stall_frac());
    assert!(linear.stall.stall_frac() > toeplitz.stall.stall_frac());
    // Cache ordering: toeplitz ≈ linear >> causal (paper 87.9/83.8/7.7).
    assert!(toeplitz.cache.efficiency() > 0.7);
    assert!(linear.cache.efficiency() > 0.7);
    assert!(causal.cache.efficiency() < 0.15);
    // Reuse: causal parks data ~100x longer than the structured ops.
    assert!(causal.cache.reuse_ns > 20.0 * toeplitz.cache.reuse_ns);
}

// ---- Table VI ----------------------------------------------------------

#[test]
fn table6_d_state_growth_factors() {
    let (hw, sim) = cfg();
    let growth = |op| {
        let lo = WorkloadSpec::new(op, 4096);
        let hi = lo.with_d_state(128);
        let a = npu::run(&ops::lower(&lo, &hw, &sim), &hw, &sim).span_ns;
        let b = npu::run(&ops::lower(&hi, &hw, &sim), &hw, &sim).span_ns;
        b / a
    };
    // Paper: Linear 1.41x, Toeplitz 4.2x, Fourier 3.67x.
    let lin = growth(OperatorKind::Linear);
    let toe = growth(OperatorKind::Toeplitz);
    let fou = growth(OperatorKind::Fourier);
    assert!((1.0..2.5).contains(&lin), "linear {lin:.2}");
    assert!((2.0..8.0).contains(&toe), "toeplitz {toe:.2}");
    assert!((1.8..6.0).contains(&fou), "fourier {fou:.2}");
    assert!(lin < fou && lin < toe, "linear least sensitive, as in paper");
}

// ---- Table VII / Fig 7 ---------------------------------------------------

#[test]
fn table7_effective_ceilings_and_intensity_ordering() {
    let (hw, sim) = cfg();
    let c = calibrate(&hw, &sim);
    // Paper: pi_eff 500 GOP/s, beta_eff 3.2 GB/s, I_crit 156.
    assert!((250.0..900.0).contains(&c.pi_eff_gops), "{}", c.pi_eff_gops);
    assert!((1.5..6.0).contains(&c.beta_eff_gbps), "{}", c.beta_eff_gbps);
    assert!((80.0..350.0).contains(&c.i_crit()), "{}", c.i_crit());
    // Intensity ordering (paper: 61 > 50 > 25 > 16 ≈ 15).
    use npuperf::ops::flops::profile;
    let intensity =
        |op| profile(&WorkloadSpec::new(op, 4096), sim.elem_bytes).intensity();
    assert!(intensity(OperatorKind::Causal) > intensity(OperatorKind::Retentive));
    assert!(intensity(OperatorKind::Retentive) > intensity(OperatorKind::Toeplitz));
    assert!(intensity(OperatorKind::Toeplitz) > intensity(OperatorKind::Fourier));
}

#[test]
fn fig7_fourier_has_catastrophic_roof_fraction() {
    // §IV-D: Fourier achieves 0.7% of its bound — orders below the rest.
    let (hw, sim) = cfg();
    let roofline = npuperf::model::Roofline::new(calibrate(&hw, &sim));
    let frac = |op| {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, &hw, &sim);
        roofline.place(&spec, &r, sim.elem_bytes).roof_fraction()
    };
    let fourier = frac(OperatorKind::Fourier);
    assert!(fourier < 0.1, "fourier roof fraction {fourier}");
    assert!(fourier * 5.0 < frac(OperatorKind::Causal));
}

// ---- §V discussion ------------------------------------------------------

#[test]
fn chunked_prefill_reproduces_paper_optimum() {
    let hw = NpuConfig::default();
    let best = chunking::optimal_chunk(16_384, 64, &hw);
    assert_eq!(best.chunk, 2048, "paper: 2048-token chunks");
    let reduction = chunking::peak_memory_reduction(16_384, 2048, 64);
    assert!(reduction > 4.0, "paper: ~8x; ours {reduction:.1}x");
}

#[test]
fn concat_offload_reduces_fourier_latency() {
    // Paper: -32%. Ours lands in the -10..-45% band.
    let (hw, _) = cfg();
    let base = SimConfig::default();
    let off = SimConfig::default().with_offload(true);
    let spec = WorkloadSpec::new(OperatorKind::Fourier, 4096);
    let a = npu::run(&ops::lower(&spec, &hw, &base), &hw, &base).span_ns;
    let b = npu::run(&ops::lower(&spec, &hw, &off), &hw, &off).span_ns;
    let delta = (a - b) / a;
    assert!((0.05..0.50).contains(&delta), "offload delta {delta:.2}");
}

// ---- Rendering sanity over the full reporting surface --------------------

#[test]
fn all_tables_and_figures_render() {
    let (hw, sim) = cfg();
    let t = tables::all_tables(&hw, &sim);
    assert!(t.len() > 2000);
    for f in [
        figures::fig3(16),
        figures::fig4(&hw, &sim),
        figures::fig5(&hw, &sim),
        figures::fig6(&hw, &sim),
        figures::fig7(&hw, &sim),
        figures::fig8(&hw, &sim),
    ] {
        assert!(f.len() > 100);
    }
}
