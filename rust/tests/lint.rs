//! Integration tests for `npuperf lint`: every rule fires on its
//! known-bad fixture and stays quiet on the known-good twin, pragmas
//! round-trip, and the repo itself lints clean (self-hosting).
//!
//! The fixtures live in `rust/tests/lint_fixtures/` as data — they are
//! lexed by the analyzer, never compiled — and are embedded here with
//! `include_str!` so the tests run from any working directory.

use std::path::Path;

use npuperf::analysis::{lint_repo, rules, Analyzer, LintReport};

/// Lint one fixture under a synthetic repo-relative path (paths drive
/// rule scoping: serve-path modules, test files, the clock module...).
fn lint_one(path: &str, src: &str) -> LintReport {
    let mut a = Analyzer::new();
    a.add_source(path, src);
    a.run()
}

/// Assert the bad fixture trips `rule` and the good one is fully clean.
fn check_pair(rule: &str, path: &str, bad: &str, good: &str) {
    let bad_report = lint_one(path, bad);
    assert!(
        bad_report.active().any(|f| f.rule == rule),
        "{rule}: bad fixture produced no active finding:\n{}",
        bad_report.render_human()
    );
    let good_report = lint_one(path, good);
    assert!(
        good_report.is_clean() && good_report.findings.is_empty(),
        "{rule}: good fixture is not clean:\n{}",
        good_report.render_human()
    );
}

#[test]
fn no_wall_clock_fires_outside_the_clock_module() {
    check_pair(
        rules::NO_WALL_CLOCK,
        "rust/src/report/fixture.rs",
        include_str!("lint_fixtures/no_wall_clock_bad.rs"),
        include_str!("lint_fixtures/no_wall_clock_good.rs"),
    );
}

#[test]
fn no_wall_clock_is_silent_in_the_blessed_clock_module() {
    let bad = include_str!("lint_fixtures/no_wall_clock_bad.rs");
    let report = lint_one("rust/src/coordinator/clock.rs", bad);
    assert!(report.is_clean(), "{}", report.render_human());
}

#[test]
fn no_panic_fires_on_the_serve_path() {
    let bad = include_str!("lint_fixtures/no_panic_bad.rs");
    check_pair(
        rules::NO_PANIC,
        "rust/src/coordinator/dispatch.rs",
        bad,
        include_str!("lint_fixtures/no_panic_good.rs"),
    );
    // All four idioms are caught: unwrap, expect, panic!, indexing.
    let report = lint_one("rust/src/memory/fixture.rs", bad);
    let hits = report.active().filter(|f| f.rule == rules::NO_PANIC).count();
    assert_eq!(hits, 4, "{}", report.render_human());
}

#[test]
fn no_panic_ignores_files_off_the_serve_path() {
    let bad = include_str!("lint_fixtures/no_panic_bad.rs");
    let report = lint_one("rust/src/model/fixture.rs", bad);
    assert!(report.is_clean(), "{}", report.render_human());
}

#[test]
fn metric_name_literals_must_come_from_names() {
    check_pair(
        rules::METRIC_NAMES,
        "rust/src/obs/fixture.rs",
        include_str!("lint_fixtures/metric_names_bad.rs"),
        include_str!("lint_fixtures/metric_names_good.rs"),
    );
}

#[test]
fn label_sets_must_agree_per_metric() {
    check_pair(
        rules::LABEL_SETS,
        "rust/src/coordinator/fixture.rs",
        include_str!("lint_fixtures/label_set_bad.rs"),
        include_str!("lint_fixtures/label_set_good.rs"),
    );
}

#[test]
fn golden_hygiene_applies_to_test_code() {
    check_pair(
        rules::GOLDEN_HYGIENE,
        "rust/tests/fixture.rs",
        include_str!("lint_fixtures/golden_hygiene_bad.rs"),
        include_str!("lint_fixtures/golden_hygiene_good.rs"),
    );
}

#[test]
fn reasoned_pragma_waives_but_keeps_the_finding() {
    let report = lint_one(
        "rust/src/memory/fixture.rs",
        include_str!("lint_fixtures/pragma_roundtrip.rs"),
    );
    assert!(report.is_clean(), "waived run must pass:\n{}", report.render_human());
    let waived: Vec<_> =
        report.findings.iter().filter(|f| f.allowed.is_some()).collect();
    assert_eq!(waived.len(), 1, "{}", report.render_human());
    assert_eq!(waived[0].rule, rules::NO_PANIC);
    assert!(
        waived[0].allowed.as_deref().unwrap().contains("reasoned waiver"),
        "pragma reason must survive into the report"
    );
}

#[test]
fn pragma_without_reason_is_rejected() {
    let report = lint_one(
        "rust/src/memory/fixture.rs",
        include_str!("lint_fixtures/pragma_missing_reason.rs"),
    );
    assert!(!report.is_clean());
    assert!(
        report.active().any(|f| f.rule == rules::PRAGMA),
        "malformed pragma must itself be a finding:\n{}",
        report.render_human()
    );
    assert!(
        report.active().any(|f| f.rule == rules::NO_PANIC),
        "a reason-less pragma must not waive:\n{}",
        report.render_human()
    );
}

#[test]
fn repo_lints_clean_at_head() {
    let report = lint_repo(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap();
    assert!(
        report.is_clean(),
        "the repo must self-host its own lint:\n{}",
        report.render_human()
    );
    assert!(report.files_scanned > 20, "scanned only {} files", report.files_scanned);
    // The waivers placed at the two measurement sites are visible in the
    // report (recorded, not hidden), each with a reason.
    assert!(report.findings.iter().any(|f| f.allowed.is_some()));
    assert!(report
        .findings
        .iter()
        .all(|f| !matches!(f.allowed.as_deref(), Some(r) if r.trim().is_empty())));
}

#[test]
fn lint_report_is_deterministic_and_jsonl_is_valid() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let a = lint_repo(root).unwrap();
    let b = lint_repo(root).unwrap();
    assert_eq!(a.render_human(), b.render_human());
    assert_eq!(a.render_jsonl(), b.render_jsonl());
    for line in a.render_jsonl().lines() {
        npuperf::obs::validate_json(line).expect(line);
    }
}

// ---- Semantic rules (parser + call graph) -----------------------------

#[test]
fn unit_consistency_flags_mixed_unit_arithmetic() {
    check_pair(
        rules::UNIT_CONSISTENCY,
        "rust/src/npu/fixture.rs",
        include_str!("lint_fixtures/unit_mix_bad.rs"),
        include_str!("lint_fixtures/unit_mix_good.rs"),
    );
}

#[test]
fn nondet_iteration_flags_hash_maps_on_emission_paths() {
    check_pair(
        rules::NONDET_ITER,
        "rust/src/obs/fixture.rs",
        include_str!("lint_fixtures/nondet_iter_bad.rs"),
        include_str!("lint_fixtures/nondet_iter_good.rs"),
    );
}

#[test]
fn nondet_iteration_ignores_files_off_emission_paths() {
    // The same HashMap iteration in a module nothing exports from is fine.
    let report = lint_one(
        "rust/src/model/fixture.rs",
        include_str!("lint_fixtures/nondet_iter_bad.rs"),
    );
    assert!(report.is_clean(), "{}", report.render_human());
}

#[test]
fn panic_reachability_reports_the_full_call_chain() {
    let entry = include_str!("lint_fixtures/panic_reach_entry.rs");
    let mut a = Analyzer::new();
    a.add_source("rust/src/coordinator/dispatch.rs", entry);
    a.add_source("rust/src/ops/fixture.rs", include_str!("lint_fixtures/panic_reach_bad.rs"));
    let report = a.run();
    let finding = report
        .active()
        .find(|f| f.rule == rules::PANIC_REACH)
        .unwrap_or_else(|| panic!("no panic-reachability finding:\n{}", report.render_human()));
    assert_eq!(finding.file, "rust/src/ops/fixture.rs");
    // The rendered chain names every frame, entry point to panic site.
    for frame in [
        "coordinator::dispatch::Dispatcher::dispatch",
        "ops::fixture::lower_stage",
        "ops::fixture::plan_tail",
    ] {
        assert!(
            finding.message.contains(frame),
            "chain missing frame {frame}: {}",
            finding.message
        );
    }

    let mut good = Analyzer::new();
    good.add_source("rust/src/coordinator/dispatch.rs", entry);
    good.add_source("rust/src/ops/fixture.rs", include_str!("lint_fixtures/panic_reach_good.rs"));
    let report = good.run();
    assert!(
        report.is_clean() && report.findings.is_empty(),
        "panic-free twin must be clean:\n{}",
        report.render_human()
    );
}

// ---- SARIF + ratchet ---------------------------------------------------

#[test]
fn sarif_export_of_the_repo_is_valid_and_schema_shaped() {
    let report = lint_repo(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap();
    let sarif = npuperf::analysis::sarif::render_sarif(&report);
    npuperf::obs::validate_json(sarif.trim()).expect("SARIF must be valid JSON");
    assert!(sarif.contains("\"$schema\":\"https://json.schemastore.org/sarif-2.1.0.json\""));
    assert!(sarif.contains("\"version\":\"2.1.0\""));
    assert!(sarif.contains("\"name\":\"npuperf-lint\""));
    for rule in rules::RULE_NAMES {
        assert!(sarif.contains(&format!("{{\"id\":\"{rule}\"}}")), "rule {rule} not declared");
    }
    // The repo's in-source waivers surface as suppressed notes.
    assert!(sarif.contains("\"suppressions\":[{\"kind\":\"inSource\""));
}

#[test]
fn ratchet_fails_on_growth_and_passes_on_shrinkage() {
    use npuperf::analysis::baseline::Baseline;
    let noisy = lint_one(
        "rust/src/npu/fixture.rs",
        include_str!("lint_fixtures/unit_mix_bad.rs"),
    );
    let quiet = lint_one(
        "rust/src/npu/fixture.rs",
        include_str!("lint_fixtures/unit_mix_good.rs"),
    );
    let grow = Baseline::from_report(&quiet).check(&Baseline::from_report(&noisy));
    assert!(!grow.passed(), "new findings must fail the ratchet");
    assert!(!grow.regressions.is_empty());
    let shrink = Baseline::from_report(&noisy).check(&Baseline::from_report(&quiet));
    assert!(shrink.passed(), "fixed findings must pass the ratchet");
    assert!(!shrink.improvements.is_empty());
}

#[test]
fn checked_in_baseline_holds_at_head() {
    use npuperf::analysis::baseline::Baseline;
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(root.join("lint-baseline.json")).unwrap();
    let recorded = Baseline::parse(&text).unwrap();
    let report = lint_repo(root).unwrap();
    let outcome = recorded.check(&Baseline::from_report(&report));
    assert!(outcome.passed(), "{}", outcome.render_human());
}

// ---- Discovery scope ---------------------------------------------------

#[test]
fn lint_discovers_benches_with_the_right_rule_scope() {
    let dir = std::env::temp_dir().join(format!("npuperf-lint-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("rust/src")).unwrap();
    std::fs::create_dir_all(dir.join("rust/benches")).unwrap();
    std::fs::write(dir.join("rust/src/lib.rs"), "pub fn ok() {}\n").unwrap();
    // The planted metric-name literal is assembled at runtime so this
    // test file itself stays lint-clean.
    let planted = format!(
        "use std::time::Instant;\nfn main() {{\n    let t0 = Instant::now();\n    \
         let name = \"{}planted_total\";\n    let _ = (t0, name);\n}}\n",
        concat!("npu", "perf_"),
    );
    std::fs::write(dir.join("rust/benches/planted.rs"), planted).unwrap();
    let report = lint_repo(&dir).unwrap();
    assert!(
        report
            .active()
            .any(|f| f.rule == rules::METRIC_NAMES && f.file == "rust/benches/planted.rs"),
        "planted bench violation not reported:\n{}",
        report.render_human()
    );
    // Benches measure host time by design: no-wall-clock is exempt there,
    // and only there.
    assert!(!report.findings.iter().any(|f| f.rule == rules::NO_WALL_CLOCK));
}

#[test]
fn lint_repo_rejects_non_repo_roots() {
    let dir = std::env::temp_dir().join(format!("npuperf-lint-noroot-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let err = lint_repo(&dir).unwrap_err();
    assert!(err.to_string().contains("rust/src"), "{err}");
}
