//! Extension bench: decode-phase throughput (paper §II-A Eq. 3).
//!
//! Sustained tokens/s for one autoregressive decode step at growing
//! retained context — the memory-state tradeoff at decode time: KV
//! operators degrade with context, recurrent/banded operators stay flat.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::ops::decode;
use npuperf::report::export;

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!(
        "{:<12} {:>8} {:>8} {:>8} {:>8} {:>8}   tokens/s per retained context",
        "operator", "1K", "4K", "16K", "64K", "128K"
    );
    let contexts = [1024usize, 4096, 16_384, 65_536, 131_072];
    let mut rows = Vec::new();
    for op in OperatorKind::ALL {
        let tps: Vec<f64> = contexts
            .iter()
            .map(|&n| decode::tokens_per_second(&WorkloadSpec::new(op, n), &hw, &sim))
            .collect();
        println!(
            "{:<12} {:>8.0} {:>8.0} {:>8.0} {:>8.0} {:>8.0}",
            op.paper_name(),
            tps[0],
            tps[1],
            tps[2],
            tps[3],
            tps[4]
        );
        for (&n, &t) in contexts.iter().zip(&tps) {
            rows.push(vec![op.name().to_string(), n.to_string(), format!("{t:.1}")]);
        }
    }
    export::write_csv(
        export::report_dir().join("ext_decode_phase.csv"),
        &["op", "context", "tokens_per_s"],
        &rows,
    )
    .unwrap();
}
