//! Bench: regenerate paper Table VI — latency impact of growing the state
//! dimension d_state from 16 to 128 at fixed context N = 4096.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::report::{export, tables};
use npuperf::{npu, ops};

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table6(&hw, &sim));

    // Full sweep (not just the two paper points) for the CSV.
    let mut rows = Vec::new();
    for op in [OperatorKind::Linear, OperatorKind::Toeplitz, OperatorKind::Fourier] {
        for d_state in [16usize, 32, 64, 128] {
            let spec = WorkloadSpec::new(op, 4096).with_d_state(d_state);
            let g = ops::lower(&spec, &hw, &sim);
            let r = npu::run(&g, &hw, &sim);
            rows.push(vec![
                op.name().to_string(),
                d_state.to_string(),
                format!("{:.4}", r.latency_ms()),
            ]);
        }
    }
    export::write_csv(
        export::report_dir().join("table6_state_dim.csv"),
        &["op", "d_state", "latency_ms"],
        &rows,
    )
    .unwrap();
}
