//! Bench: regenerate paper Table III / Fig 5 — latency scaling of the four
//! sub-quadratic operators from N = 128 to 8192.

use npuperf::config::{NpuConfig, SimConfig};
use npuperf::report::{export, figures, tables};
use npuperf::util::stats::bench;

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table3(&hw, &sim));
    println!("{}", figures::fig5(&hw, &sim));

    let mut rows = Vec::new();
    for (op, series) in figures::fig5_series(&hw, &sim) {
        for (n, ms) in series {
            rows.push(vec![op.name().to_string(), n.to_string(), format!("{ms:.4}")]);
        }
    }
    export::write_csv(
        export::report_dir().join("table3_latency.csv"),
        &["op", "context", "latency_ms"],
        &rows,
    )
    .unwrap();

    let r = bench("table3 sweep", 1, 3, || {
        let _ = figures::fig5_series(&hw, &sim);
    });
    println!("[bench] {}: mean {:.1} ms/iter over {} iters", r.name, r.mean_ms(), r.iters);
}
