//! Bench: regenerate paper Table IV — latency and operator throughput at
//! short (512) and long (8192) context for all five operators.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig};
use npuperf::report::{export, run_cell, tables};

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table4(&hw, &sim));

    let mut rows = Vec::new();
    for op in OperatorKind::ALL {
        for n in [512usize, 8192] {
            let r = run_cell(op, n, &hw, &sim);
            rows.push(vec![
                op.name().to_string(),
                n.to_string(),
                format!("{:.4}", r.latency_ms()),
                format!("{:.1}", r.throughput_ops_s()),
            ]);
        }
    }
    export::write_csv(
        export::report_dir().join("table4_throughput.csv"),
        &["op", "context", "latency_ms", "throughput_ops_s"],
        &rows,
    )
    .unwrap();
}
