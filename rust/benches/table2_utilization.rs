//! Bench: regenerate paper Table II / Fig 4 — device utilization breakdown
//! (DPU/DMA/SHAVE %) for Fourier and Retentive across context lengths.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig};
use npuperf::report::{export, figures, tables};
use npuperf::util::stats::bench;

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table2(&hw, &sim));
    println!("{}", figures::fig4(&hw, &sim));

    // CSV series for external plotting.
    let mut rows = Vec::new();
    for op in [OperatorKind::Fourier, OperatorKind::Retentive] {
        for (n, dpu, dma, shave) in figures::fig4_series(op, &hw, &sim) {
            rows.push(vec![
                op.name().to_string(),
                n.to_string(),
                format!("{dpu:.2}"),
                format!("{dma:.2}"),
                format!("{shave:.2}"),
            ]);
        }
    }
    export::write_csv(
        export::report_dir().join("table2_utilization.csv"),
        &["op", "context", "dpu_pct", "dma_pct", "shave_pct"],
        &rows,
    )
    .unwrap();

    // Wall-clock cost of producing one full sweep (simulator throughput).
    let r = bench("table2 sweep", 1, 3, || {
        let _ = figures::fig4_series(OperatorKind::Retentive, &hw, &sim);
    });
    println!("[bench] {}: mean {:.1} ms/iter over {} iters", r.name, r.mean_ms(), r.iters);
}
