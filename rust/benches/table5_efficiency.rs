//! Bench: regenerate paper Table V / Fig 6 — pipeline stall, cache
//! efficiency and state-reuse latency at long contexts.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig};
use npuperf::report::{export, figures, run_cell, tables};

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table5(&hw, &sim));
    println!("{}", figures::fig6(&hw, &sim));

    let cells = [
        (OperatorKind::Causal, 8192),
        (OperatorKind::Retentive, 8192),
        (OperatorKind::Fourier, 4096),
        (OperatorKind::Linear, 8192),
        (OperatorKind::Toeplitz, 4096),
    ];
    let mut rows = Vec::new();
    for (op, n) in cells {
        let r = run_cell(op, n, &hw, &sim);
        rows.push(vec![
            op.name().to_string(),
            n.to_string(),
            format!("{:.2}", r.stall.stall_frac() * 100.0),
            format!("{:.2}", r.cache.efficiency() * 100.0),
            format!("{:.4}", r.cache.reuse_ns / 1e6),
        ]);
    }
    export::write_csv(
        export::report_dir().join("table5_efficiency.csv"),
        &["op", "context", "stall_pct", "cache_eff_pct", "reuse_ms"],
        &rows,
    )
    .unwrap();
}
