//! End-to-end serving bench: drive the coordinator with deployment-shaped
//! request traces (paper §I workloads) — PJRT execution for compiled
//! contexts, simulated NPU beyond — and report batched latency/throughput.

use npuperf::config::OperatorKind;
use npuperf::coordinator::{
    workload_gen::{generate, Profile},
    BackendKind, Coordinator, CoordinatorConfig, Request,
};
use npuperf::report::export;
use npuperf::util::stats::Summary;

fn run_profile(coord: &Coordinator, profile: Profile, count: usize) -> Vec<String> {
    let trace = generate(profile, count, 0xBEEF);
    let reqs: Vec<Request> = trace
        .iter()
        .enumerate()
        .map(|(i, g)| Request { spec: g.spec, session: i as u64, inputs: None })
        .collect();
    let t0 = std::time::Instant::now();
    let responses = coord.submit_all(reqs).expect("serve");
    let wall = t0.elapsed().as_secs_f64();

    let mut pjrt = Summary::new();
    let mut sim = Summary::new();
    for r in &responses {
        match r.backend {
            BackendKind::Pjrt => pjrt.push(r.backend_ns / 1e6),
            BackendKind::Simulate => sim.push(r.backend_ns / 1e6),
        }
    }
    println!(
        "{profile:?}: {count} reqs in {wall:.2}s ({:.0} req/s) — PJRT {} (mean {:.2} ms, p99 {:.2} ms), simulated {} (modeled mean {:.2} ms)",
        count as f64 / wall,
        pjrt.len(),
        pjrt.mean(),
        pjrt.percentile(99.0),
        sim.len(),
        sim.mean(),
    );
    vec![
        format!("{profile:?}"),
        count.to_string(),
        format!("{wall:.3}"),
        format!("{:.1}", count as f64 / wall),
        pjrt.len().to_string(),
        format!("{:.4}", pjrt.mean()),
        sim.len().to_string(),
        format!("{:.4}", sim.mean()),
    ]
}

fn main() {
    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cfg = if artifact_dir.join("manifest.txt").exists() {
        CoordinatorConfig { artifact_dir: Some(artifact_dir), warmup: true, ..Default::default() }
    } else {
        eprintln!("artifacts missing: simulation-only serving bench");
        CoordinatorConfig::default()
    };
    let coord = Coordinator::new(cfg).expect("coordinator");

    let mut rows = Vec::new();
    for profile in [Profile::Chat, Profile::Documents, Profile::Mixed] {
        rows.push(run_profile(&coord, profile, 100));
    }
    println!("\n{}", coord.metrics_snapshot().unwrap());
    let _ = OperatorKind::ALL;

    export::write_csv(
        export::report_dir().join("e2e_serving.csv"),
        &["profile", "requests", "wall_s", "req_per_s", "pjrt_count", "pjrt_mean_ms", "sim_count", "sim_mean_ms"],
        &rows,
    )
    .unwrap();
}
