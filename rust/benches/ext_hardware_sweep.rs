//! Extension bench: hardware what-if sweeps (co-design, DESIGN.md S24).
//!
//! How do the paper's bottlenecks move if the NPU changes? Sweeps
//! scratchpad size, DMA bandwidth and SHAVE width, reporting the
//! long-context latency of the bottlenecked operators.

use npuperf::config::{parse, NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::report::export;
use npuperf::{npu, ops};

fn lat(op: OperatorKind, n: usize, hw: &NpuConfig) -> f64 {
    let sim = SimConfig::default();
    npu::run(&ops::lower(&WorkloadSpec::new(op, n), hw, &sim), hw, &sim).latency_ms()
}

fn main() {
    let mut rows = Vec::new();

    println!("scratchpad sweep (causal N=2048 — score planes are 2x8.4 MiB):");
    for (label, bytes) in [("4m", "4m"), ("8m", "8m"), ("16m", "16m"), ("32m", "32m")] {
        let mut hw = NpuConfig::default();
        parse::apply(&mut hw, "scratchpad_bytes", bytes).unwrap();
        let ms = lat(OperatorKind::Causal, 2048, &hw);
        println!("  scratchpad={label:<4} -> {ms:>8.2} ms");
        rows.push(vec!["scratchpad".into(), label.into(), format!("{ms:.3}")]);
    }

    println!("\nDMA allocation-overhead sweep (causal N=8192 — the §V churn):");
    for alloc_ns in [20_000.0f64, 10_000.0, 5_000.0, 1_000.0] {
        let mut hw = NpuConfig::default();
        hw.dma_alloc_ns = alloc_ns;
        let ms = lat(OperatorKind::Causal, 8192, &hw);
        println!("  alloc={alloc_ns:>7.0} ns -> {ms:>8.2} ms");
        rows.push(vec!["dma_alloc_ns".into(), format!("{alloc_ns}"), format!("{ms:.3}")]);
    }

    println!("\nSHAVE width sweep (retentive N=8192 — SHAVE-bound):");
    for cores in [4usize, 8, 16, 32] {
        let mut hw = NpuConfig::default();
        hw.shave_cores = cores;
        let ms = lat(OperatorKind::Retentive, 8192, &hw);
        println!("  shave_cores={cores:<3} -> {ms:>8.2} ms");
        rows.push(vec!["shave_cores".into(), cores.to_string(), format!("{ms:.3}")]);
    }

    println!("\nDMA bandwidth sweep (fourier N=4096 — DMA-heavy):");
    for bw in [32.0f64, 64.0, 128.0, 256.0] {
        let mut hw = NpuConfig::default();
        hw.dma_bw_gbps = bw;
        let ms = lat(OperatorKind::Fourier, 4096, &hw);
        println!("  dma={bw:>5.0} GB/s -> {ms:>8.2} ms");
        rows.push(vec!["dma_bw_gbps".into(), format!("{bw}"), format!("{ms:.3}")]);
    }

    export::write_csv(
        export::report_dir().join("ext_hardware_sweep.csv"),
        &["knob", "value", "latency_ms"],
        &rows,
    )
    .unwrap();
}
