//! Ablation bench: §V "Chunked Prefill for Memory Scaling" — chunk-size
//! sweep, optimal chunk detection, and peak-memory reduction vs monolithic.

use npuperf::config::NpuConfig;
use npuperf::coordinator::chunking;
use npuperf::report::export;

fn main() {
    let hw = NpuConfig::default();
    let mut rows = Vec::new();
    for n in [4096usize, 8192, 16_384, 32_768] {
        println!("--- prefill N={n} ---");
        for c in [256usize, 512, 1024, 2048, 4096, 8192] {
            if c > n {
                continue;
            }
            let p = chunking::plan(n, c, 64, &hw);
            println!(
                "  C={:<5} chunks={:<3} peak={:<10} lat={:>8.2} ms{}",
                p.chunk,
                p.chunks,
                npuperf::util::fmt::bytes(p.peak_bytes),
                p.latency_ms,
                if p.overflows { "  [overflow]" } else { "" }
            );
            rows.push(vec![
                n.to_string(),
                c.to_string(),
                format!("{:.3}", p.latency_ms),
                p.peak_bytes.to_string(),
                p.overflows.to_string(),
            ]);
        }
        let best = chunking::optimal_chunk(n, 64, &hw);
        println!(
            "  optimal: C={} ({:.1}x peak-memory reduction; paper: 2048 / 8x)",
            best.chunk,
            chunking::peak_memory_reduction(n, best.chunk, 64)
        );
    }
    export::write_csv(
        export::report_dir().join("ablation_chunking.csv"),
        &["n", "chunk", "latency_ms", "peak_bytes", "overflows"],
        &rows,
    )
    .unwrap();
}
