//! Bench: regenerate paper Table VII / Fig 7 — operational intensity,
//! measured GOP/s and the effective-ceiling roofline.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::model::{calibrate, Roofline};
use npuperf::report::{export, figures, run_cell, tables};

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table7(&hw, &sim));
    println!("{}", figures::fig7(&hw, &sim));

    let roofline = Roofline::new(calibrate(&hw, &sim));
    let mut rows = Vec::new();
    for op in OperatorKind::ALL {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, &hw, &sim);
        let p = roofline.place(&spec, &r, sim.elem_bytes);
        rows.push(vec![
            op.name().to_string(),
            format!("{:.3}", p.intensity),
            format!("{:.3}", p.measured_gops),
            format!("{:.3}", p.bound_gops),
            format!("{:.4}", p.roof_fraction()),
        ]);
    }
    export::write_csv(
        export::report_dir().join("table7_roofline.csv"),
        &["op", "intensity_ops_per_byte", "measured_gops", "bound_gops", "roof_fraction"],
        &rows,
    )
    .unwrap();
}
