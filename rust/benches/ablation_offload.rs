//! Ablation bench: §V "DMA Management for Memory-Intensive Ops" — offload
//! Fourier's spectrum-merge concats to the host CPU (paper: −32 % latency)
//! — plus the Toeplitz double-buffering ablation.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::report::export;
use npuperf::{npu, ops};

fn run(op: OperatorKind, n: usize, sim: &SimConfig) -> f64 {
    let hw = NpuConfig::default();
    let spec = WorkloadSpec::new(op, n);
    npu::run(&ops::lower(&spec, &hw, sim), &hw, sim).latency_ms()
}

fn main() {
    let base = SimConfig::default();
    let offload = SimConfig::default().with_offload(true);
    let no_db = SimConfig::default().with_double_buffer(false);

    println!("Fourier concat offload (paper: -32% latency):");
    let mut rows = Vec::new();
    for n in [1024usize, 2048, 4096, 8192] {
        let b = run(OperatorKind::Fourier, n, &base);
        let o = run(OperatorKind::Fourier, n, &offload);
        let delta = 100.0 * (b - o) / b;
        println!("  N={n:<5} base {b:>8.2} ms  offload {o:>8.2} ms  ({delta:+.1}%)");
        rows.push(vec![
            "offload_concat".into(),
            n.to_string(),
            format!("{b:.3}"),
            format!("{o:.3}"),
            format!("{delta:.2}"),
        ]);
    }

    println!("\nToeplitz DMA double-buffering:");
    for n in [1024usize, 4096, 8192] {
        let with = run(OperatorKind::Toeplitz, n, &base);
        let without = run(OperatorKind::Toeplitz, n, &no_db);
        let delta = 100.0 * (without - with) / without;
        println!(
            "  N={n:<5} double-buffered {with:>6.2} ms  serialized {without:>6.2} ms  (saves {delta:.1}%)"
        );
        rows.push(vec![
            "double_buffer".into(),
            n.to_string(),
            format!("{without:.3}"),
            format!("{with:.3}"),
            format!("{delta:.2}"),
        ]);
    }
    export::write_csv(
        export::report_dir().join("ablation_offload.csv"),
        &["ablation", "n", "baseline_ms", "variant_ms", "delta_pct"],
        &rows,
    )
    .unwrap();
}
