//! Bench: regenerate paper Table VIII / Fig 8 — stall / cache efficiency /
//! compute utilization at N = 4096 for all five operators.

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::model::{calibrate, Roofline};
use npuperf::report::{export, figures, run_cell, tables};

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    println!("{}", tables::table8(&hw, &sim));
    println!("{}", figures::fig8(&hw, &sim));

    let ceilings = calibrate(&hw, &sim);
    let roofline = Roofline::new(ceilings);
    let mut rows = Vec::new();
    for op in OperatorKind::ALL {
        let spec = WorkloadSpec::new(op, 4096);
        let r = run_cell(op, 4096, &hw, &sim);
        let p = roofline.place(&spec, &r, sim.elem_bytes);
        rows.push(vec![
            op.name().to_string(),
            format!("{:.2}", r.stall.stall_frac() * 100.0),
            format!("{:.2}", r.cache.efficiency() * 100.0),
            format!("{:.2}", p.measured_gops / ceilings.pi_eff_gops * 100.0),
        ]);
    }
    export::write_csv(
        export::report_dir().join("table8_hw_util.csv"),
        &["op", "stall_pct", "cache_eff_pct", "compute_util_pct"],
        &rows,
    )
    .unwrap();
}
