//! Extension bench: the co-design payoff — the paper's quadratic DRA
//! kernel vs the hardware-aware chunkwise-recurrent retention form
//! (ops::retentive_chunked). Quantifies the paper's conclusion that
//! "throughput gains come from co-designing causal operators".

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::model::EnergyModel;
use npuperf::ops::{retentive, retentive_chunked};
use npuperf::report::export;
use npuperf::npu;

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let energy = EnergyModel::default();
    println!(
        "{:>6} {:>14} {:>14} {:>8} {:>12} {:>12}",
        "N", "quadratic ms", "chunkwise ms", "speedup", "quad mJ", "chunk mJ"
    );
    let mut rows = Vec::new();
    for n in [512usize, 1024, 2048, 4096, 8192, 16_384] {
        let spec = WorkloadSpec::new(OperatorKind::Retentive, n);
        let quad = npu::run(&retentive::lower(&spec, &hw, &sim), &hw, &sim);
        let chunk = npu::run(&retentive_chunked::lower(&spec, &hw, &sim), &hw, &sim);
        let speedup = quad.span_ns / chunk.span_ns;
        let qe = energy.evaluate(&quad).total_mj();
        let ce = energy.evaluate(&chunk).total_mj();
        println!(
            "{:>6} {:>14.2} {:>14.2} {:>7.1}x {:>12.3} {:>12.3}",
            n,
            quad.latency_ms(),
            chunk.latency_ms(),
            speedup,
            qe,
            ce
        );
        rows.push(vec![
            n.to_string(),
            format!("{:.4}", quad.latency_ms()),
            format!("{:.4}", chunk.latency_ms()),
            format!("{speedup:.2}"),
            format!("{qe:.4}"),
            format!("{ce:.4}"),
        ]);
    }
    export::write_csv(
        export::report_dir().join("ext_chunked_retention.csv"),
        &["n", "quadratic_ms", "chunkwise_ms", "speedup", "quad_mj", "chunk_mj"],
        &rows,
    )
    .unwrap();
}
