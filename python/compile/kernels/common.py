"""Shared helpers for the Pallas kernels.

Block-size policy (DESIGN.md §Hardware-Adaptation): blocks are multiples of
the NPU's 128×128 systolic tile (≙ TPU MXU tile) and sized so one grid
step's working set fits the 4 MB scratchpad (≙ VMEM). ``interpret=True``
everywhere — the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernels lower to plain HLO; the *structure* (BlockSpec schedule) is what
carries over to real hardware.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30

# Systolic/MXU tile edge. Query blocks are one tile row tall.
TILE = 128

# Scratchpad budget from paper Table I, used by vmem_footprint() checks.
SCRATCHPAD_BYTES = 4 * 1024 * 1024

INTERPRET = True  # CPU PJRT: always interpret-mode (see module docstring)


def q_block(n: int) -> int:
    """Query-block height: one systolic tile, shrunk for tiny test shapes."""
    return min(TILE, n)


def row_softmax_masked(scores: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Stable masked row softmax (same contract as ref._masked_softmax)."""
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask.astype(scores.dtype)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def vmem_footprint_bytes(*shapes_dtypes: tuple[tuple[int, ...], jnp.dtype]) -> int:
    """Bytes of VMEM one grid step touches — asserted < SCRATCHPAD_BYTES in
    tests so kernel block choices stay honest to the 4 MB budget."""
    total = 0
    for shape, dtype in shapes_dtypes:
        count = 1
        for s in shape:
            count *= s
        total += count * jnp.dtype(dtype).itemsize
    return total
