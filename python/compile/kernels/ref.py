"""Pure-jnp oracles for the five causal inference operators.

These are the *correctness* references (paper §II-C). Every Pallas kernel in
this package is validated against the matching function here by
``python/tests/test_kernels.py``; the Rust runtime re-validates the lowered
HLO against golden I/O produced from these same functions.

Shapes follow the paper's microbenchmark setup: single head,
``q, k, v : (N, d_h)`` with ``d_h = 64`` by default. Batch/head dims are
added at the model (L2) level with ``vmap``.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf: keeps softmax NaN-free on f32


def _causal_mask(n: int) -> jnp.ndarray:
    """Lower-triangular boolean mask M[i, j] = (j <= i)."""
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    return j <= i


def _masked_softmax(scores: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable row softmax over the masked entries only."""
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask.astype(scores.dtype)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Full Causal Mask attention: softmax(QK^T / sqrt(d) + M) V."""
    n, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    probs = _masked_softmax(scores, _causal_mask(n))
    return probs @ v


def retentive_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, gamma: float = 0.97
) -> jnp.ndarray:
    """Retentive attention: softmax((QK^T / sqrt(d)) ⊙ W) V with
    W[i, j] = gamma^(i - j) for j <= i (recency-biased decay, paper §II-C).
    """
    n, d = q.shape
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    mask = j <= i
    # gamma^(i-j) via exp/log keeps the lowering free of integer pow ops.
    decay = jnp.exp((i - j).astype(q.dtype) * jnp.log(jnp.asarray(gamma, q.dtype)))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype)) * jnp.where(mask, decay, 0.0)
    probs = _masked_softmax(scores, mask)
    return probs @ v


def toeplitz_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, gamma: float = 0.9
) -> jnp.ndarray:
    """Toeplitz structured attention (full-band reference):
    softmax(QK^T ⊙ W) V with W[i, j] = gamma^|i-j|, causal-masked.
    """
    n, d = q.shape
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    mask = j <= i
    decay = jnp.exp(jnp.abs(i - j).astype(q.dtype) * jnp.log(jnp.asarray(gamma, q.dtype)))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype)) * jnp.where(mask, decay, 0.0)
    probs = _masked_softmax(scores, mask)
    return probs @ v


def toeplitz_banded_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    band: int = 128,
    gamma: float = 0.9,
) -> jnp.ndarray:
    """Band-limited Toeplitz attention: position i attends to
    j in [i - band + 1, i]. This is the sub-quadratic variant the paper
    benchmarks (its latency scales near-linearly, Table III) — the
    gamma^|i-j| decay makes weights outside a modest band negligible.
    """
    n, d = q.shape
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    mask = (j <= i) & (i - j < band)
    decay = jnp.exp(jnp.abs(i - j).astype(q.dtype) * jnp.log(jnp.asarray(gamma, q.dtype)))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype)) * jnp.where(mask, decay, 0.0)
    probs = _masked_softmax(scores, mask)
    return probs @ v


def _phi(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """Low-rank feature map phi(x) = elu(x P) + 1 (positive by construction).

    The paper's linear attention uses "low-rank projections" as the kernel
    function; the +1-elu keeps features positive so the normalizer never
    crosses zero.
    """
    h = x @ proj
    return jnp.where(h > 0, h + 1.0, jnp.exp(h))


def linear_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, proj: jnp.ndarray
) -> jnp.ndarray:
    """Causal linear attention: y_t = phi(q_t) S_t / (phi(q_t) . z_t) with
    S_t = sum_{s<=t} phi(k_s) v_s^T and z_t = sum_{s<=t} phi(k_s).
    O(N · r · d) compute, O(r · d) state — the SSM-like end of the
    memory-state tradeoff (paper Fig 1).
    """
    pq = _phi(q, proj)  # (N, r)
    pk = _phi(k, proj)  # (N, r)
    # Cumulative KV state: S_t = cumsum_t(pk_t ⊗ v_t); materialized (N, r, d)
    # in the oracle only — kernels carry (r, d) chunk state instead.
    kv = pk[:, :, None] * v[:, None, :]
    s = jnp.cumsum(kv, axis=0)
    z = jnp.cumsum(pk, axis=0)
    num = jnp.einsum("nr,nrd->nd", pq, s)
    den = jnp.sum(pq * z, axis=-1, keepdims=True)
    return num / den


def fourier_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Fourier structured attention: F^-1(F(Q) ⊙ conj(F(K)) ⊙ F(V)),
    transforms taken along the sequence axis per channel (paper §II-C).
    Normalized by N so magnitudes stay comparable across context lengths.
    """
    n = q.shape[0]
    qw = jnp.fft.rfft(q, axis=0)
    kw = jnp.fft.rfft(k, axis=0)
    vw = jnp.fft.rfft(v, axis=0)
    out = jnp.fft.irfft(qw * jnp.conj(kw) * vw, n=n, axis=0)
    return (out / n).astype(q.dtype)
