"""Decode-phase kernels: one autoregressive step (paper §II-A, Eq. 3).

Two step forms, matching the memory-state tradeoff of Fig 1:

- :func:`causal_decode` — attention-class step: the new token's query
  attends over the whole KV cache (O(N·d) work and memory).
- :func:`linear_decode_step` — recurrent-class step: rank-r state update +
  readout (O(r·d) work, O(r·d) memory, independent of context).

Both are Pallas kernels (interpret=True) validated against the prefill
oracles: decoding token t over prefix K/V[: t] must reproduce row t of the
prefill output exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _causal_decode_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32) * scale  # (1, d)
    k = k_ref[...].astype(jnp.float32)  # (N, d)
    v = v_ref[...].astype(jnp.float32)
    scores = q @ k.T  # (1, N) — every cached position is attendable
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (probs @ v).astype(o_ref.dtype)


def causal_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """One attention decode step: q : (1, d), cache k/v : (N, d) → (1, d)."""
    n, d = k.shape
    assert q.shape == (1, d), f"decode query must be (1, {d}), got {q.shape}"
    import functools

    kernel = functools.partial(_causal_decode_kernel, scale=1.0 / (d**0.5))
    full = lambda *shape: pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[full(1, d), full(n, d), full(n, d)],
        out_specs=full(1, d),
        out_shape=jax.ShapeDtypeStruct((1, d), q.dtype),
        interpret=common.INTERPRET,
    )(q, k, v)


def _linear_step_kernel(q_ref, k_ref, v_ref, p_ref, s_ref, z_ref, o_ref, s_out, z_out):
    q = q_ref[...].astype(jnp.float32)  # (1, d)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)  # (d, r)
    s = s_ref[...].astype(jnp.float32)  # (r, d)
    z = z_ref[...].astype(jnp.float32)  # (1, r)

    def phi(x):
        h = x @ p
        return jnp.where(h > 0, h + 1.0, jnp.exp(h))

    pq = phi(q)  # (1, r)
    pk = phi(k)  # (1, r)
    s_new = s + pk.T @ v  # (r, d)
    z_new = z + pk
    num = pq @ s_new  # (1, d)
    den = jnp.sum(pq * z_new, axis=-1, keepdims=True)
    o_ref[...] = (num / den).astype(o_ref.dtype)
    s_out[...] = s_new
    z_out[...] = z_new


def linear_decode_step(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    proj: jnp.ndarray,
    s: jnp.ndarray,
    z: jnp.ndarray,
):
    """One recurrent decode step. Shapes: q/k/v (1, d), proj (d, r),
    s (r, d), z (1, r). Returns (y (1, d), s', z')."""
    d, r = proj.shape
    full = lambda *shape: pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))
    return pl.pallas_call(
        _linear_step_kernel,
        grid=(),
        in_specs=[full(1, d), full(1, d), full(1, d), full(d, r), full(r, d), full(1, r)],
        out_specs=[full(1, d), full(r, d), full(1, r)],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), q.dtype),
            jax.ShapeDtypeStruct((r, d), jnp.float32),
            jax.ShapeDtypeStruct((1, r), jnp.float32),
        ],
        interpret=common.INTERPRET,
    )(q, k, v, proj, s, z)
