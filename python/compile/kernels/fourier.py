"""Fourier structured attention: FFT at L2, frequency product in Pallas.

F^-1(F(Q) ⊙ conj(F(K)) ⊙ F(V)) — the r/fft itself is a global butterfly
network with no efficient systolic mapping (the paper's point: "FFT
overheads violate NPU execution assumptions"), so on-device it runs as DFT
matmuls + DMA-heavy concats, which the simulator models. Numerically we
lower the transform through XLA's native FFT and keep the *hot element-wise
spectrum product* — the part that would land on SHAVE — as the Pallas
kernel, split into real/imag planes (Pallas has no complex dtype support).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _spectrum_kernel(qr, qi, kr, ki, vr, vi, or_, oi_):
    """out = q * conj(k) * v over (F, d) real/imag planes."""
    a_r = qr[...] * kr[...] + qi[...] * ki[...]  # re(q * conj(k))
    a_i = qi[...] * kr[...] - qr[...] * ki[...]  # im(q * conj(k))
    or_[...] = a_r * vr[...] - a_i * vi[...]
    oi_[...] = a_r * vi[...] + a_i * vr[...]


def _spectrum_product(qw: jnp.ndarray, kw: jnp.ndarray, vw: jnp.ndarray) -> jnp.ndarray:
    f, d = qw.shape
    full = pl.BlockSpec((f, d), lambda: (0, 0))
    out_r, out_i = pl.pallas_call(
        _spectrum_kernel,
        grid=(),
        in_specs=[full] * 6,
        out_specs=[full, full],
        out_shape=[jax.ShapeDtypeStruct((f, d), jnp.float32)] * 2,
        interpret=common.INTERPRET,
    )(
        jnp.real(qw).astype(jnp.float32),
        jnp.imag(qw).astype(jnp.float32),
        jnp.real(kw).astype(jnp.float32),
        jnp.imag(kw).astype(jnp.float32),
        jnp.real(vw).astype(jnp.float32),
        jnp.imag(vw).astype(jnp.float32),
    )
    return out_r + 1j * out_i


def fourier_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Frequency-domain attention for q, k, v : (N, d)."""
    n = q.shape[0]
    qw = jnp.fft.rfft(q.astype(jnp.float32), axis=0)
    kw = jnp.fft.rfft(k.astype(jnp.float32), axis=0)
    vw = jnp.fft.rfft(v.astype(jnp.float32), axis=0)
    out = jnp.fft.irfft(_spectrum_product(qw, kw, vw), n=n, axis=0)
    return (out / n).astype(q.dtype)
