"""Retentive (decayed recurrent) attention as a Pallas kernel.

softmax((QK^T / sqrt(d)) ⊙ W) V with W[i,j] = gamma^(i-j) on the causal
triangle. The extra element-wise decay multiply is exactly the work the
paper attributes to the SHAVE cores (Table II: SHAVE-bound past N = 1024);
structurally it is a fused epilogue on the score tile.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, log_gamma: float, block_q: int):
    i = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    scores = q @ k.T
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = kpos <= qpos
    # Decay epilogue (SHAVE work on the NPU): gamma^(i-j), fused on the tile.
    delta = (qpos - kpos).astype(jnp.float32)
    decay = jnp.exp(delta * log_gamma)
    scores = scores * jnp.where(mask, decay, 0.0)
    probs = common.row_softmax_masked(scores, mask)
    o_ref[...] = (probs @ v).astype(o_ref.dtype)


def retentive_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, gamma: float = 0.97
) -> jnp.ndarray:
    """Retentive decay attention for q, k, v : (N, d)."""
    n, d = q.shape
    bq = common.q_block(n)
    assert n % bq == 0, f"context {n} must be a multiple of the query block {bq}"
    kernel = functools.partial(
        _kernel, scale=1.0 / (d**0.5), log_gamma=math.log(gamma), block_q=bq
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=common.INTERPRET,
    )(q, k, v)
